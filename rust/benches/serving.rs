//! Serving benches (§Perf): decode throughput + latency of the continuous
//! batcher vs batch size and worker count, on the W4A8-quantized model.
//! The paper's deployment claim is that the compensation branch adds
//! negligible serving cost; compare the fp16 rows against the aser rows.

use aser::calib::CalibConfig;
use aser::coordinator::{
    calibrate_model, run_ptq, serve_requests, synthetic_requests, BatchConfig, ServerConfig,
};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::synthetic_model;
use aser::quant::Precision;
use std::sync::Arc;

fn main() {
    let base = synthetic_model("micro", 7).unwrap();
    let ccfg = CalibConfig { n_seqs: 6, seq_len: 24, max_sample: 96, seed: 3 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();

    for variant in ["fp16", "aser-w4a8"] {
        let model = if variant == "fp16" {
            synthetic_model("micro", 7).unwrap()
        } else {
            let m = synthetic_model("micro", 7).unwrap();
            let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
            run_ptq(m, &stats, method.as_ref(), Precision::w4a8(), 0).unwrap().0
        };
        let model = Arc::new(model);
        println!("\n== {variant} ==");
        println!(
            "{:>6} {:>8} {:>12} {:>10} {:>10} {:>10}",
            "batch", "workers", "tok/s", "p50 ms", "p95 ms", "iters"
        );
        for &(batch, workers) in &[(1usize, 1usize), (4, 1), (8, 1), (8, 2), (16, 2)] {
            let reqs = synthetic_requests(model.cfg.vocab_size, 32, 8, 12, 11).unwrap();
            let cfg = ServerConfig {
                workers,
                batch: BatchConfig { max_batch: batch, ..Default::default() },
                kv_tokens: 1 << 14,
            };
            let run = serve_requests(Arc::clone(&model), &cfg, reqs);
            let iters: usize = run.per_worker.iter().map(|m| m.iterations).sum();
            println!(
                "{:>6} {:>8} {:>12.1} {:>10.0} {:>10.0} {:>10}",
                batch,
                workers,
                run.throughput_tok_s(),
                run.latency_percentile_ms(50.0),
                run.latency_percentile_ms(95.0),
                iters
            );
        }
    }
    println!("\n(throughput should rise with batch; aser ≈ fp16 = 'minor overhead')");
}
