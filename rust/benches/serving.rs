//! Serving benches (§Perf): decode throughput + latency of the continuous
//! batcher vs batch size and worker count, on the W4A8-quantized model, plus
//! a direct batched-vs-scalar decode comparison (the packed qgemm engine vs
//! token-at-a-time `forward_step`). The paper's deployment claim is that the
//! compensation branch adds negligible serving cost; compare the fp16 rows
//! against the aser rows.
//!
//! Emits machine-readable `BENCH_serving.json` so the perf trajectory is
//! tracked across PRs: per-config tokens/s and p50/p95 TTFT, and the
//! batched-vs-scalar speedup per batch size.

use aser::calib::CalibConfig;
use aser::coordinator::{
    calibrate_model, run_ptq, serve_requests, synthetic_requests, BatchConfig, ServerConfig,
};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::{synthetic_model, Gpt, KvCache};
use aser::quant::Precision;
use aser::tensor::QGemmArena;
use aser::util::json::{num, obj, s, Json};
use aser::util::stats::black_box;
use std::sync::Arc;
use std::time::Instant;

/// Caches with a short prefix already decoded, so the comparison below
/// measures steady-state decode, not cold-cache behavior.
fn prefilled_caches(model: &Gpt, batch: usize, prefill: usize) -> Vec<KvCache> {
    (0..batch)
        .map(|i| {
            let mut c = KvCache::new(&model.cfg);
            for t in 0..prefill {
                let tok = ((i * 7 + t) % (model.cfg.vocab_size - 1) + 1) as u32;
                let _ = model.forward_step(tok, &mut c);
            }
            c
        })
        .collect()
}

/// Decode `steps` tokens per sequence via the scalar per-token path.
fn scalar_decode_tok_s(model: &Gpt, proto: &[KvCache], steps: usize) -> f64 {
    let mut caches = proto.to_vec();
    let t0 = Instant::now();
    for _ in 0..steps {
        for c in caches.iter_mut() {
            black_box(model.forward_step(1, c));
        }
    }
    (caches.len() * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Same decode work through `forward_step_batch`: one batched quantized GEMM
/// per layer per iteration.
fn batched_decode_tok_s(model: &Gpt, proto: &[KvCache], steps: usize) -> f64 {
    let mut caches = proto.to_vec();
    let toks = vec![1u32; caches.len()];
    let mut arena = QGemmArena::new();
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        black_box(model.forward_step_batch(&toks, &mut refs, &mut arena));
    }
    (caches.len() * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    let base = synthetic_model("micro", 7).unwrap();
    let ccfg = CalibConfig { n_seqs: 6, seq_len: 24, max_sample: 96, seed: 3 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();

    let mut config_rows: Vec<Json> = Vec::new();
    let mut speedup_rows: Vec<Json> = Vec::new();

    for variant in ["fp16", "aser-w4a8"] {
        let model = if variant == "fp16" {
            synthetic_model("micro", 7).unwrap()
        } else {
            let m = synthetic_model("micro", 7).unwrap();
            let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
            run_ptq(m, &stats, method.as_ref(), Precision::w4a8(), 0).unwrap().0
        };
        let model = Arc::new(model);
        println!("\n== {variant} ==");
        println!(
            "{:>6} {:>8} {:>12} {:>10} {:>10} {:>10}",
            "batch", "workers", "tok/s", "p50 ms", "p95 ms", "iters"
        );
        for &(batch, workers) in &[(1usize, 1usize), (4, 1), (8, 1), (8, 2), (16, 2)] {
            let reqs = synthetic_requests(model.cfg.vocab_size, 32, 8, 12, 11).unwrap();
            let cfg = ServerConfig {
                workers,
                batch: BatchConfig { max_batch: batch, ..Default::default() },
                kv_tokens: 1 << 14,
            };
            let run = serve_requests(Arc::clone(&model), &cfg, reqs);
            let iters: usize = run.per_worker.iter().map(|m| m.iterations).sum();
            println!(
                "{:>6} {:>8} {:>12.1} {:>10.0} {:>10.0} {:>10}",
                batch,
                workers,
                run.throughput_tok_s(),
                run.latency_percentile_ms(50.0),
                run.latency_percentile_ms(95.0),
                iters
            );
            config_rows.push(obj(vec![
                ("variant", s(variant)),
                ("batch", num(batch as f64)),
                ("workers", num(workers as f64)),
                ("tok_s", num(run.throughput_tok_s())),
                ("p50_ttft_ms", num(run.ttft_percentile_ms(50.0))),
                ("p95_ttft_ms", num(run.ttft_percentile_ms(95.0))),
                ("p50_total_ms", num(run.latency_percentile_ms(50.0))),
                ("p95_total_ms", num(run.latency_percentile_ms(95.0))),
                ("iterations", num(iters as f64)),
            ]));
        }

        // ---- batched decode engine vs scalar per-token loop ----
        println!("{:>6} {:>14} {:>14} {:>9}", "batch", "scalar tok/s", "batched tok/s", "speedup");
        for &batch in &[1usize, 4, 8, 16] {
            let proto = prefilled_caches(&model, batch, 8);
            let steps = 24;
            // Warm both paths once (allocator, arena growth), then measure.
            let _ = scalar_decode_tok_s(&model, &proto, 2);
            let _ = batched_decode_tok_s(&model, &proto, 2);
            let scalar = scalar_decode_tok_s(&model, &proto, steps);
            let batched = batched_decode_tok_s(&model, &proto, steps);
            let speedup = batched / scalar.max(1e-9);
            println!("{batch:>6} {scalar:>14.1} {batched:>14.1} {speedup:>8.2}x");
            speedup_rows.push(obj(vec![
                ("variant", s(variant)),
                ("batch", num(batch as f64)),
                ("decode_steps", num(steps as f64)),
                ("scalar_tok_s", num(scalar)),
                ("batched_tok_s", num(batched)),
                ("speedup", num(speedup)),
            ]));
        }
    }

    let report = obj(vec![
        ("bench", s("serving")),
        ("model", s("micro")),
        ("kernel", s(aser::tensor::detect_kernel().name())),
        ("configs", Json::Arr(config_rows)),
        ("batched_vs_scalar", Json::Arr(speedup_rows)),
    ]);
    std::fs::write("BENCH_serving.json", report.to_string_pretty())
        .expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");
    println!("(throughput should rise with batch; aser ≈ fp16 = 'minor overhead';");
    println!(" batched-vs-scalar ≥ 3x at batch ≥ 8 is the engine's acceptance bar)");
}
