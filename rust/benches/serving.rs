//! Serving benches (§Perf): decode throughput + latency of the continuous
//! batcher vs batch size and worker count, on the W4A8-quantized model, plus
//! a direct batched-vs-scalar decode comparison (the packed qgemm engine vs
//! token-at-a-time `forward_step`), chunked-vs-scalar **prefill** throughput
//! (`prefill_tok_s`), and a long-prompt serving workload comparing TTFT
//! under chunked prefill vs the old one-token-per-iteration schedule. The
//! paper's deployment claim is that the compensation branch adds negligible
//! serving cost; compare the fp16 rows against the aser rows.
//!
//! Emits machine-readable `BENCH_serving.json` so the perf trajectory is
//! tracked across PRs: per-config tokens/s and p50/p95 TTFT, the
//! batched-vs-scalar speedup per batch size, `prefill` rows,
//! `long_prompt_ttft` rows, `attn` rows (long-context decode tok/s at
//! ≥ 1k cached positions — the vectorized attention engine's workload), and
//! `stream` rows (decode tok/s through the streaming `Engine`
//! submit/recv path, inter-token latency p50/p95, and time-to-cancel),
//! a `kv_quant` section (int8 vs f32 KV cache: long-context decode
//! tok/s side by side plus resident-capacity tokens at an equal byte
//! budget), and a `prefix_cache` section (repeated-prefix workload, both
//! KV dtypes: cold vs warm prompt-absorption tok/s and p50/p95 TTFT —
//! warm waves adopt the shared pages from the pool's radix trie and
//! prefill only the novel tails), and a `spec_decode` section (speculative
//! decoding with a truncated self-draft at batch 4: decode tok/s,
//! acceptance rate, and speedup vs `spec_k = 0` — target ≥ 1.2x best-row),
//! and a `resilience` section (the engine resilience layer under pressure:
//! time-to-drain for a mid-stream `shutdown(Drain)`, deadline-hit rate on
//! an oversubscribed worker, p99 TTFT under `queue_cap` shedding, and
//! decode tok/s with the layer installed but idle), and an `http` section
//! (the network front end end to end: concurrent raw-TCP clients streaming
//! SSE completions through `HttpServer` — decode tok/s, client-side TTFB
//! p50/p95, and time-to-cancel-on-disconnect, i.e. socket dropped
//! mid-stream until the KV pool meter reads zero).
//! `scripts/bench_diff` gates on long-prompt TTFT, long-context decode,
//! the Engine-path decode tok/s, int8/f32 decode ≥ 0.9x, int8/f32
//! capacity ≥ 3x, warm prefix TTFT ≤ 0.6x cold, spec_decode speedup
//! ≥ 0.9x baseline, faults-off resilience decode ≥ 0.9x baseline, and
//! http streamed decode ≥ 0.9x baseline.
//! `--kv-bits {8,32}` flips the serving/stream sections onto the
//! quantized cache.

use aser::calib::CalibConfig;
use aser::coordinator::{
    calibrate_model, poll_streams, run_ptq, serve_requests, synthetic_requests, BatchConfig,
    BatchMetrics, Engine, EngineConfig, FinishReason, GenRequest, ServerConfig, Shutdown,
    SubmitError, TokenEvent,
};
use aser::coordinator::KvPool;
use aser::methods::{method_by_name, RankPolicy};
use aser::model::{synthetic_model, ChunkLogits, DraftModel, Gpt, KvCache, KvDtype, SeqChunk};
use aser::quant::Precision;
use aser::tensor::QGemmArena;
use aser::util::json::{num, obj, s, Json};
use aser::util::stats::{black_box, percentile_sorted};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Caches with a short prefix already decoded, so the comparison below
/// measures steady-state decode, not cold-cache behavior.
fn prefilled_caches(model: &Gpt, batch: usize, prefill: usize) -> Vec<KvCache> {
    (0..batch)
        .map(|i| {
            let mut c = KvCache::new(&model.cfg);
            for t in 0..prefill {
                let tok = ((i * 7 + t) % (model.cfg.vocab_size - 1) + 1) as u32;
                let _ = model.forward_step(tok, &mut c);
            }
            c
        })
        .collect()
}

/// Decode `steps` tokens per sequence via the scalar per-token path.
fn scalar_decode_tok_s(model: &Gpt, proto: &[KvCache], steps: usize) -> f64 {
    let mut caches = proto.to_vec();
    let t0 = Instant::now();
    for _ in 0..steps {
        for c in caches.iter_mut() {
            black_box(model.forward_step(1, c));
        }
    }
    (caches.len() * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Same decode work through `forward_step_batch`: one batched quantized GEMM
/// per layer per iteration.
fn batched_decode_tok_s(model: &Gpt, proto: &[KvCache], steps: usize) -> f64 {
    let mut caches = proto.to_vec();
    let toks = vec![1u32; caches.len()];
    let mut arena = QGemmArena::new();
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        black_box(model.forward_step_batch(&toks, &mut refs, &mut arena));
    }
    (caches.len() * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Prefill `prompt` token-by-token through the scalar `forward_step` loop.
fn scalar_prefill_tok_s(model: &Gpt, prompt: &[u32], reps: usize) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut cache = KvCache::new(&model.cfg);
        for &t in prompt {
            black_box(model.forward_step(t, &mut cache));
        }
    }
    (prompt.len() * reps) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Same prefill fed as `chunk`-token spans through `forward_chunk_batch`
/// (only the final span pays the lm_head GEMM).
fn chunked_prefill_tok_s(model: &Gpt, prompt: &[u32], chunk: usize, reps: usize) -> f64 {
    let mut arena = QGemmArena::new();
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut cache = KvCache::new(&model.cfg);
        let mut fed = 0;
        while fed < prompt.len() {
            let end = (fed + chunk).min(prompt.len());
            let last = end == prompt.len();
            let span = [SeqChunk {
                tokens: &prompt[fed..end],
                logits: if last { ChunkLogits::Last } else { ChunkLogits::None },
            }];
            black_box(model.forward_chunk_batch(&span, &mut [&mut cache], &mut arena));
            fed = end;
        }
    }
    (prompt.len() * reps) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    // `--kv-bits {8,32}` selects the KV-cache dtype the serving/stream
    // sections run with (32 = f32 default, 8 = int8 + fused dequant). The
    // `kv_quant` section below always measures both side by side.
    let kv_bits: usize = std::env::args()
        .skip_while(|a| a != "--kv-bits")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let kv_dtype = match KvDtype::from_bits(kv_bits) {
        Some(d) => d,
        None => {
            eprintln!(
                "unsupported --kv-bits {kv_bits}: supported bit-widths are {}",
                KvDtype::SUPPORTED_BITS.map(|b| b.to_string()).join("/")
            );
            std::process::exit(2);
        }
    };

    let base = synthetic_model("micro", 7).unwrap();
    let ccfg = CalibConfig { n_seqs: 6, seq_len: 24, max_sample: 96, seed: 3 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();

    let mut config_rows: Vec<Json> = Vec::new();
    let mut speedup_rows: Vec<Json> = Vec::new();
    let mut prefill_rows: Vec<Json> = Vec::new();
    let mut long_prompt_rows: Vec<Json> = Vec::new();
    let mut attn_rows: Vec<Json> = Vec::new();
    let mut stream_rows: Vec<Json> = Vec::new();
    let mut kv_quant_decode_rows: Vec<Json> = Vec::new();
    let mut kv_quant_capacity_rows: Vec<Json> = Vec::new();
    let mut prefix_cache_rows: Vec<Json> = Vec::new();
    let mut spec_decode_rows: Vec<Json> = Vec::new();

    for variant in ["fp16", "aser-w4a8"] {
        let model = if variant == "fp16" {
            synthetic_model("micro", 7).unwrap()
        } else {
            let m = synthetic_model("micro", 7).unwrap();
            let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
            run_ptq(m, &stats, method.as_ref(), Precision::w4a8(), 0).unwrap().0
        };
        let model = Arc::new(model);
        println!("\n== {variant} ==");
        println!(
            "{:>6} {:>8} {:>12} {:>10} {:>10} {:>10}",
            "batch", "workers", "tok/s", "p50 ms", "p95 ms", "iters"
        );
        for &(batch, workers) in &[(1usize, 1usize), (4, 1), (8, 1), (8, 2), (16, 2)] {
            let reqs = synthetic_requests(model.cfg.vocab_size, 32, 8, 12, 11).unwrap();
            let cfg = ServerConfig {
                workers,
                batch: BatchConfig { max_batch: batch, kv_dtype, ..Default::default() },
                kv_tokens: 1 << 14,
                ..Default::default()
            };
            let run = serve_requests(Arc::clone(&model), &cfg, reqs);
            let iters: usize = run.per_worker.iter().map(|m| m.iterations).sum();
            println!(
                "{:>6} {:>8} {:>12.1} {:>10.0} {:>10.0} {:>10}",
                batch,
                workers,
                run.throughput_tok_s(),
                run.latency_percentile_ms(50.0),
                run.latency_percentile_ms(95.0),
                iters
            );
            config_rows.push(obj(vec![
                ("variant", s(variant)),
                ("batch", num(batch as f64)),
                ("workers", num(workers as f64)),
                ("tok_s", num(run.throughput_tok_s())),
                ("p50_ttft_ms", num(run.ttft_percentile_ms(50.0))),
                ("p95_ttft_ms", num(run.ttft_percentile_ms(95.0))),
                ("p50_total_ms", num(run.latency_percentile_ms(50.0))),
                ("p95_total_ms", num(run.latency_percentile_ms(95.0))),
                ("iterations", num(iters as f64)),
            ]));
        }

        // ---- batched decode engine vs scalar per-token loop ----
        println!("{:>6} {:>14} {:>14} {:>9}", "batch", "scalar tok/s", "batched tok/s", "speedup");
        for &batch in &[1usize, 4, 8, 16] {
            let proto = prefilled_caches(&model, batch, 8);
            let steps = 24;
            // Warm both paths once (allocator, arena growth), then measure.
            let _ = scalar_decode_tok_s(&model, &proto, 2);
            let _ = batched_decode_tok_s(&model, &proto, 2);
            let scalar = scalar_decode_tok_s(&model, &proto, steps);
            let batched = batched_decode_tok_s(&model, &proto, steps);
            let speedup = batched / scalar.max(1e-9);
            println!("{batch:>6} {scalar:>14.1} {batched:>14.1} {speedup:>8.2}x");
            speedup_rows.push(obj(vec![
                ("variant", s(variant)),
                ("batch", num(batch as f64)),
                ("decode_steps", num(steps as f64)),
                ("scalar_tok_s", num(scalar)),
                ("batched_tok_s", num(batched)),
                ("speedup", num(speedup)),
            ]));
        }

        // ---- chunked vs scalar prefill throughput (the TTFT lever) ----
        let long_prompt: Vec<u32> =
            (0..56).map(|i| ((i * 11) % (model.cfg.vocab_size - 1) + 1) as u32).collect();
        println!("{:>6} {:>14} {:>14} {:>9}", "chunk", "scalar tok/s", "chunked tok/s", "speedup");
        for &chunk in &[8usize, 16, 32, 56] {
            let reps = 6;
            let _ = scalar_prefill_tok_s(&model, &long_prompt, 1);
            let _ = chunked_prefill_tok_s(&model, &long_prompt, chunk, 1);
            let scalar = scalar_prefill_tok_s(&model, &long_prompt, reps);
            let chunked = chunked_prefill_tok_s(&model, &long_prompt, chunk, reps);
            let speedup = chunked / scalar.max(1e-9);
            println!("{chunk:>6} {scalar:>14.1} {chunked:>14.1} {speedup:>8.2}x");
            prefill_rows.push(obj(vec![
                ("variant", s(variant)),
                ("prompt_len", num(long_prompt.len() as f64)),
                ("chunk", num(chunk as f64)),
                ("scalar_prefill_tok_s", num(scalar)),
                ("prefill_tok_s", num(chunked)),
                ("speedup", num(speedup)),
            ]));
        }

        // ---- attn: long-context decode throughput (the vectorized
        //      attention engine's acceptance surface — ≥ 1k cached
        //      positions, where attention dominates the iteration) ----
        {
            let cached = 1024usize;
            let batch = 4usize;
            let steps = 48usize;
            let mut long_base = synthetic_model("micro", 7).unwrap();
            long_base.cfg.max_seq = 1536; // stretch the KV window; weights unchanged
            long_base.refresh_derived();
            let long_model = if variant == "fp16" {
                long_base
            } else {
                let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
                run_ptq(long_base, &stats, method.as_ref(), Precision::w4a8(), 0).unwrap().0
            };
            let mut arena = QGemmArena::new();
            let mut caches: Vec<KvCache> = (0..batch)
                .map(|_| KvCache::with_capacity(&long_model.cfg, cached + steps + 1))
                .collect();
            let prompt: Vec<u32> = (0..cached)
                .map(|i| ((i * 13) % (long_model.cfg.vocab_size - 1) + 1) as u32)
                .collect();
            let mut fed = 0usize;
            while fed < cached {
                let end = (fed + 128).min(cached);
                let spans: Vec<SeqChunk> = (0..batch)
                    .map(|_| SeqChunk { tokens: &prompt[fed..end], logits: ChunkLogits::None })
                    .collect();
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                long_model.forward_chunk_batch(&spans, &mut refs, &mut arena);
                fed = end;
            }
            let toks = vec![1u32; batch];
            {
                // Warm the arena + allocator before timing.
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                black_box(long_model.forward_step_batch(&toks, &mut refs, &mut arena));
            }
            let t0 = Instant::now();
            for _ in 0..steps {
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                black_box(long_model.forward_step_batch(&toks, &mut refs, &mut arena));
            }
            let tok_s = (batch * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9);
            println!(
                "long-context decode ({cached} cached, batch {batch}): {tok_s:>10.1} tok/s"
            );
            attn_rows.push(obj(vec![
                ("variant", s(variant)),
                ("batch", num(batch as f64)),
                ("cached_positions", num(cached as f64)),
                ("decode_steps", num(steps as f64)),
                ("decode_tok_s", num(tok_s)),
            ]));
        }

        // ---- stream: the Engine submit/stream/cancel path — decode tok/s
        //      through streaming handles, inter-token receive latency, and
        //      time from cancel() to the terminal event ----
        {
            let n_requests = 16usize;
            let max_new = 16usize;
            let engine = Engine::new(
                Arc::clone(&model),
                EngineConfig {
                    workers: 1,
                    batch: BatchConfig { max_batch: 8, kv_dtype, ..Default::default() },
                    kv_tokens: 1 << 14,
                    ..Default::default()
                },
            );
            let reqs =
                synthetic_requests(model.cfg.vocab_size, n_requests, 8, max_new, 23).unwrap();
            let t0 = Instant::now();
            let handles: Vec<_> = reqs.into_iter().map(|r| engine.submit(r).unwrap()).collect();
            // poll_streams drains round-robin, so receive time tracks
            // generation time for every stream, not just the first handle.
            let mut last_at: Vec<Option<Instant>> = vec![None; handles.len()];
            let mut gaps_ms: Vec<f64> = Vec::new();
            let mut total_tokens = 0usize;
            poll_streams(&handles, |i, ev| {
                if matches!(ev, Some(TokenEvent::Token { .. })) {
                    let now = Instant::now();
                    if let Some(prev) = last_at[i] {
                        gaps_ms.push((now - prev).as_secs_f64() * 1e3);
                    }
                    last_at[i] = Some(now);
                    total_tokens += 1;
                }
            });
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            let decode_tok_s = total_tokens as f64 / wall;
            gaps_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (itl_p50, itl_p95) = if gaps_ms.is_empty() {
                (0.0, 0.0)
            } else {
                (percentile_sorted(&gaps_ms, 50.0), percentile_sorted(&gaps_ms, 95.0))
            };
            drop(handles);
            engine.shutdown();

            // Time-to-cancel: cancel after the second streamed token and
            // measure until the terminal Cancelled event lands (the lease
            // is already back in the pool at that point).
            let cancel_engine = Engine::new(
                Arc::clone(&model),
                EngineConfig {
                    workers: 1,
                    batch: BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() },
                    kv_tokens: 1 << 14,
                    ..Default::default()
                },
            );
            let mut cancel_ms: Vec<f64> = Vec::new();
            for rep in 0..5u64 {
                let mut req = synthetic_requests(model.cfg.vocab_size, 1, 8, 48, 29 + rep)
                    .unwrap()
                    .remove(0);
                req.id = rep;
                let h = cancel_engine.submit(req).unwrap();
                let mut seen = 0usize;
                let cancelled_at = loop {
                    match h.recv().expect("stream open") {
                        TokenEvent::Token { .. } => {
                            seen += 1;
                            if seen == 2 {
                                let t = Instant::now();
                                h.cancel();
                                break t;
                            }
                        }
                        TokenEvent::Finished { .. } => panic!("finished before cancel"),
                        TokenEvent::PrefillDone { .. } => {}
                    }
                };
                loop {
                    match h.recv().expect("terminal event") {
                        TokenEvent::Finished { reason, .. } => {
                            if reason == FinishReason::Cancelled {
                                cancel_ms.push(cancelled_at.elapsed().as_secs_f64() * 1e3);
                            }
                            break;
                        }
                        _ => {}
                    }
                }
            }
            cancel_engine.shutdown();
            cancel_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ttc_p50 =
                if cancel_ms.is_empty() { 0.0 } else { percentile_sorted(&cancel_ms, 50.0) };
            println!(
                "stream: {decode_tok_s:>10.1} tok/s decode | inter-token p50/p95 \
                 {itl_p50:.2}/{itl_p95:.2} ms | time-to-cancel p50 {ttc_p50:.2} ms"
            );
            stream_rows.push(obj(vec![
                ("variant", s(variant)),
                ("requests", num(n_requests as f64)),
                ("max_new", num(max_new as f64)),
                ("decode_tok_s", num(decode_tok_s)),
                ("inter_token_p50_ms", num(itl_p50)),
                ("inter_token_p95_ms", num(itl_p95)),
                ("time_to_cancel_p50_ms", num(ttc_p50)),
            ]));
        }

        // ---- long-prompt serving TTFT: chunked schedule vs the old
        //      one-token-per-sequence-per-iteration schedule ----
        println!(
            "{:>10} {:>14} {:>10} {:>10}",
            "schedule", "prefill tok/s", "p50 ttft", "p95 ttft"
        );
        for (mode, bcfg) in [
            ("chunked", BatchConfig { max_batch: 8, ..Default::default() }),
            (
                "per-token",
                BatchConfig {
                    max_batch: 8,
                    prefill_chunk: 1,
                    token_budget: 8,
                    ..Default::default()
                },
            ),
        ] {
            let reqs = synthetic_requests(model.cfg.vocab_size, 24, 48, 8, 17).unwrap();
            let cfg =
                ServerConfig { workers: 1, batch: bcfg, kv_tokens: 1 << 14, ..Default::default() };
            let run = serve_requests(Arc::clone(&model), &cfg, reqs);
            let (p50, p95) = (run.ttft_percentile_ms(50.0), run.ttft_percentile_ms(95.0));
            println!(
                "{mode:>10} {:>14.1} {p50:>9.0}ms {p95:>9.0}ms",
                run.prefill_tok_s()
            );
            long_prompt_rows.push(obj(vec![
                ("variant", s(variant)),
                ("mode", s(mode)),
                ("prompt_len", num(48.0)),
                ("max_new", num(8.0)),
                ("prefill_tok_s", num(run.prefill_tok_s())),
                ("p50_ttft_ms", num(p50)),
                ("p95_ttft_ms", num(p95)),
            ]));
        }
    }

    // ---- kv_quant: int8 vs f32 KV cache at the long-context decode
    //      workload (1024 cached positions, batch 4 — where the fused
    //      dequant attention kernels carry the iteration), plus resident
    //      capacity at an equal byte budget. Acceptance: int8 decode tok/s
    //      ≥ 0.9x f32 while admitting ≥ 3x the sequences per byte. ----
    {
        let cached = 1024usize;
        let batch = 4usize;
        let steps = 48usize;
        let mut long_model = synthetic_model("micro", 7).unwrap();
        long_model.cfg.max_seq = 1536; // stretch the KV window; weights unchanged
        long_model.refresh_derived();
        println!("\n== kv_quant ==");
        println!("{:>8} {:>14} {:>16} {:>16}", "kv bits", "decode tok/s", "bytes/token", "capacity toks");
        for &bits in &[32usize, 8] {
            let dtype = KvDtype::from_bits(bits).unwrap();
            let mut arena = QGemmArena::new();
            let mut caches: Vec<KvCache> = (0..batch)
                .map(|_| KvCache::with_capacity_dtype(&long_model.cfg, cached + steps + 1, dtype))
                .collect();
            let prompt: Vec<u32> = (0..cached)
                .map(|i| ((i * 13) % (long_model.cfg.vocab_size - 1) + 1) as u32)
                .collect();
            let mut fed = 0usize;
            while fed < cached {
                let end = (fed + 128).min(cached);
                let spans: Vec<SeqChunk> = (0..batch)
                    .map(|_| SeqChunk { tokens: &prompt[fed..end], logits: ChunkLogits::None })
                    .collect();
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                long_model.forward_chunk_batch(&spans, &mut refs, &mut arena);
                fed = end;
            }
            let toks = vec![1u32; batch];
            {
                // Warm the arena + allocator before timing.
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                black_box(long_model.forward_step_batch(&toks, &mut refs, &mut arena));
            }
            let t0 = Instant::now();
            for _ in 0..steps {
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                black_box(long_model.forward_step_batch(&toks, &mut refs, &mut arena));
            }
            let tok_s = (batch * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9);
            let pool = KvPool::for_model_dtype(&long_model.cfg, 1 << 20, dtype);
            println!(
                "{bits:>8} {tok_s:>14.1} {:>16} {:>16}",
                pool.bytes_per_token,
                pool.capacity_tokens()
            );
            kv_quant_decode_rows.push(obj(vec![
                ("variant", s("fp16")),
                ("kv_bits", num(bits as f64)),
                ("batch", num(batch as f64)),
                ("cached_positions", num(cached as f64)),
                ("decode_steps", num(steps as f64)),
                ("decode_tok_s", num(tok_s)),
            ]));
            kv_quant_capacity_rows.push(obj(vec![
                ("kv_bits", num(bits as f64)),
                ("bytes_per_token", num(pool.bytes_per_token as f64)),
                ("capacity_tokens", num(pool.capacity_tokens() as f64)),
            ]));
        }
    }

    // ---- prefix_cache: repeated-prefix serving — every request shares a
    //      128-token preamble (two whole KV pages) and adds a unique
    //      8-token tail. Cold = prefix cache off; warm = cache on, measured
    //      on a second wave against a primed pool, so admission adopts the
    //      shared pages and prefills only the tails. Acceptance: warm p50
    //      TTFT ≤ 0.6x cold at equal output (bitwise on ≡ off is pinned in
    //      tests/properties.rs). ----
    {
        let shared_len = 128usize;
        let tail_len = 8usize;
        let n_requests = 12usize;
        let max_new = 4usize;
        let prompt_len = shared_len + tail_len;
        let mut pm = synthetic_model("micro", 7).unwrap();
        pm.cfg.max_seq = 512; // room for the shared preamble; weights unchanged
        pm.refresh_derived();
        let pmodel = Arc::new(pm);
        let vocab = pmodel.cfg.vocab_size;
        // Deterministic repeated-prefix trace: one preamble, per-request
        // tails varied by a wave seed so the measured warm wave shares
        // ONLY the preamble with the priming wave.
        let mk_reqs = |wave: usize| -> Vec<GenRequest> {
            let shared: Vec<u32> =
                (0..shared_len).map(|i| ((i * 17) % (vocab - 1) + 1) as u32).collect();
            (0..n_requests)
                .map(|r| {
                    let mut prompt = shared.clone();
                    prompt.extend(
                        (0..tail_len)
                            .map(|t| (((r * 31 + t * 7 + wave * 131) % (vocab - 1)) + 1) as u32),
                    );
                    GenRequest::new(r as u64, prompt, max_new)
                })
                .collect()
        };
        // One wave through an engine: wall seconds + sorted TTFT samples.
        let run_wave = |engine: &Engine, wave: usize| -> (f64, Vec<f64>) {
            let t0 = Instant::now();
            let handles: Vec<_> = mk_reqs(wave).into_iter().map(|r| engine.submit(r).unwrap()).collect();
            let responses: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            assert!(responses.iter().all(|r| r.finish.is_completed()), "prefix wave rejected");
            let mut ttft: Vec<f64> =
                responses.iter().map(|r| r.ttft.as_secs_f64() * 1e3).collect();
            ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (wall, ttft)
        };
        println!("\n== prefix_cache ==");
        println!(
            "{:>8} {:>6} {:>14} {:>10} {:>10}",
            "kv bits", "mode", "prompt tok/s", "p50 ttft", "p95 ttft"
        );
        for &bits in &[32usize, 8] {
            let dtype = KvDtype::from_bits(bits).unwrap();
            let mk_engine = |prefix_cache: bool| {
                Engine::new(
                    Arc::clone(&pmodel),
                    EngineConfig {
                        workers: 1,
                        batch: BatchConfig {
                            max_batch: 8,
                            kv_dtype: dtype,
                            prefix_cache,
                            ..Default::default()
                        },
                        kv_tokens: 1 << 13,
                        ..Default::default()
                    },
                )
            };
            for (mode, warm) in [("cold", false), ("warm", true)] {
                let engine = mk_engine(warm);
                if warm {
                    // Priming wave publishes the shared pages to the trie;
                    // discard its timings.
                    let _ = run_wave(&engine, 0);
                }
                let (wall, ttft) = run_wave(&engine, 1);
                let hit_tokens: usize =
                    engine.shutdown().iter().map(|m| m.prefix_hit_tokens).sum();
                if warm {
                    assert!(hit_tokens > 0, "warm wave must hit the prefix cache");
                } else {
                    assert_eq!(hit_tokens, 0, "cold wave ran with the cache off");
                }
                let prompt_tok_s = (n_requests * prompt_len) as f64 / wall;
                let (p50, p95) =
                    (percentile_sorted(&ttft, 50.0), percentile_sorted(&ttft, 95.0));
                println!("{bits:>8} {mode:>6} {prompt_tok_s:>14.1} {p50:>9.1}ms {p95:>9.1}ms");
                prefix_cache_rows.push(obj(vec![
                    ("kv_bits", num(bits as f64)),
                    ("mode", s(mode)),
                    ("requests", num(n_requests as f64)),
                    ("prompt_len", num(prompt_len as f64)),
                    ("shared_prefix", num(shared_len as f64)),
                    ("prefill_tok_s", num(prompt_tok_s)),
                    ("p50_ttft_ms", num(p50)),
                    ("p95_ttft_ms", num(p95)),
                ]));
            }
        }
    }

    // ---- spec_decode: speculative decoding with a truncated self-draft.
    //      The draft proposes spec_k tokens per sequence with the target's
    //      first layer only (half the depth on micro), the target verifies
    //      all k+1 rows in ONE ragged forward span, and the acceptance walk
    //      keeps streams bitwise identical to plain decode (pinned in
    //      tests/properties.rs). Measured end-to-end at batch 4 on the
    //      W4A8 model, decode-dominated workload (short prompt, 48 new
    //      tokens). Acceptance: best spec row ≥ 1.2x the spec_k=0
    //      baseline; scripts/bench_diff gates regressions at 0.9x. ----
    {
        let m = synthetic_model("micro", 7).unwrap();
        let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
        let qm =
            Arc::new(run_ptq(m, &stats, method.as_ref(), Precision::w4a8(), 0).unwrap().0);
        let draft = DraftModel::self_draft(Arc::clone(&qm), 1).unwrap();
        let target_layers = qm.cfg.n_layers;
        let batch = 4usize;
        let prompt_len = 8usize;
        let max_new = 48usize;
        let run_k = |spec_k: usize| -> (f64, BatchMetrics) {
            let engine = Engine::new(
                Arc::clone(&qm),
                EngineConfig {
                    workers: 1,
                    batch: BatchConfig {
                        max_batch: batch,
                        stop_on_eos: false,
                        prefix_cache: false,
                        spec_k,
                        ..Default::default()
                    },
                    kv_tokens: 1 << 14,
                    draft: if spec_k > 0 { Some(draft.clone()) } else { None },
                    ..Default::default()
                },
            );
            let mut wall = 1e-9f64;
            let mut tokens = 0usize;
            // Wave 0 warms the allocator/arena/thread pool; wave 1 is
            // measured. stop_on_eos is off, so every request decodes its
            // full max_new and all configs do identical token work.
            for wave in 0..2u64 {
                let reqs =
                    synthetic_requests(qm.cfg.vocab_size, batch, prompt_len, max_new, 37 + wave)
                        .unwrap();
                let t0 = Instant::now();
                let handles: Vec<_> = reqs.into_iter().map(|r| engine.submit(r).unwrap()).collect();
                let n: usize = handles.into_iter().map(|h| h.wait().tokens.len()).sum();
                assert_eq!(n, batch * max_new, "spec_decode wave under-generated");
                if wave == 1 {
                    wall = t0.elapsed().as_secs_f64().max(1e-9);
                    tokens = n;
                }
            }
            let metrics = engine.shutdown().remove(0);
            (tokens as f64 / wall, metrics)
        };
        println!("\n== spec_decode (batch {batch}, draft self:1, {max_new} new) ==");
        println!(
            "{:>7} {:>14} {:>12} {:>10} {:>9}",
            "spec_k", "decode tok/s", "accept rate", "acc/iter", "speedup"
        );
        let (base_tok_s, _) = run_k(0);
        println!("{:>7} {base_tok_s:>14.1} {:>12} {:>10} {:>9}", 0, "-", "-", "1.00x");
        spec_decode_rows.push(obj(vec![
            ("variant", s("aser-w4a8")),
            ("draft", s("off")),
            ("spec_k", num(0.0)),
            ("batch", num(batch as f64)),
            ("max_new", num(max_new as f64)),
            ("decode_tok_s", num(base_tok_s)),
            ("acceptance_rate", num(0.0)),
            ("accepted_per_iteration", num(0.0)),
            ("draft_depth_fraction", num(0.0)),
            ("speedup_vs_k0", num(1.0)),
        ]));
        for &k in &[1usize, 2, 4] {
            let (tok_s, m) = run_k(k);
            let rate = m.spec_accepted as f64 / (m.spec_drafted as f64).max(1.0);
            let acc_per_iter = m.spec_accepted as f64 / (m.iterations as f64).max(1.0);
            let speedup = tok_s / base_tok_s.max(1e-9);
            println!(
                "{k:>7} {tok_s:>14.1} {:>11.1}% {acc_per_iter:>10.2} {speedup:>8.2}x",
                100.0 * rate
            );
            spec_decode_rows.push(obj(vec![
                ("variant", s("aser-w4a8")),
                ("draft", s(draft.label())),
                ("spec_k", num(k as f64)),
                ("batch", num(batch as f64)),
                ("max_new", num(max_new as f64)),
                ("decode_tok_s", num(tok_s)),
                ("acceptance_rate", num(rate)),
                ("accepted_per_iteration", num(acc_per_iter)),
                ("draft_depth_fraction", num(draft.depth_fraction(target_layers))),
                ("speedup_vs_k0", num(speedup)),
            ]));
        }
    }

    // ---- resilience: the engine resilience layer under pressure. Four
    //      numbers: decode tok/s with the layer installed but nothing
    //      firing (no deadlines, no cap, no faults — the bench_diff 0.9x
    //      gate pins "resilience is free when nothing goes wrong"),
    //      time-to-drain for shutdown(Drain) issued mid-stream,
    //      deadline-hit rate on an oversubscribed worker with tight
    //      per-request deadlines, and p99 TTFT under queue_cap pressure
    //      where bounded admission sheds instead of queueing. ----
    let resilience = {
        let model = Arc::new(synthetic_model("micro", 7).unwrap());
        let vocab = model.cfg.vocab_size;

        // (1) faults-off decode throughput through the streaming path.
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                batch: BatchConfig { max_batch: 8, ..Default::default() },
                kv_tokens: 1 << 14,
                ..Default::default()
            },
        );
        let reqs = synthetic_requests(vocab, 16, 8, 16, 41).unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = reqs.into_iter().map(|r| engine.submit(r).unwrap()).collect();
        let mut total_tokens = 0usize;
        poll_streams(&handles, |_, ev| {
            if matches!(ev, Some(TokenEvent::Token { .. })) {
                total_tokens += 1;
            }
        });
        let faults_off_tok_s = total_tokens as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        drop(handles);
        engine.shutdown();

        // (2) time-to-drain: shutdown(Drain) lands with streams mid-flight
        //     and must finish every admitted request before returning.
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                batch: BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() },
                kv_tokens: 1 << 14,
                ..Default::default()
            },
        );
        let n_drain = 8usize;
        let reqs = synthetic_requests(vocab, n_drain, 8, 24, 43).unwrap();
        let handles: Vec<_> = reqs.into_iter().map(|r| engine.submit(r).unwrap()).collect();
        let _ = handles[0].recv(); // ensure the drain starts mid-stream
        let t0 = Instant::now();
        engine.shutdown_mode(Shutdown::Drain, Some(Duration::from_secs(30)));
        let drain_ms = t0.elapsed().as_secs_f64() * 1e3;
        let drained =
            handles.into_iter().map(|h| h.wait()).filter(|r| r.finish.is_completed()).count();
        assert_eq!(drained, n_drain, "drain must finish every admitted stream");

        // (3) deadline-hit rate: one worker, max_batch 2, 12 requests —
        //     odd-indexed requests carry a 1 ms deadline they cannot meet
        //     once anything is queued ahead of them.
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                batch: BatchConfig { max_batch: 2, stop_on_eos: false, ..Default::default() },
                kv_tokens: 1 << 14,
                ..Default::default()
            },
        );
        let mut reqs = synthetic_requests(vocab, 12, 24, 12, 47).unwrap();
        for (i, r) in reqs.iter_mut().enumerate() {
            if i % 2 == 1 {
                r.deadline = Some(Duration::from_millis(1));
            }
        }
        let n_deadline = reqs.len();
        let handles: Vec<_> = reqs.into_iter().map(|r| engine.submit(r).unwrap()).collect();
        let expired = handles
            .into_iter()
            .map(|h| h.wait())
            .filter(|r| r.finish == FinishReason::DeadlineExceeded)
            .count();
        let hit_rate = expired as f64 / n_deadline as f64;
        engine.shutdown();

        // (4) p99 TTFT under bounded admission: queue_cap 2 on one worker;
        //     submit_wait blocks up to 20 ms for a slot, overflow is shed.
        let queue_cap = 2usize;
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                batch: BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() },
                kv_tokens: 1 << 14,
                queue_cap,
                ..Default::default()
            },
        );
        let reqs = synthetic_requests(vocab, 24, 8, 12, 53).unwrap();
        let mut shed = 0usize;
        let mut handles = Vec::new();
        for req in reqs {
            match engine.submit_wait(req, Duration::from_millis(20)) {
                Ok(h) => handles.push(h),
                Err(SubmitError::QueueFull(_)) => shed += 1,
                Err(SubmitError::Closed(_)) => panic!("engine closed during bench"),
            }
        }
        let mut ttft: Vec<f64> = handles
            .into_iter()
            .map(|h| h.wait())
            .filter(|r| r.finish.is_completed())
            .map(|r| r.ttft.as_secs_f64() * 1e3)
            .collect();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = if ttft.is_empty() { 0.0 } else { percentile_sorted(&ttft, 99.0) };
        engine.shutdown();

        println!("\n== resilience ==");
        println!(
            "drain {drain_ms:.1} ms | deadline-hit {:.1}% | p99 TTFT @cap{queue_cap} \
             {p99:.1} ms ({shed} shed) | faults-off decode {faults_off_tok_s:.1} tok/s",
            100.0 * hit_rate
        );
        obj(vec![
            ("time_to_drain_ms", num(drain_ms)),
            ("drained_requests", num(drained as f64)),
            ("deadline_hit_rate", num(hit_rate)),
            ("deadline_requests", num(n_deadline as f64)),
            ("p99_ttft_ms_at_queue_cap", num(p99)),
            ("queue_cap", num(queue_cap as f64)),
            ("shed_at_submit", num(shed as f64)),
            ("decode_tok_s_faults_off", num(faults_off_tok_s)),
        ])
    };

    // ---- http: the network front end end to end — concurrent raw-TCP
    //      clients streaming completions over HttpServer (SSE framing and
    //      request parsing on the wire, not in-process), client-side TTFB,
    //      and time-to-cancel-on-disconnect: socket dropped mid-stream,
    //      measured until the engine's KV pool meter reads zero. ----
    let http = {
        use aser::coordinator::{HttpServer, HttpServerConfig};
        use aser::data::Vocab;
        use std::io::{Read, Write};
        use std::net::TcpStream;

        // One streamed completion over a fresh connection: returns
        // (client-side TTFB ms, streamed token events observed).
        fn stream_once(addr: std::net::SocketAddr, body: &str) -> (f64, usize) {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let req = format!(
                "POST /v1/completions HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let t0 = Instant::now();
            conn.write_all(req.as_bytes()).unwrap();
            let mut first = [0u8; 1];
            conn.read_exact(&mut first).unwrap();
            let ttfb_ms = t0.elapsed().as_secs_f64() * 1e3;
            let mut all = vec![first[0]];
            conn.read_to_end(&mut all).unwrap();
            // Each token chunk carries exactly one `"token_id"` key (the
            // closing quote keeps `"token_index"` from double-counting).
            let tokens = all.windows(10).filter(|w| *w == b"\"token_id\"").count();
            (ttfb_ms, tokens)
        }

        let model = Arc::new(synthetic_model("micro", 7).unwrap());
        let engine = Arc::new(Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                batch: BatchConfig { max_batch: 8, ..Default::default() },
                kv_tokens: 1 << 14,
                ..Default::default()
            },
        ));
        let server = HttpServer::bind(
            "127.0.0.1:0",
            Arc::clone(&engine),
            Arc::new(Vocab::new(model.cfg.vocab_size)),
            HttpServerConfig { threads: 4, ..Default::default() },
        )
        .expect("bind http bench server");
        let addr = server.local_addr();
        let clients = 4usize;
        let per_client = 4usize;
        let max_new = 16usize;
        let t0 = Instant::now();
        let samples: Vec<(f64, usize)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    scope.spawn(move || {
                        (0..per_client)
                            .map(|r| {
                                let body = format!(
                                    r#"{{"prompt": [{}, {}, 7], "max_tokens": {max_new}, "stream": true, "seed": {}}}"#,
                                    3 + c,
                                    5 + r,
                                    c * 10 + r
                                );
                                stream_once(addr, &body)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let total_tokens: usize = samples.iter().map(|&(_, n)| n).sum();
        assert!(total_tokens > 0, "http stream bench produced no tokens");
        let decode_tok_s = total_tokens as f64 / wall;
        let mut ttfb: Vec<f64> = samples.iter().map(|&(t, _)| t).collect();
        ttfb.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (ttfb_p50, ttfb_p95) =
            (percentile_sorted(&ttfb, 50.0), percentile_sorted(&ttfb, 95.0));
        let returned = server.shutdown(Duration::from_secs(2));
        drop(engine);
        Arc::try_unwrap(returned).ok().expect("engine still shared").shutdown();

        // Disconnect: a roomy engine that would decode thousands of tokens,
        // cut off by dropping the socket after the first streamed token.
        let mut base = synthetic_model("micro", 7).unwrap();
        base.cfg.max_seq = 8192; // room to decode until the disconnect lands
        base.refresh_derived();
        let engine = Arc::new(Engine::new(
            Arc::new(base),
            EngineConfig {
                workers: 1,
                batch: BatchConfig { stop_on_eos: false, ..Default::default() },
                kv_tokens: 1 << 14,
                ..Default::default()
            },
        ));
        let server = HttpServer::bind(
            "127.0.0.1:0",
            Arc::clone(&engine),
            Arc::new(Vocab::new(model.cfg.vocab_size)),
            HttpServerConfig { threads: 2, ..Default::default() },
        )
        .expect("bind http disconnect server");
        let daddr = server.local_addr();
        let mut cancel_ms: Vec<f64> = Vec::new();
        for rep in 0..5u32 {
            let body = format!(
                r#"{{"prompt": [2, 3, {}], "max_tokens": 5000, "stream": true}}"#,
                4 + rep
            );
            let mut conn = TcpStream::connect(daddr).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let req = format!(
                "POST /v1/completions HTTP/1.1\r\nHost: bench\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            conn.write_all(req.as_bytes()).unwrap();
            let mut seen: Vec<u8> = Vec::new();
            let mut b = [0u8; 1];
            while !seen.windows(10).any(|w| w == b"\"token_id\"") {
                conn.read_exact(&mut b).unwrap();
                seen.push(b[0]);
            }
            drop(conn); // the disconnect under measurement
            let t0 = Instant::now();
            while engine.kv_used_tokens() > 0 {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "disconnect did not drain the pool"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            cancel_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        cancel_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (ttc_p50, ttc_p95) =
            (percentile_sorted(&cancel_ms, 50.0), percentile_sorted(&cancel_ms, 95.0));
        let returned = server.shutdown(Duration::from_secs(2));
        drop(engine);
        Arc::try_unwrap(returned).ok().expect("engine still shared").shutdown();

        println!("\n== http ==");
        println!(
            "stream: {clients} clients x {per_client} reqs: {decode_tok_s:.1} tok/s | \
             ttfb p50/p95 {ttfb_p50:.1}/{ttfb_p95:.1} ms | disconnect time-to-cancel \
             p50/p95 {ttc_p50:.2}/{ttc_p95:.2} ms"
        );
        obj(vec![
            (
                "stream",
                Json::Arr(vec![obj(vec![
                    ("variant", s("fp16")),
                    ("clients", num(clients as f64)),
                    ("requests", num((clients * per_client) as f64)),
                    ("max_new", num(max_new as f64)),
                    ("decode_tok_s", num(decode_tok_s)),
                    ("ttfb_p50_ms", num(ttfb_p50)),
                    ("ttfb_p95_ms", num(ttfb_p95)),
                ])]),
            ),
            (
                "disconnect",
                obj(vec![
                    ("samples", num(cancel_ms.len() as f64)),
                    ("time_to_cancel_p50_ms", num(ttc_p50)),
                    ("time_to_cancel_p95_ms", num(ttc_p95)),
                ]),
            ),
        ])
    };

    let report = obj(vec![
        ("bench", s("serving")),
        ("model", s("micro")),
        ("kernel", s(aser::tensor::detect_kernel().name())),
        ("configs", Json::Arr(config_rows)),
        ("batched_vs_scalar", Json::Arr(speedup_rows)),
        ("prefill", Json::Arr(prefill_rows)),
        ("long_prompt_ttft", Json::Arr(long_prompt_rows)),
        ("attn", Json::Arr(attn_rows)),
        ("stream", Json::Arr(stream_rows)),
        (
            "kv_quant",
            obj(vec![
                ("decode", Json::Arr(kv_quant_decode_rows)),
                ("capacity", Json::Arr(kv_quant_capacity_rows)),
            ]),
        ),
        ("prefix_cache", Json::Arr(prefix_cache_rows)),
        ("spec_decode", Json::Arr(spec_decode_rows)),
        ("resilience", resilience),
        ("http", http),
    ]);
    std::fs::write("BENCH_serving.json", report.to_string_pretty())
        .expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");
    println!("(throughput should rise with batch; aser ≈ fp16 = 'minor overhead';");
    println!(" batched-vs-scalar ≥ 3x at batch ≥ 8, and chunked prefill ≥ 2x p50 TTFT");
    println!(" on the long-prompt rows, are the engine's acceptance bars)");
}
