//! Pipeline benches (§Perf): whole-model quantization wall-time per method
//! and calibration throughput — the offline costs the paper's "minor
//! overhead" claim is about.

use aser::calib::CalibConfig;
use aser::coordinator::{calibrate_model, run_ptq};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::synthetic_model;
use aser::quant::Precision;
use std::time::Instant;

fn main() {
    // Calibration throughput.
    let model = synthetic_model("A", 7).unwrap();
    let ccfg = CalibConfig { n_seqs: 16, seq_len: 48, max_sample: 192, seed: 3 };
    let t = Instant::now();
    let stats = calibrate_model(&model, "wiki", &ccfg).unwrap();
    let calib_s = t.elapsed().as_secs_f64();
    let tokens = ccfg.n_seqs * ccfg.seq_len;
    println!(
        "bench calibrate  model A: {tokens} tokens, {} layers  {:.2}s  ({:.0} tok/s)",
        stats.len(),
        calib_s,
        tokens as f64 / calib_s
    );

    // Per-method whole-model quantization.
    println!("\nbench quantize (model A, W4A8, rank 16):");
    println!("{:<14} {:>9} {:>14} {:>10}", "method", "sec", "mean rel err", "+FLOPs%");
    for m in
        ["rtn", "llm_int", "smoothquant", "smoothquant+", "awq", "gptq", "lorc", "l2qer", "aser-er", "aser"]
    {
        let model = synthetic_model("A", 7).unwrap();
        let method = method_by_name(m, RankPolicy::Fixed(16), 8).unwrap();
        let t = Instant::now();
        let (_, rep) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 0).unwrap();
        println!(
            "{:<14} {:>9.2} {:>14.5} {:>10.2}",
            m,
            t.elapsed().as_secs_f64(),
            rep.mean_rel_error(),
            rep.flops_overhead_pct()
        );
    }
}
