//! Table regeneration bench: runs the Table-4 harness end-to-end in --fast
//! mode and prints it — `cargo bench` therefore exercises the complete
//! experiment path (calibrate → ASER α-sweep → accuracy + overhead).
//! The full-resolution tables are produced by `repro bench-table --id tN`
//! (see Makefile `tables` target) and recorded in EXPERIMENTS.md.

use aser::cli_entry::ctx::Ctx;
use aser::cli_entry::table_cmd::build_table;
use aser::util::cli::Args;
use std::time::Instant;

fn main() {
    let argv: Vec<String> = ["bench-table", "--fast", "--alphas", "0.05,0.1", "--rank", "16", "--outlier-f", "8"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = Args::parse(&argv, &["fast"]).unwrap();
    let ctx = Ctx::from_args(&args).unwrap();
    let t = Instant::now();
    match build_table(&ctx, "t4", &args) {
        Ok(table) => {
            println!("{}", table.render());
            println!("bench table t4 (--fast): {:.1}s", t.elapsed().as_secs_f64());
        }
        Err(e) => {
            // Without `make artifacts` the synthetic fallback still runs;
            // only a genuine harness error should fail the bench.
            panic!("table bench failed: {e:#}");
        }
    }
}
