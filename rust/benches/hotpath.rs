//! Hot-path microbenches (§Perf): the quantized linear forward in all its
//! variants vs the dense fp32 GEMM of the same shape, the packed batched
//! qgemm kernel vs the scalar token loop, the auto-detected SIMD int8
//! microkernel vs the pinned scalar microkernel, the attention span kernel
//! (SIMD vs scalar over head-major KV tiles), the int8 dot kernel, and
//! SVD variants. `cargo bench --offline` (criterion is not vendored;
//! `util::stats::bench` provides warmup + robust summaries).
//!
//! Emits machine-readable `BENCH_hotpath.json` (median ns per benchmark,
//! the batched-vs-scalar speedups, per-kernel int-GEMM speedups under
//! `int_kernel_speedup`, and per-kernel attention timings + speedups under
//! `attn`) for cross-PR perf tracking — compare runs with
//! `scripts/bench_diff`.

use aser::methods::aser::Aser;
use aser::methods::{LayerCalib, PtqMethod, RankPolicy};
use aser::model::linear::{dot_i8, forward_quant_token};
use aser::model::Linear;
use aser::quant::Precision;
use aser::quant::quantize_tile;
use aser::tensor::{
    attn_head_span, attn_head_span_int8, detect_attn_kernel, detect_kernel, matmul, matvec,
    AttnKernelKind, Matrix, QGemmArena, QKernelKind,
};
use aser::util::json::{num, obj, s, Json};
use aser::util::stats::{bench, black_box, Summary};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(400);
    let mut rng = aser::util::rng::Pcg64::seed(7);
    let mut records: Vec<Json> = Vec::new();
    let mut record = |name: &str, sm: &Summary| {
        records.push(obj(vec![
            ("name", s(name)),
            ("median_ns", num(sm.median_ns)),
            ("mean_ns", num(sm.mean_ns)),
            ("p90_ns", num(sm.p90_ns)),
            ("n", num(sm.n as f64)),
        ]));
    };
    let mut speedups: Vec<Json> = Vec::new();
    let mut kernel_speedups: Vec<Json> = Vec::new();
    let auto_kernel = detect_kernel();
    println!("int8 microkernel: {auto_kernel} (scalar fallback pinned for comparison)");

    // ---- shapes of model A's four linears ----
    for (label, d_in, d_out) in
        [("qkv 256->768", 256usize, 768usize), ("fc1 256->1024", 256, 1024), ("fc2 512->256", 512, 256)]
    {
        let w = Matrix::randn(&mut rng, d_out, d_in, 0.05);
        let mut xs = Matrix::randn(&mut rng, 128, d_in, 1.0);
        for r in 0..xs.rows {
            xs[(r, 3)] *= 25.0;
        }
        let calib = LayerCalib::from_sample(xs);
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal()).collect();

        // dense reference
        let dense = Linear::Dense(w.clone());
        let s_dense = bench(&format!("dense    matvec {label}"), budget, || {
            black_box(dense.forward_token(black_box(&x)));
        });
        record(&format!("dense_matvec {label}"), &s_dense);

        // RTN W4A8 (no compensation)
        let rtn = aser::methods::rtn::Rtn.quantize_layer(&w, &calib, Precision::w4a8());
        let s_rtn = bench(&format!("w4a8 rtn  token  {label}"), budget, || {
            black_box(forward_quant_token(black_box(&rtn), black_box(&x)));
        });
        record(&format!("w4a8_rtn_token {label}"), &s_rtn);

        // full ASER W4A8 (smooth + low-rank r=16)
        let aser = Aser { rank: RankPolicy::Fixed(16), outlier_f: 8, ..Default::default() }
            .quantize_layer(&w, &calib, Precision::w4a8());
        let s_aser = bench(&format!("w4a8 aser token  {label}"), budget, || {
            black_box(forward_quant_token(black_box(&aser), black_box(&x)));
        });
        record(&format!("w4a8_aser_token {label}"), &s_aser);
        println!(
            "  -> aser/dense ratio {:.2}x (target ≤ 1.5x: compensation ~free)",
            s_aser.median_ns / s_dense.median_ns
        );

        // packed batched kernel vs the scalar token loop at batch 8
        let batch = 8usize;
        let xb = Matrix::randn(&mut rng, batch, d_in, 1.0);
        let lin = Linear::quantized(aser.clone());
        let mut arena = QGemmArena::new();
        let s_scalar8 = bench(&format!("w4a8 aser tok×{batch} {label}"), budget, || {
            for t in 0..batch {
                black_box(forward_quant_token(black_box(&aser), black_box(xb.row(t))));
            }
        });
        record(&format!("w4a8_aser_scalar_b{batch} {label}"), &s_scalar8);
        let s_qgemm8 = bench(&format!("w4a8 aser qgemm{batch} {label}"), budget, || {
            black_box(lin.forward_with(black_box(&xb), &mut arena));
        });
        record(&format!("w4a8_aser_qgemm_b{batch} {label}"), &s_qgemm8);
        let sp = s_scalar8.median_ns / s_qgemm8.median_ns;
        println!("  -> qgemm batch-{batch} speedup over scalar loop: {sp:.2}x");
        speedups.push(obj(vec![
            ("shape", s(label)),
            ("batch", num(batch as f64)),
            ("scalar_median_ns", num(s_scalar8.median_ns)),
            ("qgemm_median_ns", num(s_qgemm8.median_ns)),
            ("speedup", num(sp)),
        ]));

        // Auto-detected SIMD microkernel vs the pinned scalar microkernel
        // on the same packed path (the int-GEMM acceptance bar: ≥1.5x on a
        // SIMD-capable host). Two variants: full ASER (smoothing + outliers
        // + low-rank dilute the int GEMM) and plain RTN (pure int path —
        // the cleanest read on the microkernel itself). Skipped entirely on
        // scalar-only hosts: benching the same kernel twice would emit
        // duplicate record names and ~1.0x speedup rows that pollute
        // bench_diff's geomean.
        if auto_kernel == QKernelKind::Scalar {
            println!("  -> no SIMD kernel on this host; skipping per-kernel comparison");
        }
        let all_variants = [("aser", &aser), ("rtn", &rtn)];
        let kernel_variants: &[(&str, &aser::methods::QuantizedLinear)] =
            if auto_kernel == QKernelKind::Scalar { &[] } else { &all_variants };
        for &(variant, q) in kernel_variants {
            let lin_auto = Linear::quantized_with(q.clone(), auto_kernel);
            let lin_sk = Linear::quantized_with(q.clone(), QKernelKind::Scalar);
            let mut arena_a = QGemmArena::new();
            let mut arena_s = QGemmArena::new();
            let s_auto = bench(&format!("w4a8 {variant} qgemm{batch} {auto_kernel} {label}"), budget, || {
                black_box(lin_auto.forward_with(black_box(&xb), &mut arena_a));
            });
            record(&format!("w4a8_{variant}_qgemm_b{batch}_kernel_{auto_kernel} {label}"), &s_auto);
            let s_sk = bench(&format!("w4a8 {variant} qgemm{batch} scalar-kernel {label}"), budget, || {
                black_box(lin_sk.forward_with(black_box(&xb), &mut arena_s));
            });
            record(&format!("w4a8_{variant}_qgemm_b{batch}_kernel_scalar {label}"), &s_sk);
            let ksp = s_sk.median_ns / s_auto.median_ns;
            println!("  -> int8 microkernel {auto_kernel} vs scalar kernel ({variant}): {ksp:.2}x");
            kernel_speedups.push(obj(vec![
                ("shape", s(label)),
                ("variant", s(variant)),
                ("batch", num(batch as f64)),
                ("kernel", s(auto_kernel.name())),
                ("scalar_kernel_median_ns", num(s_sk.median_ns)),
                ("simd_kernel_median_ns", num(s_auto.median_ns)),
                ("speedup", num(ksp)),
            ]));
        }
    }

    // ---- attention span kernel: SIMD vs scalar over head-major KV tiles
    //      (one (sequence, head) work item of long-context decode /
    //      teacher-forced prefill; ctx = cached positions) ----
    let attn_kernel = detect_attn_kernel();
    let mut attn_speedups: Vec<Json> = Vec::new();
    println!("attention kernel: {attn_kernel} (scalar reference pinned for comparison)");
    for (hd, ctx, t) in [(64usize, 1024usize, 1usize), (64, 1024, 32), (32, 1024, 1)] {
        let slen = ctx + t;
        let q: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
        let keys: Vec<f32> = (0..slen * hd).map(|_| rng.normal() * 0.3).collect();
        let values: Vec<f32> = (0..slen * hd).map(|_| rng.normal()).collect();
        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0f32; slen];
        let mut out = vec![0f32; t * hd];
        let label = format!("hd{hd} ctx{ctx} t{t}");
        let s_scalar = bench(&format!("attn span scalar {label}"), budget, || {
            attn_head_span(
                AttnKernelKind::Scalar,
                black_box(&q),
                hd,
                0,
                hd,
                ctx,
                t,
                black_box(&keys),
                black_box(&values),
                scale,
                &mut scores,
                &mut out,
            );
            black_box(&out);
        });
        record(&format!("attn_span_scalar {label}"), &s_scalar);
        if attn_kernel == AttnKernelKind::Scalar {
            println!("  -> no SIMD attention kernel on this host; skipping comparison");
            continue;
        }
        let s_simd = bench(&format!("attn span {attn_kernel} {label}"), budget, || {
            attn_head_span(
                attn_kernel,
                black_box(&q),
                hd,
                0,
                hd,
                ctx,
                t,
                black_box(&keys),
                black_box(&values),
                scale,
                &mut scores,
                &mut out,
            );
            black_box(&out);
        });
        record(&format!("attn_span_{attn_kernel} {label}"), &s_simd);
        let sp = s_scalar.median_ns / s_simd.median_ns;
        println!("  -> attention kernel {attn_kernel} vs scalar ({label}): {sp:.2}x");
        attn_speedups.push(obj(vec![
            ("shape", s(&label)),
            ("kernel", s(attn_kernel.name())),
            ("scalar_median_ns", num(s_scalar.median_ns)),
            ("simd_median_ns", num(s_simd.median_ns)),
            ("speedup", num(sp)),
        ]));
    }

    // ---- int8 attention span kernel: fused-dequant q·K and P·V over
    //      int8-quantized KV tiles, same shapes as the f32 span above ----
    for (hd, ctx, t) in [(64usize, 1024usize, 1usize), (64, 1024, 32), (32, 1024, 1)] {
        let slen = ctx + t;
        let q: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
        let keys: Vec<f32> = (0..slen * hd).map(|_| rng.normal() * 0.3).collect();
        let values: Vec<f32> = (0..slen * hd).map(|_| rng.normal()).collect();
        let mut q_codes = vec![0i8; t * hd];
        let mut q_scales = vec![0f32; t];
        for j in 0..t {
            q_scales[j] = quantize_tile(&q[j * hd..(j + 1) * hd], 8, &mut q_codes[j * hd..(j + 1) * hd]);
        }
        let mut k_codes = vec![0i8; slen * hd];
        let mut k_scales = vec![0f32; slen];
        let mut v_codes = vec![0i8; slen * hd];
        let mut v_scales = vec![0f32; slen];
        for p in 0..slen {
            k_scales[p] = quantize_tile(&keys[p * hd..(p + 1) * hd], 8, &mut k_codes[p * hd..(p + 1) * hd]);
            v_scales[p] = quantize_tile(&values[p * hd..(p + 1) * hd], 8, &mut v_codes[p * hd..(p + 1) * hd]);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0f32; slen];
        let mut out = vec![0f32; t * hd];
        let label = format!("hd{hd} ctx{ctx} t{t}");
        let s_scalar = bench(&format!("attn span int8 scalar {label}"), budget, || {
            attn_head_span_int8(
                AttnKernelKind::Scalar,
                black_box(&q_codes),
                black_box(&q_scales),
                1,
                0,
                hd,
                0,
                hd,
                ctx,
                t,
                black_box(&k_codes),
                black_box(&k_scales),
                black_box(&v_codes),
                black_box(&v_scales),
                scale,
                &mut scores,
                &mut out,
            );
            black_box(&out);
        });
        record(&format!("attn_span_int8_scalar {label}"), &s_scalar);
        if attn_kernel == AttnKernelKind::Scalar {
            continue;
        }
        let s_simd = bench(&format!("attn span int8 {attn_kernel} {label}"), budget, || {
            attn_head_span_int8(
                attn_kernel,
                black_box(&q_codes),
                black_box(&q_scales),
                1,
                0,
                hd,
                0,
                hd,
                ctx,
                t,
                black_box(&k_codes),
                black_box(&k_scales),
                black_box(&v_codes),
                black_box(&v_scales),
                scale,
                &mut scores,
                &mut out,
            );
            black_box(&out);
        });
        record(&format!("attn_span_int8_{attn_kernel} {label}"), &s_simd);
        let sp = s_scalar.median_ns / s_simd.median_ns;
        println!("  -> int8 attention kernel {attn_kernel} vs scalar ({label}): {sp:.2}x");
        attn_speedups.push(obj(vec![
            ("shape", s(&format!("int8 {label}"))),
            ("kernel", s(attn_kernel.name())),
            ("scalar_median_ns", num(s_scalar.median_ns)),
            ("simd_median_ns", num(s_simd.median_ns)),
            ("speedup", num(sp)),
        ]));
    }

    // ---- int8 dot kernel ----
    let a: Vec<i8> = (0..1024).map(|i| (i % 15 - 7) as i8).collect();
    let b: Vec<i8> = (0..1024).map(|i| (i % 13 - 6) as i8).collect();
    let sm = bench("dot_i8 1024", budget, || {
        black_box(dot_i8(black_box(&a), black_box(&b)));
    });
    println!("  -> {:.2} G i8-madd/s", 1024.0 / sm.median_ns);
    record("dot_i8_1024", &sm);

    // ---- f32 GEMM ----
    let ma = Matrix::randn(&mut rng, 256, 256, 1.0);
    let mb = Matrix::randn(&mut rng, 256, 256, 1.0);
    let sm = bench("gemm 256x256x256", budget, || {
        black_box(matmul(black_box(&ma), black_box(&mb)));
    });
    println!("  -> {:.2} GFLOP/s", 2.0 * 256f64.powi(3) / sm.median_ns);
    record("gemm_256", &sm);
    let v: Vec<f32> = (0..256).map(|i| i as f32).collect();
    let sm = bench("matvec 256x256", budget, || {
        black_box(matvec(black_box(&ma), black_box(&v)));
    });
    record("matvec_256", &sm);

    // ---- blocked A·Bᵀ (the PPL batch-forward kernel) ----
    let bt_a = Matrix::randn(&mut rng, 128, 512, 1.0);
    let bt_b = Matrix::randn(&mut rng, 256, 512, 1.0);
    let sm = bench("matmul_bt 128x512x256", budget, || {
        black_box(aser::tensor::matmul_bt(black_box(&bt_a), black_box(&bt_b)));
    });
    println!("  -> {:.2} GFLOP/s blocked A·Bᵀ", 2.0 * 128.0 * 512.0 * 256.0 / sm.median_ns);
    record("matmul_bt_128x512x256", &sm);

    // ---- SVD variants (the quantization-pipeline bottleneck) ----
    for (m, n) in [(256usize, 256usize), (1024, 256)] {
        let a = Matrix::randn(&mut rng, m, n, 1.0);
        let s_j = bench(&format!("svd jacobi {m}x{n}"), Duration::from_millis(1200), || {
            black_box(aser::linalg::svd(black_box(&a)));
        });
        let s_g = bench(&format!("svd gram   {m}x{n}"), Duration::from_millis(1200), || {
            black_box(aser::linalg::svd_gram(black_box(&a)));
        });
        println!("  -> gram speedup {:.1}x", s_j.median_ns / s_g.median_ns);
        record(&format!("svd_jacobi_{m}x{n}"), &s_j);
        record(&format!("svd_gram_{m}x{n}"), &s_g);
    }

    let report = obj(vec![
        ("bench", s("hotpath")),
        ("kernel", s(auto_kernel.name())),
        ("records", Json::Arr(records)),
        ("batched_vs_scalar", Json::Arr(speedups)),
        ("int_kernel_speedup", Json::Arr(kernel_speedups)),
        (
            "attn",
            obj(vec![
                ("kernel", s(attn_kernel.name())),
                ("attn_kernel_speedup", Json::Arr(attn_speedups)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_hotpath.json", report.to_string_pretty())
        .expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");
}
