//! Hot-path microbenches (§Perf): the quantized linear forward in all its
//! variants vs the dense fp32 GEMM of the same shape, the int8 dot kernel,
//! and SVD variants. `cargo bench --offline` (criterion is not vendored;
//! `util::stats::bench` provides warmup + robust summaries).

use aser::methods::aser::Aser;
use aser::methods::{LayerCalib, PtqMethod, RankPolicy};
use aser::model::linear::{dot_i8, forward_quant_token};
use aser::model::Linear;
use aser::quant::Precision;
use aser::tensor::{matmul, matvec, Matrix};
use aser::util::rng::Pcg64;
use aser::util::stats::{bench, black_box};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(400);
    let mut rng = Pcg64::seed(7);

    // ---- shapes of model A's four linears ----
    for (label, d_in, d_out) in
        [("qkv 256->768", 256usize, 768usize), ("fc1 256->1024", 256, 1024), ("fc2 512->256", 512, 256)]
    {
        let w = Matrix::randn(&mut rng, d_out, d_in, 0.05);
        let mut xs = Matrix::randn(&mut rng, 128, d_in, 1.0);
        for r in 0..xs.rows {
            xs[(r, 3)] *= 25.0;
        }
        let calib = LayerCalib::from_sample(xs);
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal()).collect();

        // dense reference
        let dense = Linear::Dense(w.clone());
        let s_dense = bench(&format!("dense    matvec {label}"), budget, || {
            black_box(dense.forward_token(black_box(&x)));
        });

        // RTN W4A8 (no compensation)
        let rtn = aser::methods::rtn::Rtn.quantize_layer(&w, &calib, Precision::w4a8());
        bench(&format!("w4a8 rtn  token  {label}"), budget, || {
            black_box(forward_quant_token(black_box(&rtn), black_box(&x)));
        });

        // full ASER W4A8 (smooth + low-rank r=16)
        let aser = Aser { rank: RankPolicy::Fixed(16), outlier_f: 8, ..Default::default() }
            .quantize_layer(&w, &calib, Precision::w4a8());
        let s_aser = bench(&format!("w4a8 aser token  {label}"), budget, || {
            black_box(forward_quant_token(black_box(&aser), black_box(&x)));
        });
        println!(
            "  -> aser/dense ratio {:.2}x (target ≤ 1.5x: compensation ~free)",
            s_aser.median_ns / s_dense.median_ns
        );
    }

    // ---- int8 dot kernel ----
    let a: Vec<i8> = (0..1024).map(|i| (i % 15 - 7) as i8).collect();
    let b: Vec<i8> = (0..1024).map(|i| (i % 13 - 6) as i8).collect();
    let s = bench("dot_i8 1024", budget, || {
        black_box(dot_i8(black_box(&a), black_box(&b)));
    });
    println!("  -> {:.2} G i8-madd/s", 1024.0 / s.median_ns);

    // ---- f32 GEMM ----
    let ma = Matrix::randn(&mut rng, 256, 256, 1.0);
    let mb = Matrix::randn(&mut rng, 256, 256, 1.0);
    let s = bench("gemm 256x256x256", budget, || {
        black_box(matmul(black_box(&ma), black_box(&mb)));
    });
    println!("  -> {:.2} GFLOP/s", 2.0 * 256f64.powi(3) / s.median_ns);
    let v: Vec<f32> = (0..256).map(|i| i as f32).collect();
    bench("matvec 256x256", budget, || {
        black_box(matvec(black_box(&ma), black_box(&v)));
    });

    // ---- SVD variants (the quantization-pipeline bottleneck) ----
    for (m, n) in [(256usize, 256usize), (1024, 256)] {
        let a = Matrix::randn(&mut rng, m, n, 1.0);
        let s_j = bench(&format!("svd jacobi {m}x{n}"), Duration::from_millis(1200), || {
            black_box(aser::linalg::svd(black_box(&a)));
        });
        let s_g = bench(&format!("svd gram   {m}x{n}"), Duration::from_millis(1200), || {
            black_box(aser::linalg::svd_gram(black_box(&a)));
        });
        println!("  -> gram speedup {:.1}x", s_j.median_ns / s_g.median_ns);
    }
}
