//! Integration tests across modules: cross-language model parity, the full
//! calibrate→quantize→eval pipeline, serving end-to-end, and the PJRT
//! runtime bridge. Tests that need `make artifacts` outputs skip gracefully
//! when the artifacts are absent (CI without the python step).

use aser::calib::CalibConfig;
use aser::coordinator::{
    calibrate_model, run_ptq, serve_requests, synthetic_requests, BatchConfig, Engine,
    EngineConfig, FinishReason, GenRequest, ServerConfig, TokenEvent,
};
use aser::eval::{perplexity, tasks};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::{load_model, synthetic_model, KvDtype, ModelConfig, NullSink};
use aser::quant::Precision;
use aser::util::io::TensorFile;
use std::path::Path;

fn artifacts() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

trait Leak {
    fn leak(self) -> &'static Path;
}
impl Leak for std::path::PathBuf {
    fn leak(self) -> &'static Path {
        Box::leak(self.into_boxed_path())
    }
}

/// Cross-language contract: the rust forward of the python-pretrained model
/// must reproduce the JAX logits that were exported next to the weights.
#[test]
fn rust_forward_matches_jax_reference_logits() {
    let dir = artifacts().join("models").join("A");
    if !dir.join("ref_logits.atns").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = ModelConfig::by_name("A").unwrap();
    let model = load_model(cfg, &dir.join("weights.atns")).unwrap();
    let tf = TensorFile::load(&dir.join("ref_logits.atns")).unwrap();
    let tokens_raw = tf.get("tokens").unwrap();
    let tokens: Vec<u32> = tokens_raw
        .bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u32)
        .collect();
    let (dims, want) = tf.get_f32("logits").unwrap();
    let got = model.forward_logits(&tokens, &mut NullSink);
    assert_eq!(got.rows, dims[0]);
    assert_eq!(got.cols, dims[1]);
    // f32 accumulation order differs across stacks; compare relative.
    let mut max_rel = 0f32;
    let scale = want.iter().fold(0f32, |m, x| m.max(x.abs()));
    for (a, b) in got.data.iter().zip(&want) {
        max_rel = max_rel.max((a - b).abs() / scale);
    }
    assert!(max_rel < 2e-3, "rust vs jax logits max_rel {max_rel}");
}

/// Full pipeline on a pretrained model (skips without artifacts): ASER at
/// W4A8 must (a) beat RTN on perplexity, (b) stay close to fp16.
#[test]
fn e2e_aser_recovers_ppl_on_pretrained_model() {
    let dir = artifacts().join("models").join("A");
    if !dir.join("weights.atns").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = ModelConfig::by_name("A").unwrap();
    let load = || load_model(cfg.clone(), &dir.join("weights.atns")).unwrap();
    let ccfg = CalibConfig { n_seqs: 16, seq_len: 48, max_sample: 192, seed: 7 };
    let model = load();
    let stats = calibrate_model(&model, "wiki", &ccfg).unwrap();
    let corpus = aser::data::corpus(cfg.vocab_size, "wiki").unwrap();
    let mut rng = aser::util::rng::Pcg64::seed(99);
    let stream = corpus.stream(&mut rng, 384);

    let ppl_fp = perplexity(&model, &stream, 64);
    let prec = Precision::w4a8();
    let aser_m = method_by_name("aser", RankPolicy::Fixed(16), 8).unwrap();
    let (qm_aser, _) = run_ptq(load(), &stats, aser_m.as_ref(), prec, 1).unwrap();
    let ppl_aser = perplexity(&qm_aser, &stream, 64);
    let rtn = method_by_name("rtn", RankPolicy::Fixed(16), 8).unwrap();
    let (qm_rtn, _) = run_ptq(load(), &stats, rtn.as_ref(), prec, 1).unwrap();
    let ppl_rtn = perplexity(&qm_rtn, &stream, 64);

    assert!(ppl_aser < ppl_rtn, "aser {ppl_aser} !< rtn {ppl_rtn}");
    assert!(
        ppl_aser < ppl_fp * 1.25,
        "aser ppl {ppl_aser} strays too far from fp16 {ppl_fp}"
    );
}

/// Quantized serving end-to-end through BOTH public surfaces: the streaming
/// `Engine::submit` path and the `serve_requests` compat wrapper must each
/// match the unbatched quantized model exactly, and all requests complete.
#[test]
fn e2e_quantized_serving_matches_offline_generation() {
    let model = synthetic_model("micro", 401).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 3 };
    let stats = calibrate_model(&model, "wiki", &ccfg).unwrap();
    let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
    let (qmodel, _) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 1).unwrap();

    let reqs = synthetic_requests(qmodel.cfg.vocab_size, 8, 5, 6, 11).unwrap();
    let offline: Vec<Vec<u32>> =
        reqs.iter().map(|r| qmodel.generate_greedy(&r.prompt, r.max_new)).collect();
    let qmodel = std::sync::Arc::new(qmodel);

    // Streaming surface: submit all, consume each event stream, check the
    // protocol (PrefillDone → Token* → Finished) and the token content.
    let engine = Engine::new(
        std::sync::Arc::clone(&qmodel),
        EngineConfig { workers: 2, kv_tokens: 4096, ..Default::default() },
    );
    let handles: Vec<_> = reqs.iter().map(|r| engine.submit(r.clone()).unwrap()).collect();
    for h in handles {
        let id = h.id() as usize;
        let mut tokens = Vec::new();
        let mut saw_prefill = false;
        loop {
            match h.recv().expect("stream must stay open until Finished") {
                TokenEvent::PrefillDone { .. } => saw_prefill = true,
                TokenEvent::Token { token, index } => {
                    assert!(saw_prefill, "req {id}: token before PrefillDone");
                    assert_eq!(index, tokens.len(), "req {id}: index gap");
                    tokens.push(token);
                }
                TokenEvent::Finished { reason, n_tokens, .. } => {
                    assert!(reason.is_completed(), "req {id}: {reason:?}");
                    assert_eq!(n_tokens, tokens.len());
                    break;
                }
            }
        }
        let want = &offline[id];
        assert!(
            want.starts_with(&tokens) || *want == tokens,
            "req {id}: streamed {tokens:?} vs offline {want:?}"
        );
    }
    assert_eq!(engine.kv_used_tokens(), 0, "streams done ⇒ pools drained");
    engine.shutdown();

    // Compat surface: the blocking wrapper reproduces the same outputs.
    let cfg = ServerConfig { workers: 2, kv_tokens: 4096, ..Default::default() };
    let run = serve_requests(qmodel, &cfg, reqs.clone());
    assert_eq!(run.responses.len(), 8);
    for resp in &run.responses {
        let want = &offline[resp.id as usize];
        assert!(resp.finish.is_completed());
        assert!(
            want.starts_with(&resp.tokens) || *want == resp.tokens,
            "req {}: batched {:?} vs offline {:?}",
            resp.id,
            resp.tokens,
            want
        );
    }
}

/// Smoke: serving end to end on the int8-quantized KV cache (`--kv-bits 8`
/// equivalent). Every request must complete through the fused-dequant
/// attention path, streams must obey the event protocol, and the pool must
/// drain — the content-level guarantees live in the property suite.
#[test]
fn e2e_int8_kv_serving_completes_and_drains() {
    let model = synthetic_model("micro", 405).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 7 };
    let stats = calibrate_model(&model, "wiki", &ccfg).unwrap();
    let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
    let (qmodel, _) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 1).unwrap();
    let qmodel = std::sync::Arc::new(qmodel);

    let reqs = synthetic_requests(qmodel.cfg.vocab_size, 8, 5, 6, 13).unwrap();
    let engine = Engine::new(
        std::sync::Arc::clone(&qmodel),
        EngineConfig {
            workers: 2,
            // stop_on_eos off ⇒ every request runs its full max_new budget,
            // so completion is deterministic regardless of sampled content.
            batch: BatchConfig {
                kv_dtype: KvDtype::Int8,
                stop_on_eos: false,
                ..Default::default()
            },
            kv_tokens: 4096,
            ..Default::default()
        },
    );
    let handles: Vec<_> = reqs.iter().map(|r| engine.submit(r.clone()).unwrap()).collect();
    for h in handles {
        let id = h.id() as usize;
        let mut n_tokens = 0usize;
        let mut saw_prefill = false;
        loop {
            match h.recv().expect("stream must stay open until Finished") {
                TokenEvent::PrefillDone { .. } => saw_prefill = true,
                TokenEvent::Token { index, .. } => {
                    assert!(saw_prefill, "req {id}: token before PrefillDone");
                    assert_eq!(index, n_tokens, "req {id}: index gap");
                    n_tokens += 1;
                }
                TokenEvent::Finished { reason, n_tokens: n, .. } => {
                    assert!(reason.is_completed(), "req {id}: {reason:?}");
                    assert_eq!(n, n_tokens);
                    break;
                }
            }
        }
        assert!(n_tokens > 0, "req {id}: no tokens generated on int8 KV");
    }
    assert_eq!(engine.kv_used_tokens(), 0, "streams done ⇒ pools drained");
    engine.shutdown();
}

/// Acceptance: a mid-decode `cancel()` on a quantized serving stream frees
/// its KV lease within one batcher iteration — observed through the
/// guarantee that the lease is back in the pool by the time the terminal
/// `Finished { Cancelled }` event is delivered — while co-scheduled
/// requests keep running to completion.
#[test]
fn e2e_cancel_mid_decode_frees_kv_promptly() {
    let mut model = synthetic_model("micro", 403).unwrap();
    model.cfg.max_seq = 4096; // room to keep decoding until cancelled
    model.refresh_derived();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 5 };
    let stats = calibrate_model(&model, "wiki", &ccfg).unwrap();
    let method = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
    let (qmodel, _) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 1).unwrap();
    let qmodel = std::sync::Arc::new(qmodel);

    let engine = Engine::new(
        qmodel,
        EngineConfig {
            workers: 1,
            batch: BatchConfig { stop_on_eos: false, ..Default::default() },
            kv_tokens: 1 << 14,
            ..Default::default()
        },
    );
    let victim = engine.submit(GenRequest::new(0, vec![2, 3, 4], 2000)).unwrap();
    let bystander = engine.submit(GenRequest::new(1, vec![5, 6, 7], 8)).unwrap();
    // Let the victim decode a few tokens, then cancel it.
    let mut seen = 0usize;
    loop {
        match victim.recv().expect("victim stream open") {
            TokenEvent::Token { .. } => {
                seen += 1;
                if seen == 3 {
                    break;
                }
            }
            TokenEvent::Finished { .. } => panic!("victim finished before cancel"),
            TokenEvent::PrefillDone { .. } => {}
        }
    }
    victim.cancel();
    let reason = loop {
        match victim.recv().expect("terminal event must arrive") {
            TokenEvent::Finished { reason, n_tokens, .. } => {
                assert!(n_tokens < 2000, "cancel must cut generation short");
                break reason;
            }
            _ => {}
        }
    };
    assert_eq!(reason, FinishReason::Cancelled);
    // The Finished event is sent only after the lease is freed, so the
    // victim's KV tokens are reusable the moment we observed it. Only the
    // bystander's lease may still be live.
    assert!(engine.kv_live_leases() <= 1, "victim lease must be gone");
    let r = bystander.wait();
    assert!(r.finish.is_completed());
    assert_eq!(r.tokens.len(), 8, "bystander unaffected by the cancel");
    assert_eq!(engine.kv_used_tokens(), 0);
    let metrics = engine.shutdown();
    assert_eq!(metrics[0].cancelled, 1);
    assert_eq!(metrics[0].requests, 2);
}

/// PJRT bridge (skips without artifacts): manifest loads, a kernel runs.
#[test]
fn pjrt_runtime_executes_artifacts() {
    let hlo = artifacts().join("hlo");
    if !hlo.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = aser::runtime::Manifest::load(&hlo).unwrap();
    assert!(!manifest.qlinear.is_empty());
    let mut rt = aser::runtime::Runtime::new(&hlo).unwrap();
    let art = &manifest.qlinear[0];
    let mut rng = aser::util::rng::Pcg64::seed(5);
    let x = aser::tensor::Matrix::randn(&mut rng, art.t, art.d_in, 1.0);
    let w = aser::tensor::Matrix::randn(&mut rng, art.d_out, art.d_in, 0.05);
    let qw = aser::quant::QuantizedWeight::quantize(&w, 4);
    let packed = aser::quant::pack_int4(&qw.codes);
    let m = vec![1.0f32; art.d_in];
    let la = aser::tensor::Matrix::zeros(art.d_out, art.rank);
    let lb = aser::tensor::Matrix::zeros(art.rank, art.d_in);
    let y = rt.run_qlinear(art, &x, &m, &packed, &qw.scales, &la, &lb).unwrap();
    let want = aser::runtime::qlinear_reference(
        &x,
        &m,
        &qw.codes,
        art.d_out,
        &qw.scales,
        &la,
        &lb,
        art.abits as u8,
    );
    let rel = y.sub(&want).frob_norm() / want.frob_norm();
    assert!(rel < 1e-4, "rel {rel}");
}

/// Property: the whole method registry produces models that generate valid
/// tokens for every precision (failure-injection style sweep).
#[test]
fn every_method_every_precision_generates() {
    let ccfg = CalibConfig { n_seqs: 3, seq_len: 16, max_sample: 48, seed: 13 };
    let base = synthetic_model("micro", 402).unwrap();
    let stats = calibrate_model(&base, "c4", &ccfg).unwrap();
    for m in ["rtn", "llm_int", "smoothquant", "smoothquant+", "awq", "gptq", "lorc", "l2qer", "aser-er", "aser"] {
        for prec in [Precision::w4a8(), Precision::w4a6(), Precision::w4a16(), Precision::new(3, 8)] {
            let model = synthetic_model("micro", 402).unwrap();
            let method = method_by_name(m, RankPolicy::Fixed(4), 2).unwrap();
            let (qm, report) = run_ptq(model, &stats, method.as_ref(), prec, 1).unwrap();
            assert!(report.mean_rel_error().is_finite(), "{m}@{prec}");
            let out = qm.generate_greedy(&[1, 2], 3);
            assert_eq!(out.len(), 3, "{m}@{prec}");
            assert!(out.iter().all(|&t| (t as usize) < qm.cfg.vocab_size), "{m}@{prec}");
        }
    }
}

/// Task accuracy of the pretrained model must be clearly above chance —
/// the precondition for the accuracy tables to mean anything.
#[test]
fn pretrained_model_beats_chance_on_tasks() {
    let dir = artifacts().join("models").join("A");
    if !dir.join("weights.atns").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = ModelConfig::by_name("A").unwrap();
    let model = load_model(cfg.clone(), &dir.join("weights.atns")).unwrap();
    let corpus = aser::data::corpus(cfg.vocab_size, "wiki").unwrap();
    let arc_e = tasks::generate(&corpus, "arc_e", 30, 5).unwrap();
    let acc = tasks::evaluate(&model, &arc_e);
    assert!(acc > 75.0, "arc_e accuracy {acc} not above chance band");
    let arc_c = tasks::generate(&corpus, "arc_c", 30, 5).unwrap();
    let acc_c = tasks::evaluate(&model, &arc_c);
    assert!(acc_c > 35.0, "arc_c accuracy {acc_c} (chance 25)");
}
