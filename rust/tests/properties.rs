//! Property-based invariant suites (hand-rolled harness in `util::prop`):
//! quantizer grid bounds, smoothing function-preservation, rank selection
//! monotonicity, batcher/KV-pool safety, int8-KV attention kernel
//! contracts, SVD contracts.

use aser::linalg::{rank_for_threshold, svd, svd_gram};
use aser::methods::aser::Aser;
use aser::methods::{method_by_name, LayerCalib, PtqMethod, RankPolicy};
use aser::model::{forward_quant_token, Linear};
use aser::quant::{fake_quant_vec, quantize_token, BitWidth, Precision, QuantizedWeight};
use aser::tensor::{detect_kernel, Matrix, QKernelKind};
use aser::util::prop::{all, check, ensure, gen_vec_f32, shrink_vec_f32, CaseResult, Config};
use aser::util::rng::Pcg64;

fn cfg(cases: usize) -> Config {
    Config { cases, ..Default::default() }
}

#[test]
fn prop_weight_codes_always_in_grid() {
    check(
        "weight_codes_in_grid",
        &cfg(64),
        |rng| {
            let rows = 1 + rng.below(6);
            let cols = 1 + rng.below(24);
            let bits = [2u8, 3, 4, 6, 8][rng.below(5)];
            let data: Vec<f32> = (0..rows * cols).map(|_| rng.heavy_tailed(0.2, 50.0)).collect();
            (rows, cols, bits, data)
        },
        |_| Vec::new(),
        |(rows, cols, bits, data)| {
            let w = Matrix::from_vec(*rows, *cols, data.clone());
            let q = QuantizedWeight::quantize(&w, *bits);
            let qmax = BitWidth(*bits).qmax() as i8;
            all(vec![
                ensure(q.codes.iter().all(|&c| -qmax <= c && c <= qmax), || {
                    "code out of grid".into()
                }),
                ensure(q.scales.iter().all(|&s| s > 0.0 && s.is_finite()), || {
                    "bad scale".into()
                }),
                ensure(q.dequantize().is_finite(), || "non-finite dequant".into()),
            ])
        },
    );
}

#[test]
fn prop_act_quant_error_bounded_by_half_step() {
    check(
        "act_quant_bound",
        &cfg(128),
        |rng| gen_vec_f32(rng, 64),
        shrink_vec_f32,
        |v| {
            let q = quantize_token(v, 8);
            let back = q.dequantize();
            let ok = v
                .iter()
                .zip(&back)
                .all(|(a, b)| (a - b).abs() <= 0.5 * q.scale + 1e-6);
            ensure(ok, || format!("roundtrip error exceeds step/2 (scale {})", q.scale))
        },
    );
}

#[test]
fn prop_fake_quant_idempotent() {
    // Quantizing an already-quantized vector must be exact (same grid).
    check(
        "fake_quant_idempotent",
        &cfg(96),
        |rng| gen_vec_f32(rng, 48),
        shrink_vec_f32,
        |v| {
            let mut once = v.clone();
            fake_quant_vec(&mut once, 6);
            let mut twice = once.clone();
            fake_quant_vec(&mut twice, 6);
            let ok = once
                .iter()
                .zip(&twice)
                .all(|(a, b)| (a - b).abs() <= 1e-5 * a.abs().max(1.0));
            ensure(ok, || "second quantization moved values".into())
        },
    );
}

#[test]
fn prop_smoothing_function_preserving_at_fp() {
    check(
        "smoothing_preserves_function",
        &cfg(24),
        |rng| {
            let d = 8 + rng.below(24);
            let out = 4 + rng.below(12);
            let w = Matrix::randn(rng, out, d, 0.1);
            let mut x = Matrix::randn(rng, 40, d, 1.0);
            let hot = rng.below(d);
            for r in 0..x.rows {
                x[(r, hot)] *= 10.0 + rng.f32() * 40.0;
            }
            (w, x)
        },
        |_| Vec::new(),
        |(w, x)| {
            let calib = LayerCalib::from_sample(x.clone());
            let aser = Aser { outlier_f: 4, ..Default::default() };
            let plan = aser.smoothing_plan(w, &calib);
            let wm = w.scale_cols(&plan.m);
            let inv: Vec<f32> = plan.m.iter().map(|&v| 1.0 / v).collect();
            let xs = x.scale_cols(&inv);
            let y1 = aser::tensor::matmul_bt(x, w);
            let y2 = aser::tensor::matmul_bt(&xs, &wm);
            let rel = y1.sub(&y2).frob_norm() / y1.frob_norm().max(1e-12);
            ensure(rel < 1e-3, || format!("smoothing changed function: rel {rel}"))
        },
    );
}

#[test]
fn prop_rank_threshold_monotone_and_bounded() {
    check(
        "rank_threshold_monotone",
        &cfg(128),
        |rng| {
            let n = 1 + rng.below(64);
            let mut s: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-3).collect();
            s.sort_by(|a, b| b.partial_cmp(a).unwrap());
            s
        },
        shrink_vec_f32,
        |s| {
            let mut last = 0usize;
            for alpha in [0.05, 0.2, 0.5, 0.8, 1.0] {
                let r = rank_for_threshold(s, alpha);
                if r < last || r > s.len() {
                    return CaseResult::Fail(format!("alpha {alpha}: r {r} (last {last})"));
                }
                last = r;
            }
            CaseResult::Pass
        },
    );
}

#[test]
fn prop_svd_fast_matches_reference_spectrum() {
    check(
        "svd_gram_vs_jacobi",
        &cfg(16),
        |rng| {
            let m = 4 + rng.below(28);
            let n = 4 + rng.below(28);
            Matrix::randn(rng, m, n, 1.0)
        },
        |_| Vec::new(),
        |a| {
            let f1 = svd(a);
            let f2 = svd_gram(a);
            let k = a.rows.min(a.cols);
            for i in 0..k {
                let rel = (f1.s[i] - f2.s[i]).abs() / f1.s[0].max(1e-9);
                if rel > 1e-3 {
                    return CaseResult::Fail(format!("σ{i}: {} vs {}", f1.s[i], f2.s[i]));
                }
            }
            CaseResult::Pass
        },
    );
}

#[test]
fn prop_aser_never_worse_than_rtn_on_calib() {
    // The compensation is built to minimize exactly this error, so on the
    // calibration sample ASER(W4A16) ≤ RTN(W4A16) must hold universally.
    check(
        "aser_le_rtn",
        &cfg(12),
        |rng| {
            let d = 12 + rng.below(20);
            let w = Matrix::randn(rng, d, d, 0.08);
            let mut x = Matrix::randn(rng, 3 * d, d, 1.0);
            for c in 0..d {
                let s = 10f32.powf(rng.range_f32(-0.8, 0.8));
                for r in 0..x.rows {
                    x[(r, c)] *= s;
                }
            }
            (w, x)
        },
        |_| Vec::new(),
        |(w, x)| {
            let calib = LayerCalib::from_sample(x.clone());
            let prec = Precision::w4a16();
            let aser = Aser { rank: RankPolicy::Fixed(6), smooth: false, ..Default::default() };
            let q_aser = aser.quantize_layer(w, &calib, prec);
            let q_rtn = aser::methods::rtn::Rtn.quantize_layer(w, &calib, prec);
            let e_aser = aser::methods::layer_error(w, &q_aser, x);
            let e_rtn = aser::methods::layer_error(w, &q_rtn, x);
            ensure(e_aser <= e_rtn * 1.001, || format!("aser {e_aser} > rtn {e_rtn}"))
        },
    );
}

#[test]
fn prop_batched_quant_forward_matches_token_and_reference() {
    // The packed batched kernel (Linear::forward → tensor::qgemm) must
    // reproduce both the scalar token path (`forward_quant_token`) and the
    // reference semantics (`QuantizedLinear::forward_matrix`) within 1e-3
    // relative, across the serving method/precision grid and awkward batch
    // sizes (1 = degenerate batch, 7 = ragged vs the QR/TB tiles, 64 = a
    // full token block).
    let mut rng = Pcg64::seed(907);
    let (d_in, d_out) = (40usize, 24usize);
    let w = Matrix::randn(&mut rng, d_out, d_in, 0.05);
    let mut x_all = Matrix::randn(&mut rng, 64, d_in, 1.0);
    for r in 0..x_all.rows {
        x_all[(r, 3)] *= 20.0; // hot channel: exercises smoothing + outliers
    }
    let calib = LayerCalib::from_sample(x_all.clone());
    for method in ["rtn", "aser", "aser-er", "smoothquant"] {
        let m = method_by_name(method, RankPolicy::Fixed(8), 4).unwrap();
        for prec in [Precision::w4a8(), Precision::w4a6(), Precision::w4a16()] {
            let q = m.quantize_layer(&w, &calib, prec);
            let lin = Linear::quantized(q.clone());
            for t in [1usize, 7, 64] {
                let x = x_all.rows_slice(0, t);
                let got = lin.forward(&x);
                let want_ref = q.forward_matrix(&x);
                let tol = 1e-3 * want_ref.max_abs().max(1.0);
                assert!(
                    got.max_diff(&want_ref) < tol,
                    "{method} {prec} t={t}: batched vs forward_matrix diff {}",
                    got.max_diff(&want_ref)
                );
                let mut want_tok = Matrix::zeros(t, d_out);
                for ti in 0..t {
                    want_tok
                        .row_mut(ti)
                        .copy_from_slice(&forward_quant_token(&q, x.row(ti)));
                }
                assert!(
                    got.max_diff(&want_tok) < tol,
                    "{method} {prec} t={t}: batched vs scalar token diff {}",
                    got.max_diff(&want_tok)
                );
                // The packed single-token entry point agrees with row 0.
                let y0 = lin.forward_token(x.row(0));
                let d0 = got
                    .row(0)
                    .iter()
                    .zip(&y0)
                    .fold(0f32, |mx, (&a, &b)| mx.max((a - b).abs()));
                assert!(d0 < tol, "{method} {prec} t={t}: token entry diff {d0}");
            }
        }
    }
}

#[test]
fn prop_simd_and_scalar_kernels_bitwise_equal() {
    // The int path accumulates exact i32, so the auto-detected SIMD kernel
    // (AVX2/NEON) must agree with the pinned scalar kernel bit for bit —
    // across the method grid, awkward d_in (straddling the SIMD chunk),
    // d_out (straddling the QR panel and the RB job), and batch sizes
    // (straddling the widened token tiles). On hosts without SIMD the auto
    // kernel IS scalar and the property is trivially green.
    let mut rng = Pcg64::seed(911);
    let auto = detect_kernel();
    for (d_in, d_out) in [(33usize, 24usize), (64, 66), (100, 13)] {
        let w = Matrix::randn(&mut rng, d_out, d_in, 0.05);
        let mut x_all = Matrix::randn(&mut rng, 65, d_in, 1.0);
        for r in 0..x_all.rows {
            x_all[(r, 3)] *= 20.0;
        }
        let calib = LayerCalib::from_sample(x_all.clone());
        for method in ["rtn", "aser"] {
            let m = method_by_name(method, RankPolicy::Fixed(6), 4).unwrap();
            let q = m.quantize_layer(&w, &calib, Precision::w4a8());
            let lin_auto = Linear::quantized(q.clone());
            let lin_scalar = Linear::quantized_with(q, QKernelKind::Scalar);
            assert_eq!(lin_scalar.kernel(), Some(QKernelKind::Scalar));
            assert_eq!(lin_auto.kernel(), Some(auto));
            for t in [1usize, 2, 3, 5, 7, 65] {
                let x = x_all.rows_slice(0, t);
                let ya = lin_auto.forward(&x);
                let ys = lin_scalar.forward(&x);
                assert_eq!(
                    ya, ys,
                    "{method} t={t} ({d_in}x{d_out}): {auto:?} kernel diverged from scalar"
                );
            }
        }
    }
}

#[test]
fn prop_attn_simd_kernel_matches_scalar_reference() {
    // The vectorized attention span kernel must reproduce the scalar
    // reference across head dims that are NOT multiples of the SIMD lane
    // width (8 for AVX2, 4 for NEON), nh = 1, short and long spans, and
    // non-empty pre-existing cache contents (pos0 > 0). The SIMD kernels
    // reassociate f32 sums, so the contract is tight tolerance (the scalar
    // kernel itself is pinned bitwise against the pre-refactor loops in
    // tensor::attn_kernel's unit tests).
    use aser::tensor::{attn_head_span, detect_attn_kernel, AttnKernelKind};
    let kind = detect_attn_kernel();
    check(
        "attn_simd_vs_scalar",
        &cfg(48),
        |rng| {
            let hd = 1 + rng.below(33); // straddles both SIMD lane widths
            let nh = 1 + rng.below(3); // includes nh = 1
            let pos0 = rng.below(70); // 0 = fresh cache, > 0 = pre-existing
            let t = [1usize, 3, 8][rng.below(3)]; // span lengths incl. decode
            let d = nh * hd;
            let q: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
            let keys: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
            let values: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
            (hd, nh, pos0, t, q, keys, values)
        },
        |_| Vec::new(),
        |(hd, nh, pos0, t, q, keys, values)| {
            let (hd, nh, pos0, t) = (*hd, *nh, *pos0, *t);
            let d = nh * hd;
            let scale = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0f32; pos0 + t];
            for head in 0..nh {
                let s = head * hd;
                let mut want = vec![0f32; t * hd];
                attn_head_span(
                    AttnKernelKind::Scalar,
                    q,
                    d,
                    s,
                    hd,
                    pos0,
                    t,
                    keys,
                    values,
                    scale,
                    &mut scores,
                    &mut want,
                );
                let mut got = vec![0f32; t * hd];
                attn_head_span(
                    kind, q, d, s, hd, pos0, t, keys, values, scale, &mut scores, &mut got,
                );
                let wmax = want.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1.0);
                let diff = got
                    .iter()
                    .zip(&want)
                    .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
                if diff >= 1e-5 * wmax {
                    return CaseResult::Fail(format!(
                        "{kind} hd={hd} nh={nh} pos0={pos0} t={t} head={head}: diff {diff}"
                    ));
                }
            }
            CaseResult::Pass
        },
    );
}

#[test]
fn prop_vectorized_attention_spans_match_step_reference() {
    // The serving attention engine end to end: feeding a span through
    // forward_chunk_batch — against a NON-EMPTY pre-existing cache, for
    // span lengths {1, 3, whole} — must reproduce the token-at-a-time
    // forward_step replay, for both multi-head and nh = 1 models (same
    // weights reinterpreted as a single 64-wide head).
    use aser::model::{synthetic_model, ChunkLogits, KvCache, SeqChunk};
    use aser::tensor::QGemmArena;
    for nh in [4usize, 1] {
        let mut model = synthetic_model("micro", 914).unwrap();
        model.cfg.n_heads = nh;
        model.refresh_derived();
        let history: Vec<u32> = (0..9).map(|i| 1 + (i * 5 % 120) as u32).collect();
        let tail: Vec<u32> = (0..12).map(|i| 2 + (i * 11 % 110) as u32).collect();
        let mut pre_cache = KvCache::new(&model.cfg);
        for &t in &history {
            model.forward_step(t, &mut pre_cache);
        }
        let mut want = Vec::new();
        let mut ref_cache = pre_cache.clone();
        for &t in &tail {
            want = model.forward_step(t, &mut ref_cache);
        }
        let wmax = want.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1.0);
        for chunk in [1usize, 3, tail.len()] {
            let mut cache = pre_cache.clone();
            let mut arena = QGemmArena::new();
            let mut got = Vec::new();
            let mut fed = 0usize;
            while fed < tail.len() {
                let end = (fed + chunk).min(tail.len());
                let last = end == tail.len();
                let span = [SeqChunk {
                    tokens: &tail[fed..end],
                    logits: if last { ChunkLogits::Last } else { ChunkLogits::None },
                }];
                let out = model.forward_chunk_batch(&span, &mut [&mut cache], &mut arena);
                if last {
                    got = out.row(0).to_vec();
                }
                fed = end;
            }
            assert_eq!(cache.seen, history.len() + tail.len());
            let d = want
                .iter()
                .zip(&got)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-4 * wmax, "nh={nh} chunk={chunk}: maxdiff {d}");
        }
    }
}

#[test]
fn prop_chunked_prefill_logits_match_token_by_token_reference() {
    // The tentpole equivalence: prefilling a prompt through
    // forward_chunk_batch — for any chunking — must reproduce the
    // token-by-token forward_step logits at the prompt-final position,
    // across the serving method grid and both activation widths. The int
    // path is bitwise identical per row by construction; the fp pieces
    // (attention, A16 main GEMM, low-rank branch) agree to f32 tolerance.
    use aser::calib::CalibConfig;
    use aser::coordinator::{calibrate_model, run_ptq};
    use aser::model::{synthetic_model, ChunkLogits, KvCache, SeqChunk};
    use aser::tensor::QGemmArena;

    let base = synthetic_model("micro", 913).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 31 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let prompt: Vec<u32> = (0..21).map(|i| 1 + ((i * 7) % 120) as u32).collect();
    for method in ["rtn", "aser", "aser-er", "smoothquant"] {
        for prec in [Precision::w4a8(), Precision::w4a16()] {
            let m = method_by_name(method, RankPolicy::Fixed(6), 4).unwrap();
            let model = synthetic_model("micro", 913).unwrap();
            let (qm, _) = run_ptq(model, &stats, m.as_ref(), prec, 0).unwrap();
            let mut ref_cache = KvCache::new(&qm.cfg);
            let mut want = Vec::new();
            for &t in &prompt {
                want = qm.forward_step(t, &mut ref_cache);
            }
            let wmax = want.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1.0);
            for chunk in [1usize, 3, 16, prompt.len()] {
                let mut cache = KvCache::new(&qm.cfg);
                let mut arena = QGemmArena::new();
                let mut got = Vec::new();
                let mut fed = 0;
                while fed < prompt.len() {
                    let end = (fed + chunk).min(prompt.len());
                    let last = end == prompt.len();
                    let span = [SeqChunk {
                        tokens: &prompt[fed..end],
                        logits: if last { ChunkLogits::Last } else { ChunkLogits::None },
                    }];
                    let out = qm.forward_chunk_batch(&span, &mut [&mut cache], &mut arena);
                    if last {
                        got = out.row(0).to_vec();
                    }
                    fed = end;
                }
                assert_eq!(cache.seen, prompt.len());
                let d = want
                    .iter()
                    .zip(&got)
                    .fold(0f32, |mx, (&a, &b)| mx.max((a - b).abs()));
                assert!(d < 1e-4 * wmax, "{method} {prec} chunk {chunk}: maxdiff {d}");
            }
        }
    }
}

#[test]
fn prop_mixed_iterations_respect_token_budget() {
    // Scheduling safety over random request streams, budgets, and chunk
    // widths: every request completes, the pool drains, and no iteration
    // ever feeds more rows than max(token_budget, decode rows) — decode
    // rows are planned unconditionally (one per decoding sequence, bounded
    // by max_batch), prompt chunks only from the leftover budget.
    use aser::coordinator::{BatchConfig, FinishReason, GenRequest, KvPool, Submission};
    use aser::model::synthetic_model;
    let model = synthetic_model("micro", 502).unwrap();
    check(
        "token_budget_respected",
        &cfg(8),
        |rng| {
            let n = 1 + rng.below(6);
            let budget = 1 + rng.below(24);
            let chunk = 1 + rng.below(12);
            let reqs: Vec<(Vec<u32>, usize)> = (0..n)
                .map(|_| {
                    let plen = 1 + rng.below(40);
                    (
                        (0..plen).map(|_| 2 + rng.below(120) as u32).collect(),
                        1 + rng.below(8),
                    )
                })
                .collect();
            (budget, chunk, reqs)
        },
        |_| Vec::new(),
        |(budget, chunk, reqs)| {
            let max_batch = 4usize;
            let pool = KvPool::new(10_000, 8);
            let (tx, rx) = std::sync::mpsc::channel();
            // Receivers are held open for the whole run: a dropped stream
            // counts as an implicit cancel.
            let mut streams = Vec::new();
            for (i, (prompt, max_new)) in reqs.iter().enumerate() {
                let (sub, erx, _cancel) =
                    Submission::channel(GenRequest::new(i as u64, prompt.clone(), *max_new));
                tx.send(sub).unwrap();
                streams.push(erx);
            }
            drop(tx);
            let bcfg = BatchConfig {
                max_batch,
                token_budget: *budget,
                prefill_chunk: *chunk,
                ..Default::default()
            };
            let mut n_resp = 0usize;
            let metrics =
                aser::coordinator::batcher::run_batcher(&model, &pool, &bcfg, rx, |r, reason| {
                    assert_ne!(
                        reason,
                        FinishReason::Rejected,
                        "feasible request {} rejected",
                        r.id
                    );
                    n_resp += 1;
                });
            let row_bound = (*budget).max(max_batch);
            all(vec![
                ensure(n_resp == reqs.len(), || {
                    format!("{n_resp} responses for {} requests", reqs.len())
                }),
                ensure(pool.used_tokens() == 0, || "kv leak".into()),
                ensure(metrics.peak_iter_tokens <= row_bound, || {
                    format!(
                        "peak {} rows exceeds bound {row_bound} (budget {budget}, chunk {chunk})",
                        metrics.peak_iter_tokens
                    )
                }),
            ])
        },
    );
}

#[test]
fn prop_kv_pool_never_overcommits() {
    use aser::coordinator::KvPool;
    check(
        "kv_pool_invariants",
        &cfg(64),
        |rng| {
            let cap = 16 + rng.below(200);
            let ops: Vec<(bool, usize)> =
                (0..rng.below(64)).map(|_| (rng.f32() < 0.6, 1 + rng.below(40))).collect();
            (cap, ops)
        },
        |_| Vec::new(),
        |(cap, ops)| {
            let pool = KvPool::new(*cap, 8);
            let mut held = Vec::new();
            for (is_alloc, n) in ops {
                if *is_alloc {
                    if let Some(l) = pool.alloc(*n) {
                        held.push(l);
                    }
                } else if !held.is_empty() {
                    pool.free(held.swap_remove(0));
                }
                if pool.used_tokens() > pool.capacity_tokens() {
                    return CaseResult::Fail("overcommit".into());
                }
            }
            for l in held {
                pool.free(l);
            }
            ensure(pool.used_tokens() == 0, || "leak after drain".into())
        },
    );
}

#[test]
fn prop_batcher_preserves_request_ids() {
    // Termination + completeness on ARBITRARY finite request streams,
    // including impossible ones: prompts longer than the KV window
    // (micro's max_seq is 64), prompts whose minimum footprint (prompt +
    // one token) exceeds the whole (small) pool, and empty prompts.
    // Requests whose *total* demand exceeds the pool but whose minimum
    // footprint fits are served truncated under right-sized leasing.
    // Every id must come back exactly once — served or explicitly
    // rejected — and the pool must drain. Before the admission rejection
    // fix, impossible requests livelocked run_batcher.
    use aser::coordinator::{BatchConfig, FinishReason, GenRequest, KvPool, Submission, TokenEvent};
    use aser::model::synthetic_model;
    let model = synthetic_model("micro", 501).unwrap();
    check(
        "batcher_completeness",
        &cfg(8),
        |rng| {
            let n = 1 + rng.below(10);
            (0..n)
                .map(|i| {
                    GenRequest::new(
                        i as u64,
                        // 0..=79 tokens: some empty, some past max_seq = 64.
                        (0..rng.below(80)).map(|_| rng.below(128) as u32).collect(),
                        // Wants up to ~120 tokens vs a 48-token pool below.
                        1 + rng.below(40),
                    )
                })
                .collect::<Vec<_>>()
        },
        |_| Vec::new(),
        |reqs| {
            let pool = KvPool::new(48, 8);
            let (tx, rx) = std::sync::mpsc::channel();
            let mut streams = Vec::new();
            for r in reqs.clone() {
                let (sub, erx, _cancel) = Submission::channel(r);
                tx.send(sub).unwrap();
                streams.push(erx);
            }
            drop(tx);
            let mut got = Vec::new();
            let mut n_rejected = 0usize;
            let metrics = aser::coordinator::batcher::run_batcher(
                &model,
                &pool,
                &BatchConfig::default(),
                rx,
                |req, reason| {
                    if reason == FinishReason::Rejected {
                        n_rejected += 1;
                    }
                    got.push(req.id);
                },
            );
            // Rejected streams must carry no Token events.
            for (i, erx) in streams.iter().enumerate() {
                let mut tokens = 0usize;
                let mut finish = None;
                while let Ok(ev) = erx.try_recv() {
                    match ev {
                        TokenEvent::Token { .. } => tokens += 1,
                        TokenEvent::Finished { reason, .. } => finish = Some(reason),
                        TokenEvent::PrefillDone { .. } => {}
                    }
                }
                match finish {
                    Some(FinishReason::Rejected) => {
                        assert_eq!(tokens, 0, "rejected stream {i} with tokens")
                    }
                    Some(_) => assert!(tokens > 0, "served stream {i} without tokens"),
                    None => panic!("stream {i} missing terminal event"),
                }
            }
            got.sort_unstable();
            let want: Vec<u64> = (0..reqs.len() as u64).collect();
            all(vec![
                ensure(got == want, || format!("ids {got:?} != {want:?}")),
                ensure(pool.used_tokens() == 0, || "kv leak".into()),
                ensure(metrics.rejected_impossible == n_rejected, || {
                    format!(
                        "rejected metric {} != rejected responses {n_rejected}",
                        metrics.rejected_impossible
                    )
                }),
                ensure(metrics.requests + n_rejected == reqs.len(), || {
                    format!(
                        "admitted {} + rejected {n_rejected} != {}",
                        metrics.requests,
                        reqs.len()
                    )
                }),
            ])
        },
    );
}

#[test]
fn prop_engine_greedy_matches_pre_redesign_serving() {
    // Acceptance bar for the Engine redesign: greedy generation through
    // Engine::submit reproduces the pre-redesign batch-and-drain outputs
    // token-for-token on quantized models, across the serving method grid
    // and both activation widths. `generate_greedy` is the oracle (the old
    // serve_requests was pinned to it); prompts are window-safe
    // (prompt + max_new + 1 < max_seq) so no path hits the KV boundary.
    use aser::calib::CalibConfig;
    use aser::coordinator::{
        calibrate_model, run_ptq, BatchConfig, Engine, EngineConfig, GenRequest,
    };
    use aser::model::synthetic_model;
    use std::sync::Arc;

    let base = synthetic_model("micro", 917).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 33 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let mut rng = Pcg64::seed(0xE16);
    for method in ["rtn", "aser", "smoothquant"] {
        for prec in [Precision::w4a8(), Precision::w4a16()] {
            let m = method_by_name(method, RankPolicy::Fixed(6), 4).unwrap();
            let model = synthetic_model("micro", 917).unwrap();
            let (qm, _) = run_ptq(model, &stats, m.as_ref(), prec, 0).unwrap();
            let qm = Arc::new(qm);
            let prompts: Vec<Vec<u32>> = (0..3)
                .map(|_| (0..4 + rng.below(12)).map(|_| 2 + rng.below(120) as u32).collect())
                .collect();
            let max_new = 6usize;
            let want: Vec<Vec<u32>> =
                prompts.iter().map(|p| qm.generate_greedy(p, max_new)).collect();
            let engine = Engine::new(
                Arc::clone(&qm),
                EngineConfig {
                    workers: 2,
                    // generate_greedy has no EOS early-out, so disable it
                    // here too for exact stream equality.
                    batch: BatchConfig { stop_on_eos: false, ..Default::default() },
                    kv_tokens: 4096,
                    ..Default::default()
                },
            );
            let handles: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| engine.submit(GenRequest::new(i as u64, p.clone(), max_new)).unwrap())
                .collect();
            for h in handles {
                let r = h.wait();
                assert!(r.finish.is_completed(), "{method} {prec}: {:?}", r.finish);
                assert_eq!(
                    r.tokens, want[r.id as usize],
                    "{method} {prec} req {}: engine diverged from pre-redesign greedy",
                    r.id
                );
            }
            assert_eq!(engine.kv_used_tokens(), 0);
            engine.shutdown();
        }
    }
}

#[test]
fn prop_seeded_sampling_reproducible_across_batch_shapes() {
    // A seeded sampled request must emit the same token stream regardless
    // of scheduling: chunk widths, token budgets, and co-scheduled traffic
    // must not perturb it. Holds because (a) the quantized forward is
    // bitwise identical across batch shapes and chunkings and (b) each
    // request's sampler consumes exactly one RNG draw per non-greedy token
    // from its private stream.
    use aser::calib::CalibConfig;
    use aser::coordinator::{
        calibrate_model, run_ptq, BatchConfig, FinishReason, GenRequest, KvPool, Submission,
        TokenEvent,
    };
    use aser::model::{synthetic_model, SamplingParams};

    let base = synthetic_model("micro", 919).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 37 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let m = method_by_name("aser", RankPolicy::Fixed(6), 4).unwrap();
    let (qm, _) =
        run_ptq(synthetic_model("micro", 919).unwrap(), &stats, m.as_ref(), Precision::w4a8(), 0)
            .unwrap();

    // Serve `target` (plus optional co-traffic) through one batcher run
    // under `bcfg`; return the target's token stream.
    let serve_one = |target: GenRequest, extra: Vec<GenRequest>, bcfg: BatchConfig| -> Vec<u32> {
        let pool = KvPool::new(10_000, 8);
        let (tx, rx) = std::sync::mpsc::channel();
        let (sub, erx, _c) = Submission::channel(target);
        tx.send(sub).unwrap();
        let mut co = Vec::new();
        for r in extra {
            let (sub, erx, _c) = Submission::channel(r);
            tx.send(sub).unwrap();
            co.push(erx);
        }
        drop(tx);
        aser::coordinator::batcher::run_batcher(&qm, &pool, &bcfg, rx, |_, _| {});
        assert_eq!(pool.used_tokens(), 0);
        let mut tokens = Vec::new();
        while let Ok(ev) = erx.try_recv() {
            match ev {
                TokenEvent::Token { token, .. } => tokens.push(token),
                TokenEvent::Finished { reason, .. } => {
                    assert_ne!(reason, FinishReason::Rejected)
                }
                TokenEvent::PrefillDone { .. } => {}
            }
        }
        tokens
    };

    check(
        "seeded_sampling_batch_shape_invariant",
        &cfg(6),
        |rng| {
            let plen = 2 + rng.below(14);
            let prompt: Vec<u32> = (0..plen).map(|_| 2 + rng.below(120) as u32).collect();
            let params = SamplingParams {
                temperature: 0.3 + rng.f32() * 2.5,
                top_k: if rng.f32() < 0.5 { 1 + rng.below(32) } else { 0 },
                top_p: if rng.f32() < 0.5 { 0.5 + 0.5 * rng.f32() } else { 1.0 },
                seed: rng.next_u64(),
                stop_tokens: Vec::new(),
            };
            let max_new = 2 + rng.below(8);
            (prompt, params, max_new)
        },
        |_| Vec::new(),
        |(prompt, params, max_new)| {
            let req = || {
                let mut r = GenRequest::new(0, prompt.clone(), *max_new);
                r.sampling = params.clone();
                r
            };
            let co = |n: usize| -> Vec<GenRequest> {
                (0..n)
                    .map(|i| GenRequest::new(10 + i as u64, vec![3 + i as u32, 5, 8], 4))
                    .collect()
            };
            let wide = serve_one(
                req(),
                Vec::new(),
                BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() },
            );
            let narrow = serve_one(
                req(),
                Vec::new(),
                BatchConfig {
                    max_batch: 4,
                    prefill_chunk: 1,
                    token_budget: 2,
                    stop_on_eos: false,
                    ..Default::default()
                },
            );
            let traffic = serve_one(
                req(),
                co(3),
                BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() },
            );
            all(vec![
                ensure(wide == narrow, || {
                    format!("chunking changed sampled stream: {wide:?} vs {narrow:?}")
                }),
                ensure(wide == traffic, || {
                    format!("co-traffic changed sampled stream: {wide:?} vs {traffic:?}")
                }),
                ensure(wide.len() == *max_new, || {
                    format!("expected {max_new} tokens, got {}", wide.len())
                }),
            ])
        },
    );
}

#[test]
fn prop_temperature_to_zero_pins_argmax_path() {
    // SamplingParams::greedy() and any temperature below the greedy
    // epsilon must reproduce the old hardwired-argmax batcher stream
    // token-for-token (oracle: generate_greedy, which the pre-redesign
    // serve_requests was pinned to).
    use aser::calib::CalibConfig;
    use aser::coordinator::{
        calibrate_model, run_ptq, BatchConfig, GenRequest, KvPool, Submission, TokenEvent,
    };
    use aser::model::{synthetic_model, SamplingParams};

    let base = synthetic_model("micro", 923).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 41 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let m = method_by_name("aser", RankPolicy::Fixed(6), 4).unwrap();
    let (qm, _) =
        run_ptq(synthetic_model("micro", 923).unwrap(), &stats, m.as_ref(), Precision::w4a8(), 0)
            .unwrap();

    check(
        "temperature_zero_is_argmax",
        &cfg(8),
        |rng| {
            let plen = 2 + rng.below(12);
            let prompt: Vec<u32> = (0..plen).map(|_| 2 + rng.below(120) as u32).collect();
            // 0.0 exactly, plus strictly-positive values under the epsilon.
            let temperature = [0.0f32, 1e-6, 1e-4, 9e-4][rng.below(4)];
            let seed = rng.next_u64();
            (prompt, temperature, seed)
        },
        |_| Vec::new(),
        |(prompt, temperature, seed)| {
            let max_new = 6usize;
            let want = qm.generate_greedy(prompt, max_new);
            let mut r = GenRequest::new(0, prompt.clone(), max_new);
            r.sampling = if *temperature == 0.0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::with_temperature(*temperature, *seed)
            };
            let pool = KvPool::new(10_000, 8);
            let (tx, rx) = std::sync::mpsc::channel();
            let (sub, erx, _c) = Submission::channel(r);
            tx.send(sub).unwrap();
            drop(tx);
            let bcfg = BatchConfig { stop_on_eos: false, ..Default::default() };
            aser::coordinator::batcher::run_batcher(&qm, &pool, &bcfg, rx, |_, _| {});
            let mut tokens = Vec::new();
            while let Ok(ev) = erx.try_recv() {
                if let TokenEvent::Token { token, .. } = ev {
                    tokens.push(token);
                }
            }
            ensure(tokens == want, || {
                format!("t={temperature}: {tokens:?} != argmax stream {want:?}")
            })
        },
    );
}

#[test]
fn prop_cancellation_returns_full_kv_lease() {
    // Under random cancel streams — flags raised at random points while
    // the batcher runs — every stream still gets exactly one terminal
    // event, cancelled streams stop early, and the pool drains completely
    // (capacity restored, no leaked leases).
    use aser::coordinator::{BatchConfig, GenRequest, KvPool, Submission, TokenEvent};
    use aser::model::synthetic_model;
    use std::sync::atomic::Ordering;

    let mut model = synthetic_model("micro", 929).unwrap();
    model.cfg.max_seq = 4096; // room to decode until cancelled
    model.refresh_derived();

    check(
        "cancel_frees_kv",
        &cfg(6),
        |rng| {
            let n = 2 + rng.below(5);
            (0..n)
                .map(|_| {
                    let plen = 2 + rng.below(10);
                    let prompt: Vec<u32> =
                        (0..plen).map(|_| 2 + rng.below(120) as u32).collect();
                    // cancel_after: raise the flag after this many observed
                    // tokens; None = never cancel.
                    let cancel_after =
                        if rng.f32() < 0.7 { Some(rng.below(6)) } else { None };
                    (prompt, 400usize, cancel_after)
                })
                .collect::<Vec<_>>()
        },
        |_| Vec::new(),
        |reqs| {
            let pool = KvPool::new(10_000, 8);
            let bcfg = BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() };
            let (tx, rx) = std::sync::mpsc::channel();
            let mut streams = Vec::new();
            for (i, (prompt, max_new, cancel_after)) in reqs.iter().enumerate() {
                let (sub, erx, cancel) =
                    Submission::channel(GenRequest::new(i as u64, prompt.clone(), *max_new));
                tx.send(sub).unwrap();
                streams.push((erx, cancel, *cancel_after));
            }
            drop(tx);
            // Immediate cancels (trigger 0) are raised before serving even
            // starts — they may land while the request is still queued.
            for (_, cancel, cancel_after) in streams.iter() {
                if *cancel_after == Some(0) {
                    cancel.store(true, Ordering::Release);
                }
            }
            let ok = std::thread::scope(|scope| {
                let worker = scope.spawn(|| {
                    aser::coordinator::batcher::run_batcher(&model, &pool, &bcfg, rx, |_, _| {})
                });
                // Watch every stream concurrently (round-robin polling) so
                // each cancel flag is raised as soon as its trigger count
                // of tokens has streamed — sequential blocking drains would
                // let later streams run to completion first.
                let mut seen = vec![0usize; streams.len()];
                let mut results = vec![None; streams.len()];
                let mut done = vec![false; streams.len()];
                let mut open = streams.len();
                while open > 0 {
                    let mut advanced = false;
                    for (i, (erx, cancel, cancel_after)) in streams.iter().enumerate() {
                        if done[i] {
                            continue;
                        }
                        loop {
                            match erx.try_recv() {
                                Ok(TokenEvent::Token { .. }) => {
                                    advanced = true;
                                    seen[i] += 1;
                                    if *cancel_after == Some(seen[i]) {
                                        cancel.store(true, Ordering::Release);
                                    }
                                }
                                Ok(TokenEvent::Finished { reason, n_tokens, .. }) => {
                                    advanced = true;
                                    results[i] = Some((reason, n_tokens));
                                    done[i] = true;
                                    open -= 1;
                                    break;
                                }
                                Ok(TokenEvent::PrefillDone { .. }) => advanced = true,
                                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                    // Worker died without a terminal event
                                    // (a batcher bug): stop polling so the
                                    // join below surfaces the panic.
                                    advanced = true;
                                    done[i] = true;
                                    open -= 1;
                                    break;
                                }
                            }
                        }
                    }
                    if !advanced && open > 0 {
                        std::thread::yield_now();
                    }
                }
                worker.join().expect("batcher panicked");
                results
            });
            let mut checks = vec![ensure(pool.used_tokens() == 0, || "kv tokens leaked".into())];
            checks.push(ensure(pool.live_leases() == 0, || "leases leaked".into()));
            for (i, r) in ok.iter().enumerate() {
                let Some((reason, n_tokens)) = r else {
                    return CaseResult::Fail(format!("stream {i} missing terminal event"));
                };
                let (_, max_new, cancel_after) = &reqs[i];
                if cancel_after.is_some() {
                    // A cancelled stream must have stopped well short of
                    // its 400-token budget (flag swept within one
                    // iteration of being raised — the consumer loop keeps
                    // pace with generation).
                    checks.push(ensure(*n_tokens < *max_new, || {
                        format!(
                            "stream {i}: cancel at {cancel_after:?} but ran to {n_tokens}/{max_new} ({reason:?})"
                        )
                    }));
                }
            }
            all(checks)
        },
    );
}

#[test]
fn prop_int8_attn_simd_kernel_matches_scalar_bitwise() {
    // The int8 fused-dequant span kernels accumulate q·K and P·V with
    // exact integer dots and a writeback expression kept character-
    // identical across implementations, so SIMD vs scalar is a BITWISE
    // contract — stricter than the f32 kernels' tolerance contract.
    // Trivially true on scalar-only hosts (same kernel both sides).
    use aser::tensor::{attn_head_span_int8, detect_attn_kernel, AttnKernelKind};
    let kind = detect_attn_kernel();
    check(
        "int8_attn_simd_vs_scalar_bitwise",
        &cfg(48),
        |rng| {
            let hd = 1 + rng.below(33); // straddles both SIMD chunk widths
            let nh = 1 + rng.below(3);
            let pos0 = rng.below(70);
            let t = [1usize, 3, 8][rng.below(3)];
            let d = nh * hd;
            let code = |rng: &mut Pcg64| (rng.below(255) as i32 - 127) as i8;
            let sc = |rng: &mut Pcg64| 0.01 + rng.below(1000) as f32 * 1e-3;
            let q: Vec<i8> = (0..t * d).map(|_| code(rng)).collect();
            let q_scales: Vec<f32> = (0..t * nh).map(|_| sc(rng)).collect();
            let keys: Vec<i8> = (0..(pos0 + t) * hd).map(|_| code(rng)).collect();
            let k_scales: Vec<f32> = (0..pos0 + t).map(|_| sc(rng)).collect();
            let values: Vec<i8> = (0..(pos0 + t) * hd).map(|_| code(rng)).collect();
            let v_scales: Vec<f32> = (0..pos0 + t).map(|_| sc(rng)).collect();
            (hd, nh, pos0, t, q, q_scales, keys, k_scales, values, v_scales)
        },
        |_| Vec::new(),
        |(hd, nh, pos0, t, q, q_scales, keys, k_scales, values, v_scales)| {
            let (hd, nh, pos0, t) = (*hd, *nh, *pos0, *t);
            let d = nh * hd;
            let scale = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0f32; pos0 + t];
            for head in 0..nh {
                let mut want = vec![7f32; t * hd];
                attn_head_span_int8(
                    AttnKernelKind::Scalar,
                    q,
                    q_scales,
                    nh,
                    head,
                    d,
                    head * hd,
                    hd,
                    pos0,
                    t,
                    keys,
                    k_scales,
                    values,
                    v_scales,
                    scale,
                    &mut scores,
                    &mut want,
                );
                let mut got = vec![7f32; t * hd];
                attn_head_span_int8(
                    kind,
                    q,
                    q_scales,
                    nh,
                    head,
                    d,
                    head * hd,
                    hd,
                    pos0,
                    t,
                    keys,
                    k_scales,
                    values,
                    v_scales,
                    scale,
                    &mut scores,
                    &mut got,
                );
                if got != want {
                    return CaseResult::Fail(format!(
                        "{kind} hd={hd} nh={nh} pos0={pos0} t={t} head={head}: \
                         int8 span not bitwise-equal to scalar"
                    ));
                }
            }
            CaseResult::Pass
        },
    );
}

#[test]
fn prop_int8_attn_matches_f32_within_tolerance() {
    // Quantizing K/V (and q) to int8 must leave the attention output close
    // to the f32 span on the same data — the per-tile scales keep the
    // fused-dequant path within ~1% of range; 0.1·|out|max is a loose
    // ceiling that still catches scale-indexing or layout bugs.
    use aser::quant::quantize_tile;
    use aser::tensor::{attn_head_span, attn_head_span_int8, detect_attn_kernel};
    let kind = detect_attn_kernel();
    check(
        "int8_attn_tracks_f32",
        &cfg(48),
        |rng| {
            let hd = 1 + rng.below(33);
            let pos0 = rng.below(70);
            let t = [1usize, 3, 8][rng.below(3)];
            let q: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
            let keys: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
            let values: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
            (hd, pos0, t, q, keys, values)
        },
        |_| Vec::new(),
        |(hd, pos0, t, q, keys, values)| {
            let (hd, pos0, t) = (*hd, *pos0, *t);
            let slen = pos0 + t;
            let scale = 1.0 / (hd as f32).sqrt();
            let mut q_codes = vec![0i8; t * hd];
            let mut q_scales = vec![0f32; t];
            for j in 0..t {
                q_scales[j] =
                    quantize_tile(&q[j * hd..(j + 1) * hd], 8, &mut q_codes[j * hd..(j + 1) * hd]);
            }
            let mut k_codes = vec![0i8; slen * hd];
            let mut k_scales = vec![0f32; slen];
            let mut v_codes = vec![0i8; slen * hd];
            let mut v_scales = vec![0f32; slen];
            for p in 0..slen {
                k_scales[p] = quantize_tile(
                    &keys[p * hd..(p + 1) * hd],
                    8,
                    &mut k_codes[p * hd..(p + 1) * hd],
                );
                v_scales[p] = quantize_tile(
                    &values[p * hd..(p + 1) * hd],
                    8,
                    &mut v_codes[p * hd..(p + 1) * hd],
                );
            }
            let mut scores = vec![0f32; slen];
            let mut want = vec![0f32; t * hd];
            attn_head_span(
                kind, q, hd, 0, hd, pos0, t, keys, values, scale, &mut scores, &mut want,
            );
            let mut got = vec![0f32; t * hd];
            attn_head_span_int8(
                kind,
                &q_codes,
                &q_scales,
                1,
                0,
                hd,
                0,
                hd,
                pos0,
                t,
                &k_codes,
                &k_scales,
                &v_codes,
                &v_scales,
                scale,
                &mut scores,
                &mut got,
            );
            let wmax = want.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1.0);
            let diff = got.iter().zip(&want).fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            ensure(diff < 0.1 * wmax, || {
                format!("hd={hd} pos0={pos0} t={t}: int8 span drifted {diff} from f32")
            })
        },
    );
}

#[test]
fn prop_int8_kv_chunking_invariant_and_survives_repack() {
    // The int8-cache serving path end to end: feeding a span through
    // forward_chunk_batch against a NON-EMPTY int8 cache must reproduce
    // the token-at-a-time forward_step replay on the same int8 cache.
    // History 60 + tail 12 crosses the KV_TILE = 64 grow quantum, so the
    // tail exercises `reserve`'s repack with live quantized codes+scales
    // mid-sequence. Tolerance is looser than the f32 twin (3e-2 vs 1e-4):
    // write-time quantization sits on rounding knife-edges that tiny
    // batch-shape f32 differences can flip by one code.
    use aser::model::{synthetic_model, ChunkLogits, KvCache, KvDtype, SeqChunk};
    use aser::tensor::QGemmArena;
    let model = synthetic_model("micro", 921).unwrap();
    let history: Vec<u32> = (0..60).map(|i| 1 + (i * 5 % 120) as u32).collect();
    let tail: Vec<u32> = (0..12).map(|i| 2 + (i * 11 % 110) as u32).collect();
    let mut pre_cache = KvCache::new_with(&model.cfg, KvDtype::Int8);
    for &t in &history {
        model.forward_step(t, &mut pre_cache);
    }
    let mut want = Vec::new();
    let mut ref_cache = pre_cache.clone();
    for &t in &tail {
        want = model.forward_step(t, &mut ref_cache);
    }
    let wmax = want.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1.0);
    for chunk in [1usize, 3, tail.len()] {
        let mut cache = pre_cache.clone();
        let mut arena = QGemmArena::new();
        let mut got = Vec::new();
        let mut fed = 0usize;
        while fed < tail.len() {
            let end = (fed + chunk).min(tail.len());
            let last = end == tail.len();
            let span = [SeqChunk {
                tokens: &tail[fed..end],
                logits: if last { ChunkLogits::Last } else { ChunkLogits::None },
            }];
            let out = model.forward_chunk_batch(&span, &mut [&mut cache], &mut arena);
            if last {
                got = out.row(0).to_vec();
            }
            fed = end;
        }
        assert_eq!(cache.seen, history.len() + tail.len());
        let d = want.iter().zip(&got).fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
        assert!(d < 3e-2 * wmax, "int8 chunk={chunk}: maxdiff {d}");
    }
}

#[test]
fn prop_engine_int8_greedy_matches_step_oracle() {
    // Dtype threading end to end: an Engine configured with an int8 KV
    // cache (pool sized with int8 bytes/token, batcher admitting int8
    // caches, attention on the fused-dequant kernels) must reproduce the
    // token-at-a-time int8 forward_step oracle exactly. RTN W4A8 keeps the
    // whole forward on the packed int path, which is bitwise identical per
    // row across batch shapes, so stream equality is deterministic.
    // (Exact int8-vs-f32 stream equality is NOT asserted — KV quantization
    // can legitimately flip near-tied argmaxes; that quality bound is
    // gated by the eval suite's relative perplexity-drift test instead.)
    use aser::calib::CalibConfig;
    use aser::coordinator::{
        calibrate_model, run_ptq, BatchConfig, Engine, EngineConfig, GenRequest,
    };
    use aser::model::{argmax, synthetic_model, KvCache, KvDtype};
    use std::sync::Arc;

    let base = synthetic_model("micro", 923).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 39 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let m = method_by_name("rtn", RankPolicy::Fixed(6), 4).unwrap();
    let (qm, _) =
        run_ptq(synthetic_model("micro", 923).unwrap(), &stats, m.as_ref(), Precision::w4a8(), 0)
            .unwrap();
    let qm = Arc::new(qm);
    let mut rng = Pcg64::seed(0x18E);
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..3 + rng.below(3)).map(|_| 2 + rng.below(120) as u32).collect())
        .collect();
    let max_new = 6usize;
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| {
            let mut cache = KvCache::new_with(&qm.cfg, KvDtype::Int8);
            let mut logits = Vec::new();
            for &t in p {
                logits = qm.forward_step(t, &mut cache);
            }
            let mut toks = Vec::new();
            for _ in 0..max_new {
                let next = argmax(&logits) as u32;
                toks.push(next);
                logits = qm.forward_step(next, &mut cache);
            }
            toks
        })
        .collect();
    let engine = Engine::new(
        Arc::clone(&qm),
        EngineConfig {
            workers: 1,
            batch: BatchConfig {
                stop_on_eos: false,
                kv_dtype: KvDtype::Int8,
                ..Default::default()
            },
            kv_tokens: 4096,
            ..Default::default()
        },
    );
    let handles: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| engine.submit(GenRequest::new(i as u64, p.clone(), max_new)).unwrap())
        .collect();
    for h in handles {
        let r = h.wait();
        assert!(r.finish.is_completed(), "int8 engine: {:?}", r.finish);
        assert_eq!(
            r.tokens, want[r.id as usize],
            "req {}: int8 engine diverged from int8 step oracle",
            r.id
        );
    }
    assert_eq!(engine.kv_used_tokens(), 0);
    engine.shutdown();
}

#[test]
fn prop_kv_pages_never_leak_under_admit_grow_cancel() {
    // Page-accounting safety under random admit/grow/cancel/rewrite streams
    // over both KV dtypes: the physical-page meter, lease accounting, and
    // trie-cached token count stay consistent at every step, nothing leaks
    // at drain, and clearing the prefix cache releases every page. Double
    // frees panic inside KvPool::free, so mere completion covers that half
    // of the invariant; the COW arm rewrites trie-shared pages, so a missed
    // copy would panic at the shared-page write.
    use aser::coordinator::kvpool::{KvCache, Lease, KV_TILE};
    use aser::coordinator::KvPool;
    use aser::model::{KvDtype, ModelConfig};

    let mcfg = ModelConfig::by_name("micro").unwrap();
    // Shared two-page preambles (one per family) so admissions actually
    // collide in the trie; families map to a fixed dtype so every trie
    // path stays dtype-consistent.
    let preamble =
        |fam: usize| -> Vec<u32> { (0..2 * KV_TILE).map(|i| (1 + fam * 1000 + i) as u32).collect() };
    check(
        "kv_page_refcount_invariants",
        &cfg(16),
        |rng| {
            (0..8 + rng.below(40))
                .map(|_| (rng.below(100) as u8, rng.below(4), rng.below(KV_TILE)))
                .collect::<Vec<(u8, usize, usize)>>()
        },
        |_| Vec::new(),
        |ops| {
            let pool = KvPool::new(64 * KV_TILE, 8);
            let mut live: Vec<(Lease, KvCache)> = Vec::new();
            // Reserve + write one (layer 0, head 0) row per position —
            // enough to drive the COW gate without filling every panel.
            let fill = |cache: &mut KvCache, from: usize, to: usize| {
                cache.reserve(to);
                for p in from..to {
                    match cache.dtype() {
                        KvDtype::F32 => {
                            let (k, v) = cache.kv_row_mut(0, 0, p);
                            k.fill(p as f32);
                            v.fill(-(p as f32));
                        }
                        KvDtype::Int8 => {
                            let (kc, vc, ks, vs) = cache.kv_row_quant_mut(0, 0, p);
                            kc.fill((p % 127) as i8);
                            vc.fill(-((p % 127) as i8));
                            *ks = 1.0;
                            *vs = 1.0;
                        }
                    }
                }
                cache.seen = to;
            };
            for &(kind, sel, len) in ops {
                match kind {
                    0..=49 => {
                        // Admit: family preamble + tail (tails of one family
                        // nest, so trie paths deepen across admits), matched
                        // against the trie, suffix-prefilled, republished.
                        let dtype = if sel % 2 == 0 { KvDtype::F32 } else { KvDtype::Int8 };
                        let mut prompt = preamble(sel);
                        prompt.extend((0..1 + len).map(|i| (50_000 + sel * 100 + i) as u32));
                        let (matched, pages) = pool.match_prefix(&prompt, dtype);
                        let Some(lease) = pool.alloc(prompt.len() + 4) else { continue };
                        let mut cache = pool.new_cache(&mcfg, dtype, pages, lease.tokens);
                        assert_eq!(cache.seen, matched, "cache starts at the matched prefix");
                        fill(&mut cache, matched, prompt.len());
                        pool.insert_prefix(&prompt, &cache);
                        live.push((lease, cache));
                    }
                    50..=74 => {
                        // Decode: grow one live sequence by a few tokens.
                        if live.is_empty() {
                            continue;
                        }
                        let i = sel % live.len();
                        let (lease, cache) = &mut live[i];
                        let extra = 1 + len % 4;
                        if pool.grow(lease, extra) {
                            let s = cache.seen;
                            fill(cache, s, s + extra);
                        }
                    }
                    75..=89 => {
                        // Cancel/finish: drop the cache, return the lease.
                        if live.is_empty() {
                            continue;
                        }
                        let i = sel % live.len();
                        let (lease, cache) = live.swap_remove(i);
                        drop(cache);
                        pool.free(lease);
                    }
                    _ => {
                        // Truncate-and-rewrite inside the (possibly
                        // trie-shared) leading pages — the COW path: the
                        // trie keeps its page, the sequence rewrites a
                        // private copy.
                        if live.is_empty() {
                            continue;
                        }
                        let i = sel % live.len();
                        let (_, cache) = &mut live[i];
                        let cut = len.min(cache.seen.saturating_sub(1));
                        let s = cache.seen;
                        cache.truncate(cut);
                        fill(cache, cut, s);
                    }
                }
                let (used, cached, cap) =
                    (pool.used_tokens(), pool.cached_tokens(), pool.capacity_tokens());
                if used + cached > cap {
                    return CaseResult::Fail(format!("overcommit: {used} + {cached} > {cap}"));
                }
                if pool.live_pages() < cached / KV_TILE {
                    return CaseResult::Fail(format!(
                        "page meter {} below trie floor {}",
                        pool.live_pages(),
                        cached / KV_TILE
                    ));
                }
            }
            for (lease, cache) in live.drain(..) {
                drop(cache);
                pool.free(lease);
            }
            // With every sequence gone, each trie node pins exactly one
            // physical page — any surplus in the meter is a leaked page.
            let trie_pages = pool.cached_tokens() / KV_TILE;
            let drained = all(vec![
                ensure(pool.used_tokens() == 0, || "leased tokens leaked at drain".into()),
                ensure(pool.live_leases() == 0, || "leases leaked at drain".into()),
                ensure(pool.live_pages() == trie_pages, || {
                    format!(
                        "{} physical pages alive vs {} trie pages: cache pages leaked",
                        pool.live_pages(),
                        trie_pages
                    )
                }),
            ]);
            pool.clear_prefix_cache();
            all(vec![
                drained,
                ensure(pool.cached_tokens() == 0, || "cached tokens survive clear".into()),
                ensure(pool.live_pages() == 0, || {
                    format!("{} pages alive after clear + drain", pool.live_pages())
                }),
            ])
        },
    );
}

#[test]
fn prop_prefix_cache_on_off_streams_bitwise_identical() {
    // The prefix cache must be a pure compute optimization: with identical
    // requests, an engine with the cache on — cold AND warm (second wave
    // adopting trie pages) — emits exactly the token streams of an engine
    // with it off, for greedy and seeded-sampling requests alike. Holds
    // because per-position attention and per-position int8 quantization are
    // chunking-invariant, so a cached page is bit-identical to a recomputed
    // one and suffix-only prefill is just another chunking; samplers still
    // consume one private-stream draw per non-greedy token.
    use aser::coordinator::kvpool::KV_TILE;
    use aser::coordinator::{BatchConfig, Engine, EngineConfig, GenRequest};
    use aser::model::{synthetic_model, KvDtype, SamplingParams};
    use std::sync::Arc;

    let mut model = synthetic_model("micro", 931).unwrap();
    model.cfg.max_seq = 512; // room for two-page shared prompts (micro is 64)
    model.refresh_derived();
    let model = Arc::new(model);

    // Six requests per wave sharing a two-page preamble; tails differ per
    // request, and odd ids sample at temperature with a fixed seed.
    let preamble: Vec<u32> = (0..2 * KV_TILE).map(|i| 2 + (i * 13 % 110) as u32).collect();
    let mk_reqs = || -> Vec<GenRequest> {
        (0..6usize)
            .map(|r| {
                let mut prompt = preamble.clone();
                prompt.extend((0..4 + r).map(|t| 2 + ((r * 37 + t * 11) % 110) as u32));
                let mut req = GenRequest::new(r as u64, prompt, 6);
                if r % 2 == 1 {
                    req.sampling = SamplingParams {
                        temperature: 0.9,
                        top_k: 0,
                        top_p: 1.0,
                        seed: 1000 + r as u64,
                        stop_tokens: Vec::new(),
                    };
                }
                req
            })
            .collect()
    };
    let run_wave = |engine: &Engine| -> Vec<Vec<u32>> {
        let handles: Vec<_> = mk_reqs().into_iter().map(|r| engine.submit(r).unwrap()).collect();
        let mut out = vec![Vec::new(); handles.len()];
        for h in handles {
            let r = h.wait();
            assert!(r.finish.is_completed(), "req {}: {:?}", r.id, r.finish);
            out[r.id as usize] = r.tokens;
        }
        out
    };

    for kv_dtype in [KvDtype::F32, KvDtype::Int8] {
        let mk_engine = |prefix_cache: bool| {
            Engine::new(
                Arc::clone(&model),
                EngineConfig {
                    workers: 1,
                    batch: BatchConfig {
                        stop_on_eos: false,
                        kv_dtype,
                        prefix_cache,
                        ..Default::default()
                    },
                    kv_tokens: 1 << 13,
                    ..Default::default()
                },
            )
        };
        let off = mk_engine(false);
        let want = run_wave(&off);
        let off_metrics = off.shutdown();
        let off_hits: usize = off_metrics.iter().map(|m| m.prefix_hit_tokens).sum();
        assert_eq!(off_hits, 0, "{kv_dtype}: cache-off engine reported prefix hits");

        let on = mk_engine(true);
        let cold = run_wave(&on);
        let warm = run_wave(&on); // same prompts → trie hits on the preamble
        assert_eq!(on.kv_used_tokens(), 0, "{kv_dtype}: leases must drain");
        assert!(on.kv_cached_tokens() > 0, "{kv_dtype}: trie must retain the preamble");
        let on_metrics = on.shutdown();
        let hits: usize = on_metrics.iter().map(|m| m.prefix_hit_tokens).sum();
        assert!(
            hits >= 2 * KV_TILE,
            "{kv_dtype}: warm wave reused only {hits} prefix tokens"
        );

        assert_eq!(cold, want, "{kv_dtype}: prefix-cache on (cold) diverged from off");
        assert_eq!(warm, want, "{kv_dtype}: prefix-cache warm wave diverged from off");
    }
}

#[test]
fn prop_accept_is_sample_plus_comparison() {
    // The speculative acceptance draw IS the sampling draw: two samplers
    // with identical params and seed, one stepped with `sample` and one
    // with `accept`, emit identical token streams whatever the proposals
    // are. At (or under) the greedy temperature epsilon the accepted token
    // is exactly the argmax, so temperature → 0 acceptance degenerates to
    // argmax equality with the proposal.
    use aser::model::{argmax, Sampler, SamplingParams};

    check(
        "accept_is_sample_plus_comparison",
        &cfg(64),
        |rng| {
            let steps = 1 + rng.below(8);
            let vocab = 4 + rng.below(60);
            let rows: Vec<Vec<f32>> = (0..steps)
                .map(|_| (0..vocab).map(|_| rng.heavy_tailed(0.5, 8.0)).collect())
                .collect();
            let drafts: Vec<u32> = (0..steps).map(|_| rng.below(vocab) as u32).collect();
            // 0 / sub-epsilon pin the argmax path; the rest draw for real.
            let temperature = [0.0f32, 5e-4, 0.7, 1.8][rng.below(4)];
            let seed = rng.next_u64();
            (rows, drafts, temperature, seed)
        },
        |_| Vec::new(),
        |(rows, drafts, temperature, seed)| {
            let params = if *temperature == 0.0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::with_temperature(*temperature, *seed)
            };
            let mut plain = Sampler::new(&params);
            let mut spec = Sampler::new(&params);
            let mut checks = Vec::new();
            for (row, &d) in rows.iter().zip(drafts) {
                let want = plain.sample(row);
                let (got, ok) = spec.accept(row, d);
                checks.push(ensure(got == want, || {
                    format!("accept drew {got}, sample drew {want}")
                }));
                checks.push(ensure(ok == (got == d), || "acceptance flag lies".into()));
                if params.is_greedy() {
                    let am = argmax(row) as u32;
                    checks.push(ensure(got == am, || {
                        format!("greedy accept drew {got}, argmax is {am}")
                    }));
                    checks.push(ensure(ok == (d == am), || {
                        "greedy acceptance must be argmax equality".into()
                    }));
                }
            }
            all(checks)
        },
    );
}

#[test]
fn prop_speculative_streams_invariant_to_spec_k() {
    // spec_k is a pure scheduling knob: for mixed greedy + seeded sampled
    // requests on a quantized model with a truncated self-draft proposing,
    // the emitted streams (tokens AND finish reasons) are bitwise identical
    // for spec_k ∈ {0, 1, 2, 4}. Holds because every emitted token is still
    // one sampler draw, in stream order, from a target logits row computed
    // over exactly the already-emitted context (the verify pass), and the
    // quantized forward is bitwise chunking-invariant.
    use aser::calib::CalibConfig;
    use aser::coordinator::batcher::run_batcher_spec;
    use aser::coordinator::{
        calibrate_model, run_ptq, BatchConfig, FinishReason, GenRequest, KvPool, Submission,
        TokenEvent,
    };
    use aser::model::{synthetic_model, DraftModel, SamplingParams};
    use std::sync::Arc;

    let base = synthetic_model("micro", 941).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 43 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let m = method_by_name("aser", RankPolicy::Fixed(6), 4).unwrap();
    let (qm, _) =
        run_ptq(synthetic_model("micro", 941).unwrap(), &stats, m.as_ref(), Precision::w4a8(), 0)
            .unwrap();
    let qm = Arc::new(qm);
    let draft = DraftModel::self_draft(Arc::clone(&qm), 1).unwrap();

    let serve_k = |spec_k: usize, reqs: Vec<GenRequest>| -> Vec<(Vec<u32>, FinishReason)> {
        let pool = KvPool::new(10_000, 8);
        let bcfg =
            BatchConfig { max_batch: 4, stop_on_eos: false, spec_k, ..Default::default() };
        let (tx, rx) = std::sync::mpsc::channel();
        let rxs: Vec<_> = reqs
            .into_iter()
            .map(|r| {
                let (sub, erx, _c) = Submission::channel(r);
                tx.send(sub).unwrap();
                erx
            })
            .collect();
        drop(tx);
        let metrics = run_batcher_spec(&qm, Some(&draft), &pool, &bcfg, rx, |_, _| {});
        assert_eq!(pool.used_tokens(), 0, "kv leak at spec_k={spec_k}");
        if spec_k == 0 {
            assert_eq!(metrics.spec_drafted, 0, "spec_k=0 must not draft");
        } else {
            // Every request decodes ≥ 2 tokens, so at least one iteration
            // had headroom (max_new − emitted − 1 ≥ 1) to speculate.
            assert!(metrics.spec_drafted > 0, "spec_k={spec_k} never drafted");
            assert_eq!(
                metrics.spec_drafted,
                metrics.spec_accepted + metrics.spec_rejected,
                "draft counters must balance"
            );
        }
        rxs.iter()
            .map(|erx| {
                let mut toks = Vec::new();
                let mut fin = None;
                while let Ok(ev) = erx.try_recv() {
                    match ev {
                        TokenEvent::Token { token, .. } => toks.push(token),
                        TokenEvent::Finished { reason, .. } => fin = Some(reason),
                        TokenEvent::PrefillDone { .. } => {}
                    }
                }
                (toks, fin.expect("terminal event"))
            })
            .collect()
    };

    check(
        "spec_k_stream_invariance",
        &cfg(6),
        |rng| {
            (0..2 + rng.below(3))
                .map(|_| {
                    let plen = 2 + rng.below(10);
                    let prompt: Vec<u32> =
                        (0..plen).map(|_| 2 + rng.below(120) as u32).collect();
                    let max_new = 3 + rng.below(7);
                    let params = if rng.f32() < 0.4 {
                        SamplingParams::greedy()
                    } else {
                        SamplingParams {
                            temperature: 0.4 + rng.f32() * 2.0,
                            top_k: if rng.f32() < 0.5 { 1 + rng.below(32) } else { 0 },
                            top_p: if rng.f32() < 0.5 { 0.5 + 0.5 * rng.f32() } else { 1.0 },
                            seed: rng.next_u64(),
                            stop_tokens: Vec::new(),
                        }
                    };
                    (prompt, max_new, params)
                })
                .collect::<Vec<_>>()
        },
        |_| Vec::new(),
        |reqs| {
            let mk = || -> Vec<GenRequest> {
                reqs.iter()
                    .enumerate()
                    .map(|(i, (p, mn, s))| {
                        let mut r = GenRequest::new(i as u64, p.clone(), *mn);
                        r.sampling = s.clone();
                        r
                    })
                    .collect()
            };
            let want = serve_k(0, mk());
            let mut checks = Vec::new();
            for k in [1usize, 2, 4] {
                let got = serve_k(k, mk());
                checks.push(ensure(got == want, || {
                    format!("spec_k={k} changed streams:\n  {got:?}\nvs\n  {want:?}")
                }));
            }
            all(checks)
        },
    );
}

#[test]
fn prop_greedy_speculation_bitwise_across_method_grid() {
    // Greedy speculative serving must be bitwise identical to plain greedy
    // decoding (oracle: generate_greedy) across the quantization method
    // grid × both activation widths × spec_k ∈ {1, 2, 4}, with a truncated
    // self-draft proposing. The draft's quality only moves the acceptance
    // rate — never the stream.
    use aser::calib::CalibConfig;
    use aser::coordinator::batcher::run_batcher_spec;
    use aser::coordinator::{
        calibrate_model, run_ptq, BatchConfig, GenRequest, KvPool, Submission, TokenEvent,
    };
    use aser::model::{synthetic_model, DraftModel};
    use std::sync::Arc;

    let base = synthetic_model("micro", 947).unwrap();
    let ccfg = CalibConfig { n_seqs: 4, seq_len: 24, max_sample: 64, seed: 47 };
    let stats = calibrate_model(&base, "wiki", &ccfg).unwrap();
    let mut rng = Pcg64::seed(0x5bec);
    for method in ["rtn", "aser", "aser-er"] {
        for prec in [Precision::w4a8(), Precision::w4a16()] {
            let m = method_by_name(method, RankPolicy::Fixed(6), 4).unwrap();
            let (qm, _) =
                run_ptq(synthetic_model("micro", 947).unwrap(), &stats, m.as_ref(), prec, 0)
                    .unwrap();
            let qm = Arc::new(qm);
            let draft = DraftModel::self_draft(Arc::clone(&qm), 1).unwrap();
            let prompts: Vec<Vec<u32>> = (0..3)
                .map(|_| (0..3 + rng.below(10)).map(|_| 2 + rng.below(120) as u32).collect())
                .collect();
            let max_new = 7usize;
            let want: Vec<Vec<u32>> =
                prompts.iter().map(|p| qm.generate_greedy(p, max_new)).collect();
            for spec_k in [1usize, 2, 4] {
                let pool = KvPool::new(10_000, 8);
                let bcfg = BatchConfig {
                    max_batch: 4,
                    stop_on_eos: false,
                    spec_k,
                    ..Default::default()
                };
                let (tx, rx) = std::sync::mpsc::channel();
                let rxs: Vec<_> = prompts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        let (sub, erx, _c) =
                            Submission::channel(GenRequest::new(i as u64, p.clone(), max_new));
                        tx.send(sub).unwrap();
                        erx
                    })
                    .collect();
                drop(tx);
                let metrics = run_batcher_spec(&qm, Some(&draft), &pool, &bcfg, rx, |_, _| {});
                assert_eq!(pool.used_tokens(), 0, "{method} {prec} k={spec_k}: kv leak");
                assert!(metrics.spec_drafted > 0, "{method} {prec} k={spec_k}: no drafting");
                for (i, erx) in rxs.iter().enumerate() {
                    let mut toks = Vec::new();
                    while let Ok(ev) = erx.try_recv() {
                        if let TokenEvent::Token { token, .. } = ev {
                            toks.push(token);
                        }
                    }
                    assert_eq!(
                        toks, want[i],
                        "{method} {prec} spec_k={spec_k} req {i}: speculative greedy \
                         diverged from generate_greedy"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_identity() {
    // serialize ∘ parse = identity over random `Json` values — nested
    // containers, strings full of control characters / escapes / multibyte
    // UTF-8, and numbers across the whole finite f64 range (integer-exact
    // values, subnormals, f64::MAX). Non-finite numbers are the one
    // documented lossy case (JSON has no NaN/Infinity literal; the writer
    // emits null) and are pinned by json.rs unit tests, so the generator
    // stays finite. Both writers must round-trip: the compact one and the
    // pretty one (whitespace must parse away).
    use aser::util::json::Json;
    use std::collections::BTreeMap;

    // Characters that historically break hand-rolled JSON writers: every
    // escape class, raw control chars, DEL, multibyte, astral (surrogate
    // pairs in \u escapes), and the replacement char.
    const POOL: [char; 19] = [
        'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{0}', '\u{1}', '\u{1f}',
        '\u{7f}', 'é', 'Ω', '\u{2028}', '😀', '\u{fffd}',
    ];
    const EDGES: [f64; 12] = [
        0.0,
        -0.0,
        1.0,
        -1.0,
        0.1,
        123456.789,
        1e15,
        -1e15,
        1e300,
        5e-324, // smallest subnormal
        f64::MAX,
        f64::MIN_POSITIVE,
    ];

    fn gen_string(rng: &mut Pcg64) -> String {
        let n = rng.below(12);
        (0..n).map(|_| POOL[rng.below(POOL.len())]).collect()
    }

    fn gen_num(rng: &mut Pcg64) -> f64 {
        if rng.below(2) == 0 {
            EDGES[rng.below(EDGES.len())]
        } else {
            (rng.f64() * 2.0 - 1.0) * 10f64.powi(rng.below(61) as i32 - 30)
        }
    }

    fn gen_json(rng: &mut Pcg64, depth: usize) -> Json {
        // At depth 0 only leaves remain, so the tree always terminates.
        match rng.below(if depth == 0 { 4 } else { 6 }) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num(gen_num(rng)),
            3 => Json::Str(gen_string(rng)),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|_| (gen_string(rng), gen_json(rng, depth - 1)))
                    .collect::<BTreeMap<String, Json>>(),
            ),
        }
    }

    check(
        "json_roundtrip_identity",
        &cfg(256),
        |rng| gen_json(rng, 3),
        |_| Vec::new(),
        |v| {
            let compact = v.to_string_compact();
            let pretty = v.to_string_pretty();
            all(vec![
                ensure(Json::parse(&compact).ok().as_ref() == Some(v), || {
                    format!("compact roundtrip broke: {compact}")
                }),
                ensure(Json::parse(&pretty).ok().as_ref() == Some(v), || {
                    format!("pretty roundtrip broke: {pretty}")
                }),
            ])
        },
    );
}

#[test]
fn prop_json_surrogate_pair_escapes_parse() {
    // `\ud83d\ude00` is U+1F600 (😀) written as a UTF-16 surrogate pair —
    // the one escape form that needs pairing logic in the parser — and the
    // writer's output for the decoded char must itself re-parse equal.
    use aser::util::json::Json;
    let v = Json::parse(r#""\ud83d\ude00 ok""#).unwrap();
    assert_eq!(v, Json::Str("😀 ok".to_string()));
    let rewritten = v.to_string_compact();
    assert_eq!(Json::parse(&rewritten).unwrap(), v);
}

#[test]
fn prop_fault_schedules_preserve_stream_invariants() {
    // The resilience layer's pin: under a random seeded fault schedule —
    // worker panics, transient KV-capacity clamps, slow passes — every
    // submitted request still reaches exactly one terminal event, no
    // stream hangs (poll_streams returns; the prop harness watchdog would
    // abort a wedged case with its seed), the lease meters drain to zero
    // on every pool, and shutdown(Drain) completes within its deadline.
    use aser::coordinator::faults::silence_injected_panics;
    use aser::coordinator::{
        poll_streams, BatchConfig, Engine, EngineConfig, FaultPlan, FaultPlanConfig, GenRequest,
        Shutdown, TokenEvent,
    };
    use aser::model::synthetic_model;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    silence_injected_panics();
    let model = Arc::new(synthetic_model("micro", 923).unwrap());
    check(
        "fault_schedule_stream_invariants",
        &cfg(6),
        |rng| rng.next_u64(),
        |_| Vec::new(),
        |&seed| {
            let workers = 3usize;
            // ≥ 1 panic, ≤ workers-1, so some worker always survives to
            // adopt orphans; plus a capacity clamp and a stall.
            let fcfg = FaultPlanConfig {
                panics: 1 + (seed as usize % 2),
                clamps: 1,
                stalls: 1,
                ..Default::default()
            };
            let plan = FaultPlan::random(seed, workers, &fcfg);
            let engine = Engine::new(
                Arc::clone(&model),
                EngineConfig {
                    workers,
                    batch: BatchConfig { max_batch: 2, stop_on_eos: false, ..Default::default() },
                    kv_tokens: 2048,
                    faults: Some(plan),
                    ..Default::default()
                },
            );
            let pools = engine.kv_pool_handles();
            let handles: Vec<_> = (0..10u64)
                .map(|i| {
                    let prompt: Vec<u32> = (0..2 + (i as usize % 4)).map(|t| 2 + i as u32 + t as u32).collect();
                    engine
                        .submit(GenRequest::new(i, prompt, 3 + (i as usize % 3)))
                        .expect("a worker survives every schedule")
                })
                .collect();
            let mut terminals = vec![0usize; handles.len()];
            poll_streams(&handles, |i, ev| {
                if matches!(ev, Some(TokenEvent::Finished { .. }) | None) {
                    terminals[i] += 1;
                }
            });
            let t0 = Instant::now();
            engine.shutdown_mode(Shutdown::Drain, Some(Duration::from_secs(10)));
            let drain = t0.elapsed();
            all(vec![
                ensure(terminals.iter().all(|&t| t == 1), || {
                    format!("terminal-per-stream violated: {terminals:?}")
                }),
                ensure(drain < Duration::from_secs(20), || {
                    format!("drain took {drain:?} against a 10s deadline")
                }),
                ensure(
                    pools.iter().all(|p| p.used_tokens() == 0 && p.live_leases() == 0),
                    || {
                        let used: Vec<_> =
                            pools.iter().map(|p| (p.used_tokens(), p.live_leases())).collect();
                        format!("pool meters did not drain: {used:?}")
                    },
                ),
            ])
        },
    );
}
