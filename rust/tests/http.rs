//! Integration tests for the HTTP/1.1 + SSE front end: real TCP sockets
//! against [`aser::coordinator::server::HttpServer`], covering the ISSUE-10
//! acceptance criteria — a streamed completion bitwise identical to the
//! in-process `Engine::submit` path, and a mid-stream disconnect that frees
//! the KV lease and increments `BatchMetrics::cancelled`.

use aser::coordinator::{
    BatchConfig, Engine, EngineConfig, GenRequest, HttpServer, HttpServerConfig, TokenEvent,
};
use aser::data::Vocab;
use aser::model::{synthetic_model, SamplingParams};
use aser::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

// -- tiny raw-socket HTTP client ------------------------------------------

fn send_request(conn: &mut TcpStream, method: &str, path: &str, body: Option<&str>, close: bool) {
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: test\r\n");
    if close {
        req.push_str("Connection: close\r\n");
    }
    if let Some(b) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    conn.write_all(req.as_bytes()).unwrap();
}

struct HttpResponse {
    status: u16,
    body: Vec<u8>,
}

fn read_byte(conn: &mut TcpStream) -> u8 {
    let mut b = [0u8; 1];
    let n = conn.read(&mut b).expect("socket read");
    assert!(n > 0, "unexpected EOF from server");
    b[0]
}

/// Read one response off a (possibly keep-alive) connection: headers, then a
/// `Content-Length` body or a de-framed chunked body.
fn read_response(conn: &mut TcpStream) -> HttpResponse {
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut head = Vec::new();
    while !head.ends_with(b"\r\n\r\n") {
        head.push(read_byte(conn));
    }
    let head = String::from_utf8(head).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let lower = head.to_ascii_lowercase();
    let body = if let Some(rest) = lower.split("content-length:").nth(1) {
        let n: usize = rest.split_whitespace().next().unwrap().parse().unwrap();
        let mut body = vec![0u8; n];
        conn.read_exact(&mut body).unwrap();
        body
    } else if lower.contains("transfer-encoding: chunked") {
        read_chunked(conn)
    } else {
        Vec::new()
    };
    HttpResponse { status, body }
}

fn read_chunked(conn: &mut TcpStream) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let mut line = Vec::new();
        while !line.ends_with(b"\r\n") {
            line.push(read_byte(conn));
        }
        let size =
            usize::from_str_radix(std::str::from_utf8(&line).unwrap().trim(), 16).unwrap();
        let mut chunk = vec![0u8; size + 2]; // payload + trailing CRLF
        conn.read_exact(&mut chunk).unwrap();
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&chunk[..size]);
    }
}

/// Split an SSE body into `data:` payload strings.
fn sse_events(body: &[u8]) -> Vec<String> {
    String::from_utf8_lossy(body)
        .split("\n\n")
        .filter(|s| !s.is_empty())
        .map(|s| s.trim_start_matches("data: ").to_string())
        .collect()
}

fn micro_server(engine: Arc<Engine>, model_id: &str) -> HttpServer {
    HttpServer::bind(
        "127.0.0.1:0",
        engine,
        Arc::new(Vocab::new(128)),
        HttpServerConfig { threads: 2, model_id: model_id.to_string(), ..Default::default() },
    )
    .unwrap()
}

fn teardown(server: HttpServer, engine: Arc<Engine>) -> Vec<aser::coordinator::BatchMetrics> {
    let returned = server.shutdown(Duration::from_secs(2));
    drop(engine);
    let Ok(engine) = Arc::try_unwrap(returned) else {
        panic!("engine still shared after server shutdown")
    };
    engine.shutdown()
}

// -- tests ----------------------------------------------------------------

/// ISSUE-10 acceptance: the streamed HTTP token sequence is bitwise
/// identical to the in-process `Engine::submit` path for the same seeded
/// sampled request — and so is the non-streamed response.
#[test]
fn streamed_http_matches_in_process_engine_bitwise() {
    let model = Arc::new(synthetic_model("micro", 71).unwrap());
    let engine = Arc::new(Engine::new(
        Arc::clone(&model),
        EngineConfig { workers: 1, kv_tokens: 4096, ..Default::default() },
    ));
    let mut req = GenRequest::new(999, vec![3, 5, 7], 12);
    req.sampling = SamplingParams {
        temperature: 0.9,
        top_k: 8,
        top_p: 0.95,
        seed: 42,
        stop_tokens: Vec::new(),
    };
    let want = engine.submit(req).unwrap().wait();
    assert!(!want.tokens.is_empty(), "reference stream produced no tokens");

    let server = micro_server(Arc::clone(&engine), "micro-fp16");
    let addr = server.local_addr();
    let body = r#"{"prompt": [3, 5, 7], "max_tokens": 12, "temperature": 0.9,
                   "top_k": 8, "top_p": 0.95, "seed": 42, "stream": true}"#;
    let mut conn = TcpStream::connect(addr).unwrap();
    send_request(&mut conn, "POST", "/v1/completions", Some(body), false);
    let resp = read_response(&mut conn);
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body);
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"));
    let mut got: Vec<u32> = Vec::new();
    let mut finish = String::new();
    let mut text = String::new();
    for ev in &events[..events.len() - 1] {
        let v = Json::parse(ev).unwrap();
        let choice = &v.get("choices").unwrap().as_arr().unwrap()[0];
        if let Some(t) = choice.get("token_id").and_then(Json::as_usize) {
            got.push(t as u32);
            text.push_str(choice.str_field("text").unwrap());
        }
        if let Ok(f) = choice.str_field("finish_reason") {
            finish = f.to_string();
        }
    }
    assert_eq!(got, want.tokens, "streamed HTTP tokens must match Engine::submit bitwise");
    assert_eq!(finish, want.finish.wire_str());

    // Non-streamed path, same seed: same ids, and its text equals the
    // concatenation of the streamed per-token deltas.
    let body = r#"{"prompt": [3, 5, 7], "max_tokens": 12, "temperature": 0.9,
                   "top_k": 8, "top_p": 0.95, "seed": 42}"#;
    let mut conn = TcpStream::connect(addr).unwrap();
    send_request(&mut conn, "POST", "/v1/completions", Some(body), true);
    let resp = read_response(&mut conn);
    assert_eq!(resp.status, 200);
    let v = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let choice = &v.get("choices").unwrap().as_arr().unwrap()[0];
    let ids: Vec<u32> = choice
        .get("token_ids")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(ids, want.tokens);
    assert_eq!(choice.str_field("text").unwrap(), text);
    assert_eq!(choice.str_field("finish_reason").unwrap(), want.finish.wire_str());
    let usage = v.get("usage").unwrap();
    assert_eq!(usage.int("prompt_tokens").unwrap(), 3);
    assert_eq!(usage.int("completion_tokens").unwrap(), want.tokens.len());

    teardown(server, engine);
}

/// ISSUE-10 acceptance + satellite: dropping the socket mid-generation
/// frees the request's KV lease within one batcher iteration (pool meter
/// drains to zero) and the worker's `cancelled` counter increments.
#[test]
fn mid_stream_disconnect_frees_kv_and_counts_cancelled() {
    let mut base = synthetic_model("micro", 72).unwrap();
    base.cfg.max_seq = 8192; // room to decode until cancelled
    base.refresh_derived();
    let engine = Arc::new(Engine::new(
        Arc::new(base),
        EngineConfig {
            workers: 1,
            kv_tokens: 1 << 14,
            batch: BatchConfig { stop_on_eos: false, ..Default::default() },
            ..Default::default()
        },
    ));
    let server = micro_server(Arc::clone(&engine), "micro-fp16");
    let body = r#"{"prompt": [2, 3, 4], "max_tokens": 5000, "stream": true}"#;
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    send_request(&mut conn, "POST", "/v1/completions", Some(body), false);
    // Read raw bytes until the first token event is on the wire, so the
    // disconnect provably lands mid-generation.
    let mut seen: Vec<u8> = Vec::new();
    while !seen.windows(8).any(|w| w == b"token_id") {
        seen.push(read_byte(&mut conn));
    }
    assert!(engine.kv_used_tokens() > 0, "stream mid-generation must hold a KV lease");
    drop(conn); // the disconnect under test

    let t0 = Instant::now();
    while engine.kv_used_tokens() > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "KV lease not freed after client disconnect"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(engine.kv_live_leases(), 0);

    let metrics = teardown(server, engine);
    let cancelled: usize = metrics.iter().map(|m| m.cancelled).sum();
    assert!(cancelled >= 1, "disconnect must surface as BatchMetrics::cancelled");
}

/// Routes, keep-alive, error mapping, and the admin shutdown flag.
#[test]
fn endpoints_keep_alive_and_error_mapping() {
    let model = Arc::new(synthetic_model("micro", 73).unwrap());
    let engine = Arc::new(Engine::new(
        Arc::clone(&model),
        EngineConfig { workers: 1, kv_tokens: 4096, ..Default::default() },
    ));
    let server = micro_server(Arc::clone(&engine), "micro-fp16");
    let addr = server.local_addr();

    // One connection, many requests: healthz → models → completion → 404 →
    // bad JSON → missing prompt. Keep-alive must survive every 2xx/4xx.
    let mut conn = TcpStream::connect(addr).unwrap();
    send_request(&mut conn, "GET", "/healthz", None, false);
    let r = read_response(&mut conn);
    assert_eq!(r.status, 200);
    let v = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    assert_eq!(v.str_field("status").unwrap(), "ok");
    assert_eq!(v.int("alive_workers").unwrap(), 1);

    send_request(&mut conn, "GET", "/v1/models", None, false);
    let r = read_response(&mut conn);
    assert_eq!(r.status, 200);
    assert!(String::from_utf8_lossy(&r.body).contains("micro-fp16"));

    send_request(
        &mut conn,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": [3, 5, 7], "max_tokens": 4}"#),
        false,
    );
    let r = read_response(&mut conn);
    assert_eq!(r.status, 200);
    let v = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    let choice = &v.get("choices").unwrap().as_arr().unwrap()[0];
    let n = choice.get("token_ids").unwrap().as_arr().unwrap().len();
    assert!(n > 0 && n <= 4);
    assert_eq!(v.get("usage").unwrap().int("completion_tokens").unwrap(), n);

    send_request(&mut conn, "GET", "/nope", None, false);
    assert_eq!(read_response(&mut conn).status, 404);

    send_request(&mut conn, "POST", "/v1/completions", Some("{not json"), false);
    assert_eq!(read_response(&mut conn).status, 400);

    send_request(&mut conn, "POST", "/v1/completions", Some("{}"), true);
    let r = read_response(&mut conn);
    assert_eq!(r.status, 400);
    assert!(String::from_utf8_lossy(&r.body).contains("prompt"));
    drop(conn);

    // SIGTERM-equivalent: the shutdown endpoint flips the polled flag.
    assert!(!server.shutdown_requested());
    let mut conn = TcpStream::connect(addr).unwrap();
    send_request(&mut conn, "POST", "/admin/shutdown", None, true);
    assert_eq!(read_response(&mut conn).status, 200);
    assert!(server.shutdown_requested());

    teardown(server, engine);
}

/// `SubmitError::QueueFull` maps to HTTP 429 (the engine-side recipe is the
/// `queue_cap_sheds_and_submit_wait_times_out` engine test).
#[test]
fn queue_full_maps_to_429() {
    let mut base = synthetic_model("micro", 74).unwrap();
    base.cfg.max_seq = 8192;
    base.refresh_derived();
    let engine = Arc::new(Engine::new(
        Arc::new(base),
        EngineConfig {
            workers: 1,
            kv_tokens: 1 << 14,
            batch: BatchConfig { max_batch: 1, stop_on_eos: false, ..Default::default() },
            queue_cap: 1,
            ..Default::default()
        },
    ));
    let server = micro_server(Arc::clone(&engine), "micro-fp16");

    // Occupy the single batch slot, then the single queue slot, in-process.
    let blocker = engine.submit(GenRequest::new(0, vec![2, 3], 5000)).unwrap();
    loop {
        match blocker.recv().expect("blocker stream open") {
            TokenEvent::Token { .. } => break,
            TokenEvent::Finished { .. } => panic!("blocker finished early"),
            TokenEvent::PrefillDone { .. } => {}
        }
    }
    let queued = engine.submit(GenRequest::new(1, vec![4, 5], 4)).unwrap();

    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    send_request(
        &mut conn,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": [6, 7], "max_tokens": 4}"#),
        true,
    );
    let r = read_response(&mut conn);
    assert_eq!(r.status, 429, "QueueFull must map to 429");
    let v = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    assert_eq!(v.get("error").unwrap().int("code").unwrap(), 429);

    blocker.cancel();
    drop(queued);
    teardown(server, engine);
}

/// Deadline expiry surfaces as a terminal SSE event with
/// `finish_reason: "deadline"`.
#[test]
fn deadline_expiry_streams_deadline_finish_reason() {
    let mut base = synthetic_model("micro", 75).unwrap();
    base.cfg.max_seq = 8192;
    base.refresh_derived();
    let engine = Arc::new(Engine::new(
        Arc::new(base),
        EngineConfig {
            workers: 1,
            kv_tokens: 1 << 14,
            batch: BatchConfig { stop_on_eos: false, ..Default::default() },
            ..Default::default()
        },
    ));
    let server = micro_server(Arc::clone(&engine), "micro-fp16");
    // A 1 ms budget cannot cover a 5000-token generation; the sweep expires
    // it after at most a few tokens.
    let body = r#"{"prompt": [2, 3, 4], "max_tokens": 5000, "stream": true, "deadline_ms": 1}"#;
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    send_request(&mut conn, "POST", "/v1/completions", Some(body), true);
    let resp = read_response(&mut conn);
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body);
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"));
    let terminal = Json::parse(&events[events.len() - 2]).unwrap();
    let choice = &terminal.get("choices").unwrap().as_arr().unwrap()[0];
    assert_eq!(choice.str_field("finish_reason").unwrap(), "deadline");

    teardown(server, engine);
}

/// Sanity for the helper itself: the SocketAddr type keeps the ephemeral
/// port the OS picked, so every test binds its own isolated listener.
#[test]
fn servers_bind_distinct_ephemeral_ports() {
    let model = Arc::new(synthetic_model("micro", 76).unwrap());
    let engine = Arc::new(Engine::new(
        Arc::clone(&model),
        EngineConfig { workers: 1, kv_tokens: 4096, ..Default::default() },
    ));
    let s1 = micro_server(Arc::clone(&engine), "a");
    let s2 = micro_server(Arc::clone(&engine), "b");
    let (a1, a2): (SocketAddr, SocketAddr) = (s1.local_addr(), s2.local_addr());
    assert_ne!(a1.port(), 0);
    assert_ne!(a1.port(), a2.port());
    let e1 = s1.shutdown(Duration::from_millis(100));
    let e2 = s2.shutdown(Duration::from_millis(100));
    drop((e1, e2));
    let Ok(engine) = Arc::try_unwrap(engine) else { panic!("engine still shared") };
    engine.shutdown();
}
