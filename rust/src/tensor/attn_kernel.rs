//! SIMD f32 microkernels for attention over head-major KV tiles.
//!
//! PRs 1–3 moved every linear projection onto runtime-dispatched packed int8
//! GEMMs; attention over the KV cache was the last scalar dot loop on the
//! serving path and the Amdahl bottleneck at long contexts. This module is
//! its kernel layer: the three inner loops of cached causal attention —
//!
//! 1. **q·K score sweep** ([`qk_scores`]): one query head-vector against a
//!    contiguous `t_seen × hd` key tile, producing scaled scores,
//! 2. **softmax** ([`softmax`]): max / exp / sum / normalize in place,
//! 3. **weighted-V accumulation** ([`pv_accum`]): `out = Σ_tk w[tk] · v[tk]`
//!    over the matching value tile —
//!
//! each dispatched on an [`AttnKernelKind`] selected once per forward call
//! (runtime feature detection, like `qgemm_kernel`):
//!
//! * [`AttnKernelKind::Scalar`] — portable reference. Its q·K dot is
//!   [`gemm::dot`] (the 8-wide unroll with the pinned summation order), its
//!   softmax and PV loops reproduce the pre-kernel `attn_cached_span` inner
//!   loops **bitwise** — the property tests pin the scalar kernel against a
//!   straight-line replica of that retired implementation with `assert_eq`.
//! * [`AttnKernelKind::Avx2`] — x86-64 AVX2+FMA: the score sweep processes
//!   4 keys per pass (each query register load amortized across 4 fused
//!   multiply-add accumulators), softmax vectorizes the max reduction and
//!   the `1/sum` normalization (the `exp` calls stay scalar — a polynomial
//!   exp would trade accuracy for nothing measurable here), and the PV
//!   accumulation broadcasts 4 weights per output-register round trip.
//! * [`AttnKernelKind::Neon`] — aarch64 `vfmaq_f32` variants of the same
//!   three loops.
//!
//! Unlike the int8 kernels (exact i32 ⇒ bitwise across kernels), these are
//! f32: the SIMD variants reassociate the reductions, so they promise
//! tolerance-level agreement with the scalar reference, not bit equality.
//! What **is** bitwise-stable: the scalar kernel vs the pre-refactor code,
//! and any single kernel across batch shapes and thread counts (work items
//! never share accumulators — see `Gpt::attn_layer`).
//!
//! All kernels stream **unit-stride tiles**: the paged head-major `KvCache`
//! layout (`coordinator::kvpool`) stores each (layer, head) of a page as a
//! contiguous `KV_TILE × hd` panel, so consecutive cache positions are `hd`
//! floats apart — the score sweep and PV accumulation walk memory linearly
//! instead of striding `d_model` between positions as the row-major layout
//! forced. The paged span drivers in `Gpt` call [`qk_scores`] per page
//! segment and accumulate PV via [`pv_accum_add`] / [`pv_accum_int8_add`]
//! (zero once per row, add per segment); [`attn_head_span`] /
//! [`attn_head_span_int8`] remain the contiguous single-tile drivers for
//! raw-slice callers (benches, scratch paths, tests).
//!
//! ## Int8 KV paths (fused dequant)
//!
//! For [`crate::model::KvDtype::Int8`] caches the two KV-touching loops have
//! int8 twins that stream code tiles directly and fuse dequantization into
//! the writeback — the cache is never materialized back to f32:
//!
//! * [`qk_scores_int8`] — int8 q (quantized once per (sequence, head) row
//!   into [`AttnArena`]) dotted against the `t_seen × hd` key-code tile in
//!   exact i32, with `q_scale · attn_scale · k_scale[tk]` applied once per
//!   accumulator at writeback. AVX2 uses the `qgemm_kernel` sign/abs
//!   `maddubs`+`madd` trick on **128-bit** lanes (head dims are small —
//!   16-byte chunks keep hd = 16 fully vectorized where 32-byte chunks
//!   would degenerate to the scalar tail); NEON uses `vmull_s8` +
//!   `vpadalq_s16`. Codes are ≥ −127 by construction of `quantize_tile`,
//!   so pair sums are ≤ 2·127² < `i16::MAX` and the i16 stage is exact.
//! * [`pv_accum_int8`] — softmax weights times the value-code tile with the
//!   per-row value scale folded into the broadcast weight. SIMD variants
//!   process positions **in order with separate mul-then-add** (no FMA):
//!   i8→f32 conversion is exact, so each lane reproduces the scalar
//!   `out += (w·v_scale) · code` rounding sequence bit-for-bit.
//!
//! Because integer accumulation is order-independent and the f32 writeback
//! expressions are kept character-identical across kernels, the int8 paths
//! are **bitwise identical across Scalar/AVX2/NEON** — the property tests
//! pin SIMD against the int8 scalar reference with `assert_eq`, unlike the
//! tolerance-level contract of the f32 kernels above.

// Index-heavy microkernels: indexed loops mirror the register tiling and
// keep the scalar/SIMD variants visually aligned.
#![allow(clippy::needless_range_loop)]

use super::gemm::dot;

/// The attention microkernel for this host, selected per forward call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnKernelKind {
    /// Portable reference kernel; bitwise-pinned against the pre-kernel
    /// scalar attention loops.
    Scalar,
    /// x86-64 AVX2 + FMA kernel.
    Avx2,
    /// aarch64 NEON kernel.
    Neon,
}

impl AttnKernelKind {
    pub fn name(self) -> &'static str {
        match self {
            AttnKernelKind::Scalar => "scalar",
            AttnKernelKind::Avx2 => "avx2",
            AttnKernelKind::Neon => "neon",
        }
    }

    /// Whether this kernel can run on the current host (compile target arch
    /// AND runtime CPU features).
    pub fn available(self) -> bool {
        match self {
            AttnKernelKind::Scalar => true,
            AttnKernelKind::Avx2 => avx2_fma_available(),
            AttnKernelKind::Neon => neon_available(),
        }
    }
}

impl std::fmt::Display for AttnKernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_available() -> bool {
    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
}
#[cfg(not(target_arch = "x86_64"))]
fn avx2_fma_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

/// Pick the fastest attention kernel available on this host. Feature
/// detection results are cached by std, so calling this once per forward
/// pass is cheap.
pub fn detect_attn_kernel() -> AttnKernelKind {
    if AttnKernelKind::Avx2.available() {
        AttnKernelKind::Avx2
    } else if AttnKernelKind::Neon.available() {
        AttnKernelKind::Neon
    } else {
        AttnKernelKind::Scalar
    }
}

/// Thread count for a span-attention batch of `macs` q·K multiply-adds:
/// decode and short-context batches stay inline; long-context decode and
/// teacher-forced prefill fan out across (sequence × head) work items.
/// The floor is ~2²⁰ MACs — ≳ 100µs of scalar / tens of µs of SIMD f32
/// work, comfortably above the ~10µs-per-worker scoped-thread spawn (raw
/// MACs are ~d_in× finer-grained than qgemm's output-element unit, hence
/// the higher floor). The spawn-cost logic lives in
/// [`crate::util::pool::fanout_threads`], shared with the qgemm row-block
/// heuristic.
pub fn auto_threads(macs: usize) -> usize {
    crate::util::pool::fanout_threads(macs, 1 << 20)
}

// ---------------------------------------------------------------------------
// Batch-lifetime scratch

/// Grow-only scratch for the span-attention driver (`Gpt::attn_layer`), the
/// attention analog of `QGemmArena` (it rides inside it as
/// `QGemmArena::attn`): staged roped queries, per-(sequence × head) score
/// rows, and the head-major output tiles. Capacities are high-water and
/// never released, so steady-state decode iterations allocate nothing;
/// every consumed element is overwritten before being read (queries are
/// staged, scores written by the sweep, tiles zero-filled by [`pv_accum`]),
/// so stale tails are never observed.
#[derive(Default)]
pub struct AttnArena {
    /// Staged roped queries, total × d row-major.
    pub(crate) q: Vec<f32>,
    /// Concatenated per-(sequence, head) score rows (`pos0 + t` each).
    pub(crate) scores: Vec<f32>,
    /// Head-major output tiles: per sequence, nh panels of `t × hd`.
    pub(crate) tiles: Vec<f32>,
    /// (sequence, head, scores offset, tile offset) work items — refilled
    /// per layer but capacity-reused, so the layer loop allocates nothing.
    pub(crate) items: Vec<(usize, usize, usize, usize)>,
    /// Int8 query codes mirroring `q` (total × d row-major), quantized once
    /// per (row, head) by the staging pass when any sequence in the batch
    /// carries an int8 KV cache.
    pub(crate) q_codes: Vec<i8>,
    /// Per-(row, head) query scales for `q_codes`: row-major `total × nh`.
    pub(crate) q_scales: Vec<f32>,
    /// One roped key row (`hd` floats) staged before quantization — the
    /// int8 cache stores codes, so rope needs an f32 landing pad.
    pub(crate) krow: Vec<f32>,
}

impl AttnArena {
    pub fn new() -> AttnArena {
        AttnArena::default()
    }

    pub(crate) fn ensure(&mut self, q_len: usize, scores_len: usize, tiles_len: usize) {
        if self.q.len() < q_len {
            self.q.resize(q_len, 0.0);
        }
        if self.scores.len() < scores_len {
            self.scores.resize(scores_len, 0.0);
        }
        if self.tiles.len() < tiles_len {
            self.tiles.resize(tiles_len, 0.0);
        }
    }

    /// Grow the int8 staging buffers (query codes + scales + key landing
    /// pad) — called only on batches that touch an int8 cache, so pure-f32
    /// serving never pays for them.
    pub(crate) fn ensure_int8(&mut self, q_len: usize, scales_len: usize, hd: usize) {
        if self.q_codes.len() < q_len {
            self.q_codes.resize(q_len, 0);
        }
        if self.q_scales.len() < scales_len {
            self.q_scales.resize(scales_len, 0.0);
        }
        if self.krow.len() < hd {
            self.krow.resize(hd, 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch

/// `scores[tk] = dot(q, keys[tk·hd .. (tk+1)·hd]) · scale` over a contiguous
/// key tile (`keys.len() == scores.len() · q.len()`). The caller must only
/// pass a `kind` that is [`AttnKernelKind::available`] on this host.
pub fn qk_scores(kind: AttnKernelKind, q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
    debug_assert_eq!(keys.len(), scores.len() * q.len());
    match kind {
        AttnKernelKind::Scalar => qk_scores_scalar(q, keys, scale, scores),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability is asserted by `attn_head_span` / checked by
        // callers per the contract above.
        AttnKernelKind::Avx2 => unsafe { avx2::qk_scores(q, keys, scale, scores) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        AttnKernelKind::Neon => unsafe { neon::qk_scores(q, keys, scale, scores) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// In-place softmax (max / exp / sum / normalize). Same contract on `kind`.
pub fn softmax(kind: AttnKernelKind, x: &mut [f32]) {
    match kind {
        AttnKernelKind::Scalar => softmax_scalar(x),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Avx2 => unsafe { avx2::softmax(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Neon => unsafe { neon::softmax(x) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// `out = Σ_tk scores[tk] · values[tk·hd .. (tk+1)·hd]` over a contiguous
/// value tile (`values.len() == scores.len() · out.len()`). `out` is fully
/// overwritten. Same contract on `kind`.
pub fn pv_accum(kind: AttnKernelKind, scores: &[f32], values: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    pv_accum_add(kind, scores, values, out);
}

/// [`pv_accum`] without the zero-init: accumulates **into** `out`. The paged
/// span drivers zero a row once and then add one page segment at a time;
/// because every kernel walks positions in order with an exact f32
/// load/store of `out` between calls, a segmented accumulation over
/// 4-aligned splits is bitwise-identical to one contiguous [`pv_accum`]
/// (KV pages are [`crate::coordinator::kvpool::KV_TILE`] = 64 positions, so
/// every split satisfies the AVX2 4-position block alignment).
pub fn pv_accum_add(kind: AttnKernelKind, scores: &[f32], values: &[f32], out: &mut [f32]) {
    debug_assert_eq!(values.len(), scores.len() * out.len());
    match kind {
        AttnKernelKind::Scalar => pv_accum_add_scalar(scores, values, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Avx2 => unsafe { avx2::pv_accum_add(scores, values, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Neon => unsafe { neon::pv_accum_add(scores, values, out) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// One (sequence, head) causal attention work item over head-major KV tiles
/// — the unit `Gpt::attn_layer` fans out across cores.
///
/// `q` holds the span's staged (already roped) query rows at row stride `d`
/// with this head's lanes at column offset `s`; `keys` / `values` are the
/// head's contiguous `(pos0 + t) × hd` tiles (span rows already appended);
/// `scores` is caller scratch of ≥ `pos0 + t` entries; `out` is the span's
/// `t × hd` head tile, fully overwritten. Row `j` attends over cache
/// positions `0..=pos0+j` — in-span future rows are masked purely by the
/// loop bound, which is what keeps every chunking of a prompt numerically
/// identical per row.
#[allow(clippy::too_many_arguments)]
pub fn attn_head_span(
    kind: AttnKernelKind,
    q: &[f32],
    d: usize,
    s: usize,
    hd: usize,
    pos0: usize,
    t: usize,
    keys: &[f32],
    values: &[f32],
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    assert!(kind.available(), "attention kernel {kind:?} not available on this host");
    assert!(t > 0, "empty span");
    debug_assert!(q.len() >= (t - 1) * d + s + hd);
    debug_assert!(keys.len() >= (pos0 + t) * hd);
    debug_assert!(values.len() >= (pos0 + t) * hd);
    debug_assert!(scores.len() >= pos0 + t);
    debug_assert_eq!(out.len(), t * hd);
    for j in 0..t {
        let t_seen = pos0 + j + 1;
        let qh = &q[j * d + s..j * d + s + hd];
        qk_scores(kind, qh, &keys[..t_seen * hd], scale, &mut scores[..t_seen]);
        softmax(kind, &mut scores[..t_seen]);
        pv_accum(kind, &scores[..t_seen], &values[..t_seen * hd], &mut out[j * hd..(j + 1) * hd]);
    }
}

// ---------------------------------------------------------------------------
// Scalar reference kernels
//
// These reproduce the retired `attn_cached_span` inner loops exactly: the
// score sweep uses `gemm::dot` (the pinned 8-wide summation order), softmax
// folds max / exp-sums / normalizes in position order, and the PV loop
// accumulates into a zeroed output in position order. Property tests pin
// all three bitwise against a straight-line replica.

fn qk_scores_scalar(q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
    let hd = q.len();
    for (tk, sc) in scores.iter_mut().enumerate() {
        *sc = dot(q, &keys[tk * hd..(tk + 1) * hd]) * scale;
    }
}

fn softmax_scalar(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

fn pv_accum_add_scalar(scores: &[f32], values: &[f32], out: &mut [f32]) {
    let hd = out.len();
    for (tk, &w) in scores.iter().enumerate() {
        let vrow = &values[tk * hd..(tk + 1) * hd];
        for (o, &vv) in out.iter_mut().zip(vrow) {
            *o += w * vv;
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 KV kernels (fused dequant) — see the module doc. All kernels are
// bitwise-identical across Scalar/AVX2/NEON: exact i32 accumulation plus
// character-identical f32 writeback expressions.

/// Int8 score sweep: `scores[tk] = (q · keys[tk]) · scale · k_scales[tk]`
/// with the dot in exact i32. `scale` is the caller's pre-combined
/// `q_scale · attn_scale`; `k_scales` holds one scale per key row. Same
/// availability contract on `kind` as [`qk_scores`].
pub fn qk_scores_int8(
    kind: AttnKernelKind,
    q: &[i8],
    keys: &[i8],
    k_scales: &[f32],
    scale: f32,
    scores: &mut [f32],
) {
    debug_assert_eq!(keys.len(), scores.len() * q.len());
    debug_assert!(k_scales.len() >= scores.len());
    match kind {
        AttnKernelKind::Scalar => qk_scores_int8_scalar(q, keys, k_scales, scale, scores),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Avx2 => unsafe { avx2::qk_scores_int8(q, keys, k_scales, scale, scores) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Neon => unsafe { neon::qk_scores_int8(q, keys, k_scales, scale, scores) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// Int8 weighted-V accumulation with fused dequant:
/// `out = Σ_tk (scores[tk] · v_scales[tk]) · values[tk]` with the value
/// codes converted lane-wise (i8→f32 is exact). `out` is fully overwritten.
/// Same availability contract on `kind` as [`pv_accum`].
pub fn pv_accum_int8(
    kind: AttnKernelKind,
    scores: &[f32],
    values: &[i8],
    v_scales: &[f32],
    out: &mut [f32],
) {
    out.fill(0.0);
    pv_accum_int8_add(kind, scores, values, v_scales, out);
}

/// [`pv_accum_int8`] without the zero-init — the int8 twin of
/// [`pv_accum_add`], same segmented-accumulation bitwise contract (the int8
/// kernels are position-in-order mul-then-add, so any split is exact).
pub fn pv_accum_int8_add(
    kind: AttnKernelKind,
    scores: &[f32],
    values: &[i8],
    v_scales: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(values.len(), scores.len() * out.len());
    debug_assert!(v_scales.len() >= scores.len());
    match kind {
        AttnKernelKind::Scalar => pv_accum_int8_add_scalar(scores, values, v_scales, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Avx2 => unsafe { avx2::pv_accum_int8_add(scores, values, v_scales, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Neon => unsafe { neon::pv_accum_int8_add(scores, values, v_scales, out) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// One (sequence, head) causal attention work item over **int8** head-major
/// KV tiles — the int8 twin of [`attn_head_span`], with dequantization fused
/// into the score sweep and PV writebacks.
///
/// `q` holds the span's quantized query rows at row stride `d` with this
/// head's lanes at column offset `s`; `q_scales` holds the matching
/// per-(row, head) scales, row `j`'s at `j · q_scale_stride + q_scale_off`
/// (the `Gpt` driver passes stride `nh`, offset `head`). `keys` / `values`
/// are the head's contiguous `(pos0 + t) × hd` code tiles and
/// `k_scales` / `v_scales` the matching per-position scales (one KV page
/// panel, or any raw contiguous tile). Masking, chunking invariance, and
/// the `scores` / `out` contracts match [`attn_head_span`].
#[allow(clippy::too_many_arguments)]
pub fn attn_head_span_int8(
    kind: AttnKernelKind,
    q: &[i8],
    q_scales: &[f32],
    q_scale_stride: usize,
    q_scale_off: usize,
    d: usize,
    s: usize,
    hd: usize,
    pos0: usize,
    t: usize,
    keys: &[i8],
    k_scales: &[f32],
    values: &[i8],
    v_scales: &[f32],
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    assert!(kind.available(), "attention kernel {kind:?} not available on this host");
    assert!(t > 0, "empty span");
    debug_assert!(q.len() >= (t - 1) * d + s + hd);
    debug_assert!(q_scales.len() >= (t - 1) * q_scale_stride + q_scale_off + 1);
    debug_assert!(keys.len() >= (pos0 + t) * hd);
    debug_assert!(values.len() >= (pos0 + t) * hd);
    debug_assert!(k_scales.len() >= pos0 + t);
    debug_assert!(v_scales.len() >= pos0 + t);
    debug_assert!(scores.len() >= pos0 + t);
    debug_assert_eq!(out.len(), t * hd);
    for j in 0..t {
        let t_seen = pos0 + j + 1;
        let qh = &q[j * d + s..j * d + s + hd];
        let qs = q_scales[j * q_scale_stride + q_scale_off] * scale;
        qk_scores_int8(
            kind,
            qh,
            &keys[..t_seen * hd],
            &k_scales[..t_seen],
            qs,
            &mut scores[..t_seen],
        );
        softmax(kind, &mut scores[..t_seen]);
        pv_accum_int8(
            kind,
            &scores[..t_seen],
            &values[..t_seen * hd],
            &v_scales[..t_seen],
            &mut out[j * hd..(j + 1) * hd],
        );
    }
}

fn qk_scores_int8_scalar(q: &[i8], keys: &[i8], k_scales: &[f32], scale: f32, scores: &mut [f32]) {
    let hd = q.len();
    for (tk, sc) in scores.iter_mut().enumerate() {
        let krow = &keys[tk * hd..(tk + 1) * hd];
        let mut acc = 0i32;
        for (&a, &b) in q.iter().zip(krow) {
            acc += a as i32 * b as i32;
        }
        // Writeback kept character-identical to the SIMD kernels — the
        // bitwise cross-kernel contract hangs on this exact expression.
        *sc = acc as f32 * (scale * k_scales[tk]);
    }
}

fn pv_accum_int8_add_scalar(scores: &[f32], values: &[i8], v_scales: &[f32], out: &mut [f32]) {
    let hd = out.len();
    for (tk, &w) in scores.iter().enumerate() {
        let wv = w * v_scales[tk];
        let vrow = &values[tk * hd..(tk + 1) * hd];
        for (o, &c) in out.iter_mut().zip(vrow) {
            *o += wv * (c as f32);
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    //! AVX2+FMA attention kernels. The reductions reassociate relative to
    //! the scalar reference (8-lane partial sums + scalar tails), so these
    //! agree to f32 tolerance, not bitwise — see the module doc.

    use std::arch::x86_64::*;

    /// Horizontal sum of the 8 f32 lanes of `v`.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        // Explicit inner block: edition-2024-proof (unsafe_op_in_unsafe_fn).
        unsafe {
            let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps::<0x55>(s, s));
            _mm_cvtss_f32(s)
        }
    }

    /// Score sweep: 4 keys per pass so each 8-lane query load feeds four
    /// FMA accumulators; lane tail (`hd % 8`) and key tail (`n % 4`) run
    /// scalar.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present and
    /// `keys.len() == scores.len() * q.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn qk_scores(q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
        unsafe {
            let hd = q.len();
            let n = scores.len();
            let chunks = hd / 8 * 8;
            let qp = q.as_ptr();
            let kp = keys.as_ptr();
            let mut tk = 0usize;
            while tk + 4 <= n {
                let base = [
                    kp.add(tk * hd),
                    kp.add((tk + 1) * hd),
                    kp.add((tk + 2) * hd),
                    kp.add((tk + 3) * hd),
                ];
                let mut acc = [_mm256_setzero_ps(); 4];
                let mut i = 0usize;
                while i < chunks {
                    let qv = _mm256_loadu_ps(qp.add(i));
                    acc[0] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[0].add(i)), acc[0]);
                    acc[1] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[1].add(i)), acc[1]);
                    acc[2] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[2].add(i)), acc[2]);
                    acc[3] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[3].add(i)), acc[3]);
                    i += 8;
                }
                let mut j = 0usize;
                while j < 4 {
                    let mut s = hsum_ps(acc[j]);
                    for i in chunks..hd {
                        s += q[i] * *base[j].add(i);
                    }
                    scores[tk + j] = s * scale;
                    j += 1;
                }
                tk += 4;
            }
            while tk < n {
                let base = kp.add(tk * hd);
                let mut acc = _mm256_setzero_ps();
                let mut i = 0usize;
                while i < chunks {
                    acc = _mm256_fmadd_ps(
                        _mm256_loadu_ps(qp.add(i)),
                        _mm256_loadu_ps(base.add(i)),
                        acc,
                    );
                    i += 8;
                }
                let mut s = hsum_ps(acc);
                for i in chunks..hd {
                    s += q[i] * *base.add(i);
                }
                scores[tk] = s * scale;
                tk += 1;
            }
        }
    }

    /// Softmax with a vectorized max reduction and `1/sum` normalization;
    /// the exp stage stays scalar (accuracy over a marginal win).
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn softmax(x: &mut [f32]) {
        unsafe {
            let n = x.len();
            let chunks = n / 8 * 8;
            let mut max = {
                let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
                let p = x.as_ptr();
                let mut i = 0usize;
                while i < chunks {
                    vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(p.add(i)));
                    i += 8;
                }
                let m = _mm_max_ps(_mm256_castps256_ps128(vmax), _mm256_extractf128_ps::<1>(vmax));
                let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
                let m = _mm_max_ss(m, _mm_shuffle_ps::<0x55>(m, m));
                _mm_cvtss_f32(m)
            };
            for &v in &x[chunks..] {
                max = max.max(v);
            }
            let mut sum = 0f32;
            for v in x.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            let vinv = _mm256_set1_ps(inv);
            let pm = x.as_mut_ptr();
            let mut i = 0usize;
            while i < chunks {
                _mm256_storeu_ps(pm.add(i), _mm256_mul_ps(_mm256_loadu_ps(pm.add(i)), vinv));
                i += 8;
            }
            for v in &mut x[chunks..] {
                *v *= inv;
            }
        }
    }

    /// Weighted-V accumulation into `out` (no zero-init — the dispatcher
    /// fills for the overwrite variant): 4 broadcast weights per
    /// output-register round trip (`out` loaded/stored once per 4
    /// positions). Positions run in order, so a 4-aligned segmented call
    /// sequence is bitwise-identical to one contiguous call.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present and
    /// `values.len() == scores.len() * out.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn pv_accum_add(scores: &[f32], values: &[f32], out: &mut [f32]) {
        unsafe {
            let hd = out.len();
            let n = scores.len();
            let chunks = hd / 8 * 8;
            let vp = values.as_ptr();
            let op = out.as_mut_ptr();
            let mut tk = 0usize;
            while tk + 4 <= n {
                let base = [
                    vp.add(tk * hd),
                    vp.add((tk + 1) * hd),
                    vp.add((tk + 2) * hd),
                    vp.add((tk + 3) * hd),
                ];
                let w = [
                    _mm256_set1_ps(scores[tk]),
                    _mm256_set1_ps(scores[tk + 1]),
                    _mm256_set1_ps(scores[tk + 2]),
                    _mm256_set1_ps(scores[tk + 3]),
                ];
                let mut i = 0usize;
                while i < chunks {
                    let mut o = _mm256_loadu_ps(op.add(i));
                    o = _mm256_fmadd_ps(w[0], _mm256_loadu_ps(base[0].add(i)), o);
                    o = _mm256_fmadd_ps(w[1], _mm256_loadu_ps(base[1].add(i)), o);
                    o = _mm256_fmadd_ps(w[2], _mm256_loadu_ps(base[2].add(i)), o);
                    o = _mm256_fmadd_ps(w[3], _mm256_loadu_ps(base[3].add(i)), o);
                    _mm256_storeu_ps(op.add(i), o);
                    i += 8;
                }
                let mut j = 0usize;
                while j < 4 {
                    let s = scores[tk + j];
                    for i in chunks..hd {
                        *op.add(i) += s * *base[j].add(i);
                    }
                    j += 1;
                }
                tk += 4;
            }
            while tk < n {
                let base = vp.add(tk * hd);
                let w = _mm256_set1_ps(scores[tk]);
                let mut i = 0usize;
                while i < chunks {
                    let o = _mm256_fmadd_ps(w, _mm256_loadu_ps(base.add(i)), _mm256_loadu_ps(op.add(i)));
                    _mm256_storeu_ps(op.add(i), o);
                    i += 8;
                }
                let s = scores[tk];
                for i in chunks..hd {
                    *op.add(i) += s * *base.add(i);
                }
                tk += 1;
            }
        }
    }

    /// Horizontal sum of the 4 i32 lanes of `v`.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn hsum_i32_128(v: __m128i) -> i32 {
        unsafe {
            let s = _mm_add_epi32(v, _mm_unpackhi_epi64(v, v));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
            _mm_cvtsi128_si32(s)
        }
    }

    /// Int8 score sweep: the `qgemm_kernel` sign/abs `maddubs`+`madd` trick
    /// on **128-bit** lanes — head dims are small (16 on the micro model),
    /// and 16-byte chunks keep them fully vectorized where 32-byte chunks
    /// would fall to the scalar tail. i32 accumulation is exact (codes
    /// ≥ −127 ⇒ pair sums ≤ 2·127² < `i16::MAX`), and the writeback
    /// expression matches the scalar kernel character-for-character, so
    /// this kernel is bitwise-identical to the int8 scalar reference.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present and
    /// `keys.len() == scores.len() * q.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn qk_scores_int8(
        q: &[i8],
        keys: &[i8],
        k_scales: &[f32],
        scale: f32,
        scores: &mut [f32],
    ) {
        unsafe {
            let hd = q.len();
            let n = scores.len();
            let chunks = hd / 16 * 16;
            let ones = _mm_set1_epi16(1);
            let qp = q.as_ptr();
            let kp = keys.as_ptr();
            for tk in 0..n {
                let base = kp.add(tk * hd);
                let mut vacc = _mm_setzero_si128();
                let mut i = 0usize;
                while i < chunks {
                    let qv = _mm_loadu_si128(qp.add(i) as *const __m128i);
                    let kv = _mm_loadu_si128(base.add(i) as *const __m128i);
                    // |k| · (q·sign(k)) == q·k ; pairs sum exactly in i16.
                    let p = _mm_maddubs_epi16(_mm_abs_epi8(kv), _mm_sign_epi8(qv, kv));
                    vacc = _mm_add_epi32(vacc, _mm_madd_epi16(p, ones));
                    i += 16;
                }
                let mut acc = hsum_i32_128(vacc);
                for i in chunks..hd {
                    acc += q[i] as i32 * *base.add(i) as i32;
                }
                scores[tk] = acc as f32 * (scale * k_scales[tk]);
            }
        }
    }

    /// Int8 weighted-V accumulation into `out` with fused dequant (no
    /// zero-init — the dispatcher fills for the overwrite variant): 8 value
    /// codes per pass widened i8→i32→f32 (exact), then **separate
    /// mul-then-add** — no FMA — one position at a time in position order,
    /// so every lane reproduces the scalar `out += (w·v_scale)·code`
    /// rounding sequence bit-for-bit, segmented or not.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present and
    /// `values.len() == scores.len() * out.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn pv_accum_int8_add(
        scores: &[f32],
        values: &[i8],
        v_scales: &[f32],
        out: &mut [f32],
    ) {
        unsafe {
            let hd = out.len();
            let n = scores.len();
            let chunks = hd / 8 * 8;
            let vp = values.as_ptr();
            let op = out.as_mut_ptr();
            for tk in 0..n {
                let wv = scores[tk] * v_scales[tk];
                let wvec = _mm256_set1_ps(wv);
                let base = vp.add(tk * hd);
                let mut i = 0usize;
                while i < chunks {
                    let c8 = _mm_loadl_epi64(base.add(i) as *const __m128i);
                    let vf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(c8));
                    let o = _mm256_add_ps(_mm256_loadu_ps(op.add(i)), _mm256_mul_ps(wvec, vf));
                    _mm256_storeu_ps(op.add(i), o);
                    i += 8;
                }
                for i in chunks..hd {
                    *op.add(i) += wv * (*base.add(i) as f32);
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    //! NEON `vfmaq_f32` attention kernels: 4-lane FMA streams over the
    //! contiguous tiles, scalar lane tails. Same tolerance contract as the
    //! AVX2 variants.

    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must guarantee NEON is present and
    /// `keys.len() == scores.len() * q.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn qk_scores(q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
        unsafe {
            let hd = q.len();
            let n = scores.len();
            let chunks = hd / 4 * 4;
            let qp = q.as_ptr();
            let kp = keys.as_ptr();
            for tk in 0..n {
                let base = kp.add(tk * hd);
                let mut acc = vdupq_n_f32(0.0);
                let mut i = 0usize;
                while i < chunks {
                    acc = vfmaq_f32(acc, vld1q_f32(qp.add(i)), vld1q_f32(base.add(i)));
                    i += 4;
                }
                let mut s = vaddvq_f32(acc);
                for i in chunks..hd {
                    s += q[i] * *base.add(i);
                }
                scores[tk] = s * scale;
            }
        }
    }

    /// # Safety
    /// Caller must guarantee NEON is present.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn softmax(x: &mut [f32]) {
        unsafe {
            let n = x.len();
            let chunks = n / 4 * 4;
            let mut max = {
                let mut vmax = vdupq_n_f32(f32::NEG_INFINITY);
                let p = x.as_ptr();
                let mut i = 0usize;
                while i < chunks {
                    vmax = vmaxq_f32(vmax, vld1q_f32(p.add(i)));
                    i += 4;
                }
                vmaxvq_f32(vmax)
            };
            for &v in &x[chunks..] {
                max = max.max(v);
            }
            let mut sum = 0f32;
            for v in x.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            let vinv = vdupq_n_f32(inv);
            let pm = x.as_mut_ptr();
            let mut i = 0usize;
            while i < chunks {
                vst1q_f32(pm.add(i), vmulq_f32(vld1q_f32(pm.add(i)), vinv));
                i += 4;
            }
            for v in &mut x[chunks..] {
                *v *= inv;
            }
        }
    }

    /// Accumulates into `out` without zero-init (the dispatcher fills for
    /// the overwrite variant); positions run in order so segmented calls
    /// match one contiguous call bitwise.
    ///
    /// # Safety
    /// Caller must guarantee NEON is present and
    /// `values.len() == scores.len() * out.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn pv_accum_add(scores: &[f32], values: &[f32], out: &mut [f32]) {
        unsafe {
            let hd = out.len();
            let n = scores.len();
            let chunks = hd / 4 * 4;
            let vp = values.as_ptr();
            let op = out.as_mut_ptr();
            for tk in 0..n {
                let base = vp.add(tk * hd);
                let w = vdupq_n_f32(scores[tk]);
                let mut i = 0usize;
                while i < chunks {
                    let o = vfmaq_f32(vld1q_f32(op.add(i)), w, vld1q_f32(base.add(i)));
                    vst1q_f32(op.add(i), o);
                    i += 4;
                }
                let s = scores[tk];
                for i in chunks..hd {
                    *op.add(i) += s * *base.add(i);
                }
            }
        }
    }

    /// Int8 score sweep: `vmull_s8` widens i8×i8→i16 exactly and
    /// `vpadalq_s16` pairwise-accumulates into i32, so the dot is exact and
    /// the per-key writeback matches the scalar reference bitwise.
    ///
    /// # Safety
    /// Caller must guarantee NEON is present and
    /// `keys.len() == scores.len() * q.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn qk_scores_int8(
        q: &[i8],
        keys: &[i8],
        k_scales: &[f32],
        scale: f32,
        scores: &mut [f32],
    ) {
        unsafe {
            let hd = q.len();
            let n = scores.len();
            let chunks = hd / 16 * 16;
            let qp = q.as_ptr();
            let kp = keys.as_ptr();
            for tk in 0..n {
                let base = kp.add(tk * hd);
                let mut vacc = vdupq_n_s32(0);
                let mut i = 0usize;
                while i < chunks {
                    let qv = vld1q_s8(qp.add(i));
                    let kv = vld1q_s8(base.add(i));
                    vacc = vpadalq_s16(vacc, vmull_s8(vget_low_s8(qv), vget_low_s8(kv)));
                    vacc = vpadalq_s16(vacc, vmull_s8(vget_high_s8(qv), vget_high_s8(kv)));
                    i += 16;
                }
                let mut acc = vaddvq_s32(vacc);
                for i in chunks..hd {
                    acc += q[i] as i32 * *base.add(i) as i32;
                }
                scores[tk] = acc as f32 * (scale * k_scales[tk]);
            }
        }
    }

    /// Int8 weighted-V accumulation into `out` with fused dequant (no
    /// zero-init — the dispatcher fills for the overwrite variant): 8 codes
    /// per pass widened i8→i16→i32→f32 (exact), then separate mul-then-add
    /// — no FMA — in position order, matching the scalar rounding sequence
    /// bitwise, segmented or not.
    ///
    /// # Safety
    /// Caller must guarantee NEON is present and
    /// `values.len() == scores.len() * out.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn pv_accum_int8_add(
        scores: &[f32],
        values: &[i8],
        v_scales: &[f32],
        out: &mut [f32],
    ) {
        unsafe {
            let hd = out.len();
            let n = scores.len();
            let chunks = hd / 8 * 8;
            let vp = values.as_ptr();
            let op = out.as_mut_ptr();
            for tk in 0..n {
                let wv = scores[tk] * v_scales[tk];
                let wvec = vdupq_n_f32(wv);
                let base = vp.add(tk * hd);
                let mut i = 0usize;
                while i < chunks {
                    let c16 = vmovl_s8(vld1_s8(base.add(i)));
                    let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(c16)));
                    let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(c16)));
                    let o0 = vaddq_f32(vld1q_f32(op.add(i)), vmulq_f32(wvec, lo));
                    let o1 = vaddq_f32(vld1q_f32(op.add(i + 4)), vmulq_f32(wvec, hi));
                    vst1q_f32(op.add(i), o0);
                    vst1q_f32(op.add(i + 4), o1);
                    i += 8;
                }
                for i in chunks..hd {
                    *op.add(i) += wv * (*base.add(i) as f32);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Straight-line replica of the retired `attn_cached_span` inner loops
    /// (the pre-kernel scalar attention): per row, `gemm::dot`-scored sweep,
    /// in-order softmax, zero-init += PV accumulation.
    #[allow(clippy::too_many_arguments)]
    fn reference_span(
        q: &[f32],
        d: usize,
        s: usize,
        hd: usize,
        pos0: usize,
        t: usize,
        keys: &[f32],
        values: &[f32],
        scale: f32,
    ) -> Vec<f32> {
        let mut out = vec![0f32; t * hd];
        let mut scores = vec![0f32; pos0 + t];
        for j in 0..t {
            let t_seen = pos0 + j + 1;
            let qh = &q[j * d + s..j * d + s + hd];
            for tk in 0..t_seen {
                scores[tk] = crate::tensor::dot(qh, &keys[tk * hd..(tk + 1) * hd]) * scale;
            }
            let sc = &mut scores[..t_seen];
            let max = sc.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut sum = 0f32;
            for v in sc.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in sc.iter_mut() {
                *v *= inv;
            }
            let orow = &mut out[j * hd..(j + 1) * hd];
            for tk in 0..t_seen {
                let w = sc[tk];
                for (o, &vv) in orow.iter_mut().zip(&values[tk * hd..(tk + 1) * hd]) {
                    *o += w * vv;
                }
            }
        }
        out
    }

    fn random_case(
        rng: &mut Pcg64,
        hd: usize,
        nh: usize,
        pos0: usize,
        t: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let d = nh * hd;
        let q: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
        let keys: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
        let values: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
        (q, keys, values)
    }

    #[test]
    fn scalar_span_bitwise_matches_prerefactor_reference() {
        let mut rng = Pcg64::seed(1201);
        for (hd, nh, pos0, t) in
            [(1, 1, 0, 1), (3, 2, 5, 3), (5, 1, 0, 7), (8, 4, 2, 1), (11, 2, 9, 4), (16, 1, 31, 8)]
        {
            let (q, keys, values) = random_case(&mut rng, hd, nh, pos0, t);
            let scale = 1.0 / (hd as f32).sqrt();
            let d = nh * hd;
            for s_head in 0..nh {
                let s = s_head * hd;
                let want = reference_span(&q, d, s, hd, pos0, t, &keys, &values, scale);
                let mut scores = vec![0f32; pos0 + t];
                let mut got = vec![7f32; t * hd]; // poisoned: out must be overwritten
                attn_head_span(
                    AttnKernelKind::Scalar,
                    &q,
                    d,
                    s,
                    hd,
                    pos0,
                    t,
                    &keys,
                    &values,
                    scale,
                    &mut scores,
                    &mut got,
                );
                assert_eq!(got, want, "hd={hd} nh={nh} pos0={pos0} t={t} head={s_head}");
            }
        }
    }

    #[test]
    fn simd_span_matches_scalar_within_tolerance() {
        let kind = detect_attn_kernel();
        if kind == AttnKernelKind::Scalar {
            return; // no SIMD on this host; scalar covered above
        }
        let mut rng = Pcg64::seed(1202);
        // Head dims straddle the SIMD lane width (8 for AVX2, 4 for NEON),
        // spans straddle the 4-key/4-weight blocks, nh = 1 included.
        for (hd, nh, pos0, t) in [
            (1, 1, 0, 1),
            (3, 2, 5, 3),
            (7, 1, 2, 5),
            (8, 2, 0, 9),
            (9, 1, 6, 2),
            (12, 3, 1, 4),
            (20, 2, 65, 1),
            (32, 1, 13, 6),
        ] {
            let (q, keys, values) = random_case(&mut rng, hd, nh, pos0, t);
            let scale = 1.0 / (hd as f32).sqrt();
            let d = nh * hd;
            let mut scores = vec![0f32; pos0 + t];
            let mut want = vec![0f32; t * hd];
            attn_head_span(
                AttnKernelKind::Scalar,
                &q,
                d,
                0,
                hd,
                pos0,
                t,
                &keys,
                &values,
                scale,
                &mut scores,
                &mut want,
            );
            let mut got = vec![0f32; t * hd];
            attn_head_span(
                kind, &q, d, 0, hd, pos0, t, &keys, &values, scale, &mut scores, &mut got,
            );
            let wmax = want.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1.0);
            let diff = got
                .iter()
                .zip(&want)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(diff < 1e-5 * wmax, "{kind} hd={hd} pos0={pos0} t={t}: diff {diff}");
        }
    }

    /// Random int8 codes in `[-127, 127]` (never −128, like the quantizers
    /// emit) plus positive per-row scales.
    fn random_int8_case(
        rng: &mut Pcg64,
        hd: usize,
        nh: usize,
        pos0: usize,
        t: usize,
    ) -> (Vec<i8>, Vec<f32>, Vec<i8>, Vec<f32>, Vec<i8>, Vec<f32>) {
        let d = nh * hd;
        let code = |rng: &mut Pcg64| (rng.below(255) as i32 - 127) as i8;
        let scale = |rng: &mut Pcg64| 0.01 + rng.below(1000) as f32 * 1e-3;
        let q: Vec<i8> = (0..t * d).map(|_| code(rng)).collect();
        let q_scales: Vec<f32> = (0..t * nh).map(|_| scale(rng)).collect();
        let keys: Vec<i8> = (0..(pos0 + t) * hd).map(|_| code(rng)).collect();
        let k_scales: Vec<f32> = (0..pos0 + t).map(|_| scale(rng)).collect();
        let values: Vec<i8> = (0..(pos0 + t) * hd).map(|_| code(rng)).collect();
        let v_scales: Vec<f32> = (0..pos0 + t).map(|_| scale(rng)).collect();
        (q, q_scales, keys, k_scales, values, v_scales)
    }

    #[test]
    fn int8_scalar_span_bitwise_matches_straightline_reference() {
        // Pins the int8 scalar kernels against a straight-line replica of
        // their defining loops: exact i32 q·K with scale-at-writeback,
        // in-order softmax, zero-init (w·v_scale)·code accumulation.
        let mut rng = Pcg64::seed(1204);
        for (hd, nh, pos0, t) in
            [(1, 1, 0, 1), (3, 2, 5, 3), (8, 4, 2, 1), (16, 1, 31, 8), (20, 2, 9, 4)]
        {
            let (q, q_scales, keys, k_scales, values, v_scales) =
                random_int8_case(&mut rng, hd, nh, pos0, t);
            let scale = 1.0 / (hd as f32).sqrt();
            let d = nh * hd;
            for head in 0..nh {
                let s = head * hd;
                let mut want = vec![0f32; t * hd];
                let mut scores = vec![0f32; pos0 + t];
                for j in 0..t {
                    let t_seen = pos0 + j + 1;
                    let qh = &q[j * d + s..j * d + s + hd];
                    let qs = q_scales[j * nh + head] * scale;
                    for tk in 0..t_seen {
                        let mut acc = 0i32;
                        for i in 0..hd {
                            acc += qh[i] as i32 * keys[tk * hd + i] as i32;
                        }
                        scores[tk] = acc as f32 * (qs * k_scales[tk]);
                    }
                    let sc = &mut scores[..t_seen];
                    let max = sc.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                    let mut sum = 0f32;
                    for v in sc.iter_mut() {
                        *v = (*v - max).exp();
                        sum += *v;
                    }
                    let inv = 1.0 / sum;
                    for v in sc.iter_mut() {
                        *v *= inv;
                    }
                    let orow = &mut want[j * hd..(j + 1) * hd];
                    for tk in 0..t_seen {
                        let wv = sc[tk] * v_scales[tk];
                        for (o, &c) in orow.iter_mut().zip(&values[tk * hd..(tk + 1) * hd]) {
                            *o += wv * (c as f32);
                        }
                    }
                }
                let mut got = vec![7f32; t * hd]; // poisoned: out must be overwritten
                attn_head_span_int8(
                    AttnKernelKind::Scalar,
                    &q,
                    &q_scales,
                    nh,
                    head,
                    d,
                    s,
                    hd,
                    pos0,
                    t,
                    &keys,
                    &k_scales,
                    &values,
                    &v_scales,
                    scale,
                    &mut scores,
                    &mut got,
                );
                assert_eq!(got, want, "hd={hd} nh={nh} pos0={pos0} t={t} head={head}");
            }
        }
    }

    #[test]
    fn int8_simd_span_bitwise_matches_int8_scalar() {
        // The int8 contract is stronger than the f32 one: exact integer
        // accumulation + identical writeback expressions ⇒ SIMD must equal
        // the int8 scalar reference bit-for-bit, across lane-straddling
        // head dims (hd ∤ 16 and ∤ 8), spans, and deep pos0.
        let kind = detect_attn_kernel();
        if kind == AttnKernelKind::Scalar {
            return; // no SIMD on this host; scalar covered above
        }
        let mut rng = Pcg64::seed(1205);
        for (hd, nh, pos0, t) in [
            (1, 1, 0, 1),
            (3, 2, 5, 3),
            (7, 1, 2, 5),
            (8, 2, 0, 9),
            (9, 1, 6, 2),
            (16, 4, 31, 8),
            (17, 1, 12, 3),
            (20, 2, 65, 1),
            (32, 1, 13, 6),
        ] {
            let (q, q_scales, keys, k_scales, values, v_scales) =
                random_int8_case(&mut rng, hd, nh, pos0, t);
            let scale = 1.0 / (hd as f32).sqrt();
            let d = nh * hd;
            let mut scores = vec![0f32; pos0 + t];
            let mut want = vec![0f32; t * hd];
            attn_head_span_int8(
                AttnKernelKind::Scalar,
                &q,
                &q_scales,
                nh,
                0,
                d,
                0,
                hd,
                pos0,
                t,
                &keys,
                &k_scales,
                &values,
                &v_scales,
                scale,
                &mut scores,
                &mut want,
            );
            let mut got = vec![7f32; t * hd];
            attn_head_span_int8(
                kind,
                &q,
                &q_scales,
                nh,
                0,
                d,
                0,
                hd,
                pos0,
                t,
                &keys,
                &k_scales,
                &values,
                &v_scales,
                scale,
                &mut scores,
                &mut got,
            );
            assert_eq!(got, want, "{kind} hd={hd} pos0={pos0} t={t}");
        }
    }

    #[test]
    fn int8_span_tracks_f32_span_on_quantized_data() {
        // Quantize f32 K/V/q with quantize_tile and check the fused-dequant
        // int8 span stays within int8 tolerance of the f32 span on the same
        // data — the kernel-level version of the model-level property test.
        let mut rng = Pcg64::seed(1206);
        for (hd, pos0, t) in [(8, 5, 3), (16, 40, 4), (20, 9, 2)] {
            let d = hd;
            let q: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
            let keys: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
            let values: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
            let scale = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0f32; pos0 + t];
            let mut want = vec![0f32; t * hd];
            attn_head_span(
                AttnKernelKind::Scalar,
                &q,
                d,
                0,
                hd,
                pos0,
                t,
                &keys,
                &values,
                scale,
                &mut scores,
                &mut want,
            );
            let quant_rows = |x: &[f32], rows: usize| {
                let mut codes = vec![0i8; x.len()];
                let mut scales = vec![0f32; rows];
                for r in 0..rows {
                    scales[r] = crate::quant::quantize_tile(
                        &x[r * hd..(r + 1) * hd],
                        8,
                        &mut codes[r * hd..(r + 1) * hd],
                    );
                }
                (codes, scales)
            };
            let (qc, qs) = quant_rows(&q, t);
            let (kc, ks) = quant_rows(&keys, pos0 + t);
            let (vc, vs) = quant_rows(&values, pos0 + t);
            let mut got = vec![0f32; t * hd];
            attn_head_span_int8(
                AttnKernelKind::Scalar,
                &qc,
                &qs,
                1,
                0,
                d,
                0,
                hd,
                pos0,
                t,
                &kc,
                &ks,
                &vc,
                &vs,
                scale,
                &mut scores,
                &mut got,
            );
            let wmax = want.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1.0);
            let diff =
                got.iter().zip(&want).fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(diff < 0.1 * wmax, "hd={hd} pos0={pos0} t={t}: diff {diff}");
        }
    }

    #[test]
    fn softmax_kernels_normalize() {
        let mut rng = Pcg64::seed(1203);
        for kind in [AttnKernelKind::Scalar, detect_attn_kernel()] {
            for n in [1usize, 3, 7, 8, 9, 31, 64] {
                let mut x: Vec<f32> = (0..n).map(|_| rng.normal() * 4.0).collect();
                softmax(kind, &mut x);
                let sum: f32 = x.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "{kind} n={n}: sum {sum}");
                assert!(x.iter().all(|&v| v >= 0.0), "{kind} n={n}: negative weight");
            }
        }
    }

    #[test]
    fn detection_is_consistent() {
        let kind = detect_attn_kernel();
        assert!(kind.available());
        assert!(AttnKernelKind::Scalar.available());
        assert_eq!(AttnKernelKind::Scalar.name(), "scalar");
        assert!(auto_threads(1) == 1, "tiny batches stay inline");
        assert!(auto_threads(1 << 20) >= 1);
    }
}
