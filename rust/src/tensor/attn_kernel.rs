//! SIMD f32 microkernels for attention over head-major KV tiles.
//!
//! PRs 1–3 moved every linear projection onto runtime-dispatched packed int8
//! GEMMs; attention over the KV cache was the last scalar dot loop on the
//! serving path and the Amdahl bottleneck at long contexts. This module is
//! its kernel layer: the three inner loops of cached causal attention —
//!
//! 1. **q·K score sweep** ([`qk_scores`]): one query head-vector against a
//!    contiguous `t_seen × hd` key tile, producing scaled scores,
//! 2. **softmax** ([`softmax`]): max / exp / sum / normalize in place,
//! 3. **weighted-V accumulation** ([`pv_accum`]): `out = Σ_tk w[tk] · v[tk]`
//!    over the matching value tile —
//!
//! each dispatched on an [`AttnKernelKind`] selected once per forward call
//! (runtime feature detection, like `qgemm_kernel`):
//!
//! * [`AttnKernelKind::Scalar`] — portable reference. Its q·K dot is
//!   [`gemm::dot`] (the 8-wide unroll with the pinned summation order), its
//!   softmax and PV loops reproduce the pre-kernel `attn_cached_span` inner
//!   loops **bitwise** — the property tests pin the scalar kernel against a
//!   straight-line replica of that retired implementation with `assert_eq`.
//! * [`AttnKernelKind::Avx2`] — x86-64 AVX2+FMA: the score sweep processes
//!   4 keys per pass (each query register load amortized across 4 fused
//!   multiply-add accumulators), softmax vectorizes the max reduction and
//!   the `1/sum` normalization (the `exp` calls stay scalar — a polynomial
//!   exp would trade accuracy for nothing measurable here), and the PV
//!   accumulation broadcasts 4 weights per output-register round trip.
//! * [`AttnKernelKind::Neon`] — aarch64 `vfmaq_f32` variants of the same
//!   three loops.
//!
//! Unlike the int8 kernels (exact i32 ⇒ bitwise across kernels), these are
//! f32: the SIMD variants reassociate the reductions, so they promise
//! tolerance-level agreement with the scalar reference, not bit equality.
//! What **is** bitwise-stable: the scalar kernel vs the pre-refactor code,
//! and any single kernel across batch shapes and thread counts (work items
//! never share accumulators — see `Gpt::attn_layer`).
//!
//! All kernels stream **unit-stride tiles**: the head-major `KvCache` layout
//! (`coordinator::kvpool`) stores each (layer, head) as a contiguous
//! `cap × hd` panel, so consecutive cache positions are `hd` floats apart —
//! the score sweep and PV accumulation walk memory linearly instead of
//! striding `d_model` between positions as the row-major layout forced.

// Index-heavy microkernels: indexed loops mirror the register tiling and
// keep the scalar/SIMD variants visually aligned.
#![allow(clippy::needless_range_loop)]

use super::gemm::dot;

/// The attention microkernel for this host, selected per forward call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnKernelKind {
    /// Portable reference kernel; bitwise-pinned against the pre-kernel
    /// scalar attention loops.
    Scalar,
    /// x86-64 AVX2 + FMA kernel.
    Avx2,
    /// aarch64 NEON kernel.
    Neon,
}

impl AttnKernelKind {
    pub fn name(self) -> &'static str {
        match self {
            AttnKernelKind::Scalar => "scalar",
            AttnKernelKind::Avx2 => "avx2",
            AttnKernelKind::Neon => "neon",
        }
    }

    /// Whether this kernel can run on the current host (compile target arch
    /// AND runtime CPU features).
    pub fn available(self) -> bool {
        match self {
            AttnKernelKind::Scalar => true,
            AttnKernelKind::Avx2 => avx2_fma_available(),
            AttnKernelKind::Neon => neon_available(),
        }
    }
}

impl std::fmt::Display for AttnKernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_available() -> bool {
    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
}
#[cfg(not(target_arch = "x86_64"))]
fn avx2_fma_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

/// Pick the fastest attention kernel available on this host. Feature
/// detection results are cached by std, so calling this once per forward
/// pass is cheap.
pub fn detect_attn_kernel() -> AttnKernelKind {
    if AttnKernelKind::Avx2.available() {
        AttnKernelKind::Avx2
    } else if AttnKernelKind::Neon.available() {
        AttnKernelKind::Neon
    } else {
        AttnKernelKind::Scalar
    }
}

/// Thread count for a span-attention batch of `macs` q·K multiply-adds:
/// decode and short-context batches stay inline; long-context decode and
/// teacher-forced prefill fan out across (sequence × head) work items.
/// The floor is ~2²⁰ MACs — ≳ 100µs of scalar / tens of µs of SIMD f32
/// work, comfortably above the ~10µs-per-worker scoped-thread spawn (raw
/// MACs are ~d_in× finer-grained than qgemm's output-element unit, hence
/// the higher floor). The spawn-cost logic lives in
/// [`crate::util::pool::fanout_threads`], shared with the qgemm row-block
/// heuristic.
pub fn auto_threads(macs: usize) -> usize {
    crate::util::pool::fanout_threads(macs, 1 << 20)
}

// ---------------------------------------------------------------------------
// Batch-lifetime scratch

/// Grow-only scratch for the span-attention driver (`Gpt::attn_layer`), the
/// attention analog of `QGemmArena` (it rides inside it as
/// `QGemmArena::attn`): staged roped queries, per-(sequence × head) score
/// rows, and the head-major output tiles. Capacities are high-water and
/// never released, so steady-state decode iterations allocate nothing;
/// every consumed element is overwritten before being read (queries are
/// staged, scores written by the sweep, tiles zero-filled by [`pv_accum`]),
/// so stale tails are never observed.
#[derive(Default)]
pub struct AttnArena {
    /// Staged roped queries, total × d row-major.
    pub(crate) q: Vec<f32>,
    /// Concatenated per-(sequence, head) score rows (`pos0 + t` each).
    pub(crate) scores: Vec<f32>,
    /// Head-major output tiles: per sequence, nh panels of `t × hd`.
    pub(crate) tiles: Vec<f32>,
    /// (sequence, head, scores offset, tile offset) work items — refilled
    /// per layer but capacity-reused, so the layer loop allocates nothing.
    pub(crate) items: Vec<(usize, usize, usize, usize)>,
}

impl AttnArena {
    pub fn new() -> AttnArena {
        AttnArena::default()
    }

    pub(crate) fn ensure(&mut self, q_len: usize, scores_len: usize, tiles_len: usize) {
        if self.q.len() < q_len {
            self.q.resize(q_len, 0.0);
        }
        if self.scores.len() < scores_len {
            self.scores.resize(scores_len, 0.0);
        }
        if self.tiles.len() < tiles_len {
            self.tiles.resize(tiles_len, 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch

/// `scores[tk] = dot(q, keys[tk·hd .. (tk+1)·hd]) · scale` over a contiguous
/// key tile (`keys.len() == scores.len() · q.len()`). The caller must only
/// pass a `kind` that is [`AttnKernelKind::available`] on this host.
pub fn qk_scores(kind: AttnKernelKind, q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
    debug_assert_eq!(keys.len(), scores.len() * q.len());
    match kind {
        AttnKernelKind::Scalar => qk_scores_scalar(q, keys, scale, scores),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability is asserted by `attn_head_span` / checked by
        // callers per the contract above.
        AttnKernelKind::Avx2 => unsafe { avx2::qk_scores(q, keys, scale, scores) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        AttnKernelKind::Neon => unsafe { neon::qk_scores(q, keys, scale, scores) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// In-place softmax (max / exp / sum / normalize). Same contract on `kind`.
pub fn softmax(kind: AttnKernelKind, x: &mut [f32]) {
    match kind {
        AttnKernelKind::Scalar => softmax_scalar(x),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Avx2 => unsafe { avx2::softmax(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Neon => unsafe { neon::softmax(x) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// `out = Σ_tk scores[tk] · values[tk·hd .. (tk+1)·hd]` over a contiguous
/// value tile (`values.len() == scores.len() · out.len()`). `out` is fully
/// overwritten. Same contract on `kind`.
pub fn pv_accum(kind: AttnKernelKind, scores: &[f32], values: &[f32], out: &mut [f32]) {
    debug_assert_eq!(values.len(), scores.len() * out.len());
    match kind {
        AttnKernelKind::Scalar => pv_accum_scalar(scores, values, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Avx2 => unsafe { avx2::pv_accum(scores, values, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see `qk_scores`.
        AttnKernelKind::Neon => unsafe { neon::pv_accum(scores, values, out) },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// One (sequence, head) causal attention work item over head-major KV tiles
/// — the unit `Gpt::attn_layer` fans out across cores.
///
/// `q` holds the span's staged (already roped) query rows at row stride `d`
/// with this head's lanes at column offset `s`; `keys` / `values` are the
/// head's contiguous `(pos0 + t) × hd` tiles (span rows already appended);
/// `scores` is caller scratch of ≥ `pos0 + t` entries; `out` is the span's
/// `t × hd` head tile, fully overwritten. Row `j` attends over cache
/// positions `0..=pos0+j` — in-span future rows are masked purely by the
/// loop bound, which is what keeps every chunking of a prompt numerically
/// identical per row.
#[allow(clippy::too_many_arguments)]
pub fn attn_head_span(
    kind: AttnKernelKind,
    q: &[f32],
    d: usize,
    s: usize,
    hd: usize,
    pos0: usize,
    t: usize,
    keys: &[f32],
    values: &[f32],
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    assert!(kind.available(), "attention kernel {kind:?} not available on this host");
    assert!(t > 0, "empty span");
    debug_assert!(q.len() >= (t - 1) * d + s + hd);
    debug_assert!(keys.len() >= (pos0 + t) * hd);
    debug_assert!(values.len() >= (pos0 + t) * hd);
    debug_assert!(scores.len() >= pos0 + t);
    debug_assert_eq!(out.len(), t * hd);
    for j in 0..t {
        let t_seen = pos0 + j + 1;
        let qh = &q[j * d + s..j * d + s + hd];
        qk_scores(kind, qh, &keys[..t_seen * hd], scale, &mut scores[..t_seen]);
        softmax(kind, &mut scores[..t_seen]);
        pv_accum(kind, &scores[..t_seen], &values[..t_seen * hd], &mut out[j * hd..(j + 1) * hd]);
    }
}

// ---------------------------------------------------------------------------
// Scalar reference kernels
//
// These reproduce the retired `attn_cached_span` inner loops exactly: the
// score sweep uses `gemm::dot` (the pinned 8-wide summation order), softmax
// folds max / exp-sums / normalizes in position order, and the PV loop
// accumulates into a zeroed output in position order. Property tests pin
// all three bitwise against a straight-line replica.

fn qk_scores_scalar(q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
    let hd = q.len();
    for (tk, sc) in scores.iter_mut().enumerate() {
        *sc = dot(q, &keys[tk * hd..(tk + 1) * hd]) * scale;
    }
}

fn softmax_scalar(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

fn pv_accum_scalar(scores: &[f32], values: &[f32], out: &mut [f32]) {
    let hd = out.len();
    out.fill(0.0);
    for (tk, &w) in scores.iter().enumerate() {
        let vrow = &values[tk * hd..(tk + 1) * hd];
        for (o, &vv) in out.iter_mut().zip(vrow) {
            *o += w * vv;
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    //! AVX2+FMA attention kernels. The reductions reassociate relative to
    //! the scalar reference (8-lane partial sums + scalar tails), so these
    //! agree to f32 tolerance, not bitwise — see the module doc.

    use std::arch::x86_64::*;

    /// Horizontal sum of the 8 f32 lanes of `v`.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        // Explicit inner block: edition-2024-proof (unsafe_op_in_unsafe_fn).
        unsafe {
            let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps::<0x55>(s, s));
            _mm_cvtss_f32(s)
        }
    }

    /// Score sweep: 4 keys per pass so each 8-lane query load feeds four
    /// FMA accumulators; lane tail (`hd % 8`) and key tail (`n % 4`) run
    /// scalar.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present and
    /// `keys.len() == scores.len() * q.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn qk_scores(q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
        unsafe {
            let hd = q.len();
            let n = scores.len();
            let chunks = hd / 8 * 8;
            let qp = q.as_ptr();
            let kp = keys.as_ptr();
            let mut tk = 0usize;
            while tk + 4 <= n {
                let base = [
                    kp.add(tk * hd),
                    kp.add((tk + 1) * hd),
                    kp.add((tk + 2) * hd),
                    kp.add((tk + 3) * hd),
                ];
                let mut acc = [_mm256_setzero_ps(); 4];
                let mut i = 0usize;
                while i < chunks {
                    let qv = _mm256_loadu_ps(qp.add(i));
                    acc[0] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[0].add(i)), acc[0]);
                    acc[1] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[1].add(i)), acc[1]);
                    acc[2] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[2].add(i)), acc[2]);
                    acc[3] = _mm256_fmadd_ps(qv, _mm256_loadu_ps(base[3].add(i)), acc[3]);
                    i += 8;
                }
                let mut j = 0usize;
                while j < 4 {
                    let mut s = hsum_ps(acc[j]);
                    for i in chunks..hd {
                        s += q[i] * *base[j].add(i);
                    }
                    scores[tk + j] = s * scale;
                    j += 1;
                }
                tk += 4;
            }
            while tk < n {
                let base = kp.add(tk * hd);
                let mut acc = _mm256_setzero_ps();
                let mut i = 0usize;
                while i < chunks {
                    acc = _mm256_fmadd_ps(
                        _mm256_loadu_ps(qp.add(i)),
                        _mm256_loadu_ps(base.add(i)),
                        acc,
                    );
                    i += 8;
                }
                let mut s = hsum_ps(acc);
                for i in chunks..hd {
                    s += q[i] * *base.add(i);
                }
                scores[tk] = s * scale;
                tk += 1;
            }
        }
    }

    /// Softmax with a vectorized max reduction and `1/sum` normalization;
    /// the exp stage stays scalar (accuracy over a marginal win).
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn softmax(x: &mut [f32]) {
        unsafe {
            let n = x.len();
            let chunks = n / 8 * 8;
            let mut max = {
                let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
                let p = x.as_ptr();
                let mut i = 0usize;
                while i < chunks {
                    vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(p.add(i)));
                    i += 8;
                }
                let m = _mm_max_ps(_mm256_castps256_ps128(vmax), _mm256_extractf128_ps::<1>(vmax));
                let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
                let m = _mm_max_ss(m, _mm_shuffle_ps::<0x55>(m, m));
                _mm_cvtss_f32(m)
            };
            for &v in &x[chunks..] {
                max = max.max(v);
            }
            let mut sum = 0f32;
            for v in x.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            let vinv = _mm256_set1_ps(inv);
            let pm = x.as_mut_ptr();
            let mut i = 0usize;
            while i < chunks {
                _mm256_storeu_ps(pm.add(i), _mm256_mul_ps(_mm256_loadu_ps(pm.add(i)), vinv));
                i += 8;
            }
            for v in &mut x[chunks..] {
                *v *= inv;
            }
        }
    }

    /// Weighted-V accumulation: 4 broadcast weights per output-register
    /// round trip (`out` loaded/stored once per 4 positions).
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA are present and
    /// `values.len() == scores.len() * out.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn pv_accum(scores: &[f32], values: &[f32], out: &mut [f32]) {
        unsafe {
            let hd = out.len();
            let n = scores.len();
            out.fill(0.0);
            let chunks = hd / 8 * 8;
            let vp = values.as_ptr();
            let op = out.as_mut_ptr();
            let mut tk = 0usize;
            while tk + 4 <= n {
                let base = [
                    vp.add(tk * hd),
                    vp.add((tk + 1) * hd),
                    vp.add((tk + 2) * hd),
                    vp.add((tk + 3) * hd),
                ];
                let w = [
                    _mm256_set1_ps(scores[tk]),
                    _mm256_set1_ps(scores[tk + 1]),
                    _mm256_set1_ps(scores[tk + 2]),
                    _mm256_set1_ps(scores[tk + 3]),
                ];
                let mut i = 0usize;
                while i < chunks {
                    let mut o = _mm256_loadu_ps(op.add(i));
                    o = _mm256_fmadd_ps(w[0], _mm256_loadu_ps(base[0].add(i)), o);
                    o = _mm256_fmadd_ps(w[1], _mm256_loadu_ps(base[1].add(i)), o);
                    o = _mm256_fmadd_ps(w[2], _mm256_loadu_ps(base[2].add(i)), o);
                    o = _mm256_fmadd_ps(w[3], _mm256_loadu_ps(base[3].add(i)), o);
                    _mm256_storeu_ps(op.add(i), o);
                    i += 8;
                }
                let mut j = 0usize;
                while j < 4 {
                    let s = scores[tk + j];
                    for i in chunks..hd {
                        *op.add(i) += s * *base[j].add(i);
                    }
                    j += 1;
                }
                tk += 4;
            }
            while tk < n {
                let base = vp.add(tk * hd);
                let w = _mm256_set1_ps(scores[tk]);
                let mut i = 0usize;
                while i < chunks {
                    let o = _mm256_fmadd_ps(w, _mm256_loadu_ps(base.add(i)), _mm256_loadu_ps(op.add(i)));
                    _mm256_storeu_ps(op.add(i), o);
                    i += 8;
                }
                let s = scores[tk];
                for i in chunks..hd {
                    *op.add(i) += s * *base.add(i);
                }
                tk += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    //! NEON `vfmaq_f32` attention kernels: 4-lane FMA streams over the
    //! contiguous tiles, scalar lane tails. Same tolerance contract as the
    //! AVX2 variants.

    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must guarantee NEON is present and
    /// `keys.len() == scores.len() * q.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn qk_scores(q: &[f32], keys: &[f32], scale: f32, scores: &mut [f32]) {
        unsafe {
            let hd = q.len();
            let n = scores.len();
            let chunks = hd / 4 * 4;
            let qp = q.as_ptr();
            let kp = keys.as_ptr();
            for tk in 0..n {
                let base = kp.add(tk * hd);
                let mut acc = vdupq_n_f32(0.0);
                let mut i = 0usize;
                while i < chunks {
                    acc = vfmaq_f32(acc, vld1q_f32(qp.add(i)), vld1q_f32(base.add(i)));
                    i += 4;
                }
                let mut s = vaddvq_f32(acc);
                for i in chunks..hd {
                    s += q[i] * *base.add(i);
                }
                scores[tk] = s * scale;
            }
        }
    }

    /// # Safety
    /// Caller must guarantee NEON is present.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn softmax(x: &mut [f32]) {
        unsafe {
            let n = x.len();
            let chunks = n / 4 * 4;
            let mut max = {
                let mut vmax = vdupq_n_f32(f32::NEG_INFINITY);
                let p = x.as_ptr();
                let mut i = 0usize;
                while i < chunks {
                    vmax = vmaxq_f32(vmax, vld1q_f32(p.add(i)));
                    i += 4;
                }
                vmaxvq_f32(vmax)
            };
            for &v in &x[chunks..] {
                max = max.max(v);
            }
            let mut sum = 0f32;
            for v in x.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            let vinv = vdupq_n_f32(inv);
            let pm = x.as_mut_ptr();
            let mut i = 0usize;
            while i < chunks {
                vst1q_f32(pm.add(i), vmulq_f32(vld1q_f32(pm.add(i)), vinv));
                i += 4;
            }
            for v in &mut x[chunks..] {
                *v *= inv;
            }
        }
    }

    /// # Safety
    /// Caller must guarantee NEON is present and
    /// `values.len() == scores.len() * out.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn pv_accum(scores: &[f32], values: &[f32], out: &mut [f32]) {
        unsafe {
            let hd = out.len();
            let n = scores.len();
            out.fill(0.0);
            let chunks = hd / 4 * 4;
            let vp = values.as_ptr();
            let op = out.as_mut_ptr();
            for tk in 0..n {
                let base = vp.add(tk * hd);
                let w = vdupq_n_f32(scores[tk]);
                let mut i = 0usize;
                while i < chunks {
                    let o = vfmaq_f32(vld1q_f32(op.add(i)), w, vld1q_f32(base.add(i)));
                    vst1q_f32(op.add(i), o);
                    i += 4;
                }
                let s = scores[tk];
                for i in chunks..hd {
                    *op.add(i) += s * *base.add(i);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Straight-line replica of the retired `attn_cached_span` inner loops
    /// (the pre-kernel scalar attention): per row, `gemm::dot`-scored sweep,
    /// in-order softmax, zero-init += PV accumulation.
    #[allow(clippy::too_many_arguments)]
    fn reference_span(
        q: &[f32],
        d: usize,
        s: usize,
        hd: usize,
        pos0: usize,
        t: usize,
        keys: &[f32],
        values: &[f32],
        scale: f32,
    ) -> Vec<f32> {
        let mut out = vec![0f32; t * hd];
        let mut scores = vec![0f32; pos0 + t];
        for j in 0..t {
            let t_seen = pos0 + j + 1;
            let qh = &q[j * d + s..j * d + s + hd];
            for tk in 0..t_seen {
                scores[tk] = crate::tensor::dot(qh, &keys[tk * hd..(tk + 1) * hd]) * scale;
            }
            let sc = &mut scores[..t_seen];
            let max = sc.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut sum = 0f32;
            for v in sc.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in sc.iter_mut() {
                *v *= inv;
            }
            let orow = &mut out[j * hd..(j + 1) * hd];
            for tk in 0..t_seen {
                let w = sc[tk];
                for (o, &vv) in orow.iter_mut().zip(&values[tk * hd..(tk + 1) * hd]) {
                    *o += w * vv;
                }
            }
        }
        out
    }

    fn random_case(
        rng: &mut Pcg64,
        hd: usize,
        nh: usize,
        pos0: usize,
        t: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let d = nh * hd;
        let q: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
        let keys: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
        let values: Vec<f32> = (0..(pos0 + t) * hd).map(|_| rng.normal()).collect();
        (q, keys, values)
    }

    #[test]
    fn scalar_span_bitwise_matches_prerefactor_reference() {
        let mut rng = Pcg64::seed(1201);
        for (hd, nh, pos0, t) in
            [(1, 1, 0, 1), (3, 2, 5, 3), (5, 1, 0, 7), (8, 4, 2, 1), (11, 2, 9, 4), (16, 1, 31, 8)]
        {
            let (q, keys, values) = random_case(&mut rng, hd, nh, pos0, t);
            let scale = 1.0 / (hd as f32).sqrt();
            let d = nh * hd;
            for s_head in 0..nh {
                let s = s_head * hd;
                let want = reference_span(&q, d, s, hd, pos0, t, &keys, &values, scale);
                let mut scores = vec![0f32; pos0 + t];
                let mut got = vec![7f32; t * hd]; // poisoned: out must be overwritten
                attn_head_span(
                    AttnKernelKind::Scalar,
                    &q,
                    d,
                    s,
                    hd,
                    pos0,
                    t,
                    &keys,
                    &values,
                    scale,
                    &mut scores,
                    &mut got,
                );
                assert_eq!(got, want, "hd={hd} nh={nh} pos0={pos0} t={t} head={s_head}");
            }
        }
    }

    #[test]
    fn simd_span_matches_scalar_within_tolerance() {
        let kind = detect_attn_kernel();
        if kind == AttnKernelKind::Scalar {
            return; // no SIMD on this host; scalar covered above
        }
        let mut rng = Pcg64::seed(1202);
        // Head dims straddle the SIMD lane width (8 for AVX2, 4 for NEON),
        // spans straddle the 4-key/4-weight blocks, nh = 1 included.
        for (hd, nh, pos0, t) in [
            (1, 1, 0, 1),
            (3, 2, 5, 3),
            (7, 1, 2, 5),
            (8, 2, 0, 9),
            (9, 1, 6, 2),
            (12, 3, 1, 4),
            (20, 2, 65, 1),
            (32, 1, 13, 6),
        ] {
            let (q, keys, values) = random_case(&mut rng, hd, nh, pos0, t);
            let scale = 1.0 / (hd as f32).sqrt();
            let d = nh * hd;
            let mut scores = vec![0f32; pos0 + t];
            let mut want = vec![0f32; t * hd];
            attn_head_span(
                AttnKernelKind::Scalar,
                &q,
                d,
                0,
                hd,
                pos0,
                t,
                &keys,
                &values,
                scale,
                &mut scores,
                &mut want,
            );
            let mut got = vec![0f32; t * hd];
            attn_head_span(
                kind, &q, d, 0, hd, pos0, t, &keys, &values, scale, &mut scores, &mut got,
            );
            let wmax = want.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1.0);
            let diff = got
                .iter()
                .zip(&want)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(diff < 1e-5 * wmax, "{kind} hd={hd} pos0={pos0} t={t}: diff {diff}");
        }
    }

    #[test]
    fn softmax_kernels_normalize() {
        let mut rng = Pcg64::seed(1203);
        for kind in [AttnKernelKind::Scalar, detect_attn_kernel()] {
            for n in [1usize, 3, 7, 8, 9, 31, 64] {
                let mut x: Vec<f32> = (0..n).map(|_| rng.normal() * 4.0).collect();
                softmax(kind, &mut x);
                let sum: f32 = x.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "{kind} n={n}: sum {sum}");
                assert!(x.iter().all(|&v| v >= 0.0), "{kind} n={n}: negative weight");
            }
        }
    }

    #[test]
    fn detection_is_consistent() {
        let kind = detect_attn_kernel();
        assert!(kind.available());
        assert!(AttnKernelKind::Scalar.available());
        assert_eq!(AttnKernelKind::Scalar.name(), "scalar");
        assert!(auto_threads(1) == 1, "tiny batches stay inline");
        assert!(auto_threads(1 << 20) >= 1);
    }
}
