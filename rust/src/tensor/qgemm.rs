//! Batched quantized GEMM — the packed-kernel layer of the serving path.
//!
//! This is where W4A8-class inference stops being a scalar token loop:
//! [`PackedQWeight`] is built **once at quantize time** (tile-packed int
//! codes, per-row scales, precomputed smoothing reciprocals, gathered fp
//! outlier columns, low-rank factors) and [`qgemm_forward`] then runs the
//! whole batch through one cache-blocked i8×i8→i32 GEMM per layer call:
//!
//! 1. smooth the batch with the precomputed reciprocals (`x' = x · (1/m)`),
//! 2. per-token quantize the batch into a reusable [`QGemmArena`] (no
//!    per-token `Vec` allocations on the steady-state decode path),
//! 3. integer micro-kernel: [`QR`]-row weight panels × a widened token
//!    tile, i32 accumulators, blocked over tokens (`TB`) and output rows
//!    ([`RB`], the `scope_map` parallel unit) mirroring the MC/NC/KC tiling
//!    of `gemm::matmul`,
//! 4. fused scale application (`token_scale × row_scale`) at write-out,
//! 5. fp outlier columns on the unquantized smoothed batch,
//! 6. blocked skinny-GEMM low-rank branch `Y += (X'·L_Bᵀ)·L_Aᵀ` via
//!    `matmul_bt_acc`.
//!
//! ## Kernel dispatch
//!
//! Step 3 dispatches to a microkernel selected **once at pack time**
//! (`tensor::qgemm_kernel`): AVX2 `maddubs`/`madd` on x86-64, NEON
//! `smull`/`sadalp` on aarch64 — both behind runtime feature detection —
//! with the portable scalar kernel as the always-available fallback and
//! reference. The panel interleave is a property of the selected kernel
//! ([`PackedQWeight::kernel`] / [`PackedQWeight::k_pad`]): k-major
//! QR-interleave for the scalar kernel, zero-padded row-major for the SIMD
//! kernels, chosen when the layer is packed so the serving loop never
//! re-dispatches per call.
//!
//! ## Determinism scope
//!
//! * The **int path (A≤8)** accumulates exact i32 everywhere, so results
//!   are bitwise identical across kernels (scalar/AVX2/NEON), thread
//!   counts, and batch sizes — pinned by `assert_eq` property tests.
//! * The **fp path (A16)** promises bitwise equality across thread counts
//!   and against the pre-widening QR×1 kernel (each (row, token)
//!   accumulator walks k in ascending order), but only tolerance-level
//!   agreement with other f32 orderings (`matmul_bt`, dense reference).
//! * [`auto_threads`] is a shape heuristic only — it never changes values,
//!   because row-block jobs partition disjoint output columns.
//!
//! `QuantizedLinear::forward_matrix` (methods layer) remains the reference
//! semantics; the equivalence property tests in `tests/properties.rs` pin
//! this kernel against it and against the scalar token path.

use super::attn_kernel::AttnArena;
use super::gemm::{axpy, matmul_bt_acc};
use super::matrix::Matrix;
use super::qgemm_kernel::{self, detect_kernel, QKernelKind};
use crate::quant::act::quantize_token_into;
use crate::quant::spec::FP;
use crate::util::pool::scope_map;

pub use super::qgemm_kernel::QR;

/// Output rows per `scope_map` job (the NC analog; must be a multiple of QR).
const RB: usize = 64;

/// Weight in the layout the batched kernel consumes, built once at quantize
/// time from a `QuantizedLinear`'s parts (see `QuantizedLinear::pack`).
#[derive(Clone, Debug)]
pub struct PackedQWeight {
    pub d_out: usize,
    pub d_in: usize,
    pub wbits: u8,
    /// Activation bits for the main GEMM input (`quant::FP` = fp main GEMM).
    pub abits: u8,
    /// Microkernel this weight was packed for; fixes the panel layout of
    /// `packed` (see `tensor::qgemm_kernel::pack_codes`).
    pub kernel: QKernelKind,
    /// Panel row k-stride: `d_in` padded to the kernel's SIMD chunk
    /// (== `d_in` for the scalar layout).
    pub k_pad: usize,
    /// Codes packed in QR-row panels in the layout `kernel` streams.
    packed: Vec<i8>,
    /// Per-output-row weight scales.
    pub scales: Vec<f32>,
    /// Precomputed smoothing reciprocals `1/m` (None = no smoothing).
    pub smooth_recip: Option<Vec<f32>>,
    /// Full-precision outlier columns, (input col index, column of W).
    pub fp_cols: Vec<(usize, Vec<f32>)>,
    /// Low-rank factors (L_A: out×r, L_B: r×in) applied to the smoothed fp
    /// activations.
    pub low_rank: Option<(Matrix, Matrix)>,
}

impl PackedQWeight {
    /// Tile-pack quantized codes plus all fused serve-time operands, with
    /// the microkernel auto-detected for the host.
    #[allow(clippy::too_many_arguments)]
    pub fn pack(
        codes: &[i8],
        d_out: usize,
        d_in: usize,
        wbits: u8,
        abits: u8,
        scales: &[f32],
        act_smooth: Option<&[f32]>,
        fp_cols: &[(usize, Vec<f32>)],
        low_rank: Option<(&Matrix, &Matrix)>,
    ) -> PackedQWeight {
        Self::pack_with_kernel(
            codes,
            d_out,
            d_in,
            wbits,
            abits,
            scales,
            act_smooth,
            fp_cols,
            low_rank,
            detect_kernel(),
        )
    }

    /// [`PackedQWeight::pack`] with an explicit kernel choice (benches and
    /// property tests pin the scalar reference kernel this way). Panics if
    /// `kind` is not available on this host. A16 layers always take the
    /// scalar layout — the SIMD int kernels never run on the fp main GEMM.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_with_kernel(
        codes: &[i8],
        d_out: usize,
        d_in: usize,
        wbits: u8,
        abits: u8,
        scales: &[f32],
        act_smooth: Option<&[f32]>,
        fp_cols: &[(usize, Vec<f32>)],
        low_rank: Option<(&Matrix, &Matrix)>,
        kind: QKernelKind,
    ) -> PackedQWeight {
        assert!(kind.available(), "kernel {kind:?} not available on this host");
        assert_eq!(codes.len(), d_out * d_in, "code count");
        assert_eq!(scales.len(), d_out, "scale count");
        let kind = if abits == FP { QKernelKind::Scalar } else { kind };
        let packed = qgemm_kernel::pack_codes(kind, codes, d_out, d_in);
        let smooth_recip = act_smooth.map(|m| {
            assert_eq!(m.len(), d_in, "smoothing vector length");
            m.iter().map(|&v| 1.0 / v).collect()
        });
        PackedQWeight {
            d_out,
            d_in,
            wbits,
            abits,
            kernel: kind,
            k_pad: kind.pad_k(d_in),
            packed,
            scales: scales.to_vec(),
            smooth_recip,
            fp_cols: fp_cols.to_vec(),
            low_rank: low_rank.map(|(a, b)| (a.clone(), b.clone())),
        }
    }

    /// Bytes held by the packed code buffer (overhead accounting; includes
    /// the SIMD layouts' zero padding).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len()
    }
}

/// Reusable per-caller scratch for the batched forward: smoothed fp
/// activations, int activation codes, per-token scales, low-rank
/// intermediate. Buffer **capacity is grow-only** (high-water, never
/// released) and lengths are only extended when a call actually needs more
/// rows — never re-filled just because call shapes vary (ragged prefill
/// chunks mix decode-sized and chunk-sized calls through one arena) — so
/// the steady-state serving loop neither allocates nor memsets
/// quantization scratch. Callers read only the `t`-row prefix of each
/// buffer; stale tails are never observed because every consumed element
/// is overwritten first (smoothing copy / `quantize_token_into` /
/// per-token scale stores).
#[derive(Default)]
pub struct QGemmArena {
    /// Smoothed fp activations, t × d_in row-major (prefix of the
    /// high-water buffer).
    xs: Vec<f32>,
    /// Per-token int codes, t rows at the packed weight's `k_pad` stride
    /// (tails beyond `d_in` are zeroed; the kernels' zero weight padding
    /// makes them inert either way).
    codes: Vec<i8>,
    /// Per-token activation scales.
    tok_scales: Vec<f32>,
    /// Low-rank intermediate z = X'·L_Bᵀ, t × r.
    z: Vec<f32>,
    /// Code-row stride the `codes` buffer was last laid out for. The
    /// stride invariant (`stride ≥ d_in`, i.e. the packed layout can hold
    /// a full activation row) is asserted once per layout switch here, not
    /// per call.
    stride: usize,
    /// Span-attention scratch (staged roped queries, per-(sequence × head)
    /// score rows, head-major output tiles) — same grow-only discipline,
    /// carried here so the serving loop threads ONE arena through both the
    /// packed GEMMs and `Gpt::attn_layer`.
    pub attn: AttnArena,
}

impl QGemmArena {
    pub fn new() -> QGemmArena {
        QGemmArena::default()
    }

    fn prepare(&mut self, t: usize, d_in: usize, stride: usize, int_path: bool) {
        // Grow-only (no clear, no shrink): growth pays its fill once at a
        // new high-water mark; afterwards varying chunk sizes reuse the
        // buffers as-is instead of resizing an O(t·d_in) region per layer
        // per iteration.
        if self.xs.len() < t * d_in {
            self.xs.resize(t * d_in, 0.0);
        }
        if int_path {
            if self.stride != stride {
                assert!(stride >= d_in, "packed stride {stride} < d_in {d_in}");
                self.stride = stride;
            }
            if self.codes.len() < t * stride {
                self.codes.resize(t * stride, 0);
            }
            if self.tok_scales.len() < t {
                self.tok_scales.resize(t, 1.0);
            }
        }
    }
}

/// Batched quantized forward: fp activations (t × d_in) → (t × d_out),
/// applying smoothing, per-token activation quantization, the packed int
/// GEMM, fp outlier columns, and the low-rank correction.
pub fn qgemm_forward(
    pw: &PackedQWeight,
    x: &Matrix,
    arena: &mut QGemmArena,
    threads: usize,
) -> Matrix {
    assert_eq!(x.cols, pw.d_in, "qgemm input width");
    forward_rows(pw, &x.data, x.rows, arena, threads)
}

/// Single-token forward through the same packed kernel (the scalar
/// `forward_step` reference path; serving and prefill go through
/// [`qgemm_forward`] with chunked token batches).
pub fn qgemm_forward_token(pw: &PackedQWeight, x: &[f32], arena: &mut QGemmArena) -> Vec<f32> {
    assert_eq!(x.len(), pw.d_in, "qgemm input width");
    forward_rows(pw, x, 1, arena, 1).data
}

fn forward_rows(
    pw: &PackedQWeight,
    x: &[f32],
    t: usize,
    arena: &mut QGemmArena,
    threads: usize,
) -> Matrix {
    let d_in = pw.d_in;
    let d_out = pw.d_out;
    debug_assert_eq!(x.len(), t * d_in);
    let int_path = pw.abits != FP;
    let stride = pw.k_pad;
    arena.prepare(t, d_in, stride, int_path);

    // 1. smoothing with precomputed reciprocals (or plain copy).
    match &pw.smooth_recip {
        Some(recip) => {
            for ti in 0..t {
                let src = &x[ti * d_in..(ti + 1) * d_in];
                let dst = &mut arena.xs[ti * d_in..(ti + 1) * d_in];
                for ((d, &v), &rc) in dst.iter_mut().zip(src).zip(recip) {
                    *d = v * rc;
                }
            }
        }
        None => arena.xs[..t * d_in].copy_from_slice(x),
    }

    let mut y = Matrix::zeros(t, d_out);
    if int_path {
        // 2. batch-level per-token activation quantization into the arena
        //    (same `quantize_token_into` the scalar path is built on, so the
        //    two paths produce identical codes/scales by construction).
        for ti in 0..t {
            let row = &arena.xs[ti * d_in..(ti + 1) * d_in];
            let dst = &mut arena.codes[ti * stride..(ti + 1) * stride];
            arena.tok_scales[ti] = quantize_token_into(row, pw.abits, &mut dst[..d_in]);
            dst[d_in..].fill(0); // SIMD pad lanes (≤ k_step-1 bytes per row)
        }
        // 3.+4. packed integer main GEMM with fused scale application,
        //       dispatched to the kernel this weight was packed for. The
        //       kernels see exactly the t-row prefix of the grow-only
        //       buffers.
        int_main(pw, &arena.codes[..t * stride], &arena.tok_scales[..t], t, &mut y, threads);
    } else {
        // A16: fp activations × int codes, row scale applied at write-out.
        fp_main(pw, &arena.xs[..t * d_in], t, &mut y, threads);
    }

    // 5. fp outlier columns act on the *unquantized* smoothed activations.
    for (c, wcol) in &pw.fp_cols {
        for ti in 0..t {
            let xv = arena.xs[ti * d_in + c];
            if xv != 0.0 {
                axpy(xv, wcol, y.row_mut(ti));
            }
        }
    }

    // 6. low-rank branch on the smoothed fp activations: Y += (X'·L_Bᵀ)·L_Aᵀ,
    //    both skinny GEMMs through the blocked matmul_bt kernel. The Matrix
    //    wrapper needs an exact t-row shape, so the buffer is truncated to
    //    t rows (len-only; capacity is never released, so this stays
    //    allocation-free) and handed back shortened — `prepare` re-extends
    //    the length lazily only when a later call actually needs more rows,
    //    so constant-shape steady-state decode never pays a re-fill.
    if let Some((la, lb)) = &pw.low_rank {
        let mut xs_data = std::mem::take(&mut arena.xs);
        xs_data.truncate(t * d_in);
        let xs_m = Matrix { rows: t, cols: d_in, data: xs_data };
        let mut z = Matrix { rows: t, cols: lb.rows, data: std::mem::take(&mut arena.z) };
        z.data.clear();
        z.data.resize(t * lb.rows, 0.0);
        matmul_bt_acc(&xs_m, lb, &mut z);
        matmul_bt_acc(&z, la, &mut y);
        arena.xs = xs_m.data;
        arena.z = z.data;
    }
    y
}

/// Split `d_out` into RB jobs, run them on `threads` scoped workers, and
/// scatter each job's (t × nr) column chunk into the row-major output.
fn run_row_jobs<F>(d_out: usize, t: usize, y: &mut Matrix, threads: usize, job: F)
where
    F: Fn(usize, usize) -> Vec<f32> + Sync,
{
    let n_jobs = d_out.div_ceil(RB);
    let chunks: Vec<Vec<f32>> = scope_map(n_jobs, threads, |jb| {
        let r0 = jb * RB;
        let r1 = (r0 + RB).min(d_out);
        job(r0, r1)
    });
    for (jb, chunk) in chunks.iter().enumerate() {
        let r0 = jb * RB;
        let nr = (r0 + RB).min(d_out) - r0;
        debug_assert_eq!(chunk.len(), t * nr);
        for ti in 0..t {
            y.row_mut(ti)[r0..r0 + nr].copy_from_slice(&chunk[ti * nr..(ti + 1) * nr]);
        }
    }
}

fn int_main(
    pw: &PackedQWeight,
    codes: &[i8],
    tok_scales: &[f32],
    t: usize,
    y: &mut Matrix,
    threads: usize,
) {
    run_row_jobs(pw.d_out, t, y, threads, |r0, r1| {
        let mut out = vec![0f32; t * (r1 - r0)];
        qgemm_kernel::run_int_job(
            pw.kernel, &pw.packed, pw.k_pad, pw.d_in, codes, tok_scales, &pw.scales, r0, r1, t,
            &mut out,
        );
        out
    });
}

fn fp_main(pw: &PackedQWeight, xs: &[f32], t: usize, y: &mut Matrix, threads: usize) {
    debug_assert_eq!(pw.kernel, QKernelKind::Scalar, "A16 packs force the scalar layout");
    run_row_jobs(pw.d_out, t, y, threads, |r0, r1| {
        let mut out = vec![0f32; t * (r1 - r0)];
        qgemm_kernel::fp_job(&pw.packed, pw.d_in, xs, &pw.scales, r0, r1, t, &mut out);
        out
    });
}

/// Thread count heuristic for a (t × d_out) quantized GEMM: stay inline
/// below `t·d_out = 2^16` output elements (each ~d_in int8 MACs; decode
/// batches with t ≤ 16 and d_out ≤ 4096 stay inline), fan out over row
/// blocks for eval/prefill-sized calls where the kernel dwarfs the spawn.
/// The spawn-cost logic lives in [`crate::util::pool::fanout_threads`],
/// shared with the attention span heuristic. Thread count never affects
/// values — see the determinism notes in the module doc.
pub fn auto_threads(t: usize, d_out: usize) -> usize {
    crate::util::pool::fanout_threads(t * d_out, 1 << 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Straight-line reference: dequantize-free scalar loop with the same
    /// quantization semantics.
    fn reference_forward(
        codes: &[i8],
        scales: &[f32],
        d_out: usize,
        d_in: usize,
        abits: u8,
        x: &Matrix,
    ) -> Matrix {
        let mut y = Matrix::zeros(x.rows, d_out);
        for ti in 0..x.rows {
            let row = x.row(ti);
            if abits == FP {
                for r in 0..d_out {
                    let wr = &codes[r * d_in..(r + 1) * d_in];
                    let mut acc = 0f32;
                    for (&c, &v) in wr.iter().zip(row) {
                        acc += c as f32 * v;
                    }
                    y[(ti, r)] = acc * scales[r];
                }
            } else {
                let qt = crate::quant::quantize_token(row, abits);
                for r in 0..d_out {
                    let wr = &codes[r * d_in..(r + 1) * d_in];
                    let mut acc = 0i32;
                    for (&c, &a) in wr.iter().zip(&qt.codes) {
                        acc += c as i32 * a as i32;
                    }
                    y[(ti, r)] = acc as f32 * (qt.scale * scales[r]);
                }
            }
        }
        y
    }

    fn random_codes(rng: &mut Pcg64, n: usize, qmax: i8) -> Vec<i8> {
        (0..n).map(|_| (rng.below(2 * qmax as usize + 1) as i8) - qmax).collect()
    }

    #[test]
    fn int_kernel_matches_reference_awkward_shapes() {
        let mut rng = Pcg64::seed(601);
        // d_out straddling QR and RB boundaries, batch straddling TB and the
        // token tiles, d_in straddling the SIMD chunk.
        for (t, d_in, d_out) in
            [(1, 17, 3), (7, 40, 24), (65, 33, 66), (9, 128, 130), (3, 31, 5), (5, 65, 8)]
        {
            let codes = random_codes(&mut rng, d_out * d_in, 7);
            let scales: Vec<f32> = (0..d_out).map(|_| 0.01 + rng.f32() * 0.05).collect();
            let x = Matrix::randn(&mut rng, t, d_in, 1.0);
            let pw = PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], None);
            let mut arena = QGemmArena::new();
            let got = qgemm_forward(&pw, &x, &mut arena, 1);
            let want = reference_forward(&codes, &scales, d_out, d_in, 8, &x);
            assert!(
                got.max_diff(&want) < 1e-5 * want.max_abs().max(1.0),
                "({t},{d_in},{d_out}) diff {}",
                got.max_diff(&want)
            );
        }
    }

    #[test]
    fn auto_and_scalar_kernels_bitwise_identical() {
        // The int path accumulates exact i32, so the auto-detected SIMD
        // kernel must reproduce the scalar kernel bit for bit (trivially
        // true when detection falls back to scalar).
        let mut rng = Pcg64::seed(606);
        for (t, d_in, d_out) in [(1, 31, 3), (2, 32, 5), (6, 33, 66), (7, 100, 24), (65, 64, 130)]
        {
            let codes = random_codes(&mut rng, d_out * d_in, 7);
            let scales: Vec<f32> = (0..d_out).map(|_| 0.01 + rng.f32() * 0.05).collect();
            let x = Matrix::randn(&mut rng, t, d_in, 1.0);
            let auto = PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], None);
            let scalar = PackedQWeight::pack_with_kernel(
                &codes,
                d_out,
                d_in,
                4,
                8,
                &scales,
                None,
                &[],
                None,
                QKernelKind::Scalar,
            );
            let ya = qgemm_forward(&auto, &x, &mut QGemmArena::new(), 1);
            let ys = qgemm_forward(&scalar, &x, &mut QGemmArena::new(), 1);
            assert_eq!(ya, ys, "kernel {:?} vs scalar ({t},{d_in},{d_out})", auto.kernel);
        }
    }

    #[test]
    fn fp_kernel_matches_reference() {
        let mut rng = Pcg64::seed(602);
        // Token counts straddle the widened 4-token tile.
        for (t, d_in, d_out) in [(11, 37, 29), (4, 40, 8), (3, 24, 5)] {
            let codes = random_codes(&mut rng, d_out * d_in, 7);
            let scales: Vec<f32> = (0..d_out).map(|_| 0.01 + rng.f32() * 0.05).collect();
            let x = Matrix::randn(&mut rng, t, d_in, 1.0);
            let pw = PackedQWeight::pack(&codes, d_out, d_in, 4, FP, &scales, None, &[], None);
            assert_eq!(pw.kernel, QKernelKind::Scalar, "A16 must take the scalar layout");
            let mut arena = QGemmArena::new();
            let got = qgemm_forward(&pw, &x, &mut arena, 1);
            let want = reference_forward(&codes, &scales, d_out, d_in, FP, &x);
            assert!(got.max_diff(&want) < 1e-4 * want.max_abs().max(1.0), "({t},{d_in},{d_out})");
        }
    }

    #[test]
    fn threaded_matches_single_threaded() {
        let mut rng = Pcg64::seed(603);
        let (t, d_in, d_out) = (33, 64, 200);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        let scales: Vec<f32> = (0..d_out).map(|_| 0.02 + rng.f32() * 0.02).collect();
        let x = Matrix::randn(&mut rng, t, d_in, 1.0);
        let pw = PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], None);
        let mut a1 = QGemmArena::new();
        let mut a4 = QGemmArena::new();
        let y1 = qgemm_forward(&pw, &x, &mut a1, 1);
        let y4 = qgemm_forward(&pw, &x, &mut a4, 4);
        assert_eq!(y1, y4, "row-block parallelism must be bitwise deterministic");
    }

    #[test]
    fn token_and_batch_paths_agree_with_all_branches() {
        let mut rng = Pcg64::seed(604);
        let (d_in, d_out, r) = (40, 24, 5);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        let scales: Vec<f32> = (0..d_out).map(|_| 0.02 + rng.f32() * 0.03).collect();
        let smooth: Vec<f32> = (0..d_in).map(|_| 0.5 + rng.f32() * 2.0).collect();
        let fp_cols = vec![
            (3usize, (0..d_out).map(|_| rng.normal() * 0.1).collect::<Vec<f32>>()),
            (17usize, (0..d_out).map(|_| rng.normal() * 0.1).collect::<Vec<f32>>()),
        ];
        let la = Matrix::randn(&mut rng, d_out, r, 0.05);
        let lb = Matrix::randn(&mut rng, r, d_in, 0.05);
        let pw = PackedQWeight::pack(
            &codes,
            d_out,
            d_in,
            4,
            8,
            &scales,
            Some(&smooth),
            &fp_cols,
            Some((&la, &lb)),
        );
        let x = Matrix::randn(&mut rng, 6, d_in, 1.0);
        let mut arena = QGemmArena::new();
        let batch = qgemm_forward(&pw, &x, &mut arena, 1);
        for ti in 0..x.rows {
            let y = qgemm_forward_token(&pw, x.row(ti), &mut arena);
            let d = batch
                .row(ti)
                .iter()
                .zip(&y)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-5, "token {ti}: diff {d}");
        }
    }

    #[test]
    fn arena_reuse_is_deterministic() {
        let mut rng = Pcg64::seed(605);
        let (d_in, d_out) = (32, 48);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        let scales = vec![0.03f32; d_out];
        let pw = PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], None);
        let mut arena = QGemmArena::new();
        // Big call grows the arena; subsequent smaller calls must be
        // unaffected by stale capacity.
        let xb = Matrix::randn(&mut rng, 50, d_in, 1.0);
        let _ = qgemm_forward(&pw, &xb, &mut arena, 1);
        let xs = Matrix::randn(&mut rng, 3, d_in, 1.0);
        let y1 = qgemm_forward(&pw, &xs, &mut arena, 1);
        let y2 = qgemm_forward(&pw, &xs, &mut QGemmArena::new(), 1);
        assert_eq!(y1, y2);
    }

    #[test]
    fn arena_reuse_across_strides_is_deterministic() {
        // A scalar-packed layer (stride == d_in) followed by a SIMD-packed
        // layer (stride == k_pad) sharing one arena must not corrupt the
        // padded tails.
        let mut rng = Pcg64::seed(607);
        let (d_in, d_out) = (33, 20);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        let scales = vec![0.03f32; d_out];
        let auto = PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], None);
        let scalar = PackedQWeight::pack_with_kernel(
            &codes,
            d_out,
            d_in,
            4,
            8,
            &scales,
            None,
            &[],
            None,
            QKernelKind::Scalar,
        );
        let x = Matrix::randn(&mut rng, 5, d_in, 1.0);
        let mut arena = QGemmArena::new();
        let y_s1 = qgemm_forward(&scalar, &x, &mut arena, 1);
        let y_a = qgemm_forward(&auto, &x, &mut arena, 1);
        let y_s2 = qgemm_forward(&scalar, &x, &mut arena, 1);
        assert_eq!(y_s1, y_s2, "arena stride switch corrupted the scalar path");
        assert_eq!(y_a, qgemm_forward(&auto, &x, &mut QGemmArena::new(), 1));
    }

    #[test]
    fn arena_grow_only_reuse_with_low_rank_branch() {
        // The low-rank branch temporarily truncates the grow-only xs buffer
        // to an exact t-row Matrix; ragged call shapes sharing one arena
        // must stay bitwise identical to fresh-arena runs.
        let mut rng = Pcg64::seed(609);
        let (d_in, d_out, r) = (40, 24, 5);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        let scales: Vec<f32> = (0..d_out).map(|_| 0.02 + rng.f32() * 0.03).collect();
        let la = Matrix::randn(&mut rng, d_out, r, 0.05);
        let lb = Matrix::randn(&mut rng, r, d_in, 0.05);
        let pw =
            PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], Some((&la, &lb)));
        let mut arena = QGemmArena::new();
        let xb = Matrix::randn(&mut rng, 48, d_in, 1.0);
        let _ = qgemm_forward(&pw, &xb, &mut arena, 1);
        for t in [1usize, 7, 3] {
            let xs = Matrix::randn(&mut rng, t, d_in, 1.0);
            let y1 = qgemm_forward(&pw, &xs, &mut arena, 1);
            let y2 = qgemm_forward(&pw, &xs, &mut QGemmArena::new(), 1);
            assert_eq!(y1, y2, "t={t}");
        }
    }

    #[test]
    fn zero_input_quantizes_safely() {
        let pw = PackedQWeight::pack(&[1, -2, 3, -4], 2, 2, 4, 8, &[0.1, 0.2], None, &[], None);
        let x = Matrix::zeros(2, 2);
        let y = qgemm_forward(&pw, &x, &mut QGemmArena::new(), 1);
        assert!(y.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nan_activation_row_stays_contained() {
        // `quantize_token_into` maps NaN lanes to code 0 (amax ignores NaN
        // via f32::max; the saturating float→int cast sends NaN to 0), so a
        // NaN activation must zero its own lane only — the rest of the
        // token and the other tokens stay finite and exact.
        let mut rng = Pcg64::seed(608);
        let (d_in, d_out) = (40, 12);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        let scales = vec![0.05f32; d_out];
        let pw = PackedQWeight::pack(&codes, d_out, d_in, 4, 8, &scales, None, &[], None);
        let mut x = Matrix::randn(&mut rng, 3, d_in, 1.0);
        x[(1, 7)] = f32::NAN;
        let y = qgemm_forward(&pw, &x, &mut QGemmArena::new(), 1);
        assert!(y.data.iter().all(|v| v.is_finite()), "NaN leaked into the output");
        // Token 1 must equal the same row with the NaN lane zeroed.
        let mut x_fixed = x.clone();
        x_fixed[(1, 7)] = 0.0;
        let y_fixed = qgemm_forward(&pw, &x_fixed, &mut QGemmArena::new(), 1);
        assert_eq!(y.row(1), y_fixed.row(1));
        // Untouched tokens are bitwise unaffected.
        assert_eq!(y.row(0), y_fixed.row(0));
        assert_eq!(y.row(2), y_fixed.row(2));
    }
}
