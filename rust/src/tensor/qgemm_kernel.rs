//! SIMD microkernels for the packed quantized GEMM (`tensor::qgemm`).
//!
//! PR 1's inner loop leaned on the autovectorizer over one fixed panel
//! layout; this module makes the microkernel — and the panel interleave it
//! streams — a property selected **once at pack time**:
//!
//! * [`QKernelKind::Scalar`] — portable reference kernel, always available.
//!   Panels keep the k-major QR-row interleave (`panel[k·QR + j]`); the
//!   register block is widened from QR×1 to a QR×4 token tile
//!   ([`gemm::panel_tile4`]) with a single-token tail.
//! * [`QKernelKind::Avx2`] — x86-64 `vpmaddubsw`/`vpmaddwd` i8×i8→i32
//!   kernel behind `is_x86_feature_detected!("avx2")`. Panels are repacked
//!   row-major with each row zero-padded to 32 bytes so the kernel streams
//!   whole ymm registers. The register block is QR×2 tokens: 8 ymm
//!   accumulators + 1 weight + 2 activation + 3 temp registers fill the
//!   16-register budget (a QR×4 tile would spill accumulators every
//!   k-step). Since `vpmaddubsw` takes an unsigned first operand, each
//!   product is computed as `|w| · (a·sign(w))` via `vpabsb`/`vpsignb`;
//!   pair sums are bounded by 2·128·127 = 32512 < i16::MAX, so the
//!   saturating i16 stage is exact for any codes the quantizers emit
//!   (activation codes are ≥ −127 by construction of `clamp_q`).
//! * [`QKernelKind::Neon`] — aarch64 `smull`/`sadalp` kernel with the same
//!   zero-padded row layout (16-byte chunks) and a full QR×4 token tile
//!   (32 vector registers leave room for 16 accumulators).
//!
//! All int kernels accumulate exact i32 (products ≤ 127² overflow i32 only
//! beyond d_in ≈ 1.3e5), so **every kernel produces bitwise-identical
//! results** — the property tests pin SIMD against scalar with `assert_eq`.
//! The zero-padded weight lanes contribute exactly 0 regardless of the
//! activation bytes aligned with them, so activation rows only need to be
//! allocated (not zeroed) out to the padded stride; `QGemmArena` zeroes the
//! tail anyway for debuggability.

// Index-heavy microkernels: indexed loops mirror the register tiling and
// keep the scalar/SIMD variants visually aligned.
#![allow(clippy::needless_range_loop)]

use super::gemm::panel_tile4;

/// Register-tile height: output rows computed together per micro-kernel
/// call. Panel packing zero-pads ragged final panels to a full QR rows.
pub const QR: usize = 4;
/// Token rows per cache block (the MC analog) shared by all kernels.
pub(crate) const TB: usize = 64;

/// The microkernel a [`super::PackedQWeight`] was packed for. Selected once
/// at pack time; fixes both the panel interleave layout and the inner-loop
/// instruction sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QKernelKind {
    /// Portable k-major interleaved kernel (the reference semantics).
    Scalar,
    /// x86-64 AVX2 `maddubs`/`madd` kernel, padded row-major panels.
    Avx2,
    /// aarch64 NEON `smull`/`sadalp` kernel, padded row-major panels.
    Neon,
}

impl QKernelKind {
    pub fn name(self) -> &'static str {
        match self {
            QKernelKind::Scalar => "scalar",
            QKernelKind::Avx2 => "avx2",
            QKernelKind::Neon => "neon",
        }
    }

    /// SIMD chunk (in i8 lanes) the kernel consumes per step; packed panel
    /// rows and arena activation rows are padded to a multiple of this.
    pub fn k_step(self) -> usize {
        match self {
            QKernelKind::Scalar => 1,
            QKernelKind::Avx2 => 32,
            QKernelKind::Neon => 16,
        }
    }

    /// `d_in` rounded up to the kernel's chunk — the packed panel row stride.
    pub fn pad_k(self, d_in: usize) -> usize {
        let step = self.k_step();
        d_in.div_ceil(step) * step
    }

    /// Width of the token tile of the widened register block.
    pub fn token_tile(self) -> usize {
        match self {
            QKernelKind::Scalar => 4,
            QKernelKind::Avx2 => 2,
            QKernelKind::Neon => 4,
        }
    }

    /// Whether this kernel can run on the current host (compile target arch
    /// AND runtime CPU features).
    pub fn available(self) -> bool {
        match self {
            QKernelKind::Scalar => true,
            QKernelKind::Avx2 => avx2_available(),
            QKernelKind::Neon => neon_available(),
        }
    }
}

impl std::fmt::Display for QKernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::is_x86_feature_detected!("avx2")
}
#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

/// Pick the fastest kernel available on this host. Called once per layer at
/// pack time (feature detection results are cached by std, so this is
/// cheap) — the serving loop never re-dispatches.
pub fn detect_kernel() -> QKernelKind {
    if QKernelKind::Avx2.available() {
        QKernelKind::Avx2
    } else if QKernelKind::Neon.available() {
        QKernelKind::Neon
    } else {
        QKernelKind::Scalar
    }
}

/// Pack quantized weight codes (`d_out × d_in`, row-major) into the panel
/// layout `kind` streams. Panel `p` holds output rows `[p·QR, (p+1)·QR)`
/// (ragged final panels zero-padded):
///
/// * Scalar: k-major interleave, `panel[k·QR + j] = codes[(p·QR+j)·d_in + k]`.
/// * SIMD: row-major, row `j` at `panel[j·k_pad ..]`, zero-padded to
///   `k_pad = kind.pad_k(d_in)` so the kernel loads whole registers.
pub(crate) fn pack_codes(kind: QKernelKind, codes: &[i8], d_out: usize, d_in: usize) -> Vec<i8> {
    assert_eq!(codes.len(), d_out * d_in, "code count");
    let k_pad = kind.pad_k(d_in);
    let n_panels = d_out.div_ceil(QR);
    let mut packed = vec![0i8; n_panels * QR * k_pad];
    for p in 0..n_panels {
        let panel = &mut packed[p * QR * k_pad..(p + 1) * QR * k_pad];
        for j in 0..QR {
            let r = p * QR + j;
            if r >= d_out {
                break;
            }
            let src = &codes[r * d_in..(r + 1) * d_in];
            match kind {
                QKernelKind::Scalar => {
                    for (k, &cv) in src.iter().enumerate() {
                        panel[k * QR + j] = cv;
                    }
                }
                QKernelKind::Avx2 | QKernelKind::Neon => {
                    panel[j * k_pad..j * k_pad + d_in].copy_from_slice(src);
                }
            }
        }
    }
    packed
}

/// Dispatch one row-block job `[r0, r1) × t tokens` to `kind`'s int8
/// kernel. `codes` rows have stride `k_pad` (== `d_in` for the scalar
/// layout); `out` is t-major `t × (r1-r0)` and fully overwritten with the
/// scaled result.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_int_job(
    kind: QKernelKind,
    packed: &[i8],
    k_pad: usize,
    d_in: usize,
    codes: &[i8],
    tok_scales: &[f32],
    wscales: &[f32],
    r0: usize,
    r1: usize,
    t: usize,
    out: &mut [f32],
) {
    match kind {
        QKernelKind::Scalar => {
            debug_assert_eq!(k_pad, d_in);
            scalar_int_job(packed, d_in, codes, tok_scales, wscales, r0, r1, t, out)
        }
        #[cfg(target_arch = "x86_64")]
        QKernelKind::Avx2 => {
            // SAFETY: pack_with_kernel refuses kernels whose features are
            // not present on this host, so AVX2 is available here.
            unsafe { avx2::int_job(packed, k_pad, codes, tok_scales, wscales, r0, r1, t, out) }
        }
        #[cfg(target_arch = "aarch64")]
        QKernelKind::Neon => {
            // SAFETY: as above — NEON availability checked at pack time.
            unsafe { neon::int_job(packed, k_pad, codes, tok_scales, wscales, r0, r1, t, out) }
        }
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// QR output rows × one token row, i8×i8→i32, k unrolled 4-wide — the
/// single-token tail of the scalar kernel and the layout reference for the
/// interleaved panels.
#[inline]
pub(crate) fn dot_i8_panel(a: &[i8], panel: &[i8]) -> [i32; QR] {
    debug_assert_eq!(panel.len(), a.len() * QR);
    let n = a.len();
    let mut acc = [0i32; QR];
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let p = &panel[i * QR..(i + 4) * QR];
        let mut u = 0usize;
        while u < 4 {
            let av = a[i + u] as i32;
            let base = u * QR;
            acc[0] += av * p[base] as i32;
            acc[1] += av * p[base + 1] as i32;
            acc[2] += av * p[base + 2] as i32;
            acc[3] += av * p[base + 3] as i32;
            u += 1;
        }
    }
    for i in chunks * 4..n {
        let av = a[i] as i32;
        let p = &panel[i * QR..(i + 1) * QR];
        for (j, s) in acc.iter_mut().enumerate() {
            *s += av * p[j] as i32;
        }
    }
    acc
}

/// Same tile shape for the fp-activation (A16) main GEMM, single-token tail.
#[inline]
pub(crate) fn dot_f32_panel(a: &[f32], panel: &[i8]) -> [f32; QR] {
    debug_assert_eq!(panel.len(), a.len() * QR);
    let mut acc = [0f32; QR];
    for (i, &av) in a.iter().enumerate() {
        let p = &panel[i * QR..(i + 1) * QR];
        acc[0] += av * p[0] as f32;
        acc[1] += av * p[1] as f32;
        acc[2] += av * p[2] as f32;
        acc[3] += av * p[3] as f32;
    }
    acc
}

/// Portable int8 job: QR×4 token tiles over interleaved panels, TB-blocked.
/// This is the always-available fallback and the reference the property
/// tests pin the SIMD kernels against (exact i32 accumulation makes all
/// kernels bitwise identical).
#[allow(clippy::too_many_arguments)]
pub(crate) fn scalar_int_job(
    packed: &[i8],
    d_in: usize,
    codes: &[i8],
    tok_scales: &[f32],
    wscales: &[f32],
    r0: usize,
    r1: usize,
    t: usize,
    out: &mut [f32],
) {
    let nr = r1 - r0;
    for tb in (0..t).step_by(TB) {
        let tend = (tb + TB).min(t);
        let mut r = r0;
        while r < r1 {
            let p = r / QR; // r0 is RB-aligned and RB % QR == 0
            let panel = &packed[p * QR * d_in..(p + 1) * QR * d_in];
            let pr = QR.min(r1 - r);
            let mut ti = tb;
            while ti + 4 <= tend {
                let a = [
                    &codes[ti * d_in..(ti + 1) * d_in],
                    &codes[(ti + 1) * d_in..(ti + 2) * d_in],
                    &codes[(ti + 2) * d_in..(ti + 3) * d_in],
                    &codes[(ti + 3) * d_in..(ti + 4) * d_in],
                ];
                let acc =
                    panel_tile4!(panel, a, 0i32, |s: i32, x: i8, w: i8| s + x as i32 * w as i32);
                for u in 0..4 {
                    let ts = tok_scales[ti + u];
                    let orow = &mut out[(ti + u) * nr + (r - r0)..];
                    for j in 0..pr {
                        orow[j] = acc[u][j] as f32 * (ts * wscales[r + j]);
                    }
                }
                ti += 4;
            }
            while ti < tend {
                let a = &codes[ti * d_in..(ti + 1) * d_in];
                let acc = dot_i8_panel(a, panel);
                let ts = tok_scales[ti];
                let orow = &mut out[ti * nr + (r - r0)..];
                for j in 0..pr {
                    orow[j] = acc[j] as f32 * (ts * wscales[r + j]);
                }
                ti += 1;
            }
            r += QR;
        }
    }
}

/// fp-activation (A16) job with the same QR×4 token tile widening. Always
/// runs on the interleaved scalar layout (pack forces `Scalar` for FP
/// abits). Each (row, token) accumulator walks k in ascending order — the
/// exact summation order of the old QR×1 kernel, so A16 results are
/// bitwise-unchanged by the widening.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fp_job(
    packed: &[i8],
    d_in: usize,
    xs: &[f32],
    wscales: &[f32],
    r0: usize,
    r1: usize,
    t: usize,
    out: &mut [f32],
) {
    let nr = r1 - r0;
    for tb in (0..t).step_by(TB) {
        let tend = (tb + TB).min(t);
        let mut r = r0;
        while r < r1 {
            let p = r / QR;
            let panel = &packed[p * QR * d_in..(p + 1) * QR * d_in];
            let pr = QR.min(r1 - r);
            let mut ti = tb;
            while ti + 4 <= tend {
                let a = [
                    &xs[ti * d_in..(ti + 1) * d_in],
                    &xs[(ti + 1) * d_in..(ti + 2) * d_in],
                    &xs[(ti + 2) * d_in..(ti + 3) * d_in],
                    &xs[(ti + 3) * d_in..(ti + 4) * d_in],
                ];
                let acc = panel_tile4!(panel, a, 0f32, |s: f32, x: f32, w: i8| s + x * w as f32);
                for u in 0..4 {
                    let orow = &mut out[(ti + u) * nr + (r - r0)..];
                    for j in 0..pr {
                        orow[j] = acc[u][j] * wscales[r + j];
                    }
                }
                ti += 4;
            }
            while ti < tend {
                let a = &xs[ti * d_in..(ti + 1) * d_in];
                let acc = dot_f32_panel(a, panel);
                let orow = &mut out[ti * nr + (r - r0)..];
                for j in 0..pr {
                    orow[j] = acc[j] * wscales[r + j];
                }
                ti += 1;
            }
            r += QR;
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    //! AVX2 `vpmaddubsw`/`vpmaddwd` i8 microkernel over zero-padded
    //! row-major panels. See the module doc for the sign/abs trick and the
    //! saturation bound that makes the i16 stage exact.

    use super::{QR, TB};
    use std::arch::x86_64::*;

    /// Horizontal sum of the 8 i32 lanes of `v`.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn hsum_i32(v: __m256i) -> i32 {
        // Explicit inner block: edition-2024-proof (unsafe_op_in_unsafe_fn).
        unsafe {
            let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
            let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
            _mm_cvtsi128_si32(s)
        }
    }

    /// QR rows × 2 tokens register tile: 8 ymm i32 accumulators, 32 i8
    /// lanes per k-step. `panel` points at a padded row-major QR-row panel
    /// (row stride `k_pad`), `a0`/`a1` at activation rows of `k_pad` bytes.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn panel_dot_4x2(
        panel: *const i8,
        k_pad: usize,
        a0: *const i8,
        a1: *const i8,
    ) -> [[i32; 2]; QR] {
        unsafe {
            let ones = _mm256_set1_epi16(1);
            let mut acc = [_mm256_setzero_si256(); 2 * QR];
            let mut k = 0usize;
            while k < k_pad {
                let av0 = _mm256_loadu_si256(a0.add(k) as *const __m256i);
                let av1 = _mm256_loadu_si256(a1.add(k) as *const __m256i);
                let mut r = 0usize;
                while r < QR {
                    let wv = _mm256_loadu_si256(panel.add(r * k_pad + k) as *const __m256i);
                    let wmag = _mm256_abs_epi8(wv);
                    // |w| · (a·sign(w)) == a·w ; pairs sum exactly in i16.
                    let p0 = _mm256_maddubs_epi16(wmag, _mm256_sign_epi8(av0, wv));
                    acc[2 * r] = _mm256_add_epi32(acc[2 * r], _mm256_madd_epi16(p0, ones));
                    let p1 = _mm256_maddubs_epi16(wmag, _mm256_sign_epi8(av1, wv));
                    acc[2 * r + 1] = _mm256_add_epi32(acc[2 * r + 1], _mm256_madd_epi16(p1, ones));
                    r += 1;
                }
                k += 32;
            }
            let mut res = [[0i32; 2]; QR];
            let mut r = 0usize;
            while r < QR {
                res[r][0] = hsum_i32(acc[2 * r]);
                res[r][1] = hsum_i32(acc[2 * r + 1]);
                r += 1;
            }
            res
        }
    }

    /// Single-token tail of the tile.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn panel_dot_4x1(panel: *const i8, k_pad: usize, a: *const i8) -> [i32; QR] {
        unsafe {
            let ones = _mm256_set1_epi16(1);
            let mut acc = [_mm256_setzero_si256(); QR];
            let mut k = 0usize;
            while k < k_pad {
                let av = _mm256_loadu_si256(a.add(k) as *const __m256i);
                let mut r = 0usize;
                while r < QR {
                    let wv = _mm256_loadu_si256(panel.add(r * k_pad + k) as *const __m256i);
                    let p = _mm256_maddubs_epi16(_mm256_abs_epi8(wv), _mm256_sign_epi8(av, wv));
                    acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(p, ones));
                    r += 1;
                }
                k += 32;
            }
            let mut res = [0i32; QR];
            let mut r = 0usize;
            while r < QR {
                res[r] = hsum_i32(acc[r]);
                r += 1;
            }
            res
        }
    }

    /// AVX2 row-block job; layout as [`super::scalar_int_job`] but over the
    /// padded row-major panels.
    ///
    /// # Safety
    /// Caller must guarantee the `avx2` feature is present (checked at pack
    /// time) and that `codes` holds `t` rows of `k_pad` bytes and `packed`
    /// covers every panel touched by `[r0, r1)`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn int_job(
        packed: &[i8],
        k_pad: usize,
        codes: &[i8],
        tok_scales: &[f32],
        wscales: &[f32],
        r0: usize,
        r1: usize,
        t: usize,
        out: &mut [f32],
    ) {
        debug_assert!(codes.len() >= t * k_pad);
        debug_assert_eq!(k_pad % 32, 0);
        let nr = r1 - r0;
        for tb in (0..t).step_by(TB) {
            let tend = (tb + TB).min(t);
            let mut r = r0;
            while r < r1 {
                // SAFETY: panel/code pointers stay within `packed`/`codes`
                // (panel count and row strides are checked at pack time).
                let panel = unsafe { packed.as_ptr().add(p_off(r, k_pad)) };
                let pr = QR.min(r1 - r);
                let mut ti = tb;
                while ti + 2 <= tend {
                    let acc = unsafe {
                        panel_dot_4x2(
                            panel,
                            k_pad,
                            codes.as_ptr().add(ti * k_pad),
                            codes.as_ptr().add((ti + 1) * k_pad),
                        )
                    };
                    let mut u = 0usize;
                    while u < 2 {
                        let ts = tok_scales[ti + u];
                        let orow = &mut out[(ti + u) * nr + (r - r0)..];
                        for j in 0..pr {
                            orow[j] = acc[j][u] as f32 * (ts * wscales[r + j]);
                        }
                        u += 1;
                    }
                    ti += 2;
                }
                if ti < tend {
                    let acc =
                        unsafe { panel_dot_4x1(panel, k_pad, codes.as_ptr().add(ti * k_pad)) };
                    let ts = tok_scales[ti];
                    let orow = &mut out[ti * nr + (r - r0)..];
                    for j in 0..pr {
                        orow[j] = acc[j] as f32 * (ts * wscales[r + j]);
                    }
                }
                r += QR;
            }
        }
    }

    /// Byte offset of the panel holding output row `r`.
    #[inline]
    fn p_off(r: usize, k_pad: usize) -> usize {
        (r / QR) * QR * k_pad
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    //! NEON `smull`/`sadalp` i8 microkernel over zero-padded row-major
    //! panels (16-byte chunks). `vmull_s8` widens i8×i8→i16 exactly
    //! (≤ 127² < i16::MAX) and `vpadalq_s16` pairwise-accumulates into i32,
    //! so accumulation is exact end to end. 32 vector registers leave room
    //! for a full QR×4 token tile (16 accumulators).

    use super::{QR, TB};
    use std::arch::aarch64::*;

    /// QR rows × 4 tokens register tile.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn panel_dot_4x4(
        panel: *const i8,
        k_pad: usize,
        a: [*const i8; 4],
    ) -> [[i32; 4]; QR] {
        unsafe {
            let mut acc = [[vdupq_n_s32(0); 4]; QR];
            let mut k = 0usize;
            while k < k_pad {
                let av = [
                    vld1q_s8(a[0].add(k)),
                    vld1q_s8(a[1].add(k)),
                    vld1q_s8(a[2].add(k)),
                    vld1q_s8(a[3].add(k)),
                ];
                let mut r = 0usize;
                while r < QR {
                    let wv = vld1q_s8(panel.add(r * k_pad + k));
                    let wlo = vget_low_s8(wv);
                    let whi = vget_high_s8(wv);
                    let mut t = 0usize;
                    while t < 4 {
                        acc[r][t] = vpadalq_s16(acc[r][t], vmull_s8(vget_low_s8(av[t]), wlo));
                        acc[r][t] = vpadalq_s16(acc[r][t], vmull_s8(vget_high_s8(av[t]), whi));
                        t += 1;
                    }
                    r += 1;
                }
                k += 16;
            }
            let mut res = [[0i32; 4]; QR];
            let mut r = 0usize;
            while r < QR {
                let mut t = 0usize;
                while t < 4 {
                    res[r][t] = vaddvq_s32(acc[r][t]);
                    t += 1;
                }
                r += 1;
            }
            res
        }
    }

    /// Single-token tail of the tile.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn panel_dot_4x1(panel: *const i8, k_pad: usize, a: *const i8) -> [i32; QR] {
        unsafe {
            let mut acc = [vdupq_n_s32(0); QR];
            let mut k = 0usize;
            while k < k_pad {
                let av = vld1q_s8(a.add(k));
                let alo = vget_low_s8(av);
                let ahi = vget_high_s8(av);
                let mut r = 0usize;
                while r < QR {
                    let wv = vld1q_s8(panel.add(r * k_pad + k));
                    acc[r] = vpadalq_s16(acc[r], vmull_s8(alo, vget_low_s8(wv)));
                    acc[r] = vpadalq_s16(acc[r], vmull_s8(ahi, vget_high_s8(wv)));
                    r += 1;
                }
                k += 16;
            }
            let mut res = [0i32; QR];
            let mut r = 0usize;
            while r < QR {
                res[r] = vaddvq_s32(acc[r]);
                r += 1;
            }
            res
        }
    }

    /// NEON row-block job; layout as [`super::scalar_int_job`] but over the
    /// padded row-major panels.
    ///
    /// # Safety
    /// Caller must guarantee NEON is present (checked at pack time) and the
    /// same buffer invariants as the AVX2 job.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn int_job(
        packed: &[i8],
        k_pad: usize,
        codes: &[i8],
        tok_scales: &[f32],
        wscales: &[f32],
        r0: usize,
        r1: usize,
        t: usize,
        out: &mut [f32],
    ) {
        debug_assert!(codes.len() >= t * k_pad);
        debug_assert_eq!(k_pad % 16, 0);
        let nr = r1 - r0;
        for tb in (0..t).step_by(TB) {
            let tend = (tb + TB).min(t);
            let mut r = r0;
            while r < r1 {
                // SAFETY: panel/code pointers stay within `packed`/`codes`
                // (panel count and row strides are checked at pack time).
                let panel = unsafe { packed.as_ptr().add((r / QR) * QR * k_pad) };
                let pr = QR.min(r1 - r);
                let mut ti = tb;
                while ti + 4 <= tend {
                    let acc = unsafe {
                        panel_dot_4x4(
                            panel,
                            k_pad,
                            [
                                codes.as_ptr().add(ti * k_pad),
                                codes.as_ptr().add((ti + 1) * k_pad),
                                codes.as_ptr().add((ti + 2) * k_pad),
                                codes.as_ptr().add((ti + 3) * k_pad),
                            ],
                        )
                    };
                    let mut u = 0usize;
                    while u < 4 {
                        let ts = tok_scales[ti + u];
                        let orow = &mut out[(ti + u) * nr + (r - r0)..];
                        for j in 0..pr {
                            orow[j] = acc[j][u] as f32 * (ts * wscales[r + j]);
                        }
                        u += 1;
                    }
                    ti += 4;
                }
                while ti < tend {
                    let acc =
                        unsafe { panel_dot_4x1(panel, k_pad, codes.as_ptr().add(ti * k_pad)) };
                    let ts = tok_scales[ti];
                    let orow = &mut out[ti * nr + (r - r0)..];
                    for j in 0..pr {
                        orow[j] = acc[j] as f32 * (ts * wscales[r + j]);
                    }
                    ti += 1;
                }
                r += QR;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_codes(rng: &mut Pcg64, n: usize, qmax: i8) -> Vec<i8> {
        // Draw in i32 so the full ±127 activation grid doesn't overflow the
        // i8 cast.
        (0..n)
            .map(|_| (rng.below(2 * qmax as usize + 1) as i32 - qmax as i32) as i8)
            .collect()
    }

    /// Straight-line i32 reference for one row-block job.
    #[allow(clippy::too_many_arguments)]
    fn reference_job(
        codes_w: &[i8],
        d_in: usize,
        codes_a: &[i8],
        tok_scales: &[f32],
        wscales: &[f32],
        r0: usize,
        r1: usize,
        t: usize,
    ) -> Vec<f32> {
        let nr = r1 - r0;
        let mut out = vec![0f32; t * nr];
        for ti in 0..t {
            for r in r0..r1 {
                let mut acc = 0i32;
                for k in 0..d_in {
                    acc += codes_a[ti * d_in + k] as i32 * codes_w[r * d_in + k] as i32;
                }
                out[ti * nr + (r - r0)] = acc as f32 * (tok_scales[ti] * wscales[r]);
            }
        }
        out
    }

    #[test]
    fn pack_layouts_hold_the_same_codes() {
        let mut rng = Pcg64::seed(71);
        let (d_out, d_in) = (13, 37);
        let codes = random_codes(&mut rng, d_out * d_in, 7);
        for kind in [QKernelKind::Scalar, QKernelKind::Avx2, QKernelKind::Neon] {
            let packed = pack_codes(kind, &codes, d_out, d_in);
            let k_pad = kind.pad_k(d_in);
            assert_eq!(packed.len(), d_out.div_ceil(QR) * QR * k_pad);
            for r in 0..d_out {
                let (p, j) = (r / QR, r % QR);
                for k in 0..k_pad {
                    let got = match kind {
                        QKernelKind::Scalar => packed[p * QR * k_pad + k * QR + j],
                        _ => packed[(p * QR + j) * k_pad + k],
                    };
                    let want = if k < d_in { codes[r * d_in + k] } else { 0 };
                    assert_eq!(got, want, "{kind} r={r} k={k}");
                }
            }
        }
    }

    #[test]
    fn scalar_job_matches_reference() {
        let mut rng = Pcg64::seed(72);
        for (t, d_in, d_out) in [(1, 1, 1), (3, 17, 5), (5, 40, 8), (9, 33, 13), (6, 64, 66)] {
            let codes_w = random_codes(&mut rng, d_out * d_in, 7);
            let codes_a = random_codes(&mut rng, t * d_in, 127);
            let tok_scales: Vec<f32> = (0..t).map(|_| 0.01 + rng.f32() * 0.1).collect();
            let wscales: Vec<f32> = (0..d_out).map(|_| 0.01 + rng.f32() * 0.1).collect();
            let packed = pack_codes(QKernelKind::Scalar, &codes_w, d_out, d_in);
            let mut out = vec![0f32; t * d_out];
            scalar_int_job(&packed, d_in, &codes_a, &tok_scales, &wscales, 0, d_out, t, &mut out);
            let want = reference_job(&codes_w, d_in, &codes_a, &tok_scales, &wscales, 0, d_out, t);
            assert_eq!(out, want, "({t},{d_in},{d_out})");
        }
    }

    #[test]
    fn simd_job_bitwise_matches_scalar() {
        // Runs the host's SIMD kernel against the scalar reference across
        // shapes that straddle the SIMD chunk (d_in), the QR panel (d_out),
        // and the token tile (t). Exact i32 accumulation ⇒ assert_eq.
        let kind = detect_kernel();
        if kind == QKernelKind::Scalar {
            return; // no SIMD on this host; scalar covered above
        }
        let mut rng = Pcg64::seed(73);
        let k_step = kind.k_step();
        for (t, d_in, d_out) in [
            (1, 1, 1),
            (2, k_step - 1, 5),
            (3, k_step, 8),
            (5, k_step + 1, 3),
            (7, 2 * k_step + 3, 66),
            (6, 100, 130),
            (65, 33, 24), // t straddles TB
        ] {
            let codes_w = random_codes(&mut rng, d_out * d_in, 7);
            let tok_scales: Vec<f32> = (0..t).map(|_| 0.01 + rng.f32() * 0.1).collect();
            let wscales: Vec<f32> = (0..d_out).map(|_| 0.01 + rng.f32() * 0.1).collect();
            // Activation codes at the scalar stride and the padded stride.
            let a_plain = random_codes(&mut rng, t * d_in, 127);
            let k_pad = kind.pad_k(d_in);
            let mut a_padded = vec![0i8; t * k_pad];
            for ti in 0..t {
                a_padded[ti * k_pad..ti * k_pad + d_in]
                    .copy_from_slice(&a_plain[ti * d_in..(ti + 1) * d_in]);
            }
            let p_scalar = pack_codes(QKernelKind::Scalar, &codes_w, d_out, d_in);
            let p_simd = pack_codes(kind, &codes_w, d_out, d_in);
            let mut want = vec![0f32; t * d_out];
            scalar_int_job(&p_scalar, d_in, &a_plain, &tok_scales, &wscales, 0, d_out, t, &mut want);
            let mut got = vec![0f32; t * d_out];
            run_int_job(
                kind, &p_simd, k_pad, d_in, &a_padded, &tok_scales, &wscales, 0, d_out, t, &mut got,
            );
            assert_eq!(got, want, "{kind} ({t},{d_in},{d_out})");
        }
    }

    #[test]
    fn detection_is_consistent() {
        let kind = detect_kernel();
        assert!(kind.available());
        assert!(QKernelKind::Scalar.available());
        assert_eq!(QKernelKind::Scalar.pad_k(33), 33);
        assert_eq!(QKernelKind::Avx2.pad_k(33), 64);
        assert_eq!(QKernelKind::Neon.pad_k(33), 48);
        assert_eq!(QKernelKind::Avx2.pad_k(64), 64);
        for kind in [QKernelKind::Scalar, QKernelKind::Avx2, QKernelKind::Neon] {
            assert!(kind.token_tile() >= 2, "{kind} tile");
        }
    }
}
