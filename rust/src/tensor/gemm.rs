//! GEMM kernels — the fp32 hot path.
//!
//! `matmul` is the general cache-blocked kernel (A · B). It packs B's panel
//! transposed so the inner loop is two contiguous streams, and unrolls the K
//! loop 8-wide to give the autovectorizer clean SIMD lanes. Variants:
//! `matmul_at` (Aᵀ·B, used for Gram matrices), `matmul_bt` / `matmul_bt_acc`
//! (A·Bᵀ with the same MC/NC/KC tiling), `matvec`, and `gram` (X·Xᵀ,
//! exploiting symmetry). The 8-wide unroll itself lives in the
//! [`dot_unrolled`] macro, shared with the int8 kernel in `model::linear`.

use super::matrix::Matrix;

/// Cache-block sizes tuned for ~32 KiB L1 / 1 MiB L2 on the test machine.
const MC: usize = 64; // rows of A per block
const NC: usize = 128; // cols of B per block
const KC: usize = 256; // shared dim per block

/// 8-wide unrolled dot product over two equal-length slices — the one unroll
/// shared by the f32 kernel ([`dot`]) and the i8×i8→i32 kernel
/// (`model::linear::dot_i8`). `$zero` is the accumulator identity and
/// `$madd(acc, a, b)` the fused multiply-accumulate for the element type.
/// Eight independent accumulator lanes give the autovectorizer clean SIMD
/// lanes; the tail accumulates separately and is added last (this exact
/// summation order is load-bearing for bitwise reproducibility tests).
macro_rules! dot_unrolled {
    ($a:expr, $b:expr, $zero:expr, $madd:expr) => {{
        let a_ = $a;
        let b_ = $b;
        debug_assert_eq!(a_.len(), b_.len());
        let n = a_.len();
        let chunks = n / 8;
        let mut acc = [$zero; 8];
        for c in 0..chunks {
            let i = c * 8;
            let mut k = 0usize;
            while k < 8 {
                acc[k] = $madd(acc[k], a_[i + k], b_[i + k]);
                k += 1;
            }
        }
        let mut tail = $zero;
        for i in chunks * 8..n {
            tail = $madd(tail, a_[i], b_[i]);
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
    }};
}
pub(crate) use dot_unrolled;

/// 4-row × 4-token register tile over a k-major interleaved weight panel —
/// the widened micro-tile shared by the scalar int8 and f32 panel kernels
/// in `tensor::qgemm_kernel` (QR is pinned to 4 there). `$a` is an array of
/// 4 equal-length activation slices, `$panel` the interleaved panel
/// (`panel[k·4 + j]`), `$madd(acc, a, w)` the element-type multiply-
/// accumulate. Sixteen independent accumulators let the panel stream be
/// loaded once per four tokens instead of once per token; each (row, token)
/// accumulator walks k in ascending order — the same summation order as the
/// QR×1 kernel, which keeps f32 results bitwise identical to it. Returns
/// `acc[token][row]`.
macro_rules! panel_tile4 {
    ($panel:expr, $a:expr, $zero:expr, $madd:expr) => {{
        let p_ = $panel;
        let a_ = $a;
        let n = a_[0].len();
        debug_assert_eq!(p_.len(), n * 4);
        debug_assert!(a_.iter().all(|r| r.len() == n));
        let mut acc = [[$zero; 4]; 4];
        for k in 0..n {
            let w = &p_[k * 4..(k + 1) * 4];
            let mut t = 0usize;
            while t < 4 {
                let av = a_[t][k];
                acc[t][0] = $madd(acc[t][0], av, w[0]);
                acc[t][1] = $madd(acc[t][1], av, w[1]);
                acc[t][2] = $madd(acc[t][2], av, w[2]);
                acc[t][3] = $madd(acc[t][3], av, w[3]);
                t += 1;
            }
        }
        acc
    }};
}
pub(crate) use panel_tile4;

/// C = A·B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul dims {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // Pack buffer for a KCxNC panel of B, stored column-major within the
    // panel (i.e. B^T layout) so the micro-kernel streams contiguously.
    let mut bpack = vec![0f32; KC * NC];
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        let klen = kend - kb;
        for nb in (0..n).step_by(NC) {
            let nend = (nb + NC).min(n);
            let nlen = nend - nb;
            // Pack B[kb..kend, nb..nend] transposed: bpack[j*klen + p]
            for p in 0..klen {
                let brow = &b.data[(kb + p) * b.cols + nb..(kb + p) * b.cols + nend];
                for (j, &v) in brow.iter().enumerate() {
                    bpack[j * klen + p] = v;
                }
            }
            for mb in (0..m).step_by(MC) {
                let mend = (mb + MC).min(m);
                for i in mb..mend {
                    let arow = &a.data[i * k + kb..i * k + kend];
                    let crow = &mut c.data[i * n + nb..i * n + nend];
                    for (j, cv) in crow.iter_mut().enumerate().take(nlen) {
                        let bcol = &bpack[j * klen..j * klen + klen];
                        *cv += dot(arow, bcol);
                    }
                }
            }
        }
    }
    c
}

/// Unrolled dot product over equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_unrolled!(a, b, 0f32, |acc: f32, x: f32, y: f32| acc + x * y)
}

/// C = Aᵀ·B without materializing Aᵀ.
pub fn matmul_at(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_at dims");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // Accumulate rank-1 updates row-by-row of A/B: cache-friendly since both
    // stream row-major.
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            axpy(av, brow, crow);
        }
    }
    c
}

/// C = A·Bᵀ without materializing Bᵀ. Rows of A dot rows of B.
///
/// Cache-blocked with the same MC/NC/KC tiling as [`matmul`]; since B's rows
/// are already contiguous along K no pack buffer is needed. This is the
/// eval/PPL batch-forward kernel (`Linear::Dense` with large activation
/// matrices) and the skinny low-rank branch of the quantized path.
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_bt_acc(a, b, &mut c);
    c
}

/// C += A·Bᵀ (accumulating variant; lets callers fuse the low-rank
/// correction into an existing output without a temporary).
pub fn matmul_bt_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_bt dims");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (m, n), "matmul_bt_acc output shape");
    if m < 32 {
        // Decode-sized batches (the batcher's default max_batch is 8 and the
        // serving benches go to 16): blocking amortizes little at this m, and
        // the plain full-K row dot keeps results bitwise identical to the
        // per-token `matvec` path — which is what pins batched greedy decode
        // to single-sequence decode token-for-token. The K-split below would
        // reorder f32 sums whenever k > KC. Eval/PPL batches (≥ 32 rows) take
        // the blocked path, where only tolerance-level agreement is promised.
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += dot(arow, b.row(j));
            }
        }
        return;
    }
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for nb in (0..n).step_by(NC) {
            let nend = (nb + NC).min(n);
            for mb in (0..m).step_by(MC) {
                let mend = (mb + MC).min(m);
                for i in mb..mend {
                    let arow = &a.data[i * k + kb..i * k + kend];
                    let crow = &mut c.data[i * n + nb..i * n + nend];
                    for (j, cv) in crow.iter_mut().enumerate() {
                        let brow = &b.data[(nb + j) * k + kb..(nb + j) * k + kend];
                        *cv += dot(arow, brow);
                    }
                }
            }
        }
    }
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = A·x.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows).map(|r| dot(a.row(r), x)).collect()
}

/// y = Aᵀ·x.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0f32; a.cols];
    for (r, &xv) in x.iter().enumerate() {
        if xv != 0.0 {
            axpy(xv, a.row(r), &mut y);
        }
    }
    y
}

/// G = X·Xᵀ for row-major X: G[i][j] = row_i · row_j, so for X of shape
/// samples × channels the Gram is samples × samples. Exploits symmetry by
/// computing the upper triangle and mirroring.
pub fn gram_rows(x: &Matrix) -> Matrix {
    let n = x.rows;
    let mut g = Matrix::zeros(n, n);
    for i in 0..n {
        let ri = x.row(i);
        for j in i..n {
            let v = dot(ri, x.row(j));
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    g
}

/// G = Xᵀ·X (shape cols x cols) — the calibration Gram over channels when X
/// is samples x channels. Accumulates symmetric rank-1 updates in f64 for
/// numerical robustness (it feeds Cholesky).
pub fn gram_cols_f64(x: &Matrix) -> Vec<f64> {
    let d = x.cols;
    let mut g = vec![0f64; d * d];
    for r in 0..x.rows {
        let row = x.row(r);
        for i in 0..d {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let gi = &mut g[i * d..(i + 1) * d];
            for (j, &xj) in row.iter().enumerate().skip(i) {
                gi[j] += xi * xj as f64;
            }
        }
    }
    // mirror
    for i in 0..d {
        for j in 0..i {
            g[i * d + j] = g[j * d + i];
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0f32;
                for p in 0..a.cols {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_awkward_shapes() {
        let mut rng = Pcg64::seed(7);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (70, 257, 130)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let b = Matrix::randn(&mut rng, k, n, 1.0);
            let c = matmul(&a, &b);
            let c0 = naive(&a, &b);
            let scale = c0.max_abs().max(1.0);
            assert!(c.max_diff(&c0) / scale < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_at_bt_match() {
        let mut rng = Pcg64::seed(8);
        let a = Matrix::randn(&mut rng, 23, 11, 1.0);
        let b = Matrix::randn(&mut rng, 23, 17, 1.0);
        let c1 = matmul_at(&a, &b);
        let c2 = matmul(&a.transpose(), &b);
        assert!(c1.max_diff(&c2) < 1e-4);

        let d = Matrix::randn(&mut rng, 9, 11, 1.0);
        let e = Matrix::randn(&mut rng, 13, 11, 1.0);
        let f1 = matmul_bt(&d, &e);
        let f2 = matmul(&d, &e.transpose());
        assert!(f1.max_diff(&f2) < 1e-4);
    }

    #[test]
    fn matmul_bt_blocked_matches_naive_awkward_shapes() {
        // Shapes straddling every block boundary: m < 32 (plain exact path),
        // m ≥ 32 with k > KC (split-K path), n > NC.
        let mut rng = Pcg64::seed(81);
        for (m, k, n) in [(3, 40, 5), (40, 257, 9), (70, 300, 140), (64, 256, 128), (33, 513, 7)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let b = Matrix::randn(&mut rng, n, k, 1.0);
            let c = matmul_bt(&a, &b);
            let c0 = matmul(&a, &b.transpose());
            let scale = c0.max_abs().max(1.0);
            assert!(c.max_diff(&c0) / scale < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_bt_decode_batches_bitwise_match_matvec() {
        // The serving guarantee: decode-sized batches (m < 32) must equal
        // the per-token matvec path bit-for-bit even when k exceeds KC —
        // this is what keeps batched greedy decode token-identical to
        // single-sequence decode.
        let mut rng = Pcg64::seed(83);
        let a = Matrix::randn(&mut rng, 16, 520, 1.0);
        let b = Matrix::randn(&mut rng, 24, 520, 1.0);
        let c = matmul_bt(&a, &b);
        for i in 0..a.rows {
            let y = matvec(&b, a.row(i));
            assert_eq!(c.row(i), &y[..], "row {i}");
        }
    }

    #[test]
    fn matmul_bt_acc_accumulates() {
        let mut rng = Pcg64::seed(82);
        let a = Matrix::randn(&mut rng, 12, 33, 1.0);
        let b = Matrix::randn(&mut rng, 17, 33, 1.0);
        let base = Matrix::randn(&mut rng, 12, 17, 1.0);
        let mut c = base.clone();
        matmul_bt_acc(&a, &b, &mut c);
        let want = base.add(&matmul_bt(&a, &b));
        assert!(c.max_diff(&want) < 1e-4);
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Pcg64::seed(9);
        let a = Matrix::randn(&mut rng, 12, 7, 1.0);
        let x: Vec<f32> = (0..7).map(|_| rng.normal()).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(7, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..12 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
        let z = matvec_t(&a, &y);
        let zm = matmul(&a.transpose(), &Matrix::from_vec(12, 1, y));
        for i in 0..7 {
            assert!((z[i] - zm[(i, 0)]).abs() < 1e-4);
        }
    }

    #[test]
    fn gram_symmetry_and_values() {
        let mut rng = Pcg64::seed(10);
        let x = Matrix::randn(&mut rng, 6, 40, 1.0);
        let g = gram_rows(&x);
        assert_eq!(g.rows, 6);
        for i in 0..6 {
            for j in 0..6 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-6);
                assert!((g[(i, j)] - dot(x.row(i), x.row(j))).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn gram_cols_f64_matches_matmul() {
        let mut rng = Pcg64::seed(11);
        let x = Matrix::randn(&mut rng, 30, 13, 1.0);
        let g = gram_cols_f64(&x);
        let g2 = matmul_at(&x, &x);
        for i in 0..13 {
            for j in 0..13 {
                assert!((g[i * 13 + j] as f32 - g2[(i, j)]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn dot_handles_all_lengths() {
        for n in 0..35 {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b: Vec<f32> = (0..n).map(|i| (i * 2) as f32).collect();
            let want: f32 = (0..n).map(|i| (i * i * 2) as f32).sum();
            assert_eq!(dot(&a, &b), want, "n={n}");
        }
    }
}
