//! Dense f32 tensor substrate: the `Matrix` type, fp32 GEMM kernels, and the
//! packed quantized GEMM layer (`qgemm`) the serving path runs on.

pub mod gemm;
pub mod matrix;
pub mod qgemm;

pub use gemm::{
    dot, gram_cols_f64, gram_rows, matmul, matmul_at, matmul_bt, matmul_bt_acc, matvec, matvec_t,
};
pub use matrix::Matrix;
pub use qgemm::{qgemm_forward, qgemm_forward_token, PackedQWeight, QGemmArena};
