//! Dense f32 tensor substrate: the `Matrix` type plus GEMM kernels.

pub mod gemm;
pub mod matrix;

pub use gemm::{dot, gram_cols_f64, gram_rows, matmul, matmul_at, matmul_bt, matvec, matvec_t};
pub use matrix::Matrix;
