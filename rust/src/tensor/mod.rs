//! Dense f32 tensor substrate: the `Matrix` type, fp32 GEMM kernels, the
//! packed quantized GEMM layer (`qgemm`) the serving path runs on, the SIMD
//! int8 microkernels behind it (`qgemm_kernel`: runtime-dispatched
//! AVX2/NEON kernels with a portable scalar fallback), and the f32
//! attention microkernels (`attn_kernel`: q·K sweep / softmax / weighted-V
//! over head-major KV tiles, same dispatch scheme).

pub mod attn_kernel;
pub mod gemm;
pub mod matrix;
pub mod qgemm;
pub mod qgemm_kernel;

pub use attn_kernel::{
    attn_head_span, attn_head_span_int8, detect_attn_kernel, pv_accum_int8, qk_scores_int8,
    AttnArena, AttnKernelKind,
};
pub use gemm::{
    dot, gram_cols_f64, gram_rows, matmul, matmul_at, matmul_bt, matmul_bt_acc, matvec, matvec_t,
};
pub use matrix::Matrix;
pub use qgemm::{qgemm_forward, qgemm_forward_token, PackedQWeight, QGemmArena};
pub use qgemm_kernel::{detect_kernel, QKernelKind};
