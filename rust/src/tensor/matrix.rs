//! Dense row-major f32 matrix — the numeric core of the library.
//!
//! Everything downstream (linalg, quantizers, the transformer) works on this
//! type. The GEMM is a cache-blocked, 8-wide-unrolled kernel over the
//! transposed RHS; see `gemm.rs` for the hot-path variants.

use crate::util::rng::Pcg64;
use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows <= 8 && self.cols <= 8 {
            writeln!(f)?;
            for r in 0..self.rows {
                write!(f, "  [")?;
                for c in 0..self.cols {
                    write!(f, "{:>10.4}", self[(r, c)])?;
                }
                writeln!(f, " ]")?;
            }
        }
        Ok(())
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape {rows}x{cols} != len {}", data.len());
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    pub fn diag(d: &[f32]) -> Self {
        let n = d.len();
        Matrix::from_fn(n, n, |r, c| if r == c { d[r] } else { 0.0 })
    }

    pub fn randn(rng: &mut Pcg64, rows: usize, cols: usize, std: f32) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self[(r, c)] = v[r];
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    // -- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a - b)
    }

    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|x| x * s)
    }

    /// Scale column j by s[j] (i.e. right-multiply by diag(s)).
    pub fn scale_cols(&self, s: &[f32]) -> Matrix {
        assert_eq!(s.len(), self.cols);
        let mut out = self.clone();
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for (x, &sc) in row.iter_mut().zip(s) {
                *x *= sc;
            }
        }
        out
    }

    /// Scale row i by s[i] (i.e. left-multiply by diag(s)).
    pub fn scale_rows(&self, s: &[f32]) -> Matrix {
        assert_eq!(s.len(), self.rows);
        let mut out = self.clone();
        for r in 0..self.rows {
            let sc = s[r];
            for x in out.row_mut(r) {
                *x *= sc;
            }
        }
        out
    }

    // -- reductions ----------------------------------------------------------

    pub fn frob_norm(&self) -> f32 {
        // Two-pass scaled sum to avoid overflow on large values.
        let maxabs = self.data.iter().fold(0f32, |m, x| m.max(x.abs()));
        if maxabs == 0.0 {
            return 0.0;
        }
        let inv = 1.0 / maxabs;
        let mut acc = 0f64;
        for &x in &self.data {
            let v = (x * inv) as f64;
            acc += v * v;
        }
        (acc.sqrt() * maxabs as f64) as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, x| m.max(x.abs()))
    }

    /// Per-column mean of absolute values (the paper's X̄ / W̄ statistic,
    /// computed over rows).
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let mut acc = vec![0f64; self.cols];
        for r in 0..self.rows {
            for (a, &x) in acc.iter_mut().zip(self.row(r)) {
                *a += x.abs() as f64;
            }
        }
        acc.iter().map(|&a| (a / self.rows as f64) as f32).collect()
    }

    /// Per-row mean of absolute values.
    pub fn row_abs_mean(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| {
                let s: f64 = self.row(r).iter().map(|x| x.abs() as f64).sum();
                (s / self.cols as f64) as f32
            })
            .collect()
    }

    /// Per-row max of absolute values (per-token quant scale basis).
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().fold(0f32, |m, x| m.max(x.abs())))
            .collect()
    }

    /// Per-column max of absolute values (per-channel quant scale basis).
    pub fn col_abs_max(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.cols];
        for r in 0..self.rows {
            for (mx, &x) in m.iter_mut().zip(self.row(r)) {
                *mx = mx.max(x.abs());
            }
        }
        m
    }

    // -- slicing -------------------------------------------------------------

    /// Copy of columns [c0, c1).
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Copy of rows [r0, r1).
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix::from_vec(r1 - r0, self.cols, self.data[r0 * self.cols..r1 * self.cols].to_vec())
    }

    /// Select a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (k, &c) in idx.iter().enumerate() {
                dst[k] = src[c];
            }
        }
        out
    }

    /// Zero out the listed columns, returning the extracted part so that
    /// `self == kept + extracted` (used by outlier splitting).
    pub fn split_cols(&self, idx: &[usize]) -> (Matrix, Matrix) {
        let mut kept = self.clone();
        let mut extracted = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for &c in idx {
                extracted[(r, c)] = kept[(r, c)];
                kept[(r, c)] = 0.0;
            }
        }
        (kept, extracted)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Max |a-b| over entries.
    pub fn max_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_index() {
        let m = Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(1);
        let m = Matrix::randn(&mut rng, 37, 53, 1.0);
        let t = m.transpose();
        assert_eq!(t.rows, 53);
        assert_eq!(t[(5, 7)], m[(7, 5)]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn frob_norm_matches_naive() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-6);
        let z = Matrix::zeros(3, 3);
        assert_eq!(z.frob_norm(), 0.0);
    }

    #[test]
    fn frob_norm_large_values_no_overflow() {
        let m = Matrix::from_vec(1, 2, vec![1e20, 1e20]);
        let n = m.frob_norm();
        assert!(n.is_finite());
        assert!((n - (2f32).sqrt() * 1e20).abs() / n < 1e-5);
    }

    #[test]
    fn scale_rows_cols() {
        let m = Matrix::from_fn(2, 3, |_, _| 1.0);
        let sc = m.scale_cols(&[1.0, 2.0, 3.0]);
        assert_eq!(sc.row(0), &[1.0, 2.0, 3.0]);
        let sr = m.scale_rows(&[5.0, 7.0]);
        assert_eq!(sr.row(1), &[7.0, 7.0, 7.0]);
    }

    #[test]
    fn abs_stats() {
        let m = Matrix::from_vec(2, 2, vec![1.0, -3.0, -5.0, 7.0]);
        assert_eq!(m.col_abs_mean(), vec![3.0, 5.0]);
        assert_eq!(m.row_abs_mean(), vec![2.0, 6.0]);
        assert_eq!(m.row_abs_max(), vec![3.0, 7.0]);
        assert_eq!(m.col_abs_max(), vec![5.0, 7.0]);
    }

    #[test]
    fn split_cols_reassembles() {
        let mut rng = Pcg64::seed(2);
        let m = Matrix::randn(&mut rng, 5, 8, 1.0);
        let (kept, ext) = m.split_cols(&[1, 6]);
        assert_eq!(kept.add(&ext), m);
        assert!(kept.col(1).iter().all(|&x| x == 0.0));
        assert!(ext.col(0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn select_and_slice() {
        let m = Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        let s = m.select_cols(&[4, 0]);
        assert_eq!(s.row(1), &[9.0, 5.0]);
        let cs = m.cols_slice(1, 3);
        assert_eq!(cs.row(0), &[1.0, 2.0]);
        let rs = m.rows_slice(1, 2);
        assert_eq!(rs.row(0), m.row(1));
    }

    #[test]
    fn diag_and_eye() {
        let d = Matrix::diag(&[2.0, 3.0]);
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
        assert_eq!(Matrix::eye(3)[(2, 2)], 1.0);
    }
}
