//! Model construction: synthetic initialization with function-preserving
//! outlier injection, and (de)serialization against the ATNS tensor format
//! shared with the python pretraining path.
//!
//! **Outlier injection** (DESIGN.md §3): real pretrained LLMs develop a
//! small set of high-magnitude activation channels, which is precisely the
//! phenomenon ASER exploits. We reproduce it deterministically: boost the
//! RMSNorm gain of ~`outlier_frac` of channels by `outlier_gain` and divide
//! the consuming linear's columns by the same factor. The transform is
//! exact at fp32 — the model function is unchanged — but the activations
//! entering `qkv_proj`/`fc1` now carry genuine outlier channels, so
//! quantization error concentrates exactly as in Fig. 4 of the paper.

use super::config::ModelConfig;
use super::gpt::{Block, Gpt};
use super::linear::Linear;
use crate::tensor::Matrix;
use crate::util::io::TensorFile;
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use std::path::Path;

/// Build a model with random (untrained) weights + outlier structure.
/// Used by unit tests, figures and benches; the evaluation pipeline prefers
/// pretrained weights from `artifacts/models/<name>/weights.atns`.
pub fn synthetic_model(config_name: &str, seed: u64) -> Result<Gpt> {
    let cfg = ModelConfig::by_name(config_name)?;
    let root = Pcg64::new(seed, 0xA5E1);
    let d = cfg.d_model;
    let std = 0.02f32;
    // Residual-branch scaling à la GPT-2 init.
    let resid_std = std / (2.0 * cfg.n_layers as f32).sqrt();

    let mut rng_e = root.fork("embed");
    let embed = Matrix::randn(&mut rng_e, cfg.vocab_size, d, std);
    let mut rng_h = root.fork("head");
    let lm_head = Matrix::randn(&mut rng_h, cfg.vocab_size, d, std);

    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let mut rng = root.fork(&format!("block{l}"));
        let qkv = Matrix::randn(&mut rng, 3 * d, d, std);
        let out_proj = Matrix::randn(&mut rng, d, d, resid_std);
        let fc1 = Matrix::randn(&mut rng, 2 * cfg.d_ff, d, std);
        let fc2 = Matrix::randn(&mut rng, d, cfg.d_ff, resid_std);
        blocks.push(Block {
            attn_norm: vec![1.0; d],
            qkv: Linear::Dense(qkv),
            out_proj: Linear::Dense(out_proj),
            ffn_norm: vec![1.0; d],
            fc1: Linear::Dense(fc1),
            fc2: Linear::Dense(fc2),
        });
    }
    let mut model = Gpt::assemble(cfg, embed, blocks, vec![1.0; d], lm_head);
    inject_outliers(&mut model, &root.fork("outliers"));
    Ok(model)
}

/// Function-preserving outlier injection (see module docs). Operates on
/// dense (fp) models only — call before quantization.
pub fn inject_outliers(model: &mut Gpt, rng: &Pcg64) {
    let cfg = model.cfg.clone();
    let d = cfg.d_model;
    let n_out = ((d as f32 * cfg.outlier_frac).round() as usize).max(1);
    for l in 0..cfg.n_layers {
        let mut r = rng.fork(&format!("layer{l}"));
        // Distinct channel sets per norm so layers differ (as in Fig. 3).
        for (norm_name, lin_name) in [("attn", "qkv_proj"), ("ffn", "fc1")] {
            let mut rr = r.fork(norm_name);
            let channels = rr.choose(d, n_out);
            let block = &mut model.blocks[l];
            let (norm, lin) = match norm_name {
                "attn" => (&mut block.attn_norm, &mut block.qkv),
                _ => (&mut block.ffn_norm, &mut block.fc1),
            };
            let w = match lin {
                Linear::Dense(w) => w,
                Linear::Quant(_) => panic!("inject_outliers on quantized model"),
            };
            for &c in &channels {
                // Log-spread gains around the configured magnitude.
                let gain = cfg.outlier_gain * (rr.normal() * 0.4).exp();
                norm[c] *= gain;
                let inv = 1.0 / gain;
                for row in 0..w.rows {
                    w[(row, c)] *= inv;
                }
            }
            let _ = lin_name;
        }
        let _ = &mut r;
    }
}

// -- persistence ------------------------------------------------------------

/// Save a dense model to the ATNS tensor format.
pub fn save_model(model: &Gpt, path: &Path) -> Result<()> {
    let mut tf = TensorFile::default();
    let cfg = &model.cfg;
    tf.insert_f32("embed", vec![cfg.vocab_size, cfg.d_model], &model.embed.data);
    tf.insert_f32("lm_head", vec![cfg.vocab_size, cfg.d_model], &model.lm_head.data);
    tf.insert_f32("final_norm", vec![cfg.d_model], &model.final_norm);
    for (l, b) in model.blocks.iter().enumerate() {
        let dense = |lin: &Linear| -> Result<Vec<f32>> {
            lin.dense_weight()
                .map(|w| w.data.clone())
                .context("save_model requires dense weights")
        };
        tf.insert_f32(&format!("L{l}.attn_norm"), vec![cfg.d_model], &b.attn_norm);
        tf.insert_f32(&format!("L{l}.ffn_norm"), vec![cfg.d_model], &b.ffn_norm);
        tf.insert_f32(&format!("L{l}.qkv_proj"), vec![3 * cfg.d_model, cfg.d_model], &dense(&b.qkv)?);
        tf.insert_f32(&format!("L{l}.out_proj"), vec![cfg.d_model, cfg.d_model], &dense(&b.out_proj)?);
        tf.insert_f32(&format!("L{l}.fc1"), vec![2 * cfg.d_ff, cfg.d_model], &dense(&b.fc1)?);
        tf.insert_f32(&format!("L{l}.fc2"), vec![cfg.d_model, cfg.d_ff], &dense(&b.fc2)?);
    }
    tf.save(path)
}

/// Load a dense model from ATNS written either by [`save_model`] or by the
/// python pretraining exporter.
pub fn load_model(cfg: ModelConfig, path: &Path) -> Result<Gpt> {
    let tf = TensorFile::load(path)?;
    let mat = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
        let (dims, data) = tf.get_f32(name)?;
        anyhow::ensure!(
            dims == vec![rows, cols],
            "tensor '{name}': dims {dims:?} != [{rows}, {cols}]"
        );
        Ok(Matrix::from_vec(rows, cols, data))
    };
    let vecf = |name: &str, n: usize| -> Result<Vec<f32>> {
        let (dims, data) = tf.get_f32(name)?;
        anyhow::ensure!(dims == vec![n], "tensor '{name}': dims {dims:?} != [{n}]");
        Ok(data)
    };
    let mat_any = |name: &str| -> Result<Matrix> {
        let (dims, data) = tf.get_f32(name)?;
        anyhow::ensure!(dims.len() == 2, "tensor '{name}' not 2-D");
        Ok(Matrix::from_vec(dims[0], dims[1], data))
    };
    let d = cfg.d_model;
    let embed = mat("embed", cfg.vocab_size, d)?;
    let lm_head = mat("lm_head", cfg.vocab_size, d)?;
    let final_norm = vecf("final_norm", d)?;
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        blocks.push(Block {
            attn_norm: vecf(&format!("L{l}.attn_norm"), d)?,
            qkv: Linear::Dense(mat(&format!("L{l}.qkv_proj"), 3 * d, d)?),
            out_proj: Linear::Dense(mat(&format!("L{l}.out_proj"), d, d)?),
            ffn_norm: vecf(&format!("L{l}.ffn_norm"), d)?,
            fc1: Linear::Dense(mat(&format!("L{l}.fc1"), 2 * cfg.d_ff, d)?),
            fc2: Linear::Dense(
                mat_any(&format!("L{l}.fc2"))?.transposed_if_needed(cfg.d_model, cfg.d_ff)?,
            ),
        });
    }
    Ok(Gpt::assemble(cfg, embed, blocks, final_norm, lm_head))
}

trait FixShape: Sized {
    fn transposed_if_needed(self, d_model: usize, d_ff: usize) -> Result<Matrix>;
}
impl FixShape for Matrix {
    /// fc2 is d_model × d_ff; accept either orientation from exporters.
    fn transposed_if_needed(self, d_model: usize, d_ff: usize) -> Result<Matrix> {
        if self.rows == d_model && self.cols == d_ff {
            Ok(self)
        } else if self.rows == d_ff && self.cols == d_model {
            Ok(self.transpose())
        } else {
            anyhow::bail!("fc2 shape {}x{} incompatible", self.rows, self.cols)
        }
    }
}

/// Load a model whose weights file may not exist: fall back to synthetic.
pub fn load_or_synthetic(config_name: &str, artifacts_dir: &Path, seed: u64) -> Result<(Gpt, bool)> {
    let cfg = ModelConfig::by_name(config_name)?;
    let path = artifacts_dir.join("models").join(&cfg.name).join("weights.atns");
    if path.exists() {
        Ok((load_model(cfg, &path)?, true))
    } else {
        Ok((synthetic_model(config_name, seed)?, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt::NullSink;

    #[test]
    fn injection_preserves_function() {
        // Build twice with identical weights; inject in one; logits equal.
        let cfg = ModelConfig::by_name("micro").unwrap();
        let mut with = synthetic_model("micro", 77).unwrap();
        // Rebuild the un-injected version manually by undoing: easier —
        // construct fresh and compare to a clone prior to injection.
        let root = Pcg64::new(77, 0xA5E1);
        // synthetic_model already injected; construct a non-injected twin:
        let mut plain = synthetic_model("micro", 77).unwrap();
        // Undo injection on `plain` by re-deriving gains? Instead: verify
        // directly that injecting *again* (with a different fork) keeps
        // logits identical — the property we rely on.
        let tokens = [1u32, 5, 9, 33];
        let before = with.forward_logits(&tokens, &mut NullSink);
        inject_outliers(&mut with, &root.fork("again"));
        let after = with.forward_logits(&tokens, &mut NullSink);
        let rel = before.sub(&after).frob_norm() / before.frob_norm().max(1e-9);
        assert!(rel < 1e-3, "rel={rel}");
        let _ = &mut plain;
    }

    #[test]
    fn injection_creates_activation_outliers() {
        use crate::model::gpt::ActSink;
        struct Grab(Option<Matrix>);
        impl ActSink for Grab {
            fn record(&mut self, key: &str, x: &Matrix) {
                if key == "L0.qkv_proj" && self.0.is_none() {
                    self.0 = Some(x.clone());
                }
            }
        }
        let model = synthetic_model("micro", 78).unwrap();
        let mut sink = Grab(None);
        let tokens: Vec<u32> = (0..32).map(|i| (i * 3) % 128).collect();
        model.forward_logits(&tokens, &mut sink);
        let x = sink.0.unwrap();
        let means = x.col_abs_mean();
        let mut sorted = means.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // Kurtosis check: top channel dominates the median by the gain.
        let median = sorted[sorted.len() / 2];
        assert!(sorted[0] > 5.0 * median, "top {} median {median}", sorted[0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("aser_model_io");
        let path = dir.join("m.atns");
        let model = synthetic_model("micro", 79).unwrap();
        save_model(&model, &path).unwrap();
        let back = load_model(model.cfg.clone(), &path).unwrap();
        let tokens = [2u32, 4, 8];
        let a = model.forward_logits(&tokens, &mut NullSink);
        let b = back.forward_logits(&tokens, &mut NullSink);
        assert!(a.max_diff(&b) < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_synthetic_fallback() {
        let dir = std::env::temp_dir().join("aser_no_artifacts");
        let (m, pretrained) = load_or_synthetic("micro", &dir, 5).unwrap();
        assert!(!pretrained);
        assert_eq!(m.cfg.name, "micro");
    }

    #[test]
    fn deterministic_construction() {
        let a = synthetic_model("micro", 99).unwrap();
        let b = synthetic_model("micro", 99).unwrap();
        assert_eq!(a.embed.data, b.embed.data);
        let wa = a.blocks[1].fc1.dense_weight().unwrap();
        let wb = b.blocks[1].fc1.dense_weight().unwrap();
        assert_eq!(wa.data, wb.data);
    }
}
