//! Model configurations.
//!
//! The evaluation grid uses six tiny LLaMA-style configs standing in for the
//! paper's model zoo (see DESIGN.md §3 for the substitution argument). Each
//! linear layer matches the paper's per-block naming: `qkv_proj`,
//! `out_proj`, `fc1`, `fc2`.

use crate::util::json::{num, obj, s, Json};
use anyhow::Result;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// SwiGLU inner width (fc1 produces 2×d_ff, fc2 maps d_ff→d_model).
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_base: f32,
    pub norm_eps: f32,
    /// Channels per layer boosted by function-preserving outlier injection
    /// (fraction of d_model; see `model::init`).
    pub outlier_frac: f32,
    /// Outlier magnitude multiplier.
    pub outlier_gain: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameter count of the transformer (excl. embeddings).
    pub fn block_params(&self) -> usize {
        let d = self.d_model;
        self.n_layers * (3 * d * d + d * d + d * 2 * self.d_ff + self.d_ff * d)
    }

    pub fn total_params(&self) -> usize {
        self.block_params() + 2 * self.vocab_size * self.d_model
    }

    /// The registry standing in for the paper's model zoo. Letters map to
    /// tables: A=LLaMA3-8B, B=Qwen1.5-7B, C=Qwen-72B, D=LLaMA2-13B,
    /// E=Qwen-14B, F=Qwen1.5-32B.
    pub fn by_name(name: &str) -> Result<ModelConfig> {
        let base = ModelConfig {
            name: name.to_string(),
            vocab_size: 512,
            d_model: 256,
            n_layers: 8,
            n_heads: 8,
            d_ff: 512,
            max_seq: 256,
            rope_base: 10_000.0,
            norm_eps: 1e-5,
            outlier_frac: 0.01,
            outlier_gain: 25.0,
        };
        Ok(match name {
            // "LLaMA3-8B" stand-in: the main analysis model.
            "A" | "llama3-8b" => base,
            // "Qwen1.5-7B": different width/depth + hotter outliers (the
            // Qwen family quantizes worse in the paper's tables).
            "B" | "qwen1.5-7b" => ModelConfig {
                d_model: 320,
                n_layers: 6,
                n_heads: 8,
                d_ff: 640,
                outlier_frac: 0.015,
                outlier_gain: 45.0,
                ..base
            },
            // "Qwen-72B": the large config.
            "C" | "qwen-72b" => ModelConfig {
                d_model: 512,
                n_layers: 8,
                n_heads: 8,
                d_ff: 1024,
                outlier_frac: 0.01,
                outlier_gain: 30.0,
                ..base
            },
            // "LLaMA2-13B"
            "D" | "llama2-13b" => ModelConfig {
                d_model: 384,
                n_layers: 7,
                n_heads: 8,
                d_ff: 768,
                outlier_gain: 18.0,
                ..base
            },
            // "Qwen-14B"
            "E" | "qwen-14b" => ModelConfig {
                d_model: 448,
                n_layers: 6,
                n_heads: 8,
                d_ff: 896,
                outlier_frac: 0.012,
                outlier_gain: 35.0,
                ..base
            },
            // "Qwen1.5-32B"
            "F" | "qwen1.5-32b" => ModelConfig {
                d_model: 512,
                n_layers: 7,
                n_heads: 16,
                d_ff: 1024,
                outlier_frac: 0.012,
                outlier_gain: 40.0,
                ..base
            },
            // Micro config for fast tests.
            "micro" => ModelConfig {
                vocab_size: 128,
                d_model: 64,
                n_layers: 2,
                n_heads: 4,
                d_ff: 128,
                max_seq: 64,
                ..base
            },
            other => anyhow::bail!("unknown model config '{other}'"),
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("vocab_size", num(self.vocab_size as f64)),
            ("d_model", num(self.d_model as f64)),
            ("n_layers", num(self.n_layers as f64)),
            ("n_heads", num(self.n_heads as f64)),
            ("d_ff", num(self.d_ff as f64)),
            ("max_seq", num(self.max_seq as f64)),
            ("rope_base", num(self.rope_base as f64)),
            ("norm_eps", num(self.norm_eps as f64)),
            ("outlier_frac", num(self.outlier_frac as f64)),
            ("outlier_gain", num(self.outlier_gain as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.str_field("name")?.to_string(),
            vocab_size: j.int("vocab_size")?,
            d_model: j.int("d_model")?,
            n_layers: j.int("n_layers")?,
            n_heads: j.int("n_heads")?,
            d_ff: j.int("d_ff")?,
            max_seq: j.int("max_seq")?,
            rope_base: j.num("rope_base")? as f32,
            norm_eps: j.num("norm_eps")? as f32,
            outlier_frac: j.num("outlier_frac")? as f32,
            outlier_gain: j.num("outlier_gain")? as f32,
        })
    }
}

/// Names of the quantizable linear layers in one block, matching Fig. 2.
pub const LINEAR_NAMES: [&str; 4] = ["qkv_proj", "out_proj", "fc1", "fc2"];

/// Stable layer key "L{idx}.{name}" used by calibration and the pipeline.
pub fn layer_key(block: usize, linear: &str) -> String {
    format!("L{block}.{linear}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_configs_consistent() {
        for name in ["A", "B", "C", "D", "E", "F", "micro"] {
            let c = ModelConfig::by_name(name).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0, "{name}");
            assert!(c.total_params() > 0);
        }
        assert!(ModelConfig::by_name("nope").is_err());
    }

    #[test]
    fn aliases_resolve() {
        let a = ModelConfig::by_name("A").unwrap();
        let a2 = ModelConfig::by_name("llama3-8b").unwrap();
        assert_eq!(a.d_model, a2.d_model);
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::by_name("B").unwrap();
        let j = c.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(back.d_model, c.d_model);
        assert_eq!(back.rope_base, c.rope_base);
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(ModelConfig::from_json(&reparsed).unwrap().d_ff, c.d_ff);
    }

    #[test]
    fn layer_keys() {
        assert_eq!(layer_key(3, "fc1"), "L3.fc1");
    }

    #[test]
    fn param_count_formula() {
        let c = ModelConfig::by_name("micro").unwrap();
        let d = 64;
        let per_block = 3 * d * d + d * d + d * 2 * 128 + 128 * d;
        assert_eq!(c.block_params(), 2 * per_block);
    }
}
