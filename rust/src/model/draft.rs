//! Speculative-decoding draft models: cheap token proposers the serving
//! batcher verifies through the target's ragged multi-token forward.
//!
//! ## Draft / verify / rollback protocol
//!
//! Each decode iteration of a speculating sequence runs three phases:
//!
//! 1. **Draft.** The [`DraftModel`] catches its private KV cache up on any
//!    context tokens it has not seen (the previous iteration's correction
//!    or bonus token; on the first decode step, the whole prompt), then
//!    proposes `k` tokens by greedy argmax chaining — feed `d₁` to get
//!    `d₂`, and so on. `d_k` itself is never fed (nothing needs its
//!    logits). Proposals from many sequences batch through the same ragged
//!    [`Gpt::forward_chunk_batch_layers`] engine the target uses, so a
//!    `k`-deep draft round costs one catch-up forward plus `k−1`
//!    single-row batched steps at draft depth.
//! 2. **Verify.** The batcher stacks `[pending, d₁ … d_k]` as ONE
//!    [`ChunkLogits::All`] span of the target's ragged forward: `k+1`
//!    logits rows for the price of one batched pass. Row `j` is the
//!    target's next-token distribution *given the draft prefix `d₁…d_j`
//!    was correct*.
//! 3. **Accept / rollback.** Walking rows in position order, the
//!    sequence's [`Sampler`] draws token `e_{j+1}` from row `j`
//!    ([`Sampler::accept`]). While `e_{j+1} == d_{j+1}` the draft prefix
//!    is confirmed and the walk continues; the first mismatch makes
//!    `e_{j+1}` the **correction** token (the row's context is exactly the
//!    accepted prefix, so the draw is from the true target distribution)
//!    and the walk stops. If all `k` drafts are accepted, row `k` yields a
//!    free **bonus** token. Unconfirmed suffix positions are rolled back
//!    with [`KvCache::truncate`] on BOTH caches — with paged KV this is a
//!    length clamp plus whole-page release, never a repack.
//!
//! ## Why the output distribution is preserved
//!
//! Every emitted token is drawn by the request's own [`Sampler`] from a
//! **target** logits row whose causal context is exactly the already-
//! emitted stream (speculatively-fed wrong-suffix positions are masked by
//! causality for accepted rows and truncated before they are ever read
//! again). The quantized forward is bitwise identical across batch shapes
//! and chunkings, so row `j` equals the logits non-speculative decoding
//! would have produced at the same stream position. Acceptance consumes
//! the sampler exactly once per *emitted* token in stream order — never
//! for rolled-back rows — so RNG consumption matches non-speculative
//! decoding draw-for-draw. Hence greedy speculative streams are bitwise
//! the greedy stream for ANY proposer, and seeded sampling streams are
//! bitwise invariant to `spec_k`. The draft model's quality affects only
//! the acceptance rate (throughput), never the output.
//!
//! ## Draft flavors
//!
//! - **Truncated-layer self-draft** (`self:<n>`): runs the first `n`
//!   blocks of the *target itself* (shared `Arc`, zero extra weights —
//!   [`Linear`](crate::model::Linear) packs are not clonable and never
//!   need to be) and applies the target's final norm + lm_head on the
//!   truncated residual stream. The residual architecture makes early-exit
//!   logits a usable next-token predictor at `n/L` of the per-token cost.
//! - **Independent draft** (`rtn`): a separately-quantized model (RTN over
//!   the same base weights — the cheapest method in the zoo) with the same
//!   tokenizer geometry. Full depth, so it only pays off when its
//!   quantization is materially cheaper than the target's, but it
//!   exercises the general two-model plumbing.
//!
//! The draft's KV cache is layer-truncated ([`KvCache::for_layers`]) and
//! lives outside the pool's lease accounting: it is bounded overhead
//! (`n/L` of the target's bytes per token for a self-draft), not serving
//! capacity.

use crate::coordinator::kvpool::KvCache;
use crate::model::gpt::{argmax, ChunkLogits, Gpt, SeqChunk};
use crate::tensor::QGemmArena;
use std::sync::Arc;

/// Parsed `--draft <spec>` knob: which proposer to build.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DraftSpec {
    /// No speculation (the default).
    Off,
    /// Truncated-layer self-draft over the first `n` target blocks.
    SelfLayers(usize),
    /// Independently RTN-quantized full-depth draft.
    Rtn,
}

impl DraftSpec {
    /// Parse `off`, `self:<n>`, or `rtn`.
    pub fn parse(s: &str) -> Result<DraftSpec, String> {
        if s == "off" {
            return Ok(DraftSpec::Off);
        }
        if s == "rtn" {
            return Ok(DraftSpec::Rtn);
        }
        if let Some(n) = s.strip_prefix("self:") {
            let n: usize = n
                .parse()
                .map_err(|_| format!("bad draft layer count in '{s}' (want self:<n>)"))?;
            if n == 0 {
                return Err("self-draft needs at least one layer".into());
            }
            return Ok(DraftSpec::SelfLayers(n));
        }
        Err(format!("unknown draft spec '{s}' (want off | self:<n> | rtn)"))
    }
}

impl std::fmt::Display for DraftSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DraftSpec::Off => write!(f, "off"),
            DraftSpec::SelfLayers(n) => write!(f, "self:{n}"),
            DraftSpec::Rtn => write!(f, "rtn"),
        }
    }
}

/// A token proposer for speculative decoding: a model handle plus the
/// layer depth its forward (and KV cache) runs at. Cheap to clone — the
/// weights are `Arc`-shared — and `Send + Sync`, so each engine worker
/// holds its own handle.
#[derive(Clone)]
pub struct DraftModel {
    model: Arc<Gpt>,
    n_layers: usize,
    /// Human-readable spec, for metrics/summary lines.
    label: String,
}

impl std::fmt::Debug for DraftModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DraftModel")
            .field("label", &self.label)
            .field("n_layers", &self.n_layers)
            .finish_non_exhaustive()
    }
}

impl DraftModel {
    /// Truncated-layer self-draft: the first `n_layers` blocks of the
    /// target itself (weights shared by `Arc`, nothing copied).
    pub fn self_draft(target: Arc<Gpt>, n_layers: usize) -> Result<DraftModel, String> {
        let total = target.blocks.len();
        if n_layers == 0 || n_layers > total {
            return Err(format!(
                "self-draft wants {n_layers} layers but the target has {total}"
            ));
        }
        Ok(DraftModel { model: target, n_layers, label: format!("self:{n_layers}") })
    }

    /// Independent full-depth draft (e.g. an RTN-quantized sibling). Must
    /// share the target's token geometry — same vocabulary and KV window —
    /// or proposals and rollback positions would be meaningless.
    pub fn independent(
        model: Arc<Gpt>,
        target_cfg: &crate::model::ModelConfig,
        label: &str,
    ) -> Result<DraftModel, String> {
        if model.cfg.vocab_size != target_cfg.vocab_size {
            return Err("draft/target vocabulary mismatch".into());
        }
        if model.cfg.max_seq < target_cfg.max_seq {
            return Err("draft KV window smaller than the target's".into());
        }
        let n_layers = model.cfg.n_layers;
        Ok(DraftModel { model, n_layers, label: label.to_string() })
    }

    /// Spec label (`self:<n>` / `rtn`), for summaries.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Layer depth of the draft forward.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Fraction of the target's per-token layer cost a draft step pays —
    /// the bench's draft-overhead denominator.
    pub fn depth_fraction(&self, target_layers: usize) -> f64 {
        self.n_layers as f64 / target_layers.max(1) as f64
    }

    /// A fresh per-sequence draft cache: layer-truncated, f32, outside the
    /// pool's lease accounting (see the module doc).
    pub fn new_cache(&self) -> KvCache {
        KvCache::for_layers(&self.model.cfg, self.n_layers)
    }

    /// Propose tokens for a batch of sequences. For sequence `i`,
    /// `tails[i]` holds the context tokens its `caches[i]` has not seen
    /// yet (≥ 1: at least the last emitted token) and `ks[i] ≥ 1` is the
    /// number of proposals wanted. Returns exactly `ks[i]` proposals per
    /// sequence; on return `caches[i]` has consumed the tail plus the
    /// first `ks[i] − 1` proposals (the batcher rolls unaccepted ones back
    /// via [`KvCache::truncate`]).
    ///
    /// The catch-up pass runs all tails as ONE ragged forward; every
    /// subsequent proposal round is one batched single-row step over the
    /// sequences still drafting — `max(ks)` draft-depth forwards total,
    /// independent of batch width.
    pub fn propose_batch(
        &self,
        tails: &[Vec<u32>],
        ks: &[usize],
        caches: &mut [&mut KvCache],
        arena: &mut QGemmArena,
    ) -> Vec<Vec<u32>> {
        let n = tails.len();
        debug_assert_eq!(n, ks.len());
        debug_assert_eq!(n, caches.len());
        if n == 0 {
            return Vec::new();
        }
        // Catch-up + first proposal: feed each tail, read one logits row.
        let chunks: Vec<SeqChunk> = tails
            .iter()
            .map(|t| {
                debug_assert!(!t.is_empty(), "draft tail must hold ≥ 1 token");
                SeqChunk { tokens: t, logits: ChunkLogits::Last }
            })
            .collect();
        let logits =
            self.model.forward_chunk_batch_layers(&chunks, caches, arena, self.n_layers);
        let mut props: Vec<Vec<u32>> =
            (0..n).map(|i| vec![argmax(logits.row(i)) as u32]).collect();
        let k_max = ks.iter().copied().max().unwrap_or(1);
        for round in 1..k_max {
            // Sequences still wanting proposals feed their newest draft
            // token; the rest sit this round out.
            let idxs: Vec<usize> = (0..n).filter(|&i| ks[i] > round).collect();
            if idxs.is_empty() {
                break;
            }
            let toks: Vec<u32> = idxs.iter().map(|&i| *props[i].last().unwrap()).collect();
            let chunks: Vec<SeqChunk> = toks
                .iter()
                .map(|t| SeqChunk { tokens: std::slice::from_ref(t), logits: ChunkLogits::Last })
                .collect();
            let mut sub: Vec<&mut KvCache> = caches
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| ks[*i] > round)
                .map(|(_, c)| &mut **c)
                .collect();
            let logits =
                self.model.forward_chunk_batch_layers(&chunks, &mut sub, arena, self.n_layers);
            for (r, &i) in idxs.iter().enumerate() {
                props[i].push(argmax(logits.row(r)) as u32);
            }
        }
        debug_assert!(props.iter().zip(ks).all(|(p, &k)| p.len() == k));
        props
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;

    #[test]
    fn draft_spec_parses() {
        assert_eq!(DraftSpec::parse("off").unwrap(), DraftSpec::Off);
        assert_eq!(DraftSpec::parse("rtn").unwrap(), DraftSpec::Rtn);
        assert_eq!(DraftSpec::parse("self:1").unwrap(), DraftSpec::SelfLayers(1));
        assert_eq!(DraftSpec::parse("self:3").unwrap(), DraftSpec::SelfLayers(3));
        assert!(DraftSpec::parse("self:0").is_err());
        assert!(DraftSpec::parse("self:x").is_err());
        assert!(DraftSpec::parse("eagle").is_err());
        for s in ["off", "rtn", "self:2"] {
            assert_eq!(DraftSpec::parse(s).unwrap().to_string(), s);
        }
    }

    #[test]
    fn self_draft_validates_layer_count() {
        let m = Arc::new(synthetic_model("micro", 51).unwrap());
        assert!(DraftModel::self_draft(Arc::clone(&m), 0).is_err());
        assert!(DraftModel::self_draft(Arc::clone(&m), 3).is_err(), "micro has 2 layers");
        let d = DraftModel::self_draft(m, 1).unwrap();
        assert_eq!(d.n_layers(), 1);
        assert_eq!(d.label(), "self:1");
        assert_eq!(d.depth_fraction(2), 0.5);
    }

    #[test]
    fn full_depth_self_draft_proposes_the_target_greedy_stream() {
        // A self-draft over ALL layers runs the target's exact forward, so
        // its greedy proposal chain must equal target greedy generation —
        // pinning the draft plumbing (catch-up, chaining, cache layout) to
        // an existing oracle.
        let m = Arc::new(synthetic_model("micro", 51).unwrap());
        let prompt = vec![5u32, 9, 13];
        let k = 6;
        let want = m.generate_greedy(&prompt, k);
        assert_eq!(want.len(), k, "oracle must run the full span");
        let d = DraftModel::self_draft(Arc::clone(&m), m.cfg.n_layers).unwrap();
        let mut cache = d.new_cache();
        let mut arena = QGemmArena::new();
        let props = d.propose_batch(
            &[prompt.clone()],
            &[k],
            &mut [&mut cache],
            &mut arena,
        );
        assert_eq!(props, vec![want]);
        // Cache consumed the tail + k-1 proposals, exactly.
        assert_eq!(cache.len(), prompt.len() + k - 1);
    }

    #[test]
    fn truncated_self_draft_runs_and_rolls_back() {
        let m = Arc::new(synthetic_model("micro", 51).unwrap());
        let d = DraftModel::self_draft(Arc::clone(&m), 1).unwrap();
        let mut cache = d.new_cache();
        let mut arena = QGemmArena::new();
        let tail = vec![5u32, 9, 13];
        let props = d.propose_batch(&[tail.clone()], &[3], &mut [&mut cache], &mut arena);
        assert_eq!(props[0].len(), 3);
        assert!(props[0].iter().all(|&t| (t as usize) < m.cfg.vocab_size));
        assert_eq!(cache.len(), tail.len() + 2);
        // Rollback to the context then re-propose: the draft is
        // deterministic, so the chain must repeat bitwise.
        cache.truncate(tail.len());
        let again =
            d.propose_batch(&[vec![*tail.last().unwrap()]], &[3], &mut [&mut cache], &mut arena);
        // (Re-feeding the last context token replays position tail.len()-1
        // — roll that off first for a clean comparison.)
        let mut c2 = d.new_cache();
        let again2 = d.propose_batch(&[tail.clone()], &[3], &mut [&mut c2], &mut arena);
        assert_eq!(again2, props, "fresh replay must reproduce the chain");
        drop(again);
    }

    #[test]
    fn batched_proposals_match_single_sequence_chains() {
        // Ragged batching must not change any sequence's proposals, and
        // per-sequence k raggedness (2 vs 4) must be respected.
        let m = Arc::new(synthetic_model("micro", 51).unwrap());
        let d = DraftModel::self_draft(Arc::clone(&m), 1).unwrap();
        let mut arena = QGemmArena::new();
        let tails = [vec![5u32, 9, 13], vec![7u32, 7], vec![40u32, 2, 64, 8]];
        let ks = [2usize, 4, 3];
        let solo: Vec<Vec<u32>> = tails
            .iter()
            .zip(&ks)
            .map(|(t, &k)| {
                let mut c = d.new_cache();
                d.propose_batch(&[t.clone()], &[k], &mut [&mut c], &mut arena)
                    .pop()
                    .unwrap()
            })
            .collect();
        let mut c0 = d.new_cache();
        let mut c1 = d.new_cache();
        let mut c2 = d.new_cache();
        let batched = d.propose_batch(
            &tails.to_vec(),
            &ks,
            &mut [&mut c0, &mut c1, &mut c2],
            &mut arena,
        );
        assert_eq!(batched, solo, "batch shape must not change proposals");
        assert_eq!(batched[0].len(), 2);
        assert_eq!(batched[1].len(), 4);
    }

    #[test]
    fn independent_draft_validates_geometry() {
        let m = Arc::new(synthetic_model("micro", 51).unwrap());
        let cfg = m.cfg.clone();
        let d = DraftModel::independent(Arc::clone(&m), &cfg, "rtn").unwrap();
        assert_eq!(d.n_layers(), cfg.n_layers);
        assert_eq!(d.label(), "rtn");
        let mut small = cfg.clone();
        small.vocab_size += 1;
        assert!(DraftModel::independent(m, &small, "rtn").is_err());
    }
}
