//! LLaMA-style decoder-only transformer (fp32, CPU) — the evaluation
//! substrate the quantization pipeline operates on.
//!
//! Structure per block: RMSNorm → fused `qkv_proj` → rotary → causal MHSA →
//! `out_proj` → residual; RMSNorm → fused `fc1` (gate‖up) → SwiGLU → `fc2` →
//! residual. The four named linears match the paper's Fig. 2. Embeddings and
//! the LM head stay fp (standard PTQ practice).
//!
//! Three forward paths:
//! - [`Gpt::forward_logits`] — teacher-forced batch forward (PPL/eval,
//!   calibration capture via [`ActSink`]).
//! - [`Gpt::forward_step`] — single-sequence incremental decode against a
//!   [`KvCache`] (greedy generation).
//! - [`Gpt::forward_step_batch`] — the serving hot path: advance N
//!   independent sequences by one token each, stacking every per-layer
//!   linear into one batched (packed quantized) GEMM while attention runs
//!   per-sequence against each sequence's own cache/position.

use super::config::{layer_key, ModelConfig};
use super::linear::Linear;
use crate::tensor::{Matrix, QGemmArena};

/// Receives the input activations of every quantizable linear layer.
pub trait ActSink {
    fn record(&mut self, key: &str, x: &Matrix);
}

/// No-op sink.
pub struct NullSink;
impl ActSink for NullSink {
    fn record(&mut self, _key: &str, _x: &Matrix) {}
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub qkv: Linear,      // (3·d) × d
    pub out_proj: Linear, // d × d
    pub ffn_norm: Vec<f32>,
    pub fc1: Linear, // (2·d_ff) × d   (gate ‖ up)
    pub fc2: Linear, // d × d_ff
}

pub struct Gpt {
    pub cfg: ModelConfig,
    pub embed: Matrix,   // vocab × d
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
    pub lm_head: Matrix, // vocab × d
}

#[derive(Clone)]
/// Per-layer KV cache for incremental decoding.
pub struct KvCache {
    /// keys[layer]: seen × d_model (heads packed contiguously).
    pub keys: Vec<Vec<f32>>,
    pub values: Vec<Vec<f32>>,
    pub seen: usize,
    d_model: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            keys: vec![Vec::new(); cfg.n_layers],
            values: vec![Vec::new(); cfg.n_layers],
            seen: 0,
            d_model: cfg.d_model,
        }
    }

    pub fn len(&self) -> usize {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Bytes held (for the serving cache manager's accounting).
    pub fn bytes(&self) -> usize {
        self.keys.iter().chain(&self.values).map(|v| v.len() * 4).sum()
    }

    /// Drop everything after position `n` (prefix reuse).
    pub fn truncate(&mut self, n: usize) {
        for k in &mut self.keys {
            k.truncate(n * self.d_model);
        }
        for v in &mut self.values {
            v.truncate(n * self.d_model);
        }
        self.seen = self.seen.min(n);
    }
}

// ---------------------------------------------------------------------------

/// RMSNorm with learned gain.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    rmsnorm_into(x, gain, eps, &mut out);
    out
}

/// RMSNorm writing into caller storage — the batched decode path normalizes
/// straight into its stacked row matrices instead of allocating a `Vec` per
/// sequence per layer.
pub fn rmsnorm_into(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    debug_assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * inv * g;
    }
}

fn rmsnorm_rows(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_into(x.row(r), gain, eps, out.row_mut(r));
    }
    out
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Apply rotary position embedding to one head vector in place
/// (half-split convention, matching the JAX build path).
pub fn rope_inplace(v: &mut [f32], pos: usize, base: f32) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = base.powf(-2.0 * i as f32 / hd as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = v[i];
        let b = v[half + i];
        v[i] = a * cos - b * sin;
        v[half + i] = a * sin + b * cos;
    }
}

fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

impl Gpt {
    /// Teacher-forced forward: logits for every position (T × vocab).
    pub fn forward_logits(&self, tokens: &[u32], sink: &mut dyn ActSink) -> Matrix {
        let h = self.forward_hidden(tokens, sink);
        crate::tensor::matmul_bt(&h, &self.lm_head)
    }

    /// Final hidden states (T × d), post final norm.
    pub fn forward_hidden(&self, tokens: &[u32], sink: &mut dyn ActSink) -> Matrix {
        let t_len = tokens.len();
        let d = self.cfg.d_model;
        assert!(t_len <= self.cfg.max_seq, "sequence {} > max_seq", t_len);
        let mut h = Matrix::zeros(t_len, d);
        for (t, &tok) in tokens.iter().enumerate() {
            h.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        for (l, block) in self.blocks.iter().enumerate() {
            h = self.block_forward(block, l, &h, sink);
        }
        rmsnorm_rows(&h, &self.final_norm, self.cfg.norm_eps)
    }

    fn block_forward(&self, block: &Block, l: usize, h: &Matrix, sink: &mut dyn ActSink) -> Matrix {
        let cfg = &self.cfg;
        let (t_len, d) = (h.rows, cfg.d_model);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());

        // ---- attention ----
        let x_norm = rmsnorm_rows(h, &block.attn_norm, cfg.norm_eps);
        sink.record(&layer_key(l, "qkv_proj"), &x_norm);
        let qkv = block.qkv.forward(&x_norm); // T × 3d
        // Split and apply rope per head.
        let mut q = qkv.cols_slice(0, d);
        let mut k = qkv.cols_slice(d, 2 * d);
        let v = qkv.cols_slice(2 * d, 3 * d);
        for t in 0..t_len {
            for head in 0..nh {
                let s = head * hd;
                rope_inplace(&mut q.row_mut(t)[s..s + hd], t, cfg.rope_base);
                rope_inplace(&mut k.row_mut(t)[s..s + hd], t, cfg.rope_base);
            }
        }
        // Causal attention per head.
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn_out = Matrix::zeros(t_len, d);
        let mut scores = vec![0f32; t_len];
        for head in 0..nh {
            let s = head * hd;
            for tq in 0..t_len {
                let qrow = &q.row(tq)[s..s + hd];
                for tk in 0..=tq {
                    scores[tk] = crate::tensor::dot(qrow, &k.row(tk)[s..s + hd]) * scale;
                }
                softmax_inplace(&mut scores[..=tq]);
                let orow = &mut attn_out.row_mut(tq)[s..s + hd];
                for tk in 0..=tq {
                    let w = scores[tk];
                    if w == 0.0 {
                        continue;
                    }
                    let vrow = &v.row(tk)[s..s + hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
        sink.record(&layer_key(l, "out_proj"), &attn_out);
        let attn_proj = block.out_proj.forward(&attn_out);
        let h1 = h.add(&attn_proj);

        // ---- feed-forward (SwiGLU) ----
        let x_norm2 = rmsnorm_rows(&h1, &block.ffn_norm, cfg.norm_eps);
        sink.record(&layer_key(l, "fc1"), &x_norm2);
        let gate_up = block.fc1.forward(&x_norm2); // T × 2·dff
        let dff = cfg.d_ff;
        let mut act = Matrix::zeros(t_len, dff);
        for t in 0..t_len {
            let gu = gate_up.row(t);
            let arow = act.row_mut(t);
            for i in 0..dff {
                arow[i] = silu(gu[i]) * gu[dff + i];
            }
        }
        sink.record(&layer_key(l, "fc2"), &act);
        let ffn = block.fc2.forward(&act);
        h1.add(&ffn)
    }

    /// One sequence's attention for layer `l` against its KV cache: split
    /// the fused qkv row, rope at the cache position, append k/v, attend
    /// over everything seen. Writes the concatenated head outputs into the
    /// zeroed `out` (length d_model). Shared by the single-token and batched
    /// decode paths so they stay numerically identical.
    fn attn_cached(&self, l: usize, cache: &mut KvCache, qkv: &[f32], out: &mut [f32]) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let pos = cache.seen;
        let mut q = qkv[0..d].to_vec();
        let mut k = qkv[d..2 * d].to_vec();
        let v = &qkv[2 * d..3 * d];
        for head in 0..nh {
            let s = head * hd;
            rope_inplace(&mut q[s..s + hd], pos, cfg.rope_base);
            rope_inplace(&mut k[s..s + hd], pos, cfg.rope_base);
        }
        cache.keys[l].extend_from_slice(&k);
        cache.values[l].extend_from_slice(v);
        let t_seen = pos + 1;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0f32; t_seen];
        for head in 0..nh {
            let s = head * hd;
            let qh = &q[s..s + hd];
            for tk in 0..t_seen {
                let krow = &cache.keys[l][tk * d + s..tk * d + s + hd];
                scores[tk] = crate::tensor::dot(qh, krow) * scale;
            }
            softmax_inplace(&mut scores);
            let orow = &mut out[s..s + hd];
            for tk in 0..t_seen {
                let w = scores[tk];
                let vrow = &cache.values[l][tk * d + s..tk * d + s + hd];
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
    }

    /// Incremental decode: push one token, return logits for the next.
    pub fn forward_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        assert!(cache.seen < cfg.max_seq, "kv cache full");
        let mut h: Vec<f32> = self.embed.row(token as usize).to_vec();

        for (l, block) in self.blocks.iter().enumerate() {
            // attention
            let x_norm = rmsnorm(&h, &block.attn_norm, cfg.norm_eps);
            let qkv = block.qkv.forward_token(&x_norm);
            let mut attn_out = vec![0f32; d];
            self.attn_cached(l, cache, &qkv, &mut attn_out);
            let attn_proj = block.out_proj.forward_token(&attn_out);
            for (hi, p) in h.iter_mut().zip(&attn_proj) {
                *hi += p;
            }
            // ffn
            let x_norm2 = rmsnorm(&h, &block.ffn_norm, cfg.norm_eps);
            let gate_up = block.fc1.forward_token(&x_norm2);
            let dff = cfg.d_ff;
            let mut act = vec![0f32; dff];
            for i in 0..dff {
                act[i] = silu(gate_up[i]) * gate_up[dff + i];
            }
            let ffn = block.fc2.forward_token(&act);
            for (hi, f) in h.iter_mut().zip(&ffn) {
                *hi += f;
            }
        }
        cache.seen += 1;
        let hn = rmsnorm(&h, &self.final_norm, cfg.norm_eps);
        crate::tensor::matvec(&self.lm_head, &hn)
    }

    /// Batched incremental decode — the continuous batcher's hot path.
    ///
    /// Advances `tokens.len()` independent sequences by one token each. All
    /// per-layer linears run as ONE batched (packed quantized) GEMM over the
    /// stacked token rows; attention runs per sequence against its own
    /// cache/position via the same [`Gpt::attn_cached`] used by
    /// [`Gpt::forward_step`], so per-sequence results match the scalar path.
    /// `arena` holds the reusable activation-quantization scratch. Returns
    /// logits, batch × vocab (row i belongs to `tokens[i]` / `caches[i]`).
    pub fn forward_step_batch(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
        arena: &mut QGemmArena,
    ) -> Matrix {
        let cfg = &self.cfg;
        let b = tokens.len();
        assert_eq!(b, caches.len(), "token/cache count mismatch");
        let d = cfg.d_model;
        for c in caches.iter() {
            assert!(c.seen < cfg.max_seq, "kv cache full");
        }
        let mut h = Matrix::zeros(b, d);
        for (i, &tok) in tokens.iter().enumerate() {
            h.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        for (l, block) in self.blocks.iter().enumerate() {
            // ---- attention: one batched qkv/out_proj GEMM, per-seq attend ----
            let mut x_norm = Matrix::zeros(b, d);
            for i in 0..b {
                rmsnorm_into(h.row(i), &block.attn_norm, cfg.norm_eps, x_norm.row_mut(i));
            }
            let qkv = block.qkv.forward_with(&x_norm, arena); // b × 3d
            let mut attn_out = Matrix::zeros(b, d);
            for i in 0..b {
                self.attn_cached(l, &mut *caches[i], qkv.row(i), attn_out.row_mut(i));
            }
            let attn_proj = block.out_proj.forward_with(&attn_out, arena);
            let h1 = h.add(&attn_proj);
            // ---- feed-forward: batched fc1/fc2, rowwise SwiGLU ----
            let mut x_norm2 = Matrix::zeros(b, d);
            for i in 0..b {
                rmsnorm_into(h1.row(i), &block.ffn_norm, cfg.norm_eps, x_norm2.row_mut(i));
            }
            let gate_up = block.fc1.forward_with(&x_norm2, arena); // b × 2·dff
            let dff = cfg.d_ff;
            let mut act = Matrix::zeros(b, dff);
            for i in 0..b {
                let gu = gate_up.row(i);
                let arow = act.row_mut(i);
                for j in 0..dff {
                    arow[j] = silu(gu[j]) * gu[dff + j];
                }
            }
            let ffn = block.fc2.forward_with(&act, arena);
            h = h1.add(&ffn);
        }
        for c in caches.iter_mut() {
            c.seen += 1;
        }
        let mut hn = Matrix::zeros(b, d);
        for i in 0..b {
            rmsnorm_into(h.row(i), &self.final_norm, cfg.norm_eps, hn.row_mut(i));
        }
        crate::tensor::matmul_bt(&hn, &self.lm_head)
    }

    /// Greedy generation from a prompt; returns generated token ids.
    pub fn generate_greedy(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let mut cache = KvCache::new(&self.cfg);
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.forward_step(t, &mut cache);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if cache.seen >= self.cfg.max_seq {
                break;
            }
            let next = argmax(&logits) as u32;
            out.push(next);
            logits = self.forward_step(next, &mut cache);
        }
        out
    }

    /// Swap one linear layer (the pipeline applies quantization results).
    pub fn set_linear(&mut self, block: usize, name: &str, lin: Linear) {
        let b = &mut self.blocks[block];
        match name {
            "qkv_proj" => b.qkv = lin,
            "out_proj" => b.out_proj = lin,
            "fc1" => b.fc1 = lin,
            "fc2" => b.fc2 = lin,
            other => panic!("unknown linear '{other}'"),
        }
    }

    pub fn get_linear(&self, block: usize, name: &str) -> &Linear {
        let b = &self.blocks[block];
        match name {
            "qkv_proj" => &b.qkv,
            "out_proj" => &b.out_proj,
            "fc1" => &b.fc1,
            "fc2" => &b.fc2,
            other => panic!("unknown linear '{other}'"),
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::synthetic_model;
    use crate::util::rng::Pcg64;

    #[test]
    fn batch_and_incremental_forward_agree() {
        let model = synthetic_model("micro", 7).unwrap();
        let tokens: Vec<u32> = vec![3, 17, 42, 9, 100, 55];
        let batch = model.forward_logits(&tokens, &mut NullSink);
        let mut cache = KvCache::new(&model.cfg);
        for (t, &tok) in tokens.iter().enumerate() {
            let step = model.forward_step(tok, &mut cache);
            let brow = batch.row(t);
            let maxdiff = step
                .iter()
                .zip(brow)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(maxdiff < 2e-3, "pos {t}: maxdiff {maxdiff}");
        }
        assert_eq!(cache.len(), tokens.len());
    }

    #[test]
    fn batched_step_matches_single_step() {
        let model = synthetic_model("micro", 12).unwrap();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 8], &[40, 41, 42, 43]];
        // Scalar path: each sequence fed token-at-a-time.
        let mut single: Vec<Vec<f32>> = Vec::new();
        for p in &prompts {
            let mut cache = KvCache::new(&model.cfg);
            let mut lg = Vec::new();
            for &t in *p {
                lg = model.forward_step(t, &mut cache);
            }
            single.push(lg);
        }
        // Batched path: feed position-by-position, batching the sequences
        // that still have a token at this position (ragged lengths).
        let mut caches: Vec<KvCache> =
            prompts.iter().map(|_| KvCache::new(&model.cfg)).collect();
        let mut arena = crate::tensor::QGemmArena::new();
        let maxlen = prompts.iter().map(|p| p.len()).max().unwrap();
        let mut last: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
        for pos in 0..maxlen {
            let mut toks = Vec::new();
            let mut idx = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                if pos < p.len() {
                    toks.push(p[pos]);
                    idx.push(i);
                }
            }
            let mut want = idx.iter().copied().peekable();
            let mut refs: Vec<&mut KvCache> = Vec::with_capacity(idx.len());
            for (i, c) in caches.iter_mut().enumerate() {
                if want.peek() == Some(&i) {
                    want.next();
                    refs.push(c);
                }
            }
            let lg = model.forward_step_batch(&toks, &mut refs, &mut arena);
            for (row, &i) in idx.iter().enumerate() {
                last[i] = lg.row(row).to_vec();
            }
        }
        for (i, p) in prompts.iter().enumerate() {
            assert_eq!(caches[i].seen, p.len());
            let d = single[i]
                .iter()
                .zip(&last[i])
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-5, "seq {i}: maxdiff {d}");
        }
    }

    #[test]
    fn rope_preserves_norm_and_is_position_dependent() {
        let mut rng = Pcg64::seed(141);
        let v0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let norm0: f32 = v0.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut v1 = v0.clone();
        rope_inplace(&mut v1, 5, 10_000.0);
        let norm1: f32 = v1.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm0 - norm1).abs() < 1e-4);
        let mut v2 = v0.clone();
        rope_inplace(&mut v2, 6, 10_000.0);
        assert!(v1.iter().zip(&v2).any(|(a, b)| (a - b).abs() > 1e-4));
        // pos 0 = identity
        let mut v3 = v0.clone();
        rope_inplace(&mut v3, 0, 10_000.0);
        for (a, b) in v3.iter().zip(&v0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position t must not depend on tokens after t.
        let model = synthetic_model("micro", 8).unwrap();
        let t1: Vec<u32> = vec![5, 9, 13, 70, 2];
        let t2: Vec<u32> = vec![5, 9, 13, 1, 127];
        let l1 = model.forward_logits(&t1, &mut NullSink);
        let l2 = model.forward_logits(&t2, &mut NullSink);
        for t in 0..3 {
            let d = l1
                .row(t)
                .iter()
                .zip(l2.row(t))
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-5, "pos {t} differs: {d}");
        }
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32; 8];
        let gain = vec![1.0f32; 8];
        let y = rmsnorm(&x, &gain, 1e-5);
        for v in y {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn generation_is_deterministic_and_bounded() {
        let model = synthetic_model("micro", 9).unwrap();
        let out1 = model.generate_greedy(&[1, 2, 3], 10);
        let out2 = model.generate_greedy(&[1, 2, 3], 10);
        assert_eq!(out1, out2);
        assert_eq!(out1.len(), 10);
        assert!(out1.iter().all(|&t| (t as usize) < model.cfg.vocab_size));
    }

    #[test]
    fn kv_cache_truncate() {
        let model = synthetic_model("micro", 10).unwrap();
        let mut cache = KvCache::new(&model.cfg);
        for &t in &[1u32, 2, 3, 4] {
            model.forward_step(t, &mut cache);
        }
        let bytes4 = cache.bytes();
        cache.truncate(2);
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() < bytes4);
        // Continuing from truncated prefix == fresh replay.
        let l_cont = model.forward_step(9, &mut cache);
        let mut fresh = KvCache::new(&model.cfg);
        for &t in &[1u32, 2, 9] {
            let _ = model.forward_step(t, &mut fresh);
        }
        let mut fresh2 = KvCache::new(&model.cfg);
        let mut l_fresh = Vec::new();
        for &t in &[1u32, 2, 9] {
            l_fresh = model.forward_step(t, &mut fresh2);
        }
        let d = l_cont.iter().zip(&l_fresh).fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
        assert!(d < 1e-5);
    }

    #[test]
    fn act_sink_sees_all_layers() {
        struct Counter(Vec<String>);
        impl ActSink for Counter {
            fn record(&mut self, key: &str, x: &Matrix) {
                assert!(x.rows > 0);
                self.0.push(key.to_string());
            }
        }
        let model = synthetic_model("micro", 11).unwrap();
        let mut sink = Counter(Vec::new());
        model.forward_logits(&[1, 2, 3, 4], &mut sink);
        assert_eq!(sink.0.len(), model.cfg.n_layers * 4);
        assert!(sink.0.contains(&"L0.qkv_proj".to_string()));
        assert!(sink.0.contains(&"L1.fc2".to_string()));
    }
}
