//! LLaMA-style decoder-only transformer (fp32, CPU) — the evaluation
//! substrate the quantization pipeline operates on.
//!
//! Structure per block: RMSNorm → fused `qkv_proj` → rotary → causal MHSA →
//! `out_proj` → residual; RMSNorm → fused `fc1` (gate‖up) → SwiGLU → `fc2` →
//! residual. The four named linears match the paper's Fig. 2. Embeddings and
//! the LM head stay fp (standard PTQ practice).
//!
//! Three forward paths:
//! - [`Gpt::forward_logits`] — teacher-forced batch forward (calibration
//!   capture via [`ActSink`]).
//! - [`Gpt::forward_step`] — single-sequence incremental decode against a
//!   [`KvCache`]: the token-at-a-time reference the batched paths are
//!   property-tested against.
//! - [`Gpt::forward_chunk_batch`] — the serving hot path: a **ragged chunk
//!   batch**. Each sequence contributes a span of ≥ 1 tokens (decode
//!   sequences one row, prefilling sequences up to a scheduler-chosen
//!   chunk); all rows across all sequences stack into one batched (packed
//!   quantized) GEMM per layer, while causal multi-token attention runs
//!   per sequence against its own cache/position. Each sequence declares
//!   via [`ChunkLogits`] which logits rows it needs, and the lm_head GEMM
//!   runs only over those rows — non-final prefill rows never touch the
//!   vocab projection. [`Gpt::forward_step_batch`] (all spans = 1,
//!   [`ChunkLogits::Last`]) is the decode-only special case, and
//!   [`Gpt::forward_logits_chunked`] (one sequence, [`ChunkLogits::All`])
//!   is the eval/perplexity entry — greedy generation, perplexity, and the
//!   continuous batcher all drive this single implementation.
//!
//! ## Attention engine
//!
//! All three paths share ONE attention implementation: [`Gpt::attn_layer`],
//! a span-batch driver over the paged head-major KV storage of
//! [`coordinator::kvpool::KvCache`](crate::coordinator::kvpool). Per layer
//! it (1) stages RoPE-rotated queries into grow-only arena scratch
//! ([`AttnArena`], riding inside [`QGemmArena`]) and appends rotated keys +
//! raw values to each sequence's pages (COW-splitting shared prefix pages
//! first via `KvCache::reserve`), then (2) fans the q·K sweep / softmax /
//! weighted-V inner loops out as **(sequence × head) work items** over
//! `scope_map` — decode iterations use every core between the per-layer
//! GEMMs instead of walking sequences serially — and (3) scatters the
//! per-head output tiles back into row-major activation rows. The sweep
//! reads K/V through the page indirection (`attn_head_span_paged` /
//! `attn_head_span_paged_int8`): per attended row it walks the page
//! list in `KV_TILE`-aligned segments, scoring each segment's head panel
//! and accumulating weighted V in position order — bitwise identical to
//! the contiguous single-tile drivers because q·K scores are per-key
//! independent and the SIMD P·V lane grouping aligns at page boundaries.
//! The inner loops are the runtime-dispatched SIMD kernels of
//! [`tensor::attn_kernel`](crate::tensor::attn_kernel) (AVX2 FMA / NEON,
//! scalar kept as the bitwise reference). Work items share no
//! accumulators, so results are bitwise identical across thread counts and
//! batch shapes for a fixed kernel. RoPE inverse frequencies are
//! precomputed once per model ([`Gpt::rope_inv_freq`]); the per-position
//! `sin_cos` stays at use time, bitwise-equal to the per-call `powf` path
//! it replaced.
//!
//! The teacher-forced path runs the same driver against a single-layer
//! scratch cache (`KvCache::span_scratch`) — causal masking falls out of
//! the span bound — so calibration and perplexity eval ride the same
//! kernels instead of a second scalar attention loop.

use super::config::{layer_key, ModelConfig};
use super::linear::Linear;
use crate::coordinator::kvpool::{KvCache, KvDtype, KV_TILE};
use crate::quant::quantize_tile;
use crate::tensor::attn_kernel::{
    self, pv_accum_add, pv_accum_int8_add, qk_scores, qk_scores_int8, softmax, AttnArena,
    AttnKernelKind,
};
use crate::tensor::{Matrix, QGemmArena};
use crate::util::pool::{scope_map, SendPtr};

/// Default prompt-chunk width for the chunked prefill paths
/// (`generate_greedy`, `forward_logits_chunked`, the batcher's
/// `prefill_chunk`). Wide enough that the packed GEMMs see token tiles, and
/// small enough that a mid-prefill iteration stays latency-bounded.
pub const PREFILL_CHUNK: usize = 32;

/// Which logits rows of a sequence's span [`Gpt::forward_chunk_batch`]
/// must return. The lm_head GEMM runs only over requested rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkLogits {
    /// No rows — a mid-prefill chunk whose logits nobody reads.
    None,
    /// Only the span's last row — prefill-final chunks and decode steps.
    Last,
    /// Every row — teacher-forced eval (perplexity windows).
    All,
}

/// One sequence's token span within a ragged chunk batch: the tokens to
/// feed this iteration (decode = 1, prefill = up to the scheduler's chunk)
/// and which of their logits rows the caller needs back.
#[derive(Clone, Copy, Debug)]
pub struct SeqChunk<'a> {
    pub tokens: &'a [u32],
    pub logits: ChunkLogits,
}

impl ChunkLogits {
    /// Number of logits rows a span of `t` tokens contributes.
    pub fn rows(self, t: usize) -> usize {
        match self {
            ChunkLogits::None => 0,
            ChunkLogits::Last => 1,
            ChunkLogits::All => t,
        }
    }
}

/// Receives the input activations of every quantizable linear layer.
pub trait ActSink {
    fn record(&mut self, key: &str, x: &Matrix);
}

/// No-op sink.
pub struct NullSink;
impl ActSink for NullSink {
    fn record(&mut self, _key: &str, _x: &Matrix) {}
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub qkv: Linear,      // (3·d) × d
    pub out_proj: Linear, // d × d
    pub ffn_norm: Vec<f32>,
    pub fc1: Linear, // (2·d_ff) × d   (gate ‖ up)
    pub fc2: Linear, // d × d_ff
}

pub struct Gpt {
    pub cfg: ModelConfig,
    pub embed: Matrix,   // vocab × d
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
    pub lm_head: Matrix, // vocab × d
    /// Precomputed RoPE inverse frequencies (`head_dim/2` entries), derived
    /// from `cfg` by [`Gpt::assemble`]; call [`Gpt::refresh_derived`] after
    /// mutating `cfg.rope_base` / `cfg.n_heads` in place.
    pub rope_inv_freq: Vec<f32>,
}

// ---------------------------------------------------------------------------

/// RMSNorm with learned gain.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    rmsnorm_into(x, gain, eps, &mut out);
    out
}

/// RMSNorm writing into caller storage — the batched decode path normalizes
/// straight into its stacked row matrices instead of allocating a `Vec` per
/// sequence per layer.
pub fn rmsnorm_into(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    debug_assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = v * inv * g;
    }
}

fn rmsnorm_rows(x: &Matrix, gain: &[f32], eps: f32) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_into(x.row(r), gain, eps, out.row_mut(r));
    }
    out
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Apply rotary position embedding to one head vector in place
/// (half-split convention, matching the JAX build path). Recomputes the
/// inverse frequency per lane — the hot paths use
/// [`rope_inplace_cached`] with a [`rope_inv_freq`] table instead; the two
/// are bitwise equivalent (property-pinned).
pub fn rope_inplace(v: &mut [f32], pos: usize, base: f32) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = base.powf(-2.0 * i as f32 / hd as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = v[i];
        let b = v[half + i];
        v[i] = a * cos - b * sin;
        v[half + i] = a * sin + b * cos;
    }
}

/// The RoPE inverse-frequency table for head dim `hd`:
/// `inv_freq[i] = base^(-2i/hd)` — the exact per-lane expression
/// [`rope_inplace`] evaluates, so cached and uncached rotation are bitwise
/// equal. One table serves the whole model (all layers share `rope_base`
/// and head dim); built once per [`Gpt`], retiring the per-position,
/// per-head, per-layer `powf` from the hot paths.
pub fn rope_inv_freq(base: f32, hd: usize) -> Vec<f32> {
    (0..hd / 2).map(|i| base.powf(-2.0 * i as f32 / hd as f32)).collect()
}

/// [`rope_inplace`] with the `powf` hoisted into a precomputed `inv_freq`
/// table; `sin_cos` stays per position.
pub fn rope_inplace_cached(v: &mut [f32], pos: usize, inv_freq: &[f32]) {
    let half = v.len() / 2;
    debug_assert_eq!(inv_freq.len(), half, "inv_freq table length != head_dim/2");
    for (i, &freq) in inv_freq.iter().enumerate() {
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = v[i];
        let b = v[half + i];
        v[i] = a * cos - b * sin;
        v[half + i] = a * sin + b * cos;
    }
}

impl Gpt {
    /// Assemble a model from its parts, building the derived tables (the
    /// RoPE inverse-frequency table — one per model, shared by all layers).
    pub fn assemble(
        cfg: ModelConfig,
        embed: Matrix,
        blocks: Vec<Block>,
        final_norm: Vec<f32>,
        lm_head: Matrix,
    ) -> Gpt {
        let rope_inv_freq = rope_inv_freq(cfg.rope_base, cfg.head_dim());
        Gpt { cfg, embed, blocks, final_norm, lm_head, rope_inv_freq }
    }

    /// Recompute derived tables after an in-place `cfg` mutation (benches
    /// and tests stretch `max_seq` or reinterpret `n_heads`; the RoPE table
    /// depends on `rope_base` and head dim).
    pub fn refresh_derived(&mut self) {
        self.rope_inv_freq = rope_inv_freq(self.cfg.rope_base, self.cfg.head_dim());
    }

    /// Teacher-forced forward: logits for every position (T × vocab).
    pub fn forward_logits(&self, tokens: &[u32], sink: &mut dyn ActSink) -> Matrix {
        let h = self.forward_hidden(tokens, sink);
        crate::tensor::matmul_bt(&h, &self.lm_head)
    }

    /// Final hidden states (T × d), post final norm.
    pub fn forward_hidden(&self, tokens: &[u32], sink: &mut dyn ActSink) -> Matrix {
        let t_len = tokens.len();
        let d = self.cfg.d_model;
        assert!(t_len <= self.cfg.max_seq, "sequence {} > max_seq", t_len);
        let mut h = Matrix::zeros(t_len, d);
        for (t, &tok) in tokens.iter().enumerate() {
            h.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        // One single-layer scratch cache + attention arena reused across
        // every block: `seen` stays 0 and each layer fully overwrites rows
        // 0..t, so the tiles need no reset between layers.
        let mut scratch = KvCache::span_scratch(&self.cfg);
        let mut arena = AttnArena::new();
        let kind = attn_kernel::detect_attn_kernel();
        for (l, block) in self.blocks.iter().enumerate() {
            h = self.block_forward(block, l, &h, sink, &mut scratch, &mut arena, kind);
        }
        rmsnorm_rows(&h, &self.final_norm, self.cfg.norm_eps)
    }

    #[allow(clippy::too_many_arguments)]
    fn block_forward(
        &self,
        block: &Block,
        l: usize,
        h: &Matrix,
        sink: &mut dyn ActSink,
        scratch: &mut KvCache,
        arena: &mut AttnArena,
        kind: AttnKernelKind,
    ) -> Matrix {
        let cfg = &self.cfg;
        let (t_len, d) = (h.rows, cfg.d_model);

        // ---- attention: the serving span engine against the caller's
        //      single-layer scratch cache (positions = row indices; the
        //      span's causal bound masks future rows, so this IS
        //      teacher-forced causal attention) — one implementation,
        //      same SIMD kernels ----
        let x_norm = rmsnorm_rows(h, &block.attn_norm, cfg.norm_eps);
        sink.record(&layer_key(l, "qkv_proj"), &x_norm);
        let qkv = block.qkv.forward(&x_norm); // T × 3d
        let mut attn_out = Matrix::zeros(t_len, d);
        self.attn_layer(
            0, // scratch cache layer index (rope depends only on position)
            &[(0, t_len)],
            &mut [&mut *scratch],
            &qkv,
            &mut attn_out,
            arena,
            kind,
        );
        sink.record(&layer_key(l, "out_proj"), &attn_out);
        let attn_proj = block.out_proj.forward(&attn_out);
        let h1 = h.add(&attn_proj);

        // ---- feed-forward (SwiGLU) ----
        let x_norm2 = rmsnorm_rows(&h1, &block.ffn_norm, cfg.norm_eps);
        sink.record(&layer_key(l, "fc1"), &x_norm2);
        let gate_up = block.fc1.forward(&x_norm2); // T × 2·dff
        let dff = cfg.d_ff;
        let mut act = Matrix::zeros(t_len, dff);
        for t in 0..t_len {
            let gu = gate_up.row(t);
            let arow = act.row_mut(t);
            for i in 0..dff {
                arow[i] = silu(gu[i]) * gu[dff + i];
            }
        }
        sink.record(&layer_key(l, "fc2"), &act);
        let ffn = block.fc2.forward(&act);
        h1.add(&ffn)
    }

    /// One layer's causal span attention over a ragged batch — the single
    /// attention implementation every forward path drives.
    ///
    /// `spans[i] = (r0, t)` names sequence `i`'s rows `r0..r0+t` of `qkv`
    /// (fused projections, rows × 3d) and `out` (rows × d, fully
    /// overwritten on those rows); `caches[i]` is its KV cache. Three
    /// passes:
    ///
    /// 1. **Stage** (serial): RoPE-rotate each span row's query into
    ///    `arena.q` and append the rotated key + raw value to the cache's
    ///    head-major pages at positions `seen..seen+t` (`seen` itself
    ///    advances once per forward, after all layers). `reserve` runs
    ///    first, so shared prefix pages in the write range copy-on-write
    ///    before any row is stored. In-span rows attend to each other
    ///    through the same pages.
    /// 2. **Sweep** (parallel): one work item per (sequence, head) runs
    ///    `attn_head_span_paged` — q·K scores, softmax, weighted-V — over
    ///    the page list in `KV_TILE`-aligned segments, fanned out via
    ///    `scope_map` when the batch's q·K MAC count clears
    ///    [`attn_kernel::auto_threads`]'s floor. Items write disjoint
    ///    arena ranges and share no accumulators, so results are bitwise
    ///    identical across thread counts.
    /// 3. **Scatter** (serial): copy each head tile back into the
    ///    row-major output rows.
    ///
    /// Row `j` of a span attends over positions `0..=seen+j`: the span's
    /// future rows are masked purely by the loop bound, so with t = 1 this
    /// is exactly single-token decode attention and every chunking of a
    /// prompt is numerically identical per row.
    ///
    /// Caches are dtype-mixed: each sequence's [`KvDtype`] picks its staging
    /// and sweep path independently, so f32 and int8 caches coexist in one
    /// batch. Int8 sequences quantize the roped K row and raw V row into
    /// the cache's code pages at stage time (one scale per position per
    /// head, via [`quantize_tile`]), quantize each roped query head-slice
    /// once into the arena, and sweep through `attn_head_span_paged_int8`
    /// — dequantization fused into the kernels, the cache never
    /// rematerialized to f32. Since every position quantizes independently,
    /// the chunking invariance above carries over to int8 codes verbatim.
    #[allow(clippy::too_many_arguments)]
    fn attn_layer(
        &self,
        l: usize,
        spans: &[(usize, usize)],
        caches: &mut [&mut KvCache],
        qkv: &Matrix,
        out: &mut Matrix,
        arena: &mut AttnArena,
        kind: AttnKernelKind,
    ) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        // A stale table would rotate the wrong lane count (silently wrong
        // attention, or an out-of-bounds rotation) — keep this loud in
        // release builds too.
        assert_eq!(
            self.rope_inv_freq.len(),
            hd / 2,
            "stale RoPE table: call Gpt::refresh_derived() after mutating cfg"
        );
        debug_assert_eq!(spans.len(), caches.len());
        debug_assert_eq!(qkv.cols, 3 * d);
        debug_assert_eq!(out.cols, d);
        debug_assert!(spans.iter().all(|&(r0, t)| r0 + t <= qkv.rows));
        let total: usize = spans.iter().map(|&(_, t)| t).sum();
        if total == 0 {
            return;
        }

        // Work items and their disjoint arena ranges: one (sequence, head)
        // item gets a `pos0 + t` score row and a `t × hd` output tile.
        // arena.items[w] = (seq, head, scores offset, tile offset).
        arena.items.clear();
        let (mut scores_len, mut tiles_len, mut macs) = (0usize, 0usize, 0usize);
        for (i, &(_, t)) in spans.iter().enumerate() {
            if t == 0 {
                continue;
            }
            let slen = caches[i].seen + t;
            for head in 0..nh {
                arena.items.push((i, head, scores_len, tiles_len + head * t * hd));
                scores_len += slen;
            }
            tiles_len += t * d;
            macs += t * slen * hd * nh;
        }
        // q is indexed by absolute qkv row, so size it to the full matrix
        // (== total rows for the contiguous spans every caller builds).
        arena.ensure(qkv.rows * d, scores_len, tiles_len);
        if caches.iter().any(|c| c.dtype() == KvDtype::Int8) {
            arena.ensure_int8(qkv.rows * d, qkv.rows * nh, hd);
        }

        // -- stage roped queries; append roped K + raw V tiles (int8
        //    sequences quantize queries into the arena and K/V straight
        //    into the cache's code tiles) --
        for (&(r0, t), cache) in spans.iter().zip(caches.iter_mut()) {
            let pos0 = cache.seen;
            cache.reserve(pos0 + t);
            for j in 0..t {
                let row = qkv.row(r0 + j);
                let qrow = &mut arena.q[(r0 + j) * d..(r0 + j + 1) * d];
                qrow.copy_from_slice(&row[0..d]);
                match cache.dtype() {
                    KvDtype::F32 => {
                        for head in 0..nh {
                            let s = head * hd;
                            rope_inplace_cached(&mut qrow[s..s + hd], pos0 + j, &self.rope_inv_freq);
                            let (kdst, vdst) = cache.kv_row_mut(l, head, pos0 + j);
                            kdst.copy_from_slice(&row[d + s..d + s + hd]);
                            rope_inplace_cached(kdst, pos0 + j, &self.rope_inv_freq);
                            vdst.copy_from_slice(&row[2 * d + s..2 * d + s + hd]);
                        }
                    }
                    KvDtype::Int8 => {
                        for head in 0..nh {
                            let s = head * hd;
                            rope_inplace_cached(&mut qrow[s..s + hd], pos0 + j, &self.rope_inv_freq);
                            arena.q_scales[(r0 + j) * nh + head] = quantize_tile(
                                &qrow[s..s + hd],
                                8,
                                &mut arena.q_codes[(r0 + j) * d + s..(r0 + j) * d + s + hd],
                            );
                            // Keys rope in an f32 landing pad (the cache
                            // stores codes), then quantize; values quantize
                            // straight from the projection row.
                            arena.krow[..hd].copy_from_slice(&row[d + s..d + s + hd]);
                            rope_inplace_cached(&mut arena.krow[..hd], pos0 + j, &self.rope_inv_freq);
                            let (kc, vc, ks, vs) = cache.kv_row_quant_mut(l, head, pos0 + j);
                            *ks = quantize_tile(&arena.krow[..hd], 8, kc);
                            *vs = quantize_tile(&row[2 * d + s..2 * d + s + hd], 8, vc);
                        }
                    }
                }
            }
        }

        // -- (sequence × head) fan-out over the shared tiles --
        let scale = 1.0 / (hd as f32).sqrt();
        let caches_ro: &[&mut KvCache] = caches;
        let items = &arena.items;
        let q = &arena.q[..qkv.rows * d];
        let q_codes: &[i8] = &arena.q_codes;
        let q_scales: &[f32] = &arena.q_scales;
        let scores_ptr = SendPtr(arena.scores.as_mut_ptr());
        let tiles_ptr = SendPtr(arena.tiles.as_mut_ptr());
        let threads = attn_kernel::auto_threads(macs);
        scope_map(items.len(), threads, |w| {
            let (i, head, scores_off, tile_off) = items[w];
            let (r0, t) = spans[i];
            let cache: &KvCache = &*caches_ro[i];
            let pos0 = cache.seen;
            let slen = pos0 + t;
            // SAFETY: the offsets above partition `arena.scores` /
            // `arena.tiles` into disjoint per-item ranges, and `scope_map`
            // joins every worker before the buffers are read back.
            let scores =
                unsafe { std::slice::from_raw_parts_mut(scores_ptr.0.add(scores_off), slen) };
            let tile = unsafe { std::slice::from_raw_parts_mut(tiles_ptr.0.add(tile_off), t * hd) };
            match cache.dtype() {
                KvDtype::F32 => attn_head_span_paged(
                    kind,
                    &q[r0 * d..],
                    d,
                    head * hd,
                    hd,
                    pos0,
                    t,
                    cache,
                    l,
                    head,
                    scale,
                    scores,
                    tile,
                ),
                KvDtype::Int8 => attn_head_span_paged_int8(
                    kind,
                    &q_codes[r0 * d..],
                    &q_scales[r0 * nh..],
                    nh,
                    head,
                    d,
                    head * hd,
                    hd,
                    pos0,
                    t,
                    cache,
                    l,
                    scale,
                    scores,
                    tile,
                ),
            }
        });

        // -- scatter head tiles into row-major output rows --
        let mut tile_base = 0usize;
        for &(r0, t) in spans {
            for head in 0..nh {
                let tile = &arena.tiles[tile_base + head * t * hd..tile_base + (head + 1) * t * hd];
                let s = head * hd;
                for j in 0..t {
                    out.row_mut(r0 + j)[s..s + hd].copy_from_slice(&tile[j * hd..(j + 1) * hd]);
                }
            }
            tile_base += t * d;
        }
    }

    /// Incremental decode: push one token, return logits for the next.
    pub fn forward_step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        assert!(cache.seen < cfg.max_seq, "kv cache full");
        let kind = attn_kernel::detect_attn_kernel();
        let mut arena = AttnArena::new();
        let mut h: Vec<f32> = self.embed.row(token as usize).to_vec();

        for (l, block) in self.blocks.iter().enumerate() {
            // attention
            let x_norm = rmsnorm(&h, &block.attn_norm, cfg.norm_eps);
            let qkv = Matrix::from_vec(1, 3 * d, block.qkv.forward_token(&x_norm));
            let mut attn_out = Matrix::zeros(1, d);
            self.attn_layer(l, &[(0, 1)], &mut [&mut *cache], &qkv, &mut attn_out, &mut arena, kind);
            let attn_proj = block.out_proj.forward_token(&attn_out.data);
            for (hi, p) in h.iter_mut().zip(&attn_proj) {
                *hi += p;
            }
            // ffn
            let x_norm2 = rmsnorm(&h, &block.ffn_norm, cfg.norm_eps);
            let gate_up = block.fc1.forward_token(&x_norm2);
            let dff = cfg.d_ff;
            let mut act = vec![0f32; dff];
            for i in 0..dff {
                act[i] = silu(gate_up[i]) * gate_up[dff + i];
            }
            let ffn = block.fc2.forward_token(&act);
            for (hi, f) in h.iter_mut().zip(&ffn) {
                *hi += f;
            }
        }
        cache.seen += 1;
        let hn = rmsnorm(&h, &self.final_norm, cfg.norm_eps);
        crate::tensor::matvec(&self.lm_head, &hn)
    }

    /// Ragged chunk-batch forward — the serving hot path.
    ///
    /// Advances `chunks.len()` independent sequences by their spans
    /// (`chunks[i].tokens`, ≥ 1 each; decode sequences contribute one row,
    /// prefilling sequences a multi-token chunk). All Σtᵢ rows across all
    /// sequences stack into ONE batched (packed quantized) GEMM per layer
    /// linear, while causal attention runs through the span engine
    /// ([`Gpt::attn_layer`]) — writing all span K/V positions to the
    /// head-major tiles, masking each row's future, and fanning
    /// (sequence × head) work items across cores — so per-row results match
    /// the token-at-a-time [`Gpt::forward_step`] replay.
    ///
    /// Contract:
    /// - `chunks[i]` is paired with `caches[i]`; spans must be non-empty
    ///   and fit the KV window (`cache.seen + tᵢ ≤ max_seq`).
    /// - Each cache's `seen` advances by its span length.
    /// - Returns only the logits rows requested via [`ChunkLogits`]
    ///   (rows × vocab), grouped by sequence in `chunks` order with each
    ///   sequence's requested rows in position order. The final-norm +
    ///   lm_head GEMM runs **only** over requested rows, so non-final
    ///   prefill chunks skip the vocab projection entirely.
    /// - `arena` holds the reusable activation-quantization scratch; the
    ///   steady-state serving loop allocates no quantization buffers.
    pub fn forward_chunk_batch(
        &self,
        chunks: &[SeqChunk],
        caches: &mut [&mut KvCache],
        arena: &mut QGemmArena,
    ) -> Matrix {
        self.forward_chunk_batch_layers(chunks, caches, arena, self.blocks.len())
    }

    /// [`Gpt::forward_chunk_batch`] over only the first `n_layers` blocks —
    /// the truncated-layer draft forward ([`crate::model::DraftModel`]).
    /// The final norm and lm_head still apply on top of the truncated
    /// residual stream (the residual path makes early-exit logits a usable
    /// next-token predictor), so a self-draft shares every packed weight
    /// with the target. Caches must have been built for (at least)
    /// `n_layers` layers; [`KvCache::for_layers`] sizes a draft cache to
    /// exactly the layers it writes.
    pub fn forward_chunk_batch_layers(
        &self,
        chunks: &[SeqChunk],
        caches: &mut [&mut KvCache],
        arena: &mut QGemmArena,
        n_layers: usize,
    ) -> Matrix {
        let cfg = &self.cfg;
        let b = chunks.len();
        assert_eq!(b, caches.len(), "chunk/cache count mismatch");
        let d = cfg.d_model;
        let mut total = 0usize;
        for (ch, c) in chunks.iter().zip(caches.iter()) {
            assert!(!ch.tokens.is_empty(), "empty token span");
            assert!(c.seen + ch.tokens.len() <= cfg.max_seq, "kv cache overflow");
            total += ch.tokens.len();
        }
        // Stack rows sequence-major; offsets[i] = first row of sequence i.
        let mut offsets = Vec::with_capacity(b);
        let mut h = Matrix::zeros(total, d);
        let mut row = 0usize;
        for ch in chunks {
            offsets.push(row);
            for &tok in ch.tokens {
                h.row_mut(row).copy_from_slice(self.embed.row(tok as usize));
                row += 1;
            }
        }
        let spans: Vec<(usize, usize)> =
            offsets.iter().zip(chunks).map(|(&r0, ch)| (r0, ch.tokens.len())).collect();
        let kind = attn_kernel::detect_attn_kernel();
        for (l, block) in self.blocks[..n_layers].iter().enumerate() {
            // ---- attention: one batched qkv/out_proj GEMM, then the span
            //      engine fanning (sequence × head) items across cores ----
            let mut x_norm = Matrix::zeros(total, d);
            for r in 0..total {
                rmsnorm_into(h.row(r), &block.attn_norm, cfg.norm_eps, x_norm.row_mut(r));
            }
            let qkv = block.qkv.forward_with(&x_norm, arena); // total × 3d
            let mut attn_out = Matrix::zeros(total, d);
            self.attn_layer(l, &spans, caches, &qkv, &mut attn_out, &mut arena.attn, kind);
            let attn_proj = block.out_proj.forward_with(&attn_out, arena);
            let h1 = h.add(&attn_proj);
            // ---- feed-forward: batched fc1/fc2, rowwise SwiGLU ----
            let mut x_norm2 = Matrix::zeros(total, d);
            for r in 0..total {
                rmsnorm_into(h1.row(r), &block.ffn_norm, cfg.norm_eps, x_norm2.row_mut(r));
            }
            let gate_up = block.fc1.forward_with(&x_norm2, arena); // total × 2·dff
            let dff = cfg.d_ff;
            let mut act = Matrix::zeros(total, dff);
            for r in 0..total {
                let gu = gate_up.row(r);
                let arow = act.row_mut(r);
                for j in 0..dff {
                    arow[j] = silu(gu[j]) * gu[dff + j];
                }
            }
            let ffn = block.fc2.forward_with(&act, arena);
            h = h1.add(&ffn);
        }
        for (ch, c) in chunks.iter().zip(caches.iter_mut()) {
            c.seen += ch.tokens.len();
        }
        // Final norm + lm_head only over the rows somebody asked for.
        let n_logits: usize = chunks.iter().map(|ch| ch.logits.rows(ch.tokens.len())).sum();
        let mut hn = Matrix::zeros(n_logits, d);
        let mut out_r = 0usize;
        for (i, ch) in chunks.iter().enumerate() {
            let (r0, t) = (offsets[i], ch.tokens.len());
            let rows = match ch.logits {
                ChunkLogits::None => 0..0,
                ChunkLogits::Last => (r0 + t - 1)..(r0 + t),
                ChunkLogits::All => r0..(r0 + t),
            };
            for r in rows {
                rmsnorm_into(h.row(r), &self.final_norm, cfg.norm_eps, hn.row_mut(out_r));
                out_r += 1;
            }
        }
        crate::tensor::matmul_bt(&hn, &self.lm_head)
    }

    /// Batched incremental decode: advance N sequences by one token each —
    /// the all-decode special case of [`Gpt::forward_chunk_batch`] (every
    /// span is a single token, every sequence wants its logits row back).
    /// Returns logits, batch × vocab (row i belongs to `tokens[i]` /
    /// `caches[i]`).
    pub fn forward_step_batch(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
        arena: &mut QGemmArena,
    ) -> Matrix {
        let chunks: Vec<SeqChunk> = tokens
            .iter()
            .map(|t| SeqChunk { tokens: std::slice::from_ref(t), logits: ChunkLogits::Last })
            .collect();
        self.forward_chunk_batch(&chunks, caches, arena)
    }

    /// Teacher-forced logits for every position (T × vocab) via the chunked
    /// serving path: feed `tokens` in [`PREFILL_CHUNK`]-bounded spans with
    /// [`ChunkLogits::All`] against a fresh KV cache. Same results as
    /// [`Gpt::forward_logits`] to f32 tolerance, but runs the packed batched
    /// GEMMs with caller-owned scratch — the perplexity eval entry point.
    pub fn forward_logits_chunked(
        &self,
        tokens: &[u32],
        chunk: usize,
        arena: &mut QGemmArena,
    ) -> Matrix {
        self.forward_logits_chunked_dtype(tokens, chunk, KvDtype::F32, arena)
    }

    /// [`Gpt::forward_logits_chunked`] with an explicit KV storage dtype —
    /// the eval entry for measuring int8-KV perplexity drift against the
    /// f32 cache on identical windows.
    pub fn forward_logits_chunked_dtype(
        &self,
        tokens: &[u32],
        chunk: usize,
        dtype: KvDtype,
        arena: &mut QGemmArena,
    ) -> Matrix {
        assert!(chunk > 0, "chunk must be >= 1");
        assert!(tokens.len() <= self.cfg.max_seq, "sequence {} > max_seq", tokens.len());
        let vocab = self.cfg.vocab_size;
        let mut cache = KvCache::new_with(&self.cfg, dtype);
        let mut out = Matrix::zeros(tokens.len(), vocab);
        let mut fed = 0usize;
        while fed < tokens.len() {
            let end = (fed + chunk).min(tokens.len());
            let span = [SeqChunk { tokens: &tokens[fed..end], logits: ChunkLogits::All }];
            let logits = self.forward_chunk_batch(&span, &mut [&mut cache], arena);
            out.data[fed * vocab..end * vocab].copy_from_slice(&logits.data);
            fed = end;
        }
        out
    }

    /// Greedy generation from a prompt; returns generated token ids.
    ///
    /// The prompt prefills through [`Gpt::forward_chunk_batch`] in
    /// [`PREFILL_CHUNK`]-token spans (only the final span pays the lm_head
    /// GEMM), then decode continues one token at a time through the same
    /// engine — a single code path with the continuous batcher instead of a
    /// second scalar implementation.
    pub fn generate_greedy(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        if prompt.is_empty() {
            return Vec::new();
        }
        let mut cache = KvCache::new(&self.cfg);
        let mut arena = QGemmArena::new();
        let mut logits = Vec::new();
        let mut fed = 0usize;
        while fed < prompt.len() {
            let end = (fed + PREFILL_CHUNK).min(prompt.len());
            let last = end == prompt.len();
            let span = [SeqChunk {
                tokens: &prompt[fed..end],
                logits: if last { ChunkLogits::Last } else { ChunkLogits::None },
            }];
            let out = self.forward_chunk_batch(&span, &mut [&mut cache], &mut arena);
            if last {
                logits = out.row(0).to_vec();
            }
            fed = end;
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if cache.seen >= self.cfg.max_seq {
                break;
            }
            let next = argmax(&logits) as u32;
            out.push(next);
            let span = [SeqChunk { tokens: std::slice::from_ref(&next), logits: ChunkLogits::Last }];
            logits = self.forward_chunk_batch(&span, &mut [&mut cache], &mut arena).row(0).to_vec();
        }
        out
    }

    /// Swap one linear layer (the pipeline applies quantization results).
    pub fn set_linear(&mut self, block: usize, name: &str, lin: Linear) {
        let b = &mut self.blocks[block];
        match name {
            "qkv_proj" => b.qkv = lin,
            "out_proj" => b.out_proj = lin,
            "fc1" => b.fc1 = lin,
            "fc2" => b.fc2 = lin,
            other => panic!("unknown linear '{other}'"),
        }
    }

    pub fn get_linear(&self, block: usize, name: &str) -> &Linear {
        let b = &self.blocks[block];
        match name {
            "qkv_proj" => &b.qkv,
            "out_proj" => &b.out_proj,
            "fc1" => &b.fc1,
            "fc2" => &b.fc2,
            other => panic!("unknown linear '{other}'"),
        }
    }
}

/// Paged twin of [`crate::tensor::attn_kernel::attn_head_span`]: causal
/// q·K / softmax / weighted-V for one (sequence, head) work item, reading
/// K/V through the cache's page list instead of a contiguous tile.
///
/// Row `j` of the span attends over positions `0..=pos0+j`, walked in
/// segments that start at page boundaries (`0, KV_TILE, 2·KV_TILE, …`).
/// q·K scores are per-key independent, so splitting the score pass is
/// exact; the P·V pass zeroes the output row once and accumulates each
/// segment in position order with `pv_accum_add`, whose SIMD lane
/// grouping restarts cleanly at the `KV_TILE`-aligned boundaries — the
/// result is bitwise identical to the contiguous driver for every page
/// layout of the same positions.
#[allow(clippy::too_many_arguments)]
fn attn_head_span_paged(
    kind: AttnKernelKind,
    q: &[f32],
    d: usize,
    s: usize,
    hd: usize,
    pos0: usize,
    t: usize,
    cache: &KvCache,
    l: usize,
    head: usize,
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    for j in 0..t {
        let t_seen = pos0 + j + 1;
        let qh = &q[j * d + s..j * d + s + hd];
        let mut p = 0usize;
        while p < t_seen {
            let n = (t_seen - p).min(KV_TILE);
            let (keys, _) = cache.page(p / KV_TILE).head_panel(l, head, n);
            qk_scores(kind, qh, keys, scale, &mut scores[p..p + n]);
            p += n;
        }
        softmax(kind, &mut scores[..t_seen]);
        let orow = &mut out[j * hd..(j + 1) * hd];
        orow.fill(0.0);
        let mut p = 0usize;
        while p < t_seen {
            let n = (t_seen - p).min(KV_TILE);
            let (_, values) = cache.page(p / KV_TILE).head_panel(l, head, n);
            pv_accum_add(kind, &scores[p..p + n], values, orow);
            p += n;
        }
    }
}

/// Int8 twin of [`attn_head_span_paged`] over quantized code pages —
/// fused dequant via `qk_scores_int8` / `pv_accum_int8_add`, one
/// per-(position, head) scale row per page panel. The per-row query
/// scale folds the attention scale exactly as the contiguous
/// [`crate::tensor::attn_kernel::attn_head_span_int8`] does
/// (`q_scales[j * nh + head] * scale`), so the paged sweep is bitwise
/// identical to it for any paging of the same positions.
#[allow(clippy::too_many_arguments)]
fn attn_head_span_paged_int8(
    kind: AttnKernelKind,
    q_codes: &[i8],
    q_scales: &[f32],
    nh: usize,
    head: usize,
    d: usize,
    s: usize,
    hd: usize,
    pos0: usize,
    t: usize,
    cache: &KvCache,
    l: usize,
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    for j in 0..t {
        let t_seen = pos0 + j + 1;
        let qh = &q_codes[j * d + s..j * d + s + hd];
        let qs = q_scales[j * nh + head] * scale;
        let mut p = 0usize;
        while p < t_seen {
            let n = (t_seen - p).min(KV_TILE);
            let (keys, _, k_scales, _) = cache.page(p / KV_TILE).head_panel_quant(l, head, n);
            qk_scores_int8(kind, qh, keys, k_scales, qs, &mut scores[p..p + n]);
            p += n;
        }
        softmax(kind, &mut scores[..t_seen]);
        let orow = &mut out[j * hd..(j + 1) * hd];
        orow.fill(0.0);
        let mut p = 0usize;
        while p < t_seen {
            let n = (t_seen - p).min(KV_TILE);
            let (_, values, _, v_scales) = cache.page(p / KV_TILE).head_panel_quant(l, head, n);
            pv_accum_int8_add(kind, &scores[p..p + n], values, v_scales, orow);
            p += n;
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::synthetic_model;
    use crate::util::rng::Pcg64;

    #[test]
    fn batch_and_incremental_forward_agree() {
        let model = synthetic_model("micro", 7).unwrap();
        let tokens: Vec<u32> = vec![3, 17, 42, 9, 100, 55];
        let batch = model.forward_logits(&tokens, &mut NullSink);
        let mut cache = KvCache::new(&model.cfg);
        for (t, &tok) in tokens.iter().enumerate() {
            let step = model.forward_step(tok, &mut cache);
            let brow = batch.row(t);
            let maxdiff = step
                .iter()
                .zip(brow)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(maxdiff < 2e-3, "pos {t}: maxdiff {maxdiff}");
        }
        assert_eq!(cache.len(), tokens.len());
    }

    #[test]
    fn batched_step_matches_single_step() {
        let model = synthetic_model("micro", 12).unwrap();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 8], &[40, 41, 42, 43]];
        // Scalar path: each sequence fed token-at-a-time.
        let mut single: Vec<Vec<f32>> = Vec::new();
        for p in &prompts {
            let mut cache = KvCache::new(&model.cfg);
            let mut lg = Vec::new();
            for &t in *p {
                lg = model.forward_step(t, &mut cache);
            }
            single.push(lg);
        }
        // Batched path: feed position-by-position, batching the sequences
        // that still have a token at this position (ragged lengths).
        let mut caches: Vec<KvCache> =
            prompts.iter().map(|_| KvCache::new(&model.cfg)).collect();
        let mut arena = crate::tensor::QGemmArena::new();
        let maxlen = prompts.iter().map(|p| p.len()).max().unwrap();
        let mut last: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
        for pos in 0..maxlen {
            let mut toks = Vec::new();
            let mut idx = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                if pos < p.len() {
                    toks.push(p[pos]);
                    idx.push(i);
                }
            }
            let mut want = idx.iter().copied().peekable();
            let mut refs: Vec<&mut KvCache> = Vec::with_capacity(idx.len());
            for (i, c) in caches.iter_mut().enumerate() {
                if want.peek() == Some(&i) {
                    want.next();
                    refs.push(c);
                }
            }
            let lg = model.forward_step_batch(&toks, &mut refs, &mut arena);
            for (row, &i) in idx.iter().enumerate() {
                last[i] = lg.row(row).to_vec();
            }
        }
        for (i, p) in prompts.iter().enumerate() {
            assert_eq!(caches[i].seen, p.len());
            let d = single[i]
                .iter()
                .zip(&last[i])
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-5, "seq {i}: maxdiff {d}");
        }
    }

    #[test]
    fn chunked_prefill_matches_step_reference() {
        // forward_chunk_batch over any chunking of a prompt must reproduce
        // the token-by-token forward_step logits at the final position.
        let model = synthetic_model("micro", 21).unwrap();
        let prompt: Vec<u32> = (0..19).map(|i| 1 + (i * 13 % 120) as u32).collect();
        let mut ref_cache = KvCache::new(&model.cfg);
        let mut want = Vec::new();
        for &t in &prompt {
            want = model.forward_step(t, &mut ref_cache);
        }
        for chunk in [1usize, 3, 16, prompt.len()] {
            let mut cache = KvCache::new(&model.cfg);
            let mut arena = crate::tensor::QGemmArena::new();
            let mut got = Vec::new();
            let mut fed = 0;
            while fed < prompt.len() {
                let end = (fed + chunk).min(prompt.len());
                let last = end == prompt.len();
                let span = [SeqChunk {
                    tokens: &prompt[fed..end],
                    logits: if last { ChunkLogits::Last } else { ChunkLogits::None },
                }];
                let out = model.forward_chunk_batch(&span, &mut [&mut cache], &mut arena);
                if last {
                    assert_eq!(out.rows, 1, "Last must return exactly one row");
                    got = out.row(0).to_vec();
                } else {
                    assert_eq!(out.rows, 0, "None must skip the lm_head entirely");
                }
                fed = end;
            }
            assert_eq!(cache.seen, prompt.len());
            assert_eq!(cache.bytes(), ref_cache.bytes(), "chunking changed KV size");
            let d = want
                .iter()
                .zip(&got)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-4, "chunk {chunk}: maxdiff {d}");
        }
    }

    #[test]
    fn forward_logits_chunked_matches_teacher_forced() {
        let model = synthetic_model("micro", 22).unwrap();
        let tokens: Vec<u32> = vec![3, 17, 42, 9, 100, 55, 7, 70, 31];
        let want = model.forward_logits(&tokens, &mut NullSink);
        let mut arena = crate::tensor::QGemmArena::new();
        for chunk in [1usize, 4, tokens.len()] {
            let got = model.forward_logits_chunked(&tokens, chunk, &mut arena);
            assert_eq!((got.rows, got.cols), (want.rows, want.cols));
            let d = got.max_diff(&want);
            assert!(d < 2e-3, "chunk {chunk}: maxdiff {d}");
        }
    }

    #[test]
    fn ragged_mixed_prefill_decode_batch_matches_scalar() {
        // One iteration mixing a decode row, a mid-prefill chunk (no
        // logits), and a prefill-final chunk must agree row-for-row with
        // the scalar forward_step replay of each sequence.
        let model = synthetic_model("micro", 23).unwrap();
        let decode_hist: Vec<u32> = vec![5, 9, 13];
        let decode_tok = 21u32;
        let mid: Vec<u32> = (0..11).map(|i| 2 + i as u32).collect();
        let fin: Vec<u32> = vec![40, 41, 42, 43, 44];
        // Scalar references.
        let mut c_dec = KvCache::new(&model.cfg);
        for &t in &decode_hist {
            model.forward_step(t, &mut c_dec);
        }
        let mut c_dec_ref = c_dec.clone();
        let want_dec = model.forward_step(decode_tok, &mut c_dec_ref);
        let mut c_fin_ref = KvCache::new(&model.cfg);
        let mut want_fin = Vec::new();
        for &t in &fin {
            want_fin = model.forward_step(t, &mut c_fin_ref);
        }
        let mut c_mid_ref = KvCache::new(&model.cfg);
        for &t in &mid[..7] {
            model.forward_step(t, &mut c_mid_ref);
        }
        // Ragged batch: decode row + first 7 tokens of `mid` + all of `fin`.
        let mut c_mid = KvCache::new(&model.cfg);
        let mut c_fin = KvCache::new(&model.cfg);
        let spans = [
            SeqChunk { tokens: std::slice::from_ref(&decode_tok), logits: ChunkLogits::Last },
            SeqChunk { tokens: &mid[..7], logits: ChunkLogits::None },
            SeqChunk { tokens: &fin, logits: ChunkLogits::Last },
        ];
        let mut arena = crate::tensor::QGemmArena::new();
        let out = model.forward_chunk_batch(
            &spans,
            &mut [&mut c_dec, &mut c_mid, &mut c_fin],
            &mut arena,
        );
        assert_eq!(out.rows, 2, "Last + None + Last = 2 logits rows");
        assert_eq!(c_dec.seen, decode_hist.len() + 1);
        assert_eq!(c_mid.seen, 7);
        assert_eq!(c_fin.seen, fin.len());
        for (row, want) in [(0usize, &want_dec), (1, &want_fin)] {
            let d = out
                .row(row)
                .iter()
                .zip(want)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-4, "row {row}: maxdiff {d}");
        }
        // The mid-prefill cache must hold exactly the scalar-path K/V
        // (page-for-page: the paged head-major layout is part of the
        // contract, so compare each KV_TILE-aligned panel segment).
        assert_eq!(c_mid.bytes(), c_mid_ref.bytes());
        assert_eq!(c_mid.page_count(), c_mid_ref.page_count());
        for l in 0..model.cfg.n_layers {
            for h in 0..model.cfg.n_heads {
                let mut p = 0usize;
                while p < c_mid.len() {
                    let n = (c_mid.len() - p).min(KV_TILE);
                    let (got_k, got_v) = c_mid.page(p / KV_TILE).head_panel(l, h, n);
                    let (ref_k, ref_v) = c_mid_ref.page(p / KV_TILE).head_panel(l, h, n);
                    let dk = got_k
                        .iter()
                        .zip(ref_k)
                        .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
                    assert!(dk < 1e-4, "layer {l} head {h} pos {p} keys diverged: {dk}");
                    let dv = got_v
                        .iter()
                        .zip(ref_v)
                        .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
                    assert!(dv < 1e-4, "layer {l} head {h} pos {p} values diverged: {dv}");
                    p += n;
                }
            }
        }
    }

    #[test]
    fn rope_cached_table_is_bitwise_identical_to_powf_path() {
        let mut rng = Pcg64::seed(142);
        for hd in [2usize, 4, 8, 16, 64] {
            for base in [10_000.0f32, 500.0] {
                let table = rope_inv_freq(base, hd);
                assert_eq!(table.len(), hd / 2);
                for pos in [0usize, 1, 7, 63, 1021] {
                    let v0: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
                    let mut a = v0.clone();
                    rope_inplace(&mut a, pos, base);
                    let mut b = v0;
                    rope_inplace_cached(&mut b, pos, &table);
                    assert_eq!(a, b, "hd={hd} base={base} pos={pos}");
                }
            }
        }
    }

    #[test]
    fn rope_preserves_norm_and_is_position_dependent() {
        let mut rng = Pcg64::seed(141);
        let v0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let norm0: f32 = v0.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut v1 = v0.clone();
        rope_inplace(&mut v1, 5, 10_000.0);
        let norm1: f32 = v1.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm0 - norm1).abs() < 1e-4);
        let mut v2 = v0.clone();
        rope_inplace(&mut v2, 6, 10_000.0);
        assert!(v1.iter().zip(&v2).any(|(a, b)| (a - b).abs() > 1e-4));
        // pos 0 = identity
        let mut v3 = v0.clone();
        rope_inplace(&mut v3, 0, 10_000.0);
        for (a, b) in v3.iter().zip(&v0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // Logits at position t must not depend on tokens after t.
        let model = synthetic_model("micro", 8).unwrap();
        let t1: Vec<u32> = vec![5, 9, 13, 70, 2];
        let t2: Vec<u32> = vec![5, 9, 13, 1, 127];
        let l1 = model.forward_logits(&t1, &mut NullSink);
        let l2 = model.forward_logits(&t2, &mut NullSink);
        for t in 0..3 {
            let d = l1
                .row(t)
                .iter()
                .zip(l2.row(t))
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-5, "pos {t} differs: {d}");
        }
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32; 8];
        let gain = vec![1.0f32; 8];
        let y = rmsnorm(&x, &gain, 1e-5);
        for v in y {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn generation_is_deterministic_and_bounded() {
        let model = synthetic_model("micro", 9).unwrap();
        let out1 = model.generate_greedy(&[1, 2, 3], 10);
        let out2 = model.generate_greedy(&[1, 2, 3], 10);
        assert_eq!(out1, out2);
        assert_eq!(out1.len(), 10);
        assert!(out1.iter().all(|&t| (t as usize) < model.cfg.vocab_size));
    }

    #[test]
    fn kv_cache_truncate() {
        let model = synthetic_model("micro", 10).unwrap();
        let mut cache = KvCache::new(&model.cfg);
        for &t in &[1u32, 2, 3, 4] {
            model.forward_step(t, &mut cache);
        }
        let bytes4 = cache.bytes();
        cache.truncate(2);
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() < bytes4);
        // Continuing from truncated prefix == fresh replay.
        let l_cont = model.forward_step(9, &mut cache);
        let mut fresh = KvCache::new(&model.cfg);
        for &t in &[1u32, 2, 9] {
            let _ = model.forward_step(t, &mut fresh);
        }
        let mut fresh2 = KvCache::new(&model.cfg);
        let mut l_fresh = Vec::new();
        for &t in &[1u32, 2, 9] {
            l_fresh = model.forward_step(t, &mut fresh2);
        }
        let d = l_cont.iter().zip(&l_fresh).fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
        assert!(d < 1e-5);
    }

    #[test]
    fn act_sink_sees_all_layers() {
        struct Counter(Vec<String>);
        impl ActSink for Counter {
            fn record(&mut self, key: &str, x: &Matrix) {
                assert!(x.rows > 0);
                self.0.push(key.to_string());
            }
        }
        let model = synthetic_model("micro", 11).unwrap();
        let mut sink = Counter(Vec::new());
        model.forward_logits(&[1, 2, 3, 4], &mut sink);
        assert_eq!(sink.0.len(), model.cfg.n_layers * 4);
        assert!(sink.0.contains(&"L0.qkv_proj".to_string()));
        assert!(sink.0.contains(&"L1.fc2".to_string()));
    }
}
