//! Per-request token sampling over logits rows.
//!
//! The serving engine decodes many requests concurrently out of one ragged
//! forward, so sampling state must be **per request**, not per batcher: each
//! [`Sampler`] owns its own deterministic RNG ([`crate::util::rng::Pcg64`]
//! seeded from [`SamplingParams::seed`]) and consumes exactly one draw per
//! non-greedy token. Because the draw count depends only on the request's
//! own decode sequence — never on batch composition, chunk widths, or
//! scheduling order — a seeded request reproduces its token stream bitwise
//! across batch shapes (property-tested in `rust/tests/properties.rs`).
//!
//! Decoding policies:
//! - **Greedy** (`temperature < GREEDY_TEMPERATURE_EPS`): plain [`argmax`],
//!   ties to the lowest index, no RNG consumption. This is the pre-redesign
//!   batcher's hardwired path; [`SamplingParams::greedy`] pins it exactly.
//! - **Temperature**: softmax over `logits / temperature`.
//! - **Top-k** (`top_k > 0`): restrict to the `k` highest logits before
//!   normalizing (ties broken toward lower indices, so the candidate set is
//!   deterministic).
//! - **Top-p** (`top_p < 1.0`): further restrict to the smallest
//!   probability-sorted prefix whose mass reaches `top_p` (the prefix always
//!   keeps at least the argmax).
//!
//! Candidate weights accumulate in f64 in a fixed (sorted) order, so the
//! selection is bit-stable for a given logits row regardless of platform
//! threading — the forward path already guarantees bitwise logits on the
//! quantized engine.

use crate::model::gpt::argmax;
use crate::util::rng::Pcg64;

/// Temperatures below this decode greedily (no RNG draw): `temperature → 0`
/// mathematically collapses onto the argmax anyway, and clamping keeps the
/// token stream bit-identical to the dedicated greedy path instead of
/// depending on `exp` underflow behavior.
pub const GREEDY_TEMPERATURE_EPS: f32 = 1e-3;

/// Per-request decoding parameters carried by `GenRequest`.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; values below [`GREEDY_TEMPERATURE_EPS`] (incl.
    /// `0.0`) decode greedily.
    pub temperature: f32,
    /// Keep only the `top_k` highest logits (`0` disables the filter).
    pub top_k: usize,
    /// Nucleus mass; keep the smallest high-probability prefix reaching
    /// `top_p` (`>= 1.0` disables the filter).
    pub top_p: f32,
    /// Seed of the request's private RNG stream. Two requests with the same
    /// seed and the same logits sequence emit the same tokens.
    pub seed: u64,
    /// Extra stop tokens (checked in addition to the engine's EOS handling);
    /// the matched token is still emitted before the stream finishes.
    pub stop_tokens: Vec<u32>,
}

impl SamplingParams {
    /// The deterministic argmax policy the pre-Engine batcher hardwired.
    pub fn greedy() -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop_tokens: Vec::new(),
        }
    }

    /// Stochastic sampling with a deterministic seed; `top_k`/`top_p` stay
    /// disabled until set explicitly.
    pub fn with_temperature(temperature: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature, seed, ..SamplingParams::greedy() }
    }

    /// True when this request decodes through the argmax path.
    pub fn is_greedy(&self) -> bool {
        self.temperature < GREEDY_TEMPERATURE_EPS
    }

    /// True when `tok` is one of this request's extra stop tokens.
    pub fn is_stop_token(&self, tok: u32) -> bool {
        self.stop_tokens.contains(&tok)
    }
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::greedy()
    }
}

/// Per-request sampling state: the parameters plus the request's private RNG
/// and a reusable candidate-index scratch buffer. One lives inside each
/// active sequence of the batcher.
pub struct Sampler {
    params: SamplingParams,
    rng: Pcg64,
    /// Scratch: vocab indices sorted by (logit desc, index asc).
    order: Vec<u32>,
    /// Scratch: candidate weights aligned with `order`'s kept prefix.
    weights: Vec<f64>,
}

impl Sampler {
    pub fn new(params: &SamplingParams) -> Sampler {
        Sampler {
            rng: Pcg64::new(params.seed, 0x5a3e12),
            params: params.clone(),
            order: Vec::new(),
            weights: Vec::new(),
        }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Draw the next token from one logits row. Greedy parameters take the
    /// argmax without touching the RNG; otherwise exactly one uniform draw
    /// is consumed per call.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        debug_assert!(!logits.is_empty());
        if self.params.is_greedy() {
            return argmax(logits) as u32;
        }
        let inv_t = 1.0 / self.params.temperature as f64;
        let best = argmax(logits);
        // The max-logit shift makes the leading weight exactly 1.0, so the
        // total is always >= 1 and the draws below stay well defined even
        // when every other weight underflows.
        let top = logits[best] as f64;

        let n = logits.len();
        let k_limit = if self.params.top_k > 0 { self.params.top_k.min(n) } else { n };
        let nucleus = self.params.top_p < 1.0;
        if k_limit == n && !nucleus {
            // Pure temperature: no candidate ordering needed — one softmax
            // pass in index order and one draw, instead of a vocab sort
            // per decoded token on the serving hot path.
            self.weights.clear();
            let mut total = 0f64;
            for &l in logits {
                let w = ((l as f64 - top) * inv_t).exp();
                self.weights.push(w);
                total += w;
            }
            let mut u = self.rng.f64() * total;
            for (i, &w) in self.weights.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    return i as u32;
                }
            }
            return best as u32; // f64 rounding sliver
        }

        // Truncating paths need candidates in deterministic order: logit
        // descending, index ascending (a total order, so partitioning
        // yields a deterministic candidate set).
        self.order.clear();
        self.order.extend(0..n as u32);
        let by_logit_desc = |a: &u32, b: &u32| {
            logits[*b as usize]
                .total_cmp(&logits[*a as usize])
                .then_with(|| a.cmp(b))
        };
        if k_limit < n {
            // Top-k (optionally + top-p): partition to the k highest, then
            // sort only that prefix.
            self.order.select_nth_unstable_by(k_limit - 1, by_logit_desc);
            self.order.truncate(k_limit);
            self.order.sort_unstable_by(by_logit_desc);
            self.weights.clear();
            let mut total = 0f64;
            for &i in &self.order {
                let w = ((logits[i as usize] as f64 - top) * inv_t).exp();
                self.weights.push(w);
                total += w;
            }
            let mut keep = k_limit;
            if nucleus {
                let target = self.params.top_p.max(0.0) as f64 * total;
                let mut cum = 0f64;
                for (j, &w) in self.weights.iter().enumerate() {
                    cum += w;
                    if cum >= target {
                        keep = j + 1;
                        break;
                    }
                }
            }
            return self.draw(keep);
        }

        // Top-p only: the nucleus is usually a tiny head of the
        // distribution, so never sort the whole vocabulary up front. The
        // total mass is a sort-free index-order pass; then a geometrically
        // growing head is partitioned + sorted until it holds `top_p` of
        // that mass (worst case degenerates to one full sort).
        let mut total = 0f64;
        for &l in logits {
            total += ((l as f64 - top) * inv_t).exp();
        }
        let target = self.params.top_p.max(0.0) as f64 * total;
        let mut m = 64usize.min(n);
        loop {
            if m < n {
                self.order.select_nth_unstable_by(m - 1, by_logit_desc);
            }
            self.order[..m].sort_unstable_by(by_logit_desc);
            self.weights.clear();
            let mut cum = 0f64;
            let mut keep = 0usize;
            for &i in &self.order[..m] {
                let w = ((logits[i as usize] as f64 - top) * inv_t).exp();
                self.weights.push(w);
                cum += w;
                keep += 1;
                if cum >= target {
                    break;
                }
            }
            if cum >= target || m == n {
                // Nucleus found (or the whole vocab is in play; index-order
                // vs sorted-order f64 rounding can leave `target` a hair
                // above the sorted total — then everything is kept).
                return self.draw(keep);
            }
            m = (m * 4).min(n);
        }
    }

    /// Speculative acceptance step: draw the next token from `logits`
    /// exactly as [`Sampler::sample`] would — the identical argmax for
    /// greedy parameters (no RNG touch), the identical single uniform draw
    /// otherwise — and report whether it confirms the draft proposal.
    ///
    /// Distribution preservation falls out of the construction: the
    /// emitted token IS a plain `sample()` from the **target's** logits
    /// row; the draft token only decides whether the already-verified
    /// context extends to the next row. Because the batcher calls this once
    /// per *emitted* token in stream order (never for rolled-back rows),
    /// RNG consumption matches non-speculative decoding draw-for-draw, so
    /// seeded streams are bitwise invariant to the speculation depth and
    /// greedy acceptance (`temperature → 0`) is exactly argmax acceptance.
    pub fn accept(&mut self, logits: &[f32], draft: u32) -> (u32, bool) {
        let tok = self.sample(logits);
        (tok, tok == draft)
    }

    /// Draw one token from `self.weights[..keep]` (candidates in
    /// `self.order`), consuming exactly one uniform.
    fn draw(&mut self, keep: usize) -> u32 {
        let mass: f64 = self.weights[..keep].iter().sum();
        let mut u = self.rng.f64() * mass;
        for (j, &w) in self.weights[..keep].iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return self.order[j];
            }
        }
        // f64 rounding can leave a sliver; fall back to the last candidate.
        self.order[keep - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.5, -1.0, 2.5, 0.7, -3.0, 1.9, 0.0]
    }

    #[test]
    fn greedy_matches_argmax_and_skips_rng() {
        let mut s = Sampler::new(&SamplingParams::greedy());
        let l = logits();
        for _ in 0..5 {
            // Tie at index 1 and 3: argmax keeps the lower index.
            assert_eq!(s.sample(&l), argmax(&l) as u32);
            assert_eq!(s.sample(&l), 1);
        }
    }

    #[test]
    fn tiny_temperature_clamps_to_greedy() {
        let l = logits();
        for t in [0.0f32, 1e-6, 5e-4] {
            let mut s = Sampler::new(&SamplingParams::with_temperature(t, 99));
            assert!(s.params().is_greedy(), "t={t}");
            assert_eq!(s.sample(&l), argmax(&l) as u32, "t={t}");
        }
    }

    #[test]
    fn seeded_sampling_is_reproducible() {
        let p = SamplingParams {
            temperature: 0.8,
            top_k: 5,
            top_p: 0.95,
            seed: 1234,
            stop_tokens: Vec::new(),
        };
        let l = logits();
        let draw = |p: &SamplingParams| {
            let mut s = Sampler::new(p);
            (0..32).map(|_| s.sample(&l)).collect::<Vec<_>>()
        };
        assert_eq!(draw(&p), draw(&p));
        let mut p2 = p.clone();
        p2.seed = 1235;
        assert_ne!(draw(&p), draw(&p2), "different seeds should diverge");
    }

    #[test]
    fn top_k_restricts_support() {
        let p = SamplingParams {
            temperature: 10.0, // near-uniform over the kept set
            top_k: 2,
            top_p: 1.0,
            seed: 7,
            stop_tokens: Vec::new(),
        };
        let mut s = Sampler::new(&p);
        let l = logits();
        // k=2 keeps the tied 2.5s at indices 1 and 3 (index-ascending ties).
        for _ in 0..200 {
            let t = s.sample(&l);
            assert!(t == 1 || t == 3, "token {t} outside top-2 support");
        }
    }

    #[test]
    fn top_p_keeps_at_least_argmax() {
        let p = SamplingParams {
            temperature: 0.5,
            top_k: 0,
            top_p: 1e-9, // degenerate nucleus: only the argmax survives
            seed: 3,
            stop_tokens: Vec::new(),
        };
        let mut s = Sampler::new(&p);
        let l = logits();
        for _ in 0..50 {
            assert_eq!(s.sample(&l), 1);
        }
    }

    #[test]
    fn top_p_only_restricts_to_nucleus_on_large_vocab() {
        // > 64 candidates exercises the growing partial-sort path. A steep
        // ramp concentrates the mass in the first few ranks: with top_p
        // 0.9 and temperature 1, every draw must come from a small head,
        // and the seeded stream must reproduce.
        let n = 500usize;
        let l: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.5).collect();
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.9,
            seed: 13,
            stop_tokens: Vec::new(),
        };
        let mut s = Sampler::new(&p);
        let draws: Vec<u32> = (0..300).map(|_| s.sample(&l)).collect();
        // mass(exp(-0.5 k)) cum hits 0.9 within the first ~6 ranks.
        assert!(draws.iter().all(|&t| t < 8), "draw outside the nucleus");
        assert!(draws.iter().any(|&t| t > 0), "temperature 1 should leave the argmax sometimes");
        let mut s2 = Sampler::new(&p);
        let again: Vec<u32> = (0..300).map(|_| s2.sample(&l)).collect();
        assert_eq!(draws, again, "seeded top-p stream must reproduce");
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let p = SamplingParams::with_temperature(5.0, 11);
        let mut s = Sampler::new(&p);
        let l = logits();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..500 {
            seen.insert(s.sample(&l));
        }
        assert!(seen.len() >= 4, "only {seen:?} sampled at high temperature");
    }

    #[test]
    fn stop_token_membership() {
        let mut p = SamplingParams::greedy();
        p.stop_tokens = vec![17, 4];
        assert!(p.is_stop_token(4));
        assert!(!p.is_stop_token(5));
    }
}
