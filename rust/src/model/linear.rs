//! The linear-layer abstraction the quantization pipeline swaps in place —
//! now fronting the packed-kernel architecture.
//!
//! `Linear::Dense` is the fp32 reference (batch forward = cache-blocked
//! `matmul_bt`). `Linear::Quant` holds a [`PackedQWeight`] built once at
//! install time (`Linear::quantized`) from the method-produced
//! [`QuantizedLinear`] — only the tile-packed form is kept resident, so a
//! served model carries one copy of the weight codes, not two. Both
//! `forward` (batched) and `forward_token` route through `tensor::qgemm` —
//! one cache-blocked i8×i8→i32 GEMM with fused
//! smoothing/scales/outliers/low-rank per call, with per-batch activation
//! quantization staged in a caller-supplied [`QGemmArena`] (`forward_with` /
//! `forward_token_with`) so the serving decode loop performs no steady-state
//! allocation. The int microkernel (scalar / AVX2 / NEON, see
//! `tensor::qgemm_kernel`) is selected at pack time: `Linear::quantized`
//! auto-detects the host's best kernel, [`Linear::quantized_with`] pins one
//! explicitly.
//!
//! `forward_with` is the chunked serving entry: `Gpt::forward_chunk_batch`
//! stacks every active sequence's token span (decode rows + prefill
//! chunks) into one call per layer, so prompt prefill hits the packed
//! kernels as wide token tiles rather than skinny single rows.
//!
//! `QuantizedLinear::forward_matrix` in `methods` remains the reference
//! semantics the kernel must match; [`forward_quant_token`] here is the
//! scalar (token-at-a-time) reference the serving benches compare against.
//! Equivalence across methods × precisions × batch sizes is pinned by
//! `tests/properties.rs`.

use crate::methods::QuantizedLinear;
use crate::quant::{quantize_token, FP};
use crate::tensor::qgemm::{auto_threads, qgemm_forward, qgemm_forward_token};
use crate::tensor::{matvec, Matrix, PackedQWeight, QGemmArena, QKernelKind};

pub enum Linear {
    Dense(Matrix),
    Quant(PackedQWeight),
}

impl Linear {
    /// Install a method-produced quantized layer, packing it for the batched
    /// kernel once here rather than on every forward. The unpacked
    /// `QuantizedLinear` is dropped: the serving paths only ever read the
    /// packed form, and keeping both would double weight-code memory.
    pub fn quantized(q: QuantizedLinear) -> Linear {
        Linear::Quant(q.pack())
    }

    /// Install with an explicit microkernel instead of auto-detection
    /// (benches and property tests pin the scalar reference kernel against
    /// the SIMD one this way).
    pub fn quantized_with(q: QuantizedLinear, kind: QKernelKind) -> Linear {
        Linear::Quant(q.pack_with(kind))
    }

    /// The microkernel a quantized layer was packed for (None for dense).
    pub fn kernel(&self) -> Option<QKernelKind> {
        match self {
            Linear::Dense(_) => None,
            Linear::Quant(q) => Some(q.kernel),
        }
    }

    pub fn out_features(&self) -> usize {
        match self {
            Linear::Dense(w) => w.rows,
            Linear::Quant(q) => q.d_out,
        }
    }

    pub fn in_features(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols,
            Linear::Quant(q) => q.d_in,
        }
    }

    /// Dense reference weight if fp.
    pub fn dense_weight(&self) -> Option<&Matrix> {
        match self {
            Linear::Dense(w) => Some(w),
            Linear::Quant(_) => None,
        }
    }

    /// Forward for a batch of token activations (tokens × in → tokens × out),
    /// allocating throwaway scratch. Eval/calibration paths use this; hot
    /// loops should hold a [`QGemmArena`] and call [`Linear::forward_with`].
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.forward_with(x, &mut QGemmArena::new())
    }

    /// Batched forward with caller-owned scratch (the serving path).
    pub fn forward_with(&self, x: &Matrix, arena: &mut QGemmArena) -> Matrix {
        match self {
            Linear::Dense(w) => crate::tensor::matmul_bt(x, w),
            Linear::Quant(q) => qgemm_forward(q, x, arena, auto_threads(x.rows, q.d_out)),
        }
    }

    /// Single-token forward (greedy generation, single-sequence decode).
    pub fn forward_token(&self, x: &[f32]) -> Vec<f32> {
        self.forward_token_with(x, &mut QGemmArena::new())
    }

    /// Single-token forward with caller-owned scratch.
    pub fn forward_token_with(&self, x: &[f32], arena: &mut QGemmArena) -> Vec<f32> {
        match self {
            Linear::Dense(w) => matvec(w, x),
            Linear::Quant(q) => qgemm_forward_token(q, x, arena),
        }
    }
}

/// Scalar reference for the quantized single-token forward (kept as the
/// baseline the packed kernel is benchmarked and property-tested against):
/// 1. smooth: x' = x / m
/// 2. per-token quantize x' to `abits`; integer codes dot int weight codes
///    row-wise, then apply the combined scale (token_scale × row_scale)
/// 3. fp outlier columns
/// 4. low-rank branch on fp x'
pub fn forward_quant_token(q: &QuantizedLinear, x: &[f32]) -> Vec<f32> {
    let d_in = q.in_features();
    let d_out = q.out_features();
    debug_assert_eq!(x.len(), d_in);
    // 1. smoothing
    let xs: Vec<f32> = match &q.act_smooth {
        Some(m) => x.iter().zip(m).map(|(&v, &mi)| v / mi).collect(),
        None => x.to_vec(),
    };
    let mut y = vec![0f32; d_out];
    if q.abits == FP {
        // fp activation × dequantized row — still avoids materializing W.
        for r in 0..d_out {
            let codes = &q.weight.codes[r * d_in..(r + 1) * d_in];
            let mut acc = 0f32;
            for (c, &xv) in codes.iter().zip(&xs) {
                acc += *c as f32 * xv;
            }
            y[r] = acc * q.weight.scales[r];
        }
    } else {
        // 2. per-token activation quantization, integer dot in i32.
        let qt = quantize_token(&xs, q.abits);
        for r in 0..d_out {
            let codes = &q.weight.codes[r * d_in..(r + 1) * d_in];
            let acc = dot_i8(codes, &qt.codes);
            y[r] = acc as f32 * (qt.scale * q.weight.scales[r]);
        }
    }
    // 3. fp outlier columns act on the *unquantized* smoothed activation.
    for (c, wcol) in &q.fp_cols {
        let xv = xs[*c];
        if xv != 0.0 {
            for (yo, &wv) in y.iter_mut().zip(wcol) {
                *yo += xv * wv;
            }
        }
    }
    // 4. low-rank correction (fp skinny GEMMs): y += L_A · (L_B · x).
    if let Some((la, lb)) = &q.low_rank {
        let z = matvec(lb, &xs); // r
        let corr = matvec(la, &z); // out  (la: out×r)
        for (yo, c) in y.iter_mut().zip(corr) {
            *yo += c;
        }
    }
    y
}

/// i8·i8 → i32 dot, 8-wide unrolled via the shared `dot_unrolled` kernel
/// (same unroll as `tensor::gemm::dot`).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    crate::tensor::gemm::dot_unrolled!(a, b, 0i32, |acc: i32, x: i8, y: i8| acc
        + x as i32 * y as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::{aser::Aser, rtn::Rtn, LayerCalib, PtqMethod, RankPolicy};
    use crate::quant::Precision;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(seed);
        let d = 40;
        let w = Matrix::randn(&mut rng, 24, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 64, d, 1.0);
        for r in 0..x.rows {
            x[(r, 3)] *= 20.0;
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn hot_path_matches_reference_semantics_rtn() {
        let (w, calib) = setup(131);
        for prec in [Precision::w4a8(), Precision::w4a6(), Precision::w4a16()] {
            let q = Rtn.quantize_layer(&w, &calib, prec);
            let want = q.forward_matrix(&calib.x);
            let lin = Linear::quantized(q);
            let got = lin.forward(&calib.x);
            assert!(
                want.max_diff(&got) < 1e-3 * want.max_abs().max(1.0),
                "{prec}: diff {}",
                want.max_diff(&got)
            );
        }
    }

    #[test]
    fn hot_path_matches_reference_semantics_aser() {
        let (w, calib) = setup(132);
        let aser = Aser { rank: RankPolicy::Fixed(8), outlier_f: 4, ..Default::default() };
        let q = aser.quantize_layer(&w, &calib, Precision::w4a8());
        let want = q.forward_matrix(&calib.x);
        let lin = Linear::quantized(q);
        let got = lin.forward(&calib.x);
        assert!(want.max_diff(&got) < 1e-3 * want.max_abs().max(1.0));
    }

    #[test]
    fn batched_kernel_matches_scalar_token_reference() {
        let (w, calib) = setup(134);
        let aser = Aser { rank: RankPolicy::Fixed(8), outlier_f: 4, ..Default::default() };
        let q = aser.quantize_layer(&w, &calib, Precision::w4a8());
        let lin = Linear::quantized(q.clone());
        let batch = lin.forward(&calib.x);
        for t in [0usize, 7, 63] {
            let want = forward_quant_token(&q, calib.x.row(t));
            let d = batch
                .row(t)
                .iter()
                .zip(&want)
                .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()));
            assert!(d < 1e-3 * batch.max_abs().max(1.0), "token {t}: diff {d}");
        }
    }

    #[test]
    fn dense_token_and_batch_agree() {
        let (w, calib) = setup(133);
        let lin = Linear::Dense(w);
        let batch = lin.forward(&calib.x);
        for t in [0usize, 5, 63] {
            let y = lin.forward_token(calib.x.row(t));
            assert_eq!(batch.row(t), &y[..]);
        }
    }

    #[test]
    fn arena_reuse_across_layers_and_calls() {
        let (w, calib) = setup(135);
        let q1 = Rtn.quantize_layer(&w, &calib, Precision::w4a8());
        let wide = Matrix::randn(&mut Pcg64::seed(9), 16, 40, 0.05);
        let q2 = Rtn.quantize_layer(&wide, &calib, Precision::w4a8());
        let l1 = Linear::quantized(q1);
        let l2 = Linear::quantized(q2);
        let mut arena = QGemmArena::new();
        let a1 = l1.forward_with(&calib.x, &mut arena);
        let a2 = l2.forward_with(&calib.x, &mut arena);
        // Shared arena across alternating layers must not corrupt results.
        assert_eq!(a1, l1.forward(&calib.x));
        assert_eq!(a2, l2.forward(&calib.x));
    }

    #[test]
    fn dot_i8_exact() {
        let a: Vec<i8> = (-20..21).collect();
        let b: Vec<i8> = (0..41).map(|i| (i % 7 - 3) as i8).collect();
        let want: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(dot_i8(&a, &b), want);
    }
}
