//! The linear-layer abstraction the quantization pipeline swaps in place.
//!
//! `Linear::Dense` is the fp32 reference; `Linear::Quant` wraps a
//! [`QuantizedLinear`] produced by any PTQ method. The quantized forward here
//! is the *optimized serving path* (int8 token quant + integer-ish dot with
//! per-row scales + fused low-rank branch); `QuantizedLinear::forward_matrix`
//! in `methods` is the reference semantics it must match (see tests).

use crate::methods::QuantizedLinear;
use crate::quant::{quantize_token, FP};
use crate::tensor::{matvec, Matrix};

pub enum Linear {
    Dense(Matrix),
    Quant(QuantizedLinear),
}

impl Linear {
    pub fn out_features(&self) -> usize {
        match self {
            Linear::Dense(w) => w.rows,
            Linear::Quant(q) => q.out_features(),
        }
    }

    pub fn in_features(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols,
            Linear::Quant(q) => q.in_features(),
        }
    }

    /// Dense reference weight if fp.
    pub fn dense_weight(&self) -> Option<&Matrix> {
        match self {
            Linear::Dense(w) => Some(w),
            Linear::Quant(_) => None,
        }
    }

    /// Forward for a batch of token activations (tokens × in → tokens × out).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        match self {
            Linear::Dense(w) => crate::tensor::matmul_bt(x, w),
            Linear::Quant(q) => {
                let mut out = Matrix::zeros(x.rows, q.out_features());
                for t in 0..x.rows {
                    let y = forward_quant_token(q, x.row(t));
                    out.row_mut(t).copy_from_slice(&y);
                }
                out
            }
        }
    }

    /// Single-token forward (serving hot path).
    pub fn forward_token(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Linear::Dense(w) => matvec(w, x),
            Linear::Quant(q) => forward_quant_token(q, x),
        }
    }
}

/// Optimized quantized single-token forward:
/// 1. smooth: x' = x / m
/// 2. per-token quantize x' to `abits`; integer codes dot int weight codes
///    row-wise, then apply the combined scale (token_scale × row_scale)
/// 3. fp outlier columns
/// 4. low-rank branch on fp x'
pub fn forward_quant_token(q: &QuantizedLinear, x: &[f32]) -> Vec<f32> {
    let d_in = q.in_features();
    let d_out = q.out_features();
    debug_assert_eq!(x.len(), d_in);
    // 1. smoothing
    let xs: Vec<f32> = match &q.act_smooth {
        Some(m) => x.iter().zip(m).map(|(&v, &mi)| v / mi).collect(),
        None => x.to_vec(),
    };
    let mut y = vec![0f32; d_out];
    if q.abits == FP {
        // fp activation × dequantized row — still avoids materializing W.
        for r in 0..d_out {
            let codes = &q.weight.codes[r * d_in..(r + 1) * d_in];
            let mut acc = 0f32;
            for (c, &xv) in codes.iter().zip(&xs) {
                acc += *c as f32 * xv;
            }
            y[r] = acc * q.weight.scales[r];
        }
    } else {
        // 2. per-token activation quantization, integer dot in i32.
        let qt = quantize_token(&xs, q.abits);
        for r in 0..d_out {
            let codes = &q.weight.codes[r * d_in..(r + 1) * d_in];
            let acc = dot_i8(codes, &qt.codes);
            y[r] = acc as f32 * (qt.scale * q.weight.scales[r]);
        }
    }
    // 3. fp outlier columns act on the *unquantized* smoothed activation.
    for (c, wcol) in &q.fp_cols {
        let xv = xs[*c];
        if xv != 0.0 {
            for (yo, &wv) in y.iter_mut().zip(wcol) {
                *yo += xv * wv;
            }
        }
    }
    // 4. low-rank correction (fp skinny GEMMs): y += L_A · (L_B · x).
    if let Some((la, lb)) = &q.low_rank {
        let z = matvec(lb, &xs); // r
        let corr = matvec(la, &z); // out  (la: out×r)
        for (yo, c) in y.iter_mut().zip(corr) {
            *yo += c;
        }
    }
    y
}

/// i8·i8 → i32 dot, 8-wide unrolled.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0i32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for k in 0..8 {
            acc[k] += a[i + k] as i32 * b[i + k] as i32;
        }
    }
    let mut s: i32 = acc.iter().sum();
    for i in chunks * 8..n {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::{aser::Aser, rtn::Rtn, LayerCalib, PtqMethod, RankPolicy};
    use crate::quant::Precision;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(seed);
        let d = 40;
        let w = Matrix::randn(&mut rng, 24, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 64, d, 1.0);
        for r in 0..x.rows {
            x[(r, 3)] *= 20.0;
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn hot_path_matches_reference_semantics_rtn() {
        let (w, calib) = setup(131);
        for prec in [Precision::w4a8(), Precision::w4a6(), Precision::w4a16()] {
            let q = Rtn.quantize_layer(&w, &calib, prec);
            let want = q.forward_matrix(&calib.x);
            let lin = Linear::Quant(q);
            let got = lin.forward(&calib.x);
            assert!(
                want.max_diff(&got) < 1e-3 * want.max_abs().max(1.0),
                "{prec}: diff {}",
                want.max_diff(&got)
            );
        }
    }

    #[test]
    fn hot_path_matches_reference_semantics_aser() {
        let (w, calib) = setup(132);
        let aser = Aser { rank: RankPolicy::Fixed(8), outlier_f: 4, ..Default::default() };
        let q = aser.quantize_layer(&w, &calib, Precision::w4a8());
        let want = q.forward_matrix(&calib.x);
        let lin = Linear::Quant(q);
        let got = lin.forward(&calib.x);
        assert!(want.max_diff(&got) < 1e-3 * want.max_abs().max(1.0));
    }

    #[test]
    fn dense_token_and_batch_agree() {
        let (w, calib) = setup(133);
        let lin = Linear::Dense(w);
        let batch = lin.forward(&calib.x);
        for t in [0usize, 5, 63] {
            let y = lin.forward_token(calib.x.row(t));
            assert_eq!(batch.row(t), &y[..]);
        }
    }

    #[test]
    fn dot_i8_exact() {
        let a: Vec<i8> = (-20..21).collect();
        let b: Vec<i8> = (0..41).map(|i| (i % 7 - 3) as i8).collect();
        let want: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(dot_i8(&a, &b), want);
    }
}
