//! LLaMA-style transformer substrate: config registry, the fp/quantized
//! linear abstraction, the decoder model with batch + incremental (KV-cache)
//! forward paths, and synthetic-weight construction with function-preserving
//! outlier injection.

pub mod config;
pub mod draft;
pub mod gpt;
pub mod init;
pub mod linear;
pub mod sampling;

pub use crate::coordinator::kvpool::{KvCache, KvDtype};
pub use config::{layer_key, ModelConfig, LINEAR_NAMES};
pub use draft::{DraftModel, DraftSpec};
pub use gpt::{
    argmax, rope_inplace, rope_inplace_cached, rope_inv_freq, ActSink, Block, ChunkLogits, Gpt,
    NullSink, SeqChunk, PREFILL_CHUNK,
};
pub use init::{inject_outliers, load_model, load_or_synthetic, save_model, synthetic_model};
pub use linear::{forward_quant_token, Linear};
pub use sampling::{Sampler, SamplingParams, GREEDY_TEMPERATURE_EPS};
