//! Report emission: paper-style ASCII tables + CSV/JSON artifacts.

use crate::util::json::Json;
use std::path::Path;

/// A simple column-aligned table builder.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Row indices to mark bold-equivalent (best results) per column.
    pub best: Vec<(usize, usize)>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            best: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Mark the best row per numeric column (`min` or not) over data rows
    /// `from..` (skipping e.g. the fp16 reference row). Non-numeric cells
    /// are ignored.
    pub fn mark_best(&mut self, col: usize, minimize: bool, from_row: usize) {
        let mut best: Option<(usize, f64)> = None;
        for (i, row) in self.rows.iter().enumerate().skip(from_row) {
            if let Ok(v) = row[col].trim_end_matches('*').parse::<f64>() {
                let better = match best {
                    None => true,
                    Some((_, b)) => {
                        if minimize {
                            v < b
                        } else {
                            v > b
                        }
                    }
                };
                if better {
                    best = Some((i, v));
                }
            }
        }
        if let Some((i, _)) = best {
            self.best.push((i, col));
        }
    }

    pub fn render(&self) -> String {
        let mut rows = self.rows.clone();
        for &(r, c) in &self.best {
            rows[r][c] = format!("{}*", rows[r][c]);
        }
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = format!("## {}\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &rows {
            out.push_str(&line(row, &widths));
        }
        out.push_str("(* = best in column)\n");
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn save(&self, dir: &Path, stem: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.txt")), self.render())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// A named (x, series...) dataset for figures; rendered as aligned columns +
/// an ASCII sparkline per series, saved as CSV + JSON.
pub struct Figure {
    pub title: String,
    pub x_label: String,
    pub x: Vec<f64>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl Figure {
    pub fn new(title: &str, x_label: &str, x: Vec<f64>) -> Figure {
        Figure { title: title.to_string(), x_label: x_label.to_string(), x, series: Vec::new() }
    }

    pub fn add(&mut self, name: &str, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.x.len(), "series '{name}' length");
        self.series.push((name.to_string(), ys));
    }

    pub fn sparkline(ys: &[f64]) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let (lo, hi) = ys.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        let span = (hi - lo).max(1e-12);
        ys.iter()
            .map(|&v| BARS[(((v - lo) / span) * 7.0).round() as usize])
            .collect()
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {}  (x = {})\n", self.title, self.x_label);
        for (name, ys) in &self.series {
            let (lo, hi) = ys.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
                (l.min(v), h.max(v))
            });
            out.push_str(&format!(
                "{name:<24} {}  [min {lo:.4}, max {hi:.4}]\n",
                Self::sparkline(ys)
            ));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = format!("{}", self.x_label);
        for (name, _) in &self.series {
            out.push_str(&format!(",{name}"));
        }
        out.push('\n');
        for (i, &x) in self.x.iter().enumerate() {
            out.push_str(&format!("{x}"));
            for (_, ys) in &self.series {
                out.push_str(&format!(",{}", ys[i]));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        use crate::util::json::{arr_f64, obj, s};
        let mut series = Vec::new();
        for (name, ys) in &self.series {
            series.push(obj(vec![("name", s(name)), ("y", arr_f64(ys))]));
        }
        obj(vec![
            ("title", s(&self.title)),
            ("x_label", s(&self.x_label)),
            ("x", arr_f64(&self.x)),
            ("series", Json::Arr(series)),
        ])
    }

    pub fn save(&self, dir: &Path, stem: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.txt")), self.render())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.json")), self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_and_marks_best() {
        let mut t = Table::new("demo", &["method", "ppl", "acc"]);
        t.row(vec!["fp16".into(), "6.14".into(), "64.86".into()]);
        t.row(vec!["rtn".into(), "10.21".into(), "47.85".into()]);
        t.row(vec!["aser".into(), "7.43".into(), "55.93".into()]);
        t.mark_best(1, true, 1);
        t.mark_best(2, false, 1);
        let s = t.render();
        assert!(s.contains("7.43*"));
        assert!(s.contains("55.93*"));
        assert!(!s.contains("6.14*"), "reference row excluded");
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 4);
    }

    #[test]
    fn figure_roundtrip() {
        let mut f = Figure::new("eff rank", "layer", vec![0.0, 1.0, 2.0]);
        f.add("qkv", vec![5.0, 4.0, 3.0]);
        f.add("fc1", vec![7.0, 8.0, 9.0]);
        let s = f.render();
        assert!(s.contains("qkv"));
        let csv = f.to_csv();
        assert!(csv.starts_with("layer,qkv,fc1"));
        let j = f.to_json();
        assert_eq!(j.get("x").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn sparkline_monotone() {
        let s = Figure::sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        let chars: Vec<char> = s.chars().collect();
        assert!(chars[0] < chars[2]);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
