//! `repro bench-table --id t1..t8` — regenerate every table of the paper.
//!
//! | id | paper table | here |
//! |----|-------------|------|
//! | t1 | LLaMA3-8B W4A8 + W4A6, PPL + acc     | model A |
//! | t2 | Qwen1.5-7B W4A8 + W4A6, PPL + acc    | model B |
//! | t3 | Qwen-72B W4A8 accuracy                | model C |
//! | t4 | rank threshold α sweep + FLOPs        | model B |
//! | t5 | LLaMA3-8B weight-only W4A16           | model A |
//! | t6 | LLaMA2-13B W4A16 + W4A8               | model D |
//! | t7 | Qwen-14B W4A8 accuracy                | model E |
//! | t8 | Qwen1.5-32B W4A8 accuracy             | model F |
//!
//! Absolute numbers differ from the paper (tiny models, synthetic corpora);
//! the *shape* — method ordering, the W4A6 cliff, AS gains — is the
//! reproduction target (see EXPERIMENTS.md).

use super::ctx::Ctx;
use super::harness::{evaluate_model, EvalResult, EvalSpec};
use crate::coordinator::run_ptq;
use crate::methods::{method_by_name, RankPolicy};
use crate::model::Gpt;
use crate::quant::Precision;
use crate::report::Table;
use crate::util::cli::Args;
use anyhow::Result;

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let id = args.str_or("id", "t1");
    let t0 = std::time::Instant::now();
    let table = build_table(&ctx, &id, args)?;
    println!("{}", table.render());
    table.save(&ctx.reports_dir(), &id)?;
    println!(
        "[saved {}/{id}.txt + .csv in {:.0}s]",
        ctx.reports_dir().display(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

pub fn build_table(ctx: &Ctx, id: &str, args: &Args) -> Result<Table> {
    match id {
        "t1" => main_table(ctx, args, "A", "Table 1: PTQ on model A (LLaMA3-8B stand-in)"),
        "t2" => main_table(ctx, args, "B", "Table 2: PTQ on model B (Qwen1.5-7B stand-in)"),
        "t3" => acc_table(
            ctx,
            args,
            "C",
            &["arc_e", "arc_c", "gsm", "heval"],
            "Table 3: W4A8 on model C (Qwen-72B stand-in)",
        ),
        "t4" => rank_sweep_table(ctx, args),
        "t5" => weight_only_table(ctx, args, "A", "Table 5: weight-only W4A16 on model A"),
        "t6" => table6(ctx, args),
        "t7" => acc_table(
            ctx,
            args,
            "E",
            &["arc_e", "arc_c", "hella", "piqa"],
            "Table 7: W4A8 on model E (Qwen-14B stand-in)",
        ),
        "t8" => acc_table(
            ctx,
            args,
            "F",
            &["arc_e", "arc_c", "hella", "piqa"],
            "Table 8: W4A8 on model F (Qwen1.5-32B stand-in)",
        ),
        other => anyhow::bail!("unknown table id '{other}' (t1..t8)"),
    }
}

fn spec(ctx: &Ctx) -> EvalSpec {
    if ctx.fast {
        EvalSpec::fast(ctx.seed)
    } else {
        EvalSpec::standard(ctx.seed)
    }
}

/// Evaluate one (method, precision) on a freshly quantized copy.
fn eval_method(
    ctx: &Ctx,
    model_name: &str,
    method_name: &str,
    prec: Precision,
    rank: RankPolicy,
    outlier_f: usize,
    es: &EvalSpec,
) -> Result<EvalResult> {
    let model: Gpt = ctx.model(model_name)?;
    let stats = ctx.calib(&model, "wiki")?;
    let method = method_by_name(method_name, rank, outlier_f)?;
    let (qmodel, _) = run_ptq(model, &stats, method.as_ref(), prec, 0)?;
    evaluate_model(&qmodel, es)
}

fn fmt(v: f64) -> String {
    format!("{v:.2}")
}

/// The Table-1/2 layout: fp16 row, then methods × {W4A8, W4A6}.
fn main_table(ctx: &Ctx, args: &Args, model_name: &str, title: &str) -> Result<Table> {
    let es = spec(ctx);
    let rank = RankPolicy::Fixed(args.usize_or("rank", 16)?);
    let outlier_f = args.usize_or("outlier-f", 8)?;
    let mut t = Table::new(
        title,
        &["method", "#W", "#A", "wiki", "c4", "ptb", "arc_e", "arc_c", "mmlu", "hella", "piqa", "avg"],
    );
    let fp = evaluate_model(&ctx.model(model_name)?, &es)?;
    push_row(&mut t, "fp16", "16", "16", &fp, &es);
    let methods = ["llm_int", "smoothquant", "smoothquant+", "lorc", "l2qer", "aser-er", "aser"];
    // Precision shift (EXPERIMENTS.md §Substitutions): our 6-8-layer models
    // accumulate less quantization noise than 32-80-layer LLMs, so the
    // activation-bit cliff sits one notch lower. W4A6/W4A4 here play the
    // role of the paper's W4A8/W4A6 blocks.
    let mut row_idx = vec![1usize];
    for prec in [Precision::w4a6(), Precision::new(4, 4)] {
        for m in methods {
            eprintln!("[t] {model_name} {m} @ {prec} ...");
            let r = eval_method(ctx, model_name, m, prec, rank, outlier_f, &es)?;
            push_row(&mut t, m, &prec.wbits.to_string(), &prec.abits.to_string(), &r, &es);
        }
        row_idx.push(t.rows.len());
    }
    // Mark best per block (W4A8 rows, then W4A6 rows) for ppl (min) and avg (max).
    for w in row_idx.windows(2) {
        let _ = w;
    }
    for col in 3..6 {
        t.mark_best(col, true, 1);
    }
    for col in 6..12 {
        t.mark_best(col, false, 1);
    }
    Ok(t)
}

fn push_row(t: &mut Table, name: &str, wb: &str, ab: &str, r: &EvalResult, es: &EvalSpec) {
    let mut cells = vec![name.to_string(), wb.to_string(), ab.to_string()];
    for p in &es.profiles {
        cells.push(fmt(*r.ppl.get(p).unwrap_or(&f64::NAN)));
    }
    for task in &es.tasks {
        cells.push(fmt(*r.acc.get(task).unwrap_or(&f64::NAN)));
    }
    cells.push(fmt(r.avg_acc()));
    t.row(cells);
}

/// Accuracy-only tables (3/7/8).
fn acc_table(ctx: &Ctx, args: &Args, model_name: &str, tasks: &[&str], title: &str) -> Result<Table> {
    let mut es = EvalSpec::accuracy_only(ctx.seed, tasks);
    if ctx.fast {
        es.task_instances = 12;
    }
    let rank = RankPolicy::Fixed(args.usize_or("rank", 16)?);
    let outlier_f = args.usize_or("outlier-f", 8)?;
    let mut headers = vec!["method", "#W", "#A"];
    headers.extend(tasks.iter().copied());
    headers.push("avg");
    let mut t = Table::new(title, &headers);
    let fp = evaluate_model(&ctx.model(model_name)?, &es)?;
    push_acc_row(&mut t, "fp16", "16", "16", &fp, tasks);
    // W4A6 = the paper's W4A8 analog on the tiny models (see main_table).
    let prec = Precision::w4a6();
    for m in ["llm_int", "smoothquant", "smoothquant+", "lorc", "l2qer", "aser-er", "aser"] {
        eprintln!("[t] {model_name} {m} @ {prec} ...");
        let r = eval_method(ctx, model_name, m, prec, rank, outlier_f, &es)?;
        push_acc_row(&mut t, m, "4", "6", &r, tasks);
    }
    for col in 3..3 + tasks.len() + 1 {
        t.mark_best(col, false, 1);
    }
    Ok(t)
}

fn push_acc_row(t: &mut Table, name: &str, wb: &str, ab: &str, r: &EvalResult, tasks: &[&str]) {
    let mut cells = vec![name.to_string(), wb.to_string(), ab.to_string()];
    for task in tasks {
        cells.push(fmt(*r.acc.get(*task).unwrap_or(&f64::NAN)));
    }
    cells.push(fmt(r.avg_acc()));
    t.row(cells);
}

/// Table 4: α sweep — accuracy vs mean rank vs +FLOPs on model B.
fn rank_sweep_table(ctx: &Ctx, args: &Args) -> Result<Table> {
    let alphas = args
        .list_f64("alphas")?
        // Our tiny models' whitened error spectra are more top-heavy than
        // d=4096 LLMs (σ₁ alone ≥ 10% of the mass), so the α grid is scaled
        // up to sweep the same rank range the paper's grid covers.
        .unwrap_or_else(|| vec![0.7, 0.5, 0.3, 0.2, 0.1]);
    let mut es = EvalSpec::accuracy_only(ctx.seed, &["arc_e", "hella", "piqa"]);
    if ctx.fast {
        es.task_instances = 12;
    }
    let outlier_f = args.usize_or("outlier-f", 8)?;
    let mut t = Table::new(
        "Table 4: ASER rank threshold α sweep (model B, W4A4)",
        &["alpha", "mean_rank", "arc_e", "hella", "piqa", "+FLOPs%"],
    );
    for &alpha in &alphas {
        eprintln!("[t4] alpha {alpha} ...");
        let model = ctx.model("B")?;
        let stats = ctx.calib(&model, "wiki")?;
        let method = method_by_name("aser", RankPolicy::Threshold(alpha), outlier_f)?;
        let (qmodel, report) = run_ptq(model, &stats, method.as_ref(), Precision::new(4, 4), 0)?;
        let r = evaluate_model(&qmodel, &es)?;
        t.row(vec![
            format!("{alpha}"),
            format!("{:.2}", report.mean_rank()),
            fmt(*r.acc.get("arc_e").unwrap_or(&f64::NAN)),
            fmt(*r.acc.get("hella").unwrap_or(&f64::NAN)),
            fmt(*r.acc.get("piqa").unwrap_or(&f64::NAN)),
            format!("{:.2}", report.flops_overhead_pct()),
        ]);
    }
    Ok(t)
}

/// Table 5/6 share the weight-only layout: RTN/GPTQ/AWQ/ASER at W4A16.
fn weight_only_table(ctx: &Ctx, args: &Args, model_name: &str, title: &str) -> Result<Table> {
    let es = spec(ctx);
    let rank = RankPolicy::Fixed(args.usize_or("rank", 16)?);
    let outlier_f = args.usize_or("outlier-f", 8)?;
    let mut t = Table::new(
        title,
        &["method", "#W", "#A", "wiki", "c4", "ptb", "arc_e", "arc_c", "mmlu", "hella", "piqa", "avg"],
    );
    let fp = evaluate_model(&ctx.model(model_name)?, &es)?;
    push_row(&mut t, "fp16", "16", "16", &fp, &es);
    let prec = Precision::w4a16();
    for m in ["rtn", "gptq", "awq", "aser-er", "aser"] {
        eprintln!("[t] {model_name} {m} @ {prec} ...");
        let r = eval_method(ctx, model_name, m, prec, rank, outlier_f, &es)?;
        push_row(&mut t, m, "4", "16", &r, &es);
    }
    for col in 3..6 {
        t.mark_best(col, true, 1);
    }
    for col in 6..12 {
        t.mark_best(col, false, 1);
    }
    Ok(t)
}

/// Table 6: model D, W4A16 block + W4A8 block.
fn table6(ctx: &Ctx, args: &Args) -> Result<Table> {
    let es = spec(ctx);
    let rank = RankPolicy::Fixed(args.usize_or("rank", 16)?);
    let outlier_f = args.usize_or("outlier-f", 8)?;
    let mut t = Table::new(
        "Table 6: PTQ on model D (LLaMA2-13B stand-in)",
        &["method", "#W", "#A", "wiki", "c4", "ptb", "arc_e", "arc_c", "mmlu", "hella", "piqa", "avg"],
    );
    let fp = evaluate_model(&ctx.model("D")?, &es)?;
    push_row(&mut t, "fp16", "16", "16", &fp, &es);
    for m in ["rtn", "gptq", "awq", "aser-er", "aser"] {
        eprintln!("[t6] D {m} @ W4A16 ...");
        let r = eval_method(ctx, "D", m, Precision::w4a16(), rank, outlier_f, &es)?;
        push_row(&mut t, m, "4", "16", &r, &es);
    }
    for m in ["llm_int", "smoothquant", "lorc", "l2qer", "aser-er", "aser"] {
        eprintln!("[t6] D {m} @ W4A6 ...");
        let r = eval_method(ctx, "D", m, Precision::w4a6(), rank, outlier_f, &es)?;
        push_row(&mut t, m, "4", "6", &r, &es);
    }
    for col in 3..6 {
        t.mark_best(col, true, 1);
    }
    for col in 6..12 {
        t.mark_best(col, false, 1);
    }
    Ok(t)
}
