//! `repro calibrate` and `repro quantize` — the PTQ pipeline entry points.

use super::ctx::Ctx;
use crate::coordinator::run_ptq;
use crate::quant::Precision;
use crate::util::cli::Args;
use anyhow::Result;

pub fn run_calibrate(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let model_name = args.str_or("model", "A");
    let profile = args.str_or("profile", "wiki");
    let model = ctx.model(&model_name)?;
    let t = std::time::Instant::now();
    let stats = ctx.calib(&model, &profile)?;
    println!(
        "calibrated model {model_name} on '{profile}': {} layers, {} tokens/layer, {:.1}s",
        stats.len(),
        stats.values().next().map(|c| c.tokens).unwrap_or(0),
        t.elapsed().as_secs_f64()
    );
    // Top outlier channels of the first layer — quick sanity signal.
    if let Some(c) = stats.get("L0.qkv_proj") {
        let mut idx: Vec<usize> = (0..c.x_abs_mean.len()).collect();
        idx.sort_by(|&a, &b| c.x_abs_mean[b].partial_cmp(&c.x_abs_mean[a]).unwrap());
        let top: Vec<String> =
            idx[..8.min(idx.len())].iter().map(|&i| format!("{i}:{:.2}", c.x_abs_mean[i])).collect();
        println!("L0.qkv_proj top |X̄| channels: {}", top.join(" "));
    }
    Ok(())
}

pub fn run_quantize(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let model_name = args.str_or("model", "A");
    let profile = args.str_or("profile", "wiki");
    let prec = Precision::parse(&args.str_or("prec", "w4a8"))?;
    let method = ctx.method(args)?;
    let threads = args.usize_or("threads", 0)?;

    let model = ctx.model(&model_name)?;
    let stats = ctx.calib(&model, &profile)?;
    let (qmodel, report) = run_ptq(model, &stats, method.as_ref(), prec, threads)?;

    println!(
        "quantized model {model_name} with {} @ {prec}: mean rel error {:.5}, mean rank {:.1}, +params {} (+{:.2}% FLOPs), {:.1}s",
        report.method,
        report.mean_rel_error(),
        report.mean_rank(),
        report.total_extra_params,
        report.flops_overhead_pct(),
        report.wall_ms / 1e3,
    );
    if ctx.verbose {
        for l in &report.layers {
            println!(
                "  {:<14} rel_err {:.5}  rank {:<4} {:.0}ms",
                l.key, l.rel_error, l.rank, l.millis
            );
        }
    }
    // Smoke: quantized model must still generate.
    let out = qmodel.generate_greedy(&[3, 9, 4], 8);
    println!("sample generation: {:?}", out);
    Ok(())
}
