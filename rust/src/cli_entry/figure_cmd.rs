//! `repro figure --id f2..f8` — regenerate every figure of the paper.
//!
//! | id | paper figure | content |
//! |----|--------------|---------|
//! | f2 | Fig. 2 | top-k normalized singular values of E_q vs E_qX, 4 linears |
//! | f3 | Fig. 3 | effective rank of E_qX across layers |
//! | f4 | Fig. 4 | per-channel ‖E_qX‖, X̄, W̄, X̄·W̄ (sorted by X̄·W̄) |
//! | f5 | Fig. 5 | PPL of W8Ax for x ∈ {16,8,6,4}, six methods (model B) |
//! | f6 | Fig. 6 | remaining error across layers, W4A6, four methods |
//! | f7 | Fig. 7 | activation/weight ranges before vs after smoothing |
//! | f8 | Fig. 8 | selected rank per layer for α ∈ [0.015, 0.1] |

use super::ctx::Ctx;
use crate::analysis;
use crate::coordinator::CalibStats;
use crate::data::corpus;
use crate::eval::perplexity;
use crate::methods::{aser::Aser, method_by_name, RankPolicy};
use crate::model::{layer_key, Gpt, LINEAR_NAMES};
use crate::quant::Precision;
use crate::report::Figure;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use anyhow::Result;

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let id = args.str_or("id", "f2");
    let t0 = std::time::Instant::now();
    let fig = build_figure(&ctx, &id, args)?;
    println!("{}", fig.render());
    fig.save(&ctx.reports_dir(), &id)?;
    println!(
        "[saved {}/{id}.{{txt,csv,json}} in {:.0}s]",
        ctx.reports_dir().display(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

pub fn build_figure(ctx: &Ctx, id: &str, args: &Args) -> Result<Figure> {
    let model_name = args.str_or("model", if id == "f5" || id == "f7" { "B" } else { "A" });
    let model = ctx.model(&model_name)?;
    let stats = ctx.calib(&model, "wiki")?;
    match id {
        "f2" => fig2(&model, &stats, args),
        "f3" => fig3(&model, &stats),
        "f4" => fig4(&model, &stats, args),
        "f5" => fig5(ctx, &model_name),
        "f6" => fig6(ctx, &model_name),
        "f7" => fig7(&model, &stats, args),
        "f8" => fig8(&model, &stats, args),
        other => anyhow::bail!("unknown figure id '{other}' (f2..f8)"),
    }
}

fn weight_of<'m>(model: &'m Gpt, l: usize, name: &str) -> &'m crate::tensor::Matrix {
    model.get_linear(l, name).dense_weight().expect("dense model")
}

/// Fig. 2: spectra at a deep block (paper: layer 30/32 ⇒ ~0.94 depth).
fn fig2(model: &Gpt, stats: &CalibStats, args: &Args) -> Result<Figure> {
    let l = args.usize_or("layer", model.cfg.n_layers.saturating_sub(2))?;
    let top_k = args.usize_or("top-k", 64)?.min(model.cfg.d_model);
    let mut fig = Figure::new(
        &format!("Fig.2: normalized singular values of E_q vs E_qX (block {l})"),
        "sv index",
        (0..top_k).map(|i| i as f64).collect(),
    );
    for name in LINEAR_NAMES {
        let key = layer_key(l, name);
        let calib = &stats[&key];
        let (s_w, s_ex) = analysis::error_spectra(weight_of(model, l, name), calib, 4, top_k);
        fig.add(&format!("{name} E_q"), pad(s_w, top_k));
        fig.add(&format!("{name} E_qX"), pad(s_ex, top_k));
    }
    Ok(fig)
}

fn pad(v: Vec<f32>, n: usize) -> Vec<f64> {
    let mut out: Vec<f64> = v.into_iter().map(|x| x as f64).collect();
    out.resize(n, 0.0);
    out
}

/// Fig. 3: effective rank of E_qX across layers, per linear.
fn fig3(model: &Gpt, stats: &CalibStats) -> Result<Figure> {
    let n = model.cfg.n_layers;
    let mut fig = Figure::new(
        "Fig.3: effective rank of E_qX across layers",
        "layer",
        (0..n).map(|i| i as f64).collect(),
    );
    for name in LINEAR_NAMES {
        let ys: Vec<f64> = (0..n)
            .map(|l| {
                let key = layer_key(l, name);
                analysis::error_effective_rank(weight_of(model, l, name), &stats[&key], 4) as f64
            })
            .collect();
        fig.add(name, ys);
    }
    Ok(fig)
}

/// Fig. 4: channel profile of one layer.
fn fig4(model: &Gpt, stats: &CalibStats, args: &Args) -> Result<Figure> {
    let l = args.usize_or("layer", 0)?;
    let name = args.str_or("linear", "qkv_proj");
    let top = args.usize_or("top-k", 128)?;
    let key = layer_key(l, &name);
    let p = analysis::channel_profile(weight_of(model, l, &name), &stats[&key], 4, top);
    let n = p.order.len();
    let mut fig = Figure::new(
        &format!("Fig.4: channel magnitudes sorted by X̄·W̄ ({key})"),
        "channel rank",
        (0..n).map(|i| i as f64).collect(),
    );
    fig.add("err_norm", p.err_norm.iter().map(|&x| x as f64).collect());
    fig.add("x_bar", p.x_bar.iter().map(|&x| x as f64).collect());
    fig.add("w_bar", p.w_bar.iter().map(|&x| x as f64).collect());
    fig.add("xw", p.xw.iter().map(|&x| x as f64).collect());
    Ok(fig)
}

/// Fig. 5: PPL (wiki) of W8Ax across activation bit-widths, six methods.
fn fig5(ctx: &Ctx, model_name: &str) -> Result<Figure> {
    let abits = [16u8, 8, 6, 4];
    let methods = ["llm_int", "smoothquant", "lorc", "l2qer", "aser-er", "aser"];
    let mut fig = Figure::new(
        &format!("Fig.5: PPL of W8Ax on model {model_name}"),
        "activation bits",
        abits.iter().map(|&b| b as f64).collect(),
    );
    let ppl_tokens = if ctx.fast { 192 } else { 512 };
    let c = corpus(ctx.model(model_name)?.cfg.vocab_size, "wiki")?;
    let mut rng = Pcg64::new(ctx.seed ^ 0xF15, 0);
    let stream = c.stream(&mut rng, ppl_tokens);
    for m in methods {
        let mut ys = Vec::new();
        for &ab in &abits {
            eprintln!("[f5] {m} W8A{ab} ...");
            let model = ctx.model(model_name)?;
            let stats = ctx.calib(&model, "wiki")?;
            let method = method_by_name(m, RankPolicy::Fixed(16), 8)?;
            let (qm, _) = crate::coordinator::run_ptq(
                model,
                &stats,
                method.as_ref(),
                Precision::new(8, ab),
                0,
            )?;
            ys.push(perplexity(&qm, &stream, 64));
        }
        fig.add(m, ys);
    }
    Ok(fig)
}

/// Fig. 6: remaining integral error across layers (W4A6).
fn fig6(ctx: &Ctx, model_name: &str) -> Result<Figure> {
    let model = ctx.model(model_name)?;
    let stats = ctx.calib(&model, "wiki")?;
    let n = model.cfg.n_layers;
    // x axis: the 4·n linears in block-major order (as the paper plots
    // consecutive linear layers).
    let mut fig = Figure::new(
        &format!("Fig.6: remaining quantization error across layers (model {model_name}, W4A4)"),
        "linear index (block-major)",
        (0..4 * n).map(|i| i as f64).collect(),
    );
    let prec = Precision::new(4, 4);
    for m in ["rtn", "lorc", "aser-er", "aser"] {
        let method = method_by_name(m, RankPolicy::Fixed(16), 8)?;
        let mut ys = Vec::new();
        for l in 0..n {
            for name in LINEAR_NAMES {
                let key = layer_key(l, name);
                let w = weight_of(&model, l, name);
                let q = method.quantize_layer(w, &stats[&key], prec);
                ys.push(analysis::remaining_error(w, &q, &stats[&key]) as f64);
            }
        }
        fig.add(m, ys);
    }
    Ok(fig)
}

/// Fig. 7: activation/weight channel ranges before/after smoothing (L0).
fn fig7(model: &Gpt, stats: &CalibStats, args: &Args) -> Result<Figure> {
    let l = args.usize_or("layer", 0)?;
    let key = layer_key(l, "qkv_proj");
    let w = weight_of(model, l, "qkv_proj");
    let aser = Aser { outlier_f: 32, ..Default::default() };
    let e = analysis::smoothing_effect(w, &stats[&key], &aser);
    let d = e.act_before.len();
    // Sort channels by pre-smoothing activation magnitude for readability.
    let mut order: Vec<usize> = (0..d).collect();
    order.sort_by(|&a, &b| e.act_before[b].partial_cmp(&e.act_before[a]).unwrap());
    let take: Vec<usize> = order.into_iter().take(128).collect();
    let sel = |v: &[f32]| take.iter().map(|&i| v[i] as f64).collect::<Vec<f64>>();
    let mut fig = Figure::new(
        &format!("Fig.7: smoothing effect on {key} (channels sorted by X̄)"),
        "channel rank",
        (0..take.len()).map(|i| i as f64).collect(),
    );
    fig.add("act_before", sel(&e.act_before));
    fig.add("act_after", sel(&e.act_after));
    fig.add("w_before", sel(&e.w_before));
    fig.add("w_after", sel(&e.w_after));
    Ok(fig)
}

/// Fig. 8: rank selected per layer for a ladder of α values.
fn fig8(model: &Gpt, stats: &CalibStats, args: &Args) -> Result<Figure> {
    let alphas = args
        .list_f64("alphas")?
        .unwrap_or_else(|| vec![0.015, 0.03, 0.05, 0.075, 0.1]);
    let n = model.cfg.n_layers;
    let mut fig = Figure::new(
        "Fig.8: selected rank per layer (whitened spectrum, by α)",
        "linear index (block-major)",
        (0..4 * n).map(|i| i as f64).collect(),
    );
    for &alpha in &alphas {
        let mut ys = Vec::new();
        for l in 0..n {
            for name in LINEAR_NAMES {
                let key = layer_key(l, name);
                ys.push(analysis::selected_rank(weight_of(model, l, name), &stats[&key], 4, alpha)
                    as f64);
            }
        }
        fig.add(&format!("alpha={alpha}"), ys);
    }
    Ok(fig)
}
