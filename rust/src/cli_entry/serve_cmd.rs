//! `repro serve` — the serving demo: quantize a model, run the
//! router + continuous batcher over a synthetic request trace, report
//! latency/throughput. This is the "deployed W4A8 model" path of the paper.

use super::ctx::Ctx;
use crate::coordinator::{
    run_ptq, serve_requests, synthetic_requests, BatchConfig, ServerConfig,
};
use crate::quant::Precision;
use crate::util::cli::Args;
use anyhow::Result;
use std::sync::Arc;

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let model_name = args.str_or("model", "A");
    let method_name = args.str_or("method", "aser");
    let n_requests = args.usize_or("requests", 24)?;
    let prompt_len = args.usize_or("prompt-len", 16)?;
    let max_new = args.usize_or("max-new", 24)?;
    let workers = args.usize_or("workers", 2)?;
    let max_batch = args.usize_or("batch", 8)?;
    let default_cfg = BatchConfig::default();
    // Chunked-prefill scheduling knobs: per-sequence prompt chunk width,
    // per-iteration ragged-batch row budget, and the decode headroom the
    // right-sized KV lease reserves at admission.
    let prefill_chunk = args.usize_or("chunk", default_cfg.prefill_chunk)?;
    let token_budget = args.usize_or("token-budget", default_cfg.token_budget)?;
    let kv_reserve = args.usize_or("kv-reserve", default_cfg.kv_reserve)?;

    let model = ctx.model(&model_name)?;
    let model = if method_name == "fp16" {
        model
    } else {
        let prec = Precision::parse(&args.str_or("prec", "w4a8"))?;
        let method = ctx.method(args)?;
        let stats = ctx.calib(&model, &args.str_or("profile", "wiki"))?;
        let (qmodel, report) = run_ptq(model, &stats, method.as_ref(), prec, 0)?;
        println!(
            "[quantize] {} @ {prec}: mean rel err {:.5}",
            report.method,
            report.mean_rel_error()
        );
        qmodel
    };

    let requests =
        synthetic_requests(model.cfg.vocab_size, n_requests, prompt_len, max_new, ctx.seed)?;
    let cfg = ServerConfig {
        workers,
        batch: BatchConfig {
            max_batch,
            prefill_chunk,
            token_budget,
            kv_reserve,
            ..Default::default()
        },
        kv_tokens: args.usize_or("kv-tokens", 1 << 15)?,
    };
    let run = serve_requests(Arc::new(model), &cfg, requests);

    println!(
        "== serve: {n_requests} requests, {workers} workers, batch {max_batch}, \
         chunk {prefill_chunk}, budget {token_budget} =="
    );
    println!("  completed      {}", run.responses.len());
    println!("  wall           {:.2}s", run.wall.as_secs_f64());
    println!("  throughput     {:.1} tok/s (decode)", run.throughput_tok_s());
    println!("  prefill        {:.1} tok/s", run.prefill_tok_s());
    println!(
        "  latency p50/p95  {:.0} / {:.0} ms",
        run.latency_percentile_ms(50.0),
        run.latency_percentile_ms(95.0)
    );
    println!(
        "  ttft p50/p95     {:.0} / {:.0} ms",
        run.ttft_percentile_ms(50.0),
        run.ttft_percentile_ms(95.0)
    );
    for (i, m) in run.per_worker.iter().enumerate() {
        println!(
            "  worker{i}: {} reqs, {} decode toks, {} iters, peak batch {}, peak rows {}, \
             kv-rejects {}, refused {}, kv-grows {}, truncated {}",
            m.requests,
            m.generated_tokens,
            m.iterations,
            m.peak_batch,
            m.peak_iter_tokens,
            m.rejected_capacity,
            m.rejected_impossible,
            m.kv_grows,
            m.truncated_kv
        );
    }
    Ok(())
}
