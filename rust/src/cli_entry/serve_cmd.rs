//! `repro serve` — the serving demo: quantize a model, run the streaming
//! [`Engine`] over a synthetic request trace, report latency/throughput.
//! This is the "deployed W4A8 model" path of the paper.
//!
//! Sampling is per request: `--temperature/--top-k/--top-p/--seed` set the
//! decoding policy applied to the trace (temperature 0 = the default greedy
//! path), and `--stream` switches from the blocking `serve_requests`
//! compat path to live per-token printing through `poll_streams`.
//!
//! `--listen <addr:port>` replaces the synthetic trace with the network
//! front end ([`crate::coordinator::server::HttpServer`]): an OpenAI-style
//! `POST /v1/completions` (stream + non-stream), `GET /v1/models`, and
//! `GET /healthz` over the same engine. The request body is JSON:
//! `prompt` (string, tokenized with the model vocab, or an array of token
//! ids), `max_tokens`, `temperature`, `top_k`, `top_p`, `seed`, `stream`,
//! `stop` (word / id array), `deadline_ms`, `ttft_deadline_ms`. The server
//! runs until `POST /admin/shutdown` (the SIGTERM-equivalent; std offers no
//! signal API), then drains via `Engine::shutdown_mode`.

use super::ctx::Ctx;
use crate::coordinator::{
    poll_streams, run_ptq, serve_requests, synthetic_requests, BatchConfig, BatchMetrics,
    Engine, EngineConfig, FinishReason, HttpServer, HttpServerConfig, RequestHandle, Response,
    ServerRun, Shutdown, SubmitError, TokenEvent,
};
use crate::data::Vocab;
use crate::methods::{method_by_name, RankPolicy};
use crate::model::{DraftModel, DraftSpec, Gpt, KvDtype, SamplingParams};
use crate::quant::Precision;
use crate::util::cli::Args;
use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Drain all handles through [`poll_streams`], printing each event as it
/// lands — interleaved generation is visible live instead of buffered
/// behind a blocking per-request wait.
fn drain_streaming(handles: Vec<RequestHandle>) -> Vec<Response> {
    #[derive(Default)]
    struct Acc {
        tokens: Vec<u32>,
        ttft: Duration,
        total: Duration,
        finish: Option<FinishReason>,
    }
    let mut acc: Vec<Acc> = handles.iter().map(|_| Acc::default()).collect();
    poll_streams(&handles, |i, ev| {
        let a = &mut acc[i];
        let id = handles[i].id();
        match ev {
            Some(TokenEvent::PrefillDone { ttft }) => {
                a.ttft = ttft;
                println!(
                    "[stream] req {id:>3}: prefill done ({:.0} ms)",
                    ttft.as_secs_f64() * 1e3
                );
            }
            Some(TokenEvent::Token { token, index }) => {
                a.tokens.push(token);
                println!("[stream] req {id:>3}: token[{index}] = {token}");
            }
            Some(TokenEvent::Finished { reason, n_tokens, ttft, total }) => {
                a.ttft = ttft;
                a.total = total;
                a.finish = Some(reason);
                println!(
                    "[stream] req {id:>3}: finished {reason:?} ({n_tokens} tokens, {:.0} ms)",
                    total.as_secs_f64() * 1e3
                );
            }
            None => {
                // Worker gone without a terminal event.
                a.total = handles[i].elapsed();
                a.finish = Some(FinishReason::WorkerFailed);
                println!("[stream] req {id:>3}: stream closed (worker gone)");
            }
        }
    });
    handles
        .iter()
        .zip(acc)
        .map(|(h, a)| Response {
            id: h.id(),
            prompt_len: h.prompt_len(),
            tokens: a.tokens,
            ttft: a.ttft,
            total: a.total,
            finish: a.finish.expect("stream drained"),
        })
        .collect()
}

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let model_name = args.str_or("model", "A");
    let method_name = args.str_or("method", "aser");
    let n_requests = args.usize_or("requests", 24)?;
    let prompt_len = args.usize_or("prompt-len", 16)?;
    let max_new = args.usize_or("max-new", 24)?;
    let workers = args.usize_or("workers", 2)?;
    let max_batch = args.usize_or("batch", 8)?;
    let default_cfg = BatchConfig::default();
    // Chunked-prefill scheduling knobs: per-sequence prompt chunk width,
    // per-iteration ragged-batch row budget, and the decode headroom the
    // right-sized KV lease reserves at admission.
    let prefill_chunk = args.usize_or("chunk", default_cfg.prefill_chunk)?;
    let token_budget = args.usize_or("token-budget", default_cfg.token_budget)?;
    let kv_reserve = args.usize_or("kv-reserve", default_cfg.kv_reserve)?;
    // Per-request decoding policy. temperature 0 (default) is the greedy
    // path; the sampling seed defaults to the global --seed so the whole
    // trace stays reproducible.
    let temperature = args.f64_or("temperature", 0.0)? as f32;
    let top_k = args.usize_or("top-k", 0)?;
    let top_p = args.f64_or("top-p", 1.0)? as f32;
    let sample_seed = args.u64_or("sample-seed", ctx.seed)?;
    let stream = args.flag("stream");
    // KV-cache precision: 32 keeps the f32 cache, 8 stores int8 codes with
    // per-(position, head) scales and runs the fused-dequant attention path.
    let kv_bits = args.usize_or("kv-bits", 32)?;
    let kv_dtype = match KvDtype::from_bits(kv_bits) {
        Some(d) => d,
        None => anyhow::bail!(
            "unsupported --kv-bits {kv_bits}: supported bit-widths are {}",
            KvDtype::SUPPORTED_BITS.map(|b| b.to_string()).join("/")
        ),
    };
    // Prefix cache: reuse whole KV pages across requests with a shared
    // prompt prefix. On by default; bitwise identical outputs either way.
    let prefix_cache = match args.str_or("prefix-cache", "on").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--prefix-cache must be on or off, got {other}"),
    };
    // Speculative decoding: `--draft self:<n>` proposes with the target's
    // own first n layers (weights shared, nothing copied); `--draft rtn`
    // proposes with an independently RTN-quantized sibling. `--spec-k` is
    // the proposals per sequence per iteration (defaults to 4 once a draft
    // is chosen). Outputs stay bitwise identical to --draft off.
    let draft_spec =
        DraftSpec::parse(&args.str_or("draft", "off")).map_err(anyhow::Error::msg)?;
    let spec_k =
        args.usize_or("spec-k", if draft_spec == DraftSpec::Off { 0 } else { 4 })?;
    if spec_k > 0 && draft_spec == DraftSpec::Off {
        anyhow::bail!("--spec-k {spec_k} needs a proposer: pass --draft self:<n> or --draft rtn");
    }
    if spec_k == 0 && draft_spec != DraftSpec::Off {
        anyhow::bail!("--draft {draft_spec} does nothing with --spec-k 0; drop one of the two");
    }
    // Resilience knobs: a per-request end-to-end deadline (0 = none), a
    // bounded per-worker submit queue (0 = unbounded; overflow sheds with
    // QueueFull instead of queueing forever), and the shutdown policy for
    // the streaming path (drain finishes in-flight work, abort cancels it).
    let deadline_ms = args.usize_or("deadline-ms", 0)?;
    let queue_cap = args.usize_or("queue-cap", 0)?;
    let shutdown_mode = match args.str_or("shutdown", "drain").as_str() {
        "drain" => Shutdown::Drain,
        "abort" => Shutdown::Abort,
        other => anyhow::bail!("--shutdown must be drain or abort, got {other}"),
    };
    let shutdown_timeout_ms = args.usize_or("shutdown-timeout-ms", 0)?;

    let model = ctx.model(&model_name)?;
    let model = if method_name == "fp16" {
        model
    } else {
        let prec = Precision::parse(&args.str_or("prec", "w4a8"))?;
        let method = ctx.method(args)?;
        let stats = ctx.calib(&model, &args.str_or("profile", "wiki"))?;
        let (qmodel, report) = run_ptq(model, &stats, method.as_ref(), prec, 0)?;
        println!(
            "[quantize] {} @ {prec}: mean rel err {:.5}",
            report.method,
            report.mean_rel_error()
        );
        qmodel
    };

    let model = Arc::new(model);
    let draft = match &draft_spec {
        DraftSpec::Off => None,
        DraftSpec::SelfLayers(n) => {
            Some(DraftModel::self_draft(Arc::clone(&model), *n).map_err(anyhow::Error::msg)?)
        }
        DraftSpec::Rtn => {
            // Quantize the same base model with plain RTN at the serving
            // precision — the cheap sibling the paper's methods improve on,
            // recycled here as a proposer (acceptance checks keep outputs
            // exact regardless of its quality).
            let base = ctx.model(&model_name)?;
            let prec = Precision::parse(&args.str_or("prec", "w4a8"))?;
            let stats = ctx.calib(&base, &args.str_or("profile", "wiki"))?;
            let method = method_by_name("rtn", RankPolicy::Fixed(0), 0)?;
            let (dmodel, report) = run_ptq(base, &stats, method.as_ref(), prec, 0)?;
            println!("[draft] rtn @ {prec}: mean rel err {:.5}", report.mean_rel_error());
            Some(
                DraftModel::independent(Arc::new(dmodel), &model.cfg, "rtn")
                    .map_err(anyhow::Error::msg)?,
            )
        }
    };
    let cfg = EngineConfig {
        workers,
        batch: BatchConfig {
            max_batch,
            prefill_chunk,
            token_budget,
            kv_reserve,
            kv_dtype,
            prefix_cache,
            spec_k,
            ..Default::default()
        },
        kv_tokens: args.usize_or("kv-tokens", 1 << 15)?,
        draft,
        queue_cap,
        faults: None,
    };

    // `--listen` switches from the synthetic trace to the network front
    // end: same model, same engine configuration, real clients.
    if let Some(listen) = args.get("listen").map(|s| s.to_string()) {
        return run_listen(
            &listen,
            model,
            cfg,
            &format!("{model_name}-{method_name}"),
            args,
            shutdown_mode,
        );
    }

    let mut requests =
        synthetic_requests(model.cfg.vocab_size, n_requests, prompt_len, max_new, ctx.seed)?;
    for req in requests.iter_mut() {
        req.sampling = SamplingParams {
            temperature,
            top_k,
            top_p,
            // Independent per-request streams, reproducible from one seed.
            seed: sample_seed.wrapping_add(req.id),
            stop_tokens: Vec::new(),
        };
        if deadline_ms > 0 {
            req.deadline = Some(Duration::from_millis(deadline_ms as u64));
        }
    }

    let mut shed_at_submit = 0usize;
    let run = if stream {
        let t0 = Instant::now();
        let engine = Engine::new(model, cfg);
        // Under a bounded queue, block briefly for a slot; a request that
        // still cannot get in is shed (it never gets a stream) — exactly
        // the behavior a front end would surface as HTTP 429.
        let mut handles: Vec<RequestHandle> = Vec::new();
        for req in requests {
            match engine.submit_wait(req, Duration::from_millis(50)) {
                Ok(h) => handles.push(h),
                Err(SubmitError::QueueFull(_)) => shed_at_submit += 1,
                Err(SubmitError::Closed(_)) => anyhow::bail!("engine closed during submit"),
            }
        }
        let responses = drain_streaming(handles);
        let timeout = (shutdown_timeout_ms > 0)
            .then(|| Duration::from_millis(shutdown_timeout_ms as u64));
        let per_worker = engine.shutdown_mode(shutdown_mode, timeout);
        ServerRun { responses, per_worker, wall: t0.elapsed() }
    } else {
        // The blocking path IS the compat wrapper — one implementation.
        serve_requests(model, &cfg, requests)
    };

    println!(
        "== serve: {n_requests} requests, {workers} workers, batch {max_batch}, \
         chunk {prefill_chunk}, budget {token_budget}, temperature {temperature}, \
         kv {kv_dtype}, prefix-cache {}, draft {draft_spec} (k={spec_k}) ==",
        if prefix_cache { "on" } else { "off" }
    );
    println!("  completed      {}", run.responses.len());
    println!("  wall           {:.2}s", run.wall.as_secs_f64());
    println!("  throughput     {:.1} tok/s (decode)", run.throughput_tok_s());
    println!("  prefill        {:.1} tok/s", run.prefill_tok_s());
    println!(
        "  latency p50/p95  {:.0} / {:.0} ms",
        run.latency_percentile_ms(50.0),
        run.latency_percentile_ms(95.0)
    );
    println!(
        "  ttft p50/p95     {:.0} / {:.0} ms",
        run.ttft_percentile_ms(50.0),
        run.ttft_percentile_ms(95.0)
    );
    println!(
        "  prefix cache   {} hits, {} tokens reused, hit-rate {:.1}%",
        run.prefix_hits(),
        run.prefix_hit_tokens(),
        run.prefix_hit_rate() * 100.0
    );
    println!("  peak kv        {} tokens (leased + cached, max worker)", run.peak_kv_tokens());
    let (drafted, accepted) = run
        .per_worker
        .iter()
        .fold((0usize, 0usize), |(d, a), m| (d + m.spec_drafted, a + m.spec_accepted));
    if drafted > 0 {
        println!(
            "  speculation    {accepted}/{drafted} drafted tokens accepted ({:.1}%)",
            100.0 * accepted as f64 / drafted as f64
        );
    }
    if shed_at_submit > 0 {
        println!("  shed           {shed_at_submit} requests (queue full at submit)");
    }
    for (i, m) in run.per_worker.iter().enumerate() {
        print!("{}", worker_summary(i, m));
    }
    Ok(())
}

/// `repro serve --listen <addr:port>`: put the HTTP front end over the
/// engine and run until a client posts `/admin/shutdown`. `--deadline-ms`
/// becomes the default per-request deadline, `--shutdown-timeout-ms` the
/// connection-drain grace (and engine drain timeout), `--http-threads` /
/// `--http-backlog` size the connection pool.
fn run_listen(
    listen: &str,
    model: Arc<Gpt>,
    cfg: EngineConfig,
    model_id: &str,
    args: &Args,
    shutdown_mode: Shutdown,
) -> Result<()> {
    let deadline_ms = args.usize_or("deadline-ms", 0)?;
    let shutdown_timeout_ms = args.usize_or("shutdown-timeout-ms", 0)?;
    let vocab = Arc::new(Vocab::new(model.cfg.vocab_size));
    let http_cfg = HttpServerConfig {
        threads: args.usize_or("http-threads", 4)?,
        backlog: args.usize_or("http-backlog", 64)?,
        model_id: model_id.to_string(),
        default_deadline: (deadline_ms > 0)
            .then(|| Duration::from_millis(deadline_ms as u64)),
        ..Default::default()
    };
    let engine = Arc::new(Engine::new(model, cfg));
    let server = HttpServer::bind(listen, Arc::clone(&engine), vocab, http_cfg)
        .map_err(|e| anyhow::anyhow!("cannot bind {listen}: {e}"))?;
    // The server holds its own engine handle; dropping ours keeps the
    // post-shutdown `Arc::try_unwrap` below viable.
    drop(engine);
    println!("[http] listening on {}", server.local_addr());
    println!(
        "[http] routes: POST /v1/completions (stream + non-stream) | GET /v1/models | \
         GET /healthz | POST /admin/shutdown"
    );
    while !server.shutdown_requested() {
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("[http] shutdown requested; draining connections then engine");
    let grace = Duration::from_millis(if shutdown_timeout_ms > 0 {
        shutdown_timeout_ms as u64
    } else {
        5_000
    });
    let engine = server.shutdown(grace);
    let engine = Arc::try_unwrap(engine)
        .map_err(|_| anyhow::anyhow!("engine still shared after server shutdown"))?;
    let timeout = (shutdown_timeout_ms > 0)
        .then(|| Duration::from_millis(shutdown_timeout_ms as u64));
    let per_worker = engine.shutdown_mode(shutdown_mode, timeout);
    for (i, m) in per_worker.iter().enumerate() {
        print!("{}", worker_summary(i, m));
    }
    Ok(())
}

/// One worker's metrics block for the serve summary. Every [`BatchMetrics`]
/// counter appears here exactly once — `worker_summary_surfaces_every_counter`
/// builds the metrics with an exhaustive struct literal, so adding a counter
/// without surfacing it fails the build, and dropping or double-printing one
/// fails the test.
fn worker_summary(i: usize, m: &BatchMetrics) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "  worker{i}: {} reqs, {} decode toks, {} prefill toks, {} iters, peak batch {}, \
         peak rows {}, kv-rejects {}, kv-grows {}, peak kv {}, prefix hits {} ({} toks)",
        m.requests,
        m.generated_tokens,
        m.prefill_tokens,
        m.iterations,
        m.peak_batch,
        m.peak_iter_tokens,
        m.rejected_capacity,
        m.kv_grows,
        m.peak_tokens,
        m.prefix_hits,
        m.prefix_hit_tokens,
    );
    let _ = writeln!(
        s,
        "           finish: eos {}, length {}, truncated-kv {}, cancelled {}, rejected {}",
        m.finished_eos, m.finished_length, m.truncated_kv, m.cancelled, m.rejected_impossible
    );
    let _ = writeln!(
        s,
        "           spec: drafted {}, accepted {}, rejected {}",
        m.spec_drafted, m.spec_accepted, m.spec_rejected
    );
    let _ = writeln!(
        s,
        "           resilience: deadline-expired {}, worker-failed {}, shed-queue-full {}",
        m.deadline_expired, m.worker_failed, m.shed_queue_full
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite guard: every `BatchMetrics` counter shows up in the serve
    /// summary exactly once. The struct literal is deliberately exhaustive
    /// (no `..Default::default()`): a new counter fails compilation here
    /// until it is both given a sentinel and printed by `worker_summary`.
    #[test]
    fn worker_summary_surfaces_every_counter() {
        let m = BatchMetrics {
            requests: 3101,
            generated_tokens: 3203,
            prefill_tokens: 3307,
            iterations: 3409,
            peak_batch: 3511,
            peak_iter_tokens: 3613,
            rejected_capacity: 3719,
            rejected_impossible: 3821,
            kv_grows: 3923,
            truncated_kv: 4027,
            cancelled: 4129,
            finished_eos: 4231,
            finished_length: 4337,
            peak_tokens: 4439,
            prefix_hits: 4541,
            prefix_hit_tokens: 4643,
            spec_drafted: 4745,
            spec_accepted: 4847,
            spec_rejected: 4951,
            deadline_expired: 5051,
            worker_failed: 5153,
            shed_queue_full: 5257,
        };
        let s = worker_summary(7, &m);
        // Distinct 4-digit sentinels, always delimited by non-digits in the
        // output, so a plain substring count is collision-free.
        for v in [
            3101, 3203, 3307, 3409, 3511, 3613, 3719, 3821, 3923, 4027, 4129, 4231, 4337,
            4439, 4541, 4643, 4745, 4847, 4951, 5051, 5153, 5257,
        ] {
            let needle = v.to_string();
            let n = s.matches(&needle).count();
            assert_eq!(n, 1, "counter value {v} appears {n} times in summary:\n{s}");
        }
        assert!(s.contains("worker7"));
    }
}
