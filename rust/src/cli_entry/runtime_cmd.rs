//! `repro runtime-check` — proves the AOT bridge: load every HLO artifact
//! through PJRT, execute the fused qlinear kernel with real ASER factors,
//! and cross-check numerics against the rust hot path.

use super::ctx::Ctx;
use crate::methods::{aser::Aser, PtqMethod, RankPolicy};
use crate::quant::{pack_int4, Precision};
use crate::runtime::{qlinear_reference, Manifest, Runtime};
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let hlo_dir = ctx.artifacts.join("hlo");
    let manifest = Manifest::load(&hlo_dir)
        .context("no artifacts/hlo/manifest.json — run `make artifacts` first")?;
    let mut rt = Runtime::new(&hlo_dir)?;
    println!("PJRT platform: {}", rt.platform());

    let mut checked = 0;
    for art in &manifest.qlinear {
        // Build genuine ASER factors for this shape from synthetic calib.
        let mut rng = Pcg64::new(ctx.seed, crate::util::rng::hash_label(&art.file));
        let w = Matrix::randn(&mut rng, art.d_out, art.d_in, 0.05);
        let mut xc = Matrix::randn(&mut rng, 256, art.d_in, 1.0);
        for r in 0..xc.rows {
            xc[(r, 1)] *= 20.0;
        }
        let calib = crate::methods::LayerCalib::from_sample(xc);
        let aser = Aser {
            rank: RankPolicy::Fixed(art.rank),
            outlier_f: 8,
            smooth: true,
            ..Default::default()
        };
        let q = aser.quantize_layer(&w, &calib, Precision::new(4, art.abits as u8));
        let (la, lb) = q.low_rank.clone().expect("aser has factors");
        // Pad/trim rank to the artifact's compiled rank.
        let (la, lb) = fit_rank(&la, &lb, art.rank);
        let m = q.act_smooth.clone().unwrap_or_else(|| vec![1.0; art.d_in]);
        let packed = pack_int4(&q.weight.codes);
        let x = Matrix::randn(&mut rng, art.t, art.d_in, 1.0);

        let t0 = std::time::Instant::now();
        let y = rt.run_qlinear(art, &x, &m, &packed, &q.weight.scales, &la, &lb)?;
        let compile_run_ms = t0.elapsed().as_secs_f64() * 1e3;
        let want = qlinear_reference(
            &x,
            &m,
            &q.weight.codes,
            art.d_out,
            &q.weight.scales,
            &la,
            &lb,
            art.abits as u8,
        );
        let rel = y.sub(&want).frob_norm() / want.frob_norm().max(1e-12);
        println!(
            "  {:<38} t{}×{}→{} r{}  rel_diff {:.2e}  {:.0}ms",
            art.file, art.t, art.d_in, art.d_out, art.rank, rel, compile_run_ms
        );
        anyhow::ensure!(rel < 1e-3, "{}: PJRT output diverges (rel {rel})", art.file);
        checked += 1;
    }
    for (file, cfg) in &manifest.block_fwd {
        let t0 = std::time::Instant::now();
        rt.load(file)?;
        println!("  {:<38} (block fwd, {cfg}) compiled in {:.0}ms", file, t0.elapsed().as_secs_f64() * 1e3);
        checked += 1;
    }
    println!("runtime-check OK: {checked} artifacts, {} executables cached", rt.loaded());
    Ok(())
}

/// Pad or truncate (L_A, L_B) to exactly rank r (zero-padding is exact:
/// extra components contribute 0).
fn fit_rank(la: &Matrix, lb: &Matrix, r: usize) -> (Matrix, Matrix) {
    let cur = lb.rows;
    if cur == r {
        return (la.clone(), lb.clone());
    }
    let mut la2 = Matrix::zeros(la.rows, r);
    let mut lb2 = Matrix::zeros(r, lb.cols);
    let k = cur.min(r);
    for i in 0..la.rows {
        for j in 0..k {
            la2[(i, j)] = la[(i, j)];
        }
    }
    for i in 0..k {
        lb2.row_mut(i).copy_from_slice(lb.row(i));
    }
    (la2, lb2)
}
