//! `repro eval` — perplexity + zero-shot accuracy for one
//! (model, method, precision) combination, or `--method fp16` baseline.

use super::ctx::Ctx;
use super::harness::{evaluate_model, EvalSpec};
use crate::coordinator::run_ptq;
use crate::quant::Precision;
use crate::util::cli::Args;
use anyhow::Result;

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let model_name = args.str_or("model", "A");
    let method_name = args.str_or("method", "fp16");
    let model = ctx.model(&model_name)?;

    let mut spec = if ctx.fast { EvalSpec::fast(ctx.seed) } else { EvalSpec::standard(ctx.seed) };
    spec.ppl_tokens = args.usize_or("ppl-tokens", spec.ppl_tokens)?;
    spec.task_instances = args.usize_or("task-instances", spec.task_instances)?;

    let t0 = std::time::Instant::now();
    let (label, result) = if method_name == "fp16" {
        ("fp16".to_string(), evaluate_model(&model, &spec)?)
    } else {
        let prec = Precision::parse(&args.str_or("prec", "w4a8"))?;
        let method = ctx.method(args)?;
        let stats = ctx.calib(&model, &args.str_or("profile", "wiki"))?;
        let (qmodel, report) = run_ptq(model, &stats, method.as_ref(), prec, 0)?;
        println!(
            "[quantize] {} @ {prec}: mean rel err {:.5}, +{:.2}% FLOPs",
            report.method,
            report.mean_rel_error(),
            report.flops_overhead_pct()
        );
        (format!("{} @ {prec}", report.method), evaluate_model(&qmodel, &spec)?)
    };

    println!("== eval: model {model_name}, {label} ({:.1}s) ==", t0.elapsed().as_secs_f64());
    for (profile, ppl) in &result.ppl {
        println!("  ppl[{profile}] = {ppl:.3}");
    }
    for (task, acc) in &result.acc {
        println!("  acc[{task}] = {acc:.2}%");
    }
    println!("  avg acc = {:.2}%", result.avg_acc());
    Ok(())
}
