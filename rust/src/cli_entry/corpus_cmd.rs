//! `repro gen-corpus` — emit training streams for python pretraining.
//!
//! The grammar lives in rust only (single source of truth); python reads the
//! raw little-endian u32 token stream. Training streams are a mixture of the
//! three corpus profiles so a single pretrained model handles all three
//! evaluation distributions.

use crate::data::corpus;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

pub fn run(args: &Args) -> Result<()> {
    let out = args.str_or("out", "artifacts");
    let vocabs = args
        .str_or("vocabs", "512,128")
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| anyhow::anyhow!("--vocabs: {e}"))?;
    let tokens = args.usize_or("tokens", 240_000)?;
    let seed = args.u64_or("seed", 0xC0FFEE)?;

    for vocab in vocabs {
        let path = Path::new(&out).join("corpus").join(format!("train_v{vocab}.bin"));
        write_mixture(vocab, tokens, seed, &path)?;
        println!("wrote {} ({} tokens, vocab {})", path.display(), tokens, vocab);
    }
    Ok(())
}

/// Equal-parts mixture of the three profiles, interleaved at document scale.
pub fn write_mixture(vocab: usize, tokens: usize, seed: u64, path: &Path) -> Result<()> {
    let mut stream: Vec<u32> = Vec::with_capacity(tokens);
    let profiles = corpus::CorpusProfile::all();
    let per = tokens.div_ceil(profiles.len());
    let mut chunks: Vec<Vec<u32>> = Vec::new();
    for name in &profiles {
        let c = corpus(vocab, name)?;
        let mut rng = Pcg64::new(seed, crate::util::rng::hash_label(name));
        // Emit in ~1k-token documents for later shuffling.
        let mut remaining = per;
        while remaining > 0 {
            let n = remaining.min(1024);
            chunks.push(c.stream(&mut rng, n));
            remaining -= n;
        }
    }
    let mut rng = Pcg64::new(seed, 0x5EED);
    rng.shuffle(&mut chunks);
    for ch in chunks {
        stream.extend(ch);
    }
    stream.truncate(tokens);

    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for t in &stream {
        f.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_written_and_loadable() {
        let dir = std::env::temp_dir().join("aser_corpus_cmd");
        let path = dir.join("train_v128.bin");
        write_mixture(128, 5000, 1, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 5000 * 4);
        let toks: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert!(toks.iter().all(|&t| (t as usize) < 128));
        // deterministic
        let path2 = dir.join("again.bin");
        write_mixture(128, 5000, 1, &path2).unwrap();
        assert_eq!(bytes, std::fs::read(&path2).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
}
