//! CLI dispatcher for the `repro` binary.

mod corpus_cmd;
pub mod ctx;
pub mod harness;
mod eval_cmd;
pub mod figure_cmd;
mod pipeline_cmd;
mod runtime_cmd;
mod serve_cmd;
pub mod table_cmd;

use crate::util::cli::Args;
use anyhow::Result;

pub const GLOBAL_FLAGS: [&str; 4] = ["help", "verbose", "fast", "stream"];

pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &GLOBAL_FLAGS)?;
    match args.cmd.as_str() {
        "gen-corpus" => corpus_cmd::run(&args),
        "calibrate" => pipeline_cmd::run_calibrate(&args),
        "quantize" => pipeline_cmd::run_quantize(&args),
        "eval" => eval_cmd::run(&args),
        "serve" => serve_cmd::run(&args),
        "bench-table" => table_cmd::run(&args),
        "figure" => figure_cmd::run(&args),
        "runtime-check" => runtime_cmd::run(&args),
        "" | "help" => {
            println!("{}", help());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try 'repro help')"),
    }
}

fn help() -> String {
    "\
repro — ASER (AAAI'25) reproduction: quantization pipeline + serving runtime

usage: repro <command> [options]

commands:
  gen-corpus     write synthetic training/eval token streams
                   --out artifacts --vocabs 512,128 --tokens 200000
  calibrate      capture per-layer calibration stats for a model
                   --model A --profile wiki --n-seqs 128 --seq-len 64
  quantize       quantize a model with a PTQ method
                   --model A --method aser --prec w4a8 --rank 64 --outlier-f 32
  eval           perplexity + zero-shot accuracy
                   --model A --method aser --prec w4a8 [--ppl-tokens N]
  serve          streaming-engine server demo over a quantized model
                   --model A --method aser --requests 32 --batch 8
                   per-request sampling: --temperature 0.8 --top-k 40
                   --top-p 0.95 (--seed doubles as the sampling seed;
                   --sample-seed overrides it); --stream prints token
                   events live as the engine generates them
                   --listen <addr:port> serves HTTP/1.1 + SSE instead of
                   the synthetic trace: POST /v1/completions (JSON body:
                   prompt = string|[token ids], max_tokens, temperature,
                   top_k, top_p, seed, stream, stop, deadline_ms,
                   ttft_deadline_ms), GET /v1/models, GET /healthz,
                   POST /admin/shutdown to drain and exit; --http-threads
                   and --http-backlog size the connection pool
  bench-table    regenerate a paper table: --id t1|t2|...|t8
  figure         regenerate a paper figure: --id f2|...|f8
  runtime-check  load + run the AOT HLO artifacts through PJRT

global flags: --verbose, --fast (smaller eval workloads), --seed N
"
    .to_string()
}
