//! Shared experiment context for CLI commands, tables and figures:
//! model loading (pretrained artifacts > synthetic fallback), calibration
//! with a disk cache, and method construction from CLI options.

use crate::calib::CalibConfig;
use crate::coordinator::{calibrate_model, CalibStats};
use crate::methods::{method_by_name, LayerCalib, PtqMethod, RankPolicy};
use crate::model::{load_or_synthetic, Gpt};
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::io::{DType, RawTensor, TensorFile};
use anyhow::Result;
use std::path::{Path, PathBuf};

pub struct Ctx {
    pub artifacts: PathBuf,
    pub seed: u64,
    pub fast: bool,
    pub verbose: bool,
}

impl Ctx {
    pub fn from_args(args: &Args) -> Result<Ctx> {
        Ok(Ctx {
            artifacts: PathBuf::from(args.str_or("artifacts", "artifacts")),
            seed: args.u64_or("seed", 0xA5E12)?,
            fast: args.flag("fast"),
            verbose: args.flag("verbose"),
        })
    }

    /// Load the model for a config name; prefers pretrained artifacts.
    pub fn model(&self, name: &str) -> Result<Gpt> {
        let (model, pretrained) = load_or_synthetic(name, &self.artifacts, self.seed)?;
        if self.verbose {
            eprintln!(
                "[ctx] model {name}: {} ({} params)",
                if pretrained { "pretrained artifacts" } else { "synthetic fallback" },
                model.cfg.total_params()
            );
        }
        Ok(model)
    }

    pub fn calib_config(&self) -> CalibConfig {
        if self.fast {
            CalibConfig { n_seqs: 16, seq_len: 48, max_sample: 192, seed: self.seed }
        } else {
            CalibConfig { n_seqs: 64, seq_len: 64, max_sample: 384, seed: self.seed }
        }
    }

    /// Calibration stats with a disk cache keyed by (model, profile, cfg).
    pub fn calib(&self, model: &Gpt, profile: &str) -> Result<CalibStats> {
        let cfg = self.calib_config();
        let cache = self.artifacts.join("calib").join(format!(
            "{}_{}_{}x{}_s{}.atns",
            model.cfg.name, profile, cfg.n_seqs, cfg.seq_len, cfg.seed
        ));
        if cache.exists() {
            if let Ok(stats) = load_calib(&cache) {
                if self.verbose {
                    eprintln!("[ctx] calib cache hit: {}", cache.display());
                }
                return Ok(stats);
            }
        }
        let t = std::time::Instant::now();
        let stats = calibrate_model(model, profile, &cfg)?;
        if self.verbose {
            eprintln!("[ctx] calibrated {} layers in {:.1}s", stats.len(), t.elapsed().as_secs_f64());
        }
        save_calib(&stats, &cache)?;
        Ok(stats)
    }

    /// Build a method from CLI options.
    pub fn method(&self, args: &Args) -> Result<Box<dyn PtqMethod>> {
        let name = args.str_or("method", "aser");
        let rank = rank_policy(args)?;
        let f = args.usize_or("outlier-f", 32)?;
        method_by_name(&name, rank, f)
    }

    pub fn reports_dir(&self) -> PathBuf {
        self.artifacts.join("reports")
    }
}

pub fn rank_policy(args: &Args) -> Result<RankPolicy> {
    if let Some(alpha) = args.get("alpha") {
        let a: f64 = alpha.parse().map_err(|_| anyhow::anyhow!("--alpha: bad number"))?;
        Ok(RankPolicy::Threshold(a))
    } else {
        Ok(RankPolicy::Fixed(args.usize_or("rank", 64)?))
    }
}

// -- calibration (de)serialization -------------------------------------------

pub fn save_calib(stats: &CalibStats, path: &Path) -> Result<()> {
    let mut tf = TensorFile::default();
    for (key, c) in stats {
        let d = c.in_features();
        tf.insert_f32(&format!("{key}/x"), vec![c.x.rows, c.x.cols], &c.x.data);
        tf.insert_f32(&format!("{key}/x_abs_mean"), vec![d], &c.x_abs_mean);
        // Store the f64 Gram as raw bytes (precision matters for Cholesky).
        let mut bytes = Vec::with_capacity(d * d * 8);
        for v in &c.gram {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        tf.tensors.insert(
            format!("{key}/gram_f64"),
            RawTensor { dims: vec![d * d * 8], dtype: DType::U8, bytes },
        );
        tf.insert_f32(&format!("{key}/tokens"), vec![1], &[c.tokens as f32]);
    }
    tf.save(path)
}

pub fn load_calib(path: &Path) -> Result<CalibStats> {
    let tf = TensorFile::load(path)?;
    let mut keys: Vec<String> = tf
        .tensors
        .keys()
        .filter_map(|k| k.strip_suffix("/x").map(|s| s.to_string()))
        .collect();
    keys.sort();
    let mut out = CalibStats::new();
    for key in keys {
        let (dims, data) = tf.get_f32(&format!("{key}/x"))?;
        let x = Matrix::from_vec(dims[0], dims[1], data);
        let (_, x_abs_mean) = tf.get_f32(&format!("{key}/x_abs_mean"))?;
        let raw = tf.get(&format!("{key}/gram_f64"))?;
        let gram: Vec<f64> = raw
            .bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect();
        let (_, tokens) = tf.get_f32(&format!("{key}/tokens"))?;
        anyhow::ensure!(gram.len() == x.cols * x.cols, "gram dims for {key}");
        out.insert(
            key,
            LayerCalib { x, gram, x_abs_mean, tokens: tokens[0] as usize },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;

    #[test]
    fn calib_cache_roundtrip() {
        let model = synthetic_model("micro", 81).unwrap();
        let cfg = CalibConfig { n_seqs: 3, seq_len: 12, max_sample: 16, seed: 2 };
        let stats = calibrate_model(&model, "wiki", &cfg).unwrap();
        let dir = std::env::temp_dir().join("aser_ctx_test");
        let path = dir.join("c.atns");
        save_calib(&stats, &path).unwrap();
        let back = load_calib(&path).unwrap();
        assert_eq!(back.len(), stats.len());
        let a = &stats["L0.qkv_proj"];
        let b = &back["L0.qkv_proj"];
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.gram, b.gram, "f64 gram exact roundtrip");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rank_policy_parsing() {
        let argv: Vec<String> = ["t", "--alpha", "0.05"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &[]).unwrap();
        match rank_policy(&args).unwrap() {
            RankPolicy::Threshold(a) => assert_eq!(a, 0.05),
            _ => panic!("expected threshold"),
        }
        let argv2: Vec<String> = ["t", "--rank", "32"].iter().map(|s| s.to_string()).collect();
        let args2 = Args::parse(&argv2, &[]).unwrap();
        match rank_policy(&args2).unwrap() {
            RankPolicy::Fixed(r) => assert_eq!(r, 32),
            _ => panic!("expected fixed"),
        }
    }
}
