//! Evaluation harness shared by `eval`, `bench-table` and the benches:
//! perplexity over the three corpus profiles + zero-shot task accuracy.

use crate::data::corpus;
use crate::eval::tasks::{evaluate as eval_tasks, generate};
use crate::eval::perplexity;
use crate::model::Gpt;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct EvalSpec {
    pub ppl_tokens: usize,
    pub ppl_window: usize,
    pub task_instances: usize,
    pub tasks: Vec<String>,
    pub profiles: Vec<String>,
    pub seed: u64,
}

impl EvalSpec {
    pub fn standard(seed: u64) -> EvalSpec {
        EvalSpec {
            ppl_tokens: 1024,
            ppl_window: 64,
            task_instances: 40,
            tasks: ["arc_e", "arc_c", "mmlu", "hella", "piqa"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            profiles: ["wiki", "c4", "ptb"].iter().map(|s| s.to_string()).collect(),
            seed,
        }
    }

    pub fn fast(seed: u64) -> EvalSpec {
        EvalSpec {
            ppl_tokens: 256,
            ppl_window: 48,
            task_instances: 12,
            ..EvalSpec::standard(seed)
        }
    }

    /// Accuracy-only spec (Tables 3/7/8 report no perplexity).
    pub fn accuracy_only(seed: u64, tasks: &[&str]) -> EvalSpec {
        EvalSpec {
            ppl_tokens: 0,
            tasks: tasks.iter().map(|s| s.to_string()).collect(),
            profiles: vec![],
            ..EvalSpec::standard(seed)
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    /// profile → perplexity
    pub ppl: BTreeMap<String, f64>,
    /// task → accuracy (%)
    pub acc: BTreeMap<String, f64>,
}

impl EvalResult {
    pub fn avg_acc(&self) -> f64 {
        if self.acc.is_empty() {
            return 0.0;
        }
        self.acc.values().sum::<f64>() / self.acc.len() as f64
    }
}

/// Run the full evaluation of one model snapshot.
pub fn evaluate_model(model: &Gpt, spec: &EvalSpec) -> Result<EvalResult> {
    let mut out = EvalResult::default();
    for profile in &spec.profiles {
        if spec.ppl_tokens == 0 {
            break;
        }
        let c = corpus(model.cfg.vocab_size, profile)?;
        // Held-out stream: a seed disjoint from training/calibration.
        let mut rng = Pcg64::new(spec.seed ^ 0xEEA1, crate::util::rng::hash_label(profile));
        let stream = c.stream(&mut rng, spec.ppl_tokens);
        out.ppl.insert(profile.clone(), perplexity(model, &stream, spec.ppl_window));
    }
    let c = corpus(model.cfg.vocab_size, "wiki")?;
    for task in &spec.tasks {
        let set = generate(&c, task, spec.task_instances, spec.seed ^ 0x7A5C)?;
        out.acc.insert(task.clone(), eval_tasks(model, &set));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;

    #[test]
    fn harness_runs_end_to_end() {
        let model = synthetic_model("micro", 91).unwrap();
        let mut spec = EvalSpec::fast(1);
        spec.ppl_tokens = 128;
        spec.task_instances = 6;
        spec.tasks = vec!["arc_e".into(), "piqa".into()];
        let r = evaluate_model(&model, &spec).unwrap();
        assert_eq!(r.ppl.len(), 3);
        assert!(r.ppl.values().all(|&p| p > 1.0 && p.is_finite()));
        assert_eq!(r.acc.len(), 2);
        assert!(r.avg_acc() >= 0.0 && r.avg_acc() <= 100.0);
    }

    #[test]
    fn accuracy_only_skips_ppl() {
        let model = synthetic_model("micro", 92).unwrap();
        let mut spec = EvalSpec::accuracy_only(1, &["arc_e"]);
        spec.task_instances = 5;
        let r = evaluate_model(&model, &spec).unwrap();
        assert!(r.ppl.is_empty());
        assert_eq!(r.acc.len(), 1);
    }
}
