//! Low-rank error-compensation baselines: LoRC and L²QER.
//!
//! Both quantize with RTN and then append LoRA-style factors approximating
//! the weight quantization error `E_q = W − Q(W)`:
//! - **LoRC** (Yao et al. 2024): plain `SVD(E_q)` — activation-agnostic.
//! - **L²QER** (Zhang et al. 2024): `SVD(E_q · D)` with the empirical
//!   diagonal `D = diag(X̄)`, compensation `U_rΣ_r · V_rᵀD⁻¹` — activation-
//!   scaled but not whitened. ASER replaces `D` with the Cholesky whitener
//!   `S`, which is the paper's core claim.

use super::{LayerCalib, PtqMethod, QuantizedLinear, RankPolicy};
use crate::linalg::svd_gram as svd;
use crate::quant::{Precision, QuantizedWeight};
use crate::tensor::Matrix;

/// LoRC: rank-r SVD of the raw weight error.
pub struct Lorc {
    pub rank: RankPolicy,
}

impl PtqMethod for Lorc {
    fn name(&self) -> String {
        "lorc".into()
    }

    fn quantize_layer(&self, w: &Matrix, _calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let qw = QuantizedWeight::quantize(w, prec.wbits);
        let e_q = w.sub(&qw.dequantize());
        let f = svd(&e_q);
        let r = self.rank.pick(&f.s).max(1);
        let la = f.factor_a(r);
        let lb = f.factor_vt(r);
        QuantizedLinear {
            weight: qw,
            act_smooth: None,
            low_rank: Some((la, lb)),
            fp_cols: Vec::new(),
            abits: prec.abits,
            method: self.name(),
        }
    }
}

/// L²QER: rank-r SVD of the activation-scaled weight error.
pub struct L2Qer {
    pub rank: RankPolicy,
}

impl PtqMethod for L2Qer {
    fn name(&self) -> String {
        "l2qer".into()
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let qw = QuantizedWeight::quantize(w, prec.wbits);
        let e_q = w.sub(&qw.dequantize());
        // D = diag(X̄) with an epsilon floor so D⁻¹ stays bounded.
        let eps = 1e-4f32;
        let d: Vec<f32> = calib.x_abs_mean.iter().map(|&x| x.max(eps)).collect();
        let scaled = e_q.scale_cols(&d);
        let f = svd(&scaled);
        let r = self.rank.pick(&f.s).max(1);
        let la = f.factor_a(r);
        let d_inv: Vec<f32> = d.iter().map(|&x| 1.0 / x).collect();
        let lb = f.factor_vt(r).scale_cols(&d_inv);
        QuantizedLinear {
            weight: qw,
            act_smooth: None,
            low_rank: Some((la, lb)),
            fp_cols: Vec::new(),
            abits: prec.abits,
            method: self.name(),
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::methods::{layer_error, rtn::Rtn};
    use crate::util::rng::Pcg64;

    /// Anisotropic calibration: a few hot channels (where error matters) —
    /// the setting that separates the three compensation schemes.
    pub(crate) fn aniso_setup(seed: u64, d: usize) -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(seed);
        let w = Matrix::randn(&mut rng, d, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 4 * d, d, 1.0);
        for c in 0..d {
            // log-uniform channel scales + a few hard outliers
            let s = 10f32.powf(rng.range_f32(-1.0, 0.5));
            for r in 0..x.rows {
                x[(r, c)] *= s;
            }
        }
        for &c in &[1usize, d / 2, d - 3] {
            for r in 0..x.rows {
                x[(r, c)] *= 20.0;
            }
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn lorc_beats_rtn() {
        let (w, calib) = aniso_setup(111, 40);
        let prec = Precision::w4a8();
        let q = Lorc { rank: RankPolicy::Fixed(8) }.quantize_layer(&w, &calib, prec);
        let e_lorc = layer_error(&w, &q, &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_lorc < e_rtn, "lorc {e_lorc} !< rtn {e_rtn}");
        assert_eq!(q.rank(), 8);
    }

    #[test]
    fn l2qer_beats_lorc_on_anisotropic_acts() {
        let (w, calib) = aniso_setup(112, 48);
        let prec = Precision::w4a8();
        let rank = RankPolicy::Fixed(8);
        let e_lorc =
            layer_error(&w, &Lorc { rank }.quantize_layer(&w, &calib, prec), &calib.x);
        let e_l2 =
            layer_error(&w, &L2Qer { rank }.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_l2 < e_lorc, "l2qer {e_l2} !< lorc {e_lorc}");
    }

    #[test]
    fn full_rank_lorc_recovers_weight_error_exactly() {
        let (w, calib) = aniso_setup(113, 16);
        // A16 so the only error is weight error; full rank ⇒ exact recovery.
        let prec = Precision::w4a16();
        let q = Lorc { rank: RankPolicy::Fixed(16) }.quantize_layer(&w, &calib, prec);
        let e = layer_error(&w, &q, &calib.x);
        let y_scale = crate::tensor::matmul_bt(&calib.x, &w).frob_norm();
        assert!(e / y_scale < 1e-4, "rel={}", e / y_scale);
    }

    #[test]
    fn extra_params_accounting() {
        let (w, calib) = aniso_setup(114, 24);
        let q = Lorc { rank: RankPolicy::Fixed(6) }.quantize_layer(&w, &calib, Precision::w4a8());
        assert_eq!(q.extra_params(), 6 * 24 + 6 * 24);
        assert_eq!(q.extra_flops_per_token(), 2 * 6 * (24 + 24));
    }

    #[test]
    fn threshold_policy_monotone_in_alpha() {
        let (w, calib) = aniso_setup(115, 32);
        let prec = Precision::w4a8();
        let r_small = Lorc { rank: RankPolicy::Threshold(0.05) }
            .quantize_layer(&w, &calib, prec)
            .rank();
        let r_big = Lorc { rank: RankPolicy::Threshold(0.5) }
            .quantize_layer(&w, &calib, prec)
            .rank();
        assert!(r_small <= r_big, "{r_small} > {r_big}");
    }
}
