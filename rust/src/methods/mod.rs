//! Post-training quantization methods.
//!
//! Every method implements [`PtqMethod`]: given a layer weight `W`
//! (out×in), calibration statistics and a target [`Precision`], produce a
//! [`QuantizedLinear`] — quantized weight + optional activation scaling
//! (smoothing) + optional LoRA-style low-rank compensation + optional
//! full-precision outlier columns.
//!
//! Implemented methods (paper baselines + contribution):
//! - [`rtn::Rtn`] — plain round-to-nearest per-channel.
//! - [`llm_int::LlmInt`] — LLM.int8()-style mixed-precision decomposition
//!   ("LLM.int4()" in the tables): fp outlier channels, int the rest.
//! - [`smoothquant::SmoothQuant`] — diag smoothing with α-blend of X̄/W̄.
//! - [`smoothquant::SmoothQuantPlus`] — per-layer α grid search variant.
//! - [`awq::Awq`] — activation-aware weight-only scaling (grid search).
//! - [`gptq::Gptq`] — Hessian-based sequential quantization (OBQ closed form).
//! - [`lowrank::Lorc`] — plain SVD low-rank correction of the weight error.
//! - [`lowrank::L2Qer`] — activation-scaled SVD correction (diagonal X̄).
//! - [`aser::Aser`] — the paper: whitening SVD error reconstruction
//!   (± activation smoothing with outlier extraction).

pub mod aser;
pub mod awq;
pub mod gptq;
pub mod llm_int;
pub mod lowrank;
pub mod rtn;
pub mod smoothquant;

use crate::quant::{fake_quant_acts, Precision, QuantizedWeight, FP};
use crate::tensor::{detect_kernel, matmul, matmul_bt, Matrix, PackedQWeight, QKernelKind};

/// Calibration statistics for one linear layer, captured by `calib`.
#[derive(Clone, Debug)]
pub struct LayerCalib {
    /// Subsample of input activations, tokens × in_features.
    pub x: Matrix,
    /// f64 Gram over channels: XᵀX / tokens (in×in), accumulated over the
    /// full calibration stream (not just the subsample).
    pub gram: Vec<f64>,
    /// Per-channel mean |x| over the full stream (the paper's X̄).
    pub x_abs_mean: Vec<f32>,
    /// Total tokens seen.
    pub tokens: usize,
}

impl LayerCalib {
    /// Build directly from a sample matrix (tests + small pipelines).
    pub fn from_sample(x: Matrix) -> LayerCalib {
        let mut gram = crate::tensor::gram_cols_f64(&x);
        let scale = 1.0 / x.rows.max(1) as f64;
        for v in &mut gram {
            *v *= scale;
        }
        let x_abs_mean = x.col_abs_mean();
        let tokens = x.rows;
        LayerCalib { x, gram, x_abs_mean, tokens }
    }

    pub fn in_features(&self) -> usize {
        self.x.cols
    }
}

/// Result of quantizing one linear layer.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    /// Quantized (possibly smoothed/split) weight, out×in.
    pub weight: QuantizedWeight,
    /// Per-input-channel divisor `m` from smoothing: the runtime computes
    /// `x' = x / m` before quantizing the activation. `None` = no smoothing.
    pub act_smooth: Option<Vec<f32>>,
    /// LoRA-style compensation `(L_A, L_B)`: out×r and r×in. The correction
    /// term is `L_A · (L_B · x')` on the *smoothed, full-precision* input —
    /// the skinny branch runs in fp just like deployed LoRA adapters.
    pub low_rank: Option<(Matrix, Matrix)>,
    /// Full-precision outlier columns kept outside the int grid
    /// (LLM.int8()-style decomposition). Stored as (col_index, column of W).
    pub fp_cols: Vec<(usize, Vec<f32>)>,
    /// Activation bits for the main GEMM input (FP = no act quant).
    pub abits: u8,
    /// Method label for reports.
    pub method: String,
}

impl QuantizedLinear {
    pub fn out_features(&self) -> usize {
        self.weight.rows
    }
    pub fn in_features(&self) -> usize {
        self.weight.cols
    }
    pub fn rank(&self) -> usize {
        self.low_rank.as_ref().map(|(_, b)| b.rows).unwrap_or(0)
    }

    /// Extra parameters introduced vs the plain quantized weight
    /// (low-rank factors + fp outlier columns), for the overhead tables.
    pub fn extra_params(&self) -> usize {
        let lr = self
            .low_rank
            .as_ref()
            .map(|(a, b)| a.rows * a.cols + b.rows * b.cols)
            .unwrap_or(0);
        lr + self.fp_cols.len() * self.weight.rows
    }

    /// Build the serve-time packed-kernel weight (tile-packed codes,
    /// smoothing reciprocals, gathered outlier columns, low-rank factors) —
    /// done once when the layer is installed into a model, consumed by
    /// `tensor::qgemm` on every batched forward.
    pub fn pack(&self) -> PackedQWeight {
        self.pack_with(detect_kernel())
    }

    /// [`QuantizedLinear::pack`] with an explicit microkernel choice — the
    /// panel interleave is a property of the kernel, so the choice is fixed
    /// here at pack time. Benches and property tests use this to pin the
    /// scalar reference kernel against the auto-detected SIMD one.
    pub fn pack_with(&self, kind: QKernelKind) -> PackedQWeight {
        PackedQWeight::pack_with_kernel(
            &self.weight.codes,
            self.weight.rows,
            self.weight.cols,
            self.weight.bits,
            self.abits,
            &self.weight.scales,
            self.act_smooth.as_deref(),
            &self.fp_cols,
            self.low_rank.as_ref().map(|(a, b)| (a, b)),
            kind,
        )
    }

    /// Extra FLOPs per token vs the plain `d_out × d_in` GEMM
    /// (2·r·(d_in+d_out) for the skinny branch + outlier columns).
    pub fn extra_flops_per_token(&self) -> usize {
        let r = self.rank();
        2 * r * (self.in_features() + self.out_features())
            + 2 * self.fp_cols.len() * self.out_features()
    }

    /// Reference forward over a batch of activations X (tokens × in):
    /// returns tokens × out. This is the semantics contract the serving hot
    /// path (`model::qlinear`) and the Pallas kernel must match.
    pub fn forward_matrix(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.in_features());
        // 1. smooth
        let xs = match &self.act_smooth {
            Some(m) => {
                let inv: Vec<f32> = m.iter().map(|&v| 1.0 / v).collect();
                x.scale_cols(&inv)
            }
            None => x.clone(),
        };
        // 2. main GEMM on quantized acts × quantized weight
        let xq = if self.abits == FP { xs.clone() } else { fake_quant_acts(&xs, self.abits) };
        let wq = self.weight.dequantize();
        let mut y = matmul_bt(&xq, &wq);
        // 3. fp outlier columns (decomposition methods): they act on the
        //    *unquantized* smoothed activation.
        for (c, wcol) in &self.fp_cols {
            for t in 0..xs.rows {
                let xv = xs[(t, *c)];
                if xv == 0.0 {
                    continue;
                }
                let yrow = y.row_mut(t);
                for (o, &wv) in yrow.iter_mut().zip(wcol) {
                    *o += xv * wv;
                }
            }
        }
        // 4. low-rank correction on the fp smoothed activation
        if let Some((la, lb)) = &self.low_rank {
            let z = matmul_bt(&xs, lb); // tokens × r
            let corr = matmul(&z, &la.transpose()); // tokens × out
            y = y.add(&corr);
        }
        y
    }
}

/// Integral layer error `‖W X − ŷ(X)‖_F` on calibration activations — the
/// paper's objective (Eq. 1) and the quantity plotted in Fig. 6.
pub fn layer_error(w: &Matrix, q: &QuantizedLinear, x: &Matrix) -> f32 {
    let y_ref = matmul_bt(x, w);
    let y_q = q.forward_matrix(x);
    y_ref.sub(&y_q).frob_norm()
}

/// Relative layer error, normalized by ‖WX‖_F.
pub fn layer_error_rel(w: &Matrix, q: &QuantizedLinear, x: &Matrix) -> f32 {
    let y_ref = matmul_bt(x, w);
    let y_q = q.forward_matrix(x);
    y_ref.sub(&y_q).frob_norm() / y_ref.frob_norm().max(1e-20)
}

/// A quantization method: layer-local, calibration-driven.
pub trait PtqMethod: Send + Sync {
    fn name(&self) -> String;
    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear;
}

/// Rank policy shared by the compensation methods (LoRC / L²QER / ASER).
#[derive(Clone, Copy, Debug)]
pub enum RankPolicy {
    /// Same rank everywhere (the paper's main-table setup: r = 64).
    Fixed(usize),
    /// Per-layer rank from the cumulative singular-value threshold α
    /// (paper Eq. 9 / Table 4).
    Threshold(f64),
}

impl RankPolicy {
    pub fn pick(&self, singular_values: &[f32]) -> usize {
        match *self {
            RankPolicy::Fixed(r) => r.min(singular_values.len()),
            RankPolicy::Threshold(alpha) => {
                crate::linalg::rank_for_threshold(singular_values, alpha)
            }
        }
    }
}

/// Construct a method by name — the CLI/benchmark registry.
pub fn method_by_name(name: &str, rank: RankPolicy, outlier_f: usize) -> anyhow::Result<Box<dyn PtqMethod>> {
    Ok(match name {
        "rtn" => Box::new(rtn::Rtn),
        "llm_int" | "llm.int4" | "llm.int8" => Box::new(llm_int::LlmInt::default()),
        "smoothquant" | "sq" => Box::new(smoothquant::SmoothQuant::default()),
        "smoothquant+" | "sqp" => Box::new(smoothquant::SmoothQuantPlus::default()),
        "awq" => Box::new(awq::Awq::default()),
        "gptq" => Box::new(gptq::Gptq::default()),
        "lorc" => Box::new(lowrank::Lorc { rank }),
        "l2qer" | "lqer" => Box::new(lowrank::L2Qer { rank }),
        "aser" => Box::new(aser::Aser { rank, outlier_f, smooth: true, ..Default::default() }),
        "aser-er" | "aser_no_as" => {
            Box::new(aser::Aser { rank, outlier_f, smooth: false, ..Default::default() })
        }
        other => anyhow::bail!("unknown method '{other}'"),
    })
}

/// All method names in table order.
pub fn table_methods() -> Vec<&'static str> {
    vec!["llm_int", "smoothquant", "smoothquant+", "lorc", "l2qer", "aser-er", "aser"]
}
