//! SmoothQuant (Xiao et al. 2023) and a grid-searched variant
//! ("SmoothQuant+" in the tables).
//!
//! Migrates activation quantization difficulty into the weights via a
//! per-channel diagonal: `W X = (W·diag(s)) (diag(s)⁻¹ X)` with
//! `s_i = X̄_i^α / W̄_i^{1-α}` (all channels — unlike ASER's outlier-only
//! smoothing, which is the comparison the paper draws).

use super::{layer_error, LayerCalib, PtqMethod, QuantizedLinear};
use crate::quant::{Precision, QuantizedWeight};
use crate::tensor::Matrix;

pub struct SmoothQuant {
    /// Migration strength α ∈ [0,1]; 0.5 is the paper default.
    pub alpha: f32,
}

impl Default for SmoothQuant {
    fn default() -> Self {
        SmoothQuant { alpha: 0.5 }
    }
}

/// Compute the SmoothQuant scaling vector s (per input channel).
pub fn smooth_scales(w: &Matrix, x_abs_mean: &[f32], alpha: f32) -> Vec<f32> {
    // W̄ per input channel = column abs mean of W (out×in).
    let w_abs_mean = w.col_abs_mean();
    let eps = 1e-5;
    x_abs_mean
        .iter()
        .zip(&w_abs_mean)
        .map(|(&xa, &wa)| {
            let s = (xa.max(eps)).powf(alpha) / (wa.max(eps)).powf(1.0 - alpha);
            s.max(1e-5)
        })
        .collect()
}

/// Quantize with a given smoothing vector: W' = W·diag(s), runtime divides
/// activations by s.
pub fn quantize_smoothed(
    w: &Matrix,
    s: &[f32],
    prec: Precision,
    method: String,
) -> QuantizedLinear {
    let w_s = w.scale_cols(s);
    QuantizedLinear {
        weight: QuantizedWeight::quantize(&w_s, prec.wbits),
        act_smooth: Some(s.to_vec()),
        low_rank: None,
        fp_cols: Vec::new(),
        abits: prec.abits,
        method,
    }
}

impl PtqMethod for SmoothQuant {
    fn name(&self) -> String {
        "smoothquant".into()
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let s = smooth_scales(w, &calib.x_abs_mean, self.alpha);
        quantize_smoothed(w, &s, prec, self.name())
    }
}

/// "SmoothQuant+": per-layer α grid search minimizing the integral layer
/// error on the calibration sample (the published + variant tunes the
/// migration per layer; we reproduce that spirit with a direct search).
pub struct SmoothQuantPlus {
    pub grid: Vec<f32>,
}

impl Default for SmoothQuantPlus {
    fn default() -> Self {
        SmoothQuantPlus { grid: vec![0.25, 0.4, 0.5, 0.6, 0.75, 0.9] }
    }
}

impl PtqMethod for SmoothQuantPlus {
    fn name(&self) -> String {
        "smoothquant+".into()
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let mut best: Option<(f32, QuantizedLinear)> = None;
        for &alpha in &self.grid {
            let s = smooth_scales(w, &calib.x_abs_mean, alpha);
            let q = quantize_smoothed(w, &s, prec, self.name());
            let e = layer_error(w, &q, &calib.x);
            if best.as_ref().map(|(be, _)| e < *be).unwrap_or(true) {
                best = Some((e, q));
            }
        }
        best.expect("non-empty grid").1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::rtn::Rtn;
    use crate::util::rng::Pcg64;

    /// Activations with outliers; weights smooth — SmoothQuant's home turf.
    fn setup() -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(81);
        let d = 64;
        let w = Matrix::randn(&mut rng, 48, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 256, d, 1.0);
        for &c in &[3usize, 30, 55] {
            for r in 0..x.rows {
                x[(r, c)] *= 30.0;
            }
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn smoothing_is_function_preserving_at_fp() {
        // With no quantization (W16A16 equivalent: wbits=8 is closest our
        // grid allows, so test the algebra directly): (W·diag(s))·(x/s) == Wx.
        let (w, calib) = setup();
        let s = smooth_scales(&w, &calib.x_abs_mean, 0.5);
        let w_s = w.scale_cols(&s);
        let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
        let x_s = calib.x.scale_cols(&inv);
        let y1 = crate::tensor::matmul_bt(&calib.x, &w);
        let y2 = crate::tensor::matmul_bt(&x_s, &w_s);
        assert!(y1.max_diff(&y2) < 1e-2 * y1.max_abs());
    }

    #[test]
    fn beats_rtn_when_acts_have_outliers() {
        let (w, calib) = setup();
        let prec = Precision::w4a6(); // low act bits: smoothing matters
        let e_sq =
            layer_error(&w, &SmoothQuant::default().quantize_layer(&w, &calib, prec), &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_sq < e_rtn, "sq {e_sq} !< rtn {e_rtn}");
    }

    #[test]
    fn plus_variant_no_worse_than_default_alpha() {
        let (w, calib) = setup();
        let prec = Precision::w4a8();
        let e_sq =
            layer_error(&w, &SmoothQuant::default().quantize_layer(&w, &calib, prec), &calib.x);
        let e_sqp =
            layer_error(&w, &SmoothQuantPlus::default().quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_sqp <= e_sq * 1.0001, "plus {e_sqp} worse than default {e_sq}");
    }

    #[test]
    fn scales_monotone_in_activation_magnitude() {
        let (w, calib) = setup();
        let s = smooth_scales(&w, &calib.x_abs_mean, 0.5);
        // Outlier channels must receive larger divisors.
        let mean_s: f32 = s.iter().sum::<f32>() / s.len() as f32;
        for &c in &[3usize, 30, 55] {
            assert!(s[c] > 2.0 * mean_s, "s[{c}]={} mean={mean_s}", s[c]);
        }
    }

    #[test]
    fn all_scales_positive_even_with_zero_channels() {
        let w = Matrix::zeros(4, 8);
        let x = Matrix::zeros(16, 8);
        let calib = LayerCalib::from_sample(x);
        let s = smooth_scales(&w, &calib.x_abs_mean, 0.5);
        assert!(s.iter().all(|&v| v > 0.0 && v.is_finite()));
    }
}
