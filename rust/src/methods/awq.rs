//! AWQ — Activation-aware Weight Quantization (Lin et al. 2024).
//!
//! Weight-only method: protects salient weight channels (those multiplying
//! large activations) by scaling them up before quantization,
//! `W' = W·diag(s)`, `s = X̄^α`, with α chosen per layer by grid search on
//! the reconstruction error, plus a per-channel clipping search on the
//! quantization range.

use super::{layer_error, LayerCalib, PtqMethod, QuantizedLinear};
use crate::quant::{BitWidth, Precision, QuantizedWeight};
use crate::tensor::Matrix;

pub struct Awq {
    /// α grid for the scale search (AWQ uses 20 points in [0,1]).
    pub grid_steps: usize,
    /// Shrink factors for the max-clip search; 1.0 = no clipping.
    pub clip_grid: Vec<f32>,
}

impl Default for Awq {
    fn default() -> Self {
        Awq { grid_steps: 10, clip_grid: vec![1.0, 0.95, 0.9, 0.85, 0.8] }
    }
}

impl Awq {
    fn quantize_scaled(
        &self,
        w: &Matrix,
        s: &[f32],
        prec: Precision,
        calib: &LayerCalib,
    ) -> QuantizedLinear {
        let w_s = w.scale_cols(s);
        // Per-row clip search: pick the shrink factor minimizing row-wise
        // weight reconstruction error against the calibration second moment.
        let qmax = BitWidth(prec.wbits).qmax();
        let mut scales = vec![0f32; w_s.rows];
        for r in 0..w_s.rows {
            let row = w_s.row(r);
            let amax = row.iter().fold(0f32, |m, x| m.max(x.abs()));
            if amax == 0.0 {
                scales[r] = 1.0;
                continue;
            }
            let mut best = (f64::INFINITY, amax / qmax);
            for &c in &self.clip_grid {
                let scale = amax * c / qmax;
                // weighted SSE with channel second moments (diag of Gram)
                let mut sse = 0f64;
                for (j, &x) in row.iter().enumerate() {
                    let q = (x / scale).round().clamp(-qmax, qmax) * scale;
                    let wgt = calib.gram[j * w.cols + j].max(1e-12);
                    let d = (x - q) as f64;
                    sse += d * d * wgt;
                }
                if sse < best.0 {
                    best = (sse, scale);
                }
            }
            scales[r] = best.1;
        }
        QuantizedLinear {
            weight: QuantizedWeight::quantize_with_scales(&w_s, prec.wbits, &scales),
            act_smooth: Some(s.to_vec()),
            low_rank: None,
            fp_cols: Vec::new(),
            abits: prec.abits,
            method: self.name(),
        }
    }
}

impl PtqMethod for Awq {
    fn name(&self) -> String {
        "awq".into()
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let eps = 1e-5f32;
        let mut best: Option<(f32, QuantizedLinear)> = None;
        for step in 0..self.grid_steps {
            let alpha = step as f32 / self.grid_steps as f32;
            let s: Vec<f32> =
                calib.x_abs_mean.iter().map(|&xa| xa.max(eps).powf(alpha).max(1e-4)).collect();
            let q = self.quantize_scaled(w, &s, prec, calib);
            let e = layer_error(w, &q, &calib.x);
            if best.as_ref().map(|(be, _)| e < *be).unwrap_or(true) {
                best = Some((e, q));
            }
        }
        best.expect("grid non-empty").1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::rtn::Rtn;
    use crate::util::rng::Pcg64;

    fn salient_setup() -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(91);
        let d = 64;
        let mut w = Matrix::randn(&mut rng, 32, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 256, d, 1.0);
        // Salient channels: large activations AND meaningful weights.
        for &c in &[10usize, 33] {
            for r in 0..x.rows {
                x[(r, c)] *= 25.0;
            }
            for r in 0..w.rows {
                w[(r, c)] *= 0.2; // small weights × big acts = classic AWQ case
            }
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn awq_beats_rtn_weight_only() {
        let (w, calib) = salient_setup();
        let prec = Precision::w4a16();
        let e_awq = layer_error(&w, &Awq::default().quantize_layer(&w, &calib, prec), &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_awq < e_rtn, "awq {e_awq} !< rtn {e_rtn}");
    }

    #[test]
    fn alpha_zero_in_grid_bounds_regression() {
        // Grid includes α=0 (identity scaling, clip only) so AWQ can never be
        // catastrophically worse than clipped RTN on any layer.
        let mut rng = Pcg64::seed(92);
        let w = Matrix::randn(&mut rng, 16, 32, 0.05);
        let x = Matrix::randn(&mut rng, 128, 32, 1.0);
        let calib = LayerCalib::from_sample(x);
        let prec = Precision::w4a16();
        let e_awq = layer_error(&w, &Awq::default().quantize_layer(&w, &calib, prec), &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_awq < e_rtn * 1.2, "awq {e_awq} vs rtn {e_rtn}");
    }

    #[test]
    fn smoothing_vector_attached() {
        let (w, calib) = salient_setup();
        let q = Awq::default().quantize_layer(&w, &calib, Precision::w4a16());
        let s = q.act_smooth.as_ref().unwrap();
        assert_eq!(s.len(), 64);
        assert!(s.iter().all(|&v| v > 0.0));
    }
}
