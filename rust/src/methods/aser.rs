//! ASER — the paper's method (Algorithm 1).
//!
//! **Error Reconstruction (ER)**: whiten the calibration activations with
//! the Cholesky factor `S` of the Gram matrix (`(S⁻¹X)(S⁻¹X)ᵀ = I`, Eq. 5),
//! run SVD on `E_q S`, and keep the top-r components. Under whitening the
//! i-th singular value *equals* its contribution to the integral loss
//! `‖(E_q − Ẽ_q)X‖_F` (Eq. 8), so truncation is loss-optimal. Factors:
//! `L_A = U_rΣ_r`, `L_B = V_rᵀS⁻¹` (Eq. 6).
//!
//! **Activation Smoothing (AS)**: rank channels by `X̄ ⊙ W̄`; for the top-f
//! outlier set `I_f`, migrate activation magnitude into the weight with
//! `m_i = X̄_i / X̄_min` (Eq. 11), split the scaled weight into `W_s + W_o`
//! (outlier columns), quantize only `W_s`, and fold `W_o` into the error
//! that ER reconstructs (Eq. 12–13). The outliers thus ride the fp low-rank
//! branch instead of polluting the int grid.

use super::{LayerCalib, PtqMethod, QuantizedLinear, RankPolicy};
use crate::linalg::{svd_gram as svd, Whitener};
use crate::quant::{Precision, QuantizedWeight};
use crate::tensor::{matmul, Matrix};

#[derive(Clone, Debug)]
pub struct Aser {
    pub rank: RankPolicy,
    /// Outlier budget f (paper default 32). 0 disables extraction even when
    /// `smooth` is set.
    pub outlier_f: usize,
    /// Enable Activation Smoothing (the "w/ A.S." rows).
    pub smooth: bool,
    /// Epsilon floor for X̄ when forming smoothing ratios.
    pub eps: f32,
}

impl Default for Aser {
    fn default() -> Self {
        Aser { rank: RankPolicy::Fixed(64), outlier_f: 32, smooth: true, eps: 1e-6 }
    }
}

/// Outcome of the smoothing analysis — exposed for figures (Fig. 4/7).
#[derive(Clone, Debug)]
pub struct SmoothingPlan {
    /// Outlier channel indices I_f (sorted ascending).
    pub outliers: Vec<usize>,
    /// Per-channel multiplier m (applied to W columns; runtime divides x).
    pub m: Vec<f32>,
}

impl Aser {
    /// Identify I_f and build M (Eq. 11).
    pub fn smoothing_plan(&self, w: &Matrix, calib: &LayerCalib) -> SmoothingPlan {
        let d = w.cols;
        let f = self.outlier_f.min(d);
        let x_bar = &calib.x_abs_mean;
        let w_bar = w.col_abs_mean();
        let mut score: Vec<(usize, f32)> =
            (0..d).map(|i| (i, x_bar[i] * w_bar[i])).collect();
        score.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut outliers: Vec<usize> =
            score[..f].iter().filter(|(_, s)| *s > 0.0).map(|(i, _)| *i).collect();
        outliers.sort_unstable();
        // X̄_min over the outlier set.
        let x_min = outliers
            .iter()
            .map(|&i| x_bar[i])
            .fold(f32::INFINITY, f32::min)
            .max(self.eps);
        let mut m = vec![1.0f32; d];
        for &i in &outliers {
            m[i] = (x_bar[i] / x_min).max(1.0);
        }
        SmoothingPlan { outliers, m }
    }

    /// ER core: build (L_A, L_B) approximating `err` against the whitener of
    /// the (possibly smoothed) activations. Returns the factors and the
    /// whitened singular values (for rank diagnostics / Fig. 8).
    pub fn reconstruct(
        &self,
        err: &Matrix,
        whitener: &Whitener,
    ) -> (Matrix, Matrix, Vec<f32>, usize) {
        let es = matmul(err, &whitener.s);
        let f = svd(&es);
        let r = self.rank.pick(&f.s).max(1);
        let la = f.factor_a(r);
        let lb = matmul(&f.factor_vt(r), &whitener.s_inv);
        (la, lb, f.s.clone(), r)
    }
}

/// Scale a Gram matrix by a diagonal on both sides: G' = D G D with
/// D = diag(d). Used to whiten the *smoothed* activations M⁻¹X without
/// re-streaming calibration data (Gram of M⁻¹X = M⁻¹ · Gram(X) · M⁻¹).
pub fn scale_gram(gram: &[f64], d: usize, diag: &[f32]) -> Vec<f64> {
    assert_eq!(diag.len(), d);
    let mut out = vec![0f64; d * d];
    for i in 0..d {
        let di = diag[i] as f64;
        for j in 0..d {
            out[i * d + j] = gram[i * d + j] * di * diag[j] as f64;
        }
    }
    out
}

impl PtqMethod for Aser {
    fn name(&self) -> String {
        if self.smooth {
            "aser".into()
        } else {
            "aser-er".into()
        }
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let d = w.cols;
        if self.smooth && self.outlier_f > 0 {
            // ---- Activation Smoothing path (Algorithm 1, lines 6-9) ----
            let plan = self.smoothing_plan(w, calib);
            // W·M, then split into W_s (quantized) + W_o (outlier columns).
            let wm = w.scale_cols(&plan.m);
            let (w_s, w_o) = wm.split_cols(&plan.outliers);
            let qw = QuantizedWeight::quantize(&w_s, prec.wbits);
            // Integral error to reconstruct: E = W·M − Q(W_s) = E_q + W_o.
            let err = wm.sub(&qw.dequantize());
            debug_assert!({
                let e_alt = w_s.sub(&qw.dequantize()).add(&w_o);
                err.max_diff(&e_alt) < 1e-4
            });
            // Whitener of the smoothed activations M⁻¹X.
            let m_inv: Vec<f32> = plan.m.iter().map(|&v| 1.0 / v).collect();
            let gram_s = scale_gram(&calib.gram, d, &m_inv);
            let whitener = match Whitener::from_gram(&gram_s, d) {
                Ok(wh) => wh,
                Err(_) => {
                    // Should not happen thanks to damping; degrade to ER-only.
                    return Aser { smooth: false, ..self.clone() }
                        .quantize_layer(w, calib, prec);
                }
            };
            let (la, lb, _s, _r) = self.reconstruct(&err, &whitener);
            QuantizedLinear {
                weight: qw,
                act_smooth: Some(plan.m),
                low_rank: Some((la, lb)),
                fp_cols: Vec::new(),
                abits: prec.abits,
                method: self.name(),
            }
        } else {
            // ---- ER-only path (lines 10-11) ----
            let qw = QuantizedWeight::quantize(w, prec.wbits);
            let err = w.sub(&qw.dequantize());
            let whitener = match Whitener::from_gram(&calib.gram, d) {
                Ok(wh) => wh,
                Err(_) => {
                    return super::lowrank::Lorc { rank: self.rank }
                        .quantize_layer(w, calib, prec)
                }
            };
            let (la, lb, _s, _r) = self.reconstruct(&err, &whitener);
            QuantizedLinear {
                weight: qw,
                act_smooth: None,
                low_rank: Some((la, lb)),
                fp_cols: Vec::new(),
                abits: prec.abits,
                method: self.name(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::lowrank::{tests::aniso_setup, L2Qer, Lorc};
    use crate::methods::{layer_error, rtn::Rtn};
    use crate::tensor::matmul_bt;
    use crate::util::rng::Pcg64;

    #[test]
    fn whitening_theorem_truncation_loss_equals_sigma() {
        // Paper Eq. 8: dropping the i-th whitened component costs exactly
        // σ_i (×√tokens with our Gram normalization).
        let mut rng = Pcg64::seed(121);
        let d = 24;
        let tokens = 300;
        let x = {
            let mut x = Matrix::randn(&mut rng, tokens, d, 1.0);
            for c in 0..d {
                let s = 10f32.powf(rng.range_f32(-1.0, 1.0));
                for r in 0..tokens {
                    x[(r, c)] *= s;
                }
            }
            x
        };
        let calib = crate::methods::LayerCalib::from_sample(x.clone());
        let err = Matrix::randn(&mut rng, d, d, 0.02);
        let wh = Whitener::from_gram(&calib.gram, d).unwrap();
        let es = matmul(&err, &wh.s);
        let f = svd(&es);
        // Reconstruct with all but component i, for a few i.
        for &i in &[0usize, 3, 10] {
            let mut approx = Matrix::zeros(d, d);
            for k in 0..d {
                if k == i {
                    continue;
                }
                let sk = f.s[k];
                for r in 0..d {
                    let u = f.u[(r, k)] * sk;
                    for c in 0..d {
                        approx[(r, c)] += u * f.vt[(k, c)];
                    }
                }
            }
            let e_tilde = matmul(&approx, &wh.s_inv);
            // ‖(E − Ẽ)X‖_F with X = xᵀ (d×tokens)
            let resid = err.sub(&e_tilde);
            let loss = matmul_bt(&x, &resid).frob_norm(); // tokens×d
            let want = f.s[i] * (tokens as f32).sqrt();
            let rel = (loss - want).abs() / want.max(1e-9);
            assert!(rel < 0.05, "i={i}: loss={loss} want={want} rel={rel}");
        }
    }

    #[test]
    fn aser_er_beats_lorc_and_l2qer_at_same_rank() {
        let (w, calib) = aniso_setup(122, 48);
        let prec = Precision::w4a8();
        let rank = RankPolicy::Fixed(8);
        let e_lorc = layer_error(&w, &Lorc { rank }.quantize_layer(&w, &calib, prec), &calib.x);
        let e_l2 = layer_error(&w, &L2Qer { rank }.quantize_layer(&w, &calib, prec), &calib.x);
        let aser = Aser { rank, smooth: false, ..Default::default() };
        let e_aser = layer_error(&w, &aser.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_aser < e_lorc, "aser {e_aser} !< lorc {e_lorc}");
        assert!(e_aser < e_l2, "aser {e_aser} !< l2qer {e_l2}");
    }

    #[test]
    fn smoothing_helps_at_low_act_bits() {
        let (w, calib) = aniso_setup(123, 48);
        let prec = Precision::w4a6();
        let rank = RankPolicy::Fixed(8);
        let er_only = Aser { rank, smooth: false, ..Default::default() };
        let with_as = Aser { rank, outlier_f: 6, smooth: true, ..Default::default() };
        let e_er = layer_error(&w, &er_only.quantize_layer(&w, &calib, prec), &calib.x);
        let e_as = layer_error(&w, &with_as.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_as < e_er, "w/AS {e_as} !< w/o {e_er}");
    }

    #[test]
    fn aser_beats_rtn_by_wide_margin() {
        let (w, calib) = aniso_setup(124, 40);
        let prec = Precision::w4a8();
        let aser = Aser { rank: RankPolicy::Fixed(8), outlier_f: 6, ..Default::default() };
        let e_aser = layer_error(&w, &aser.quantize_layer(&w, &calib, prec), &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_aser < 0.6 * e_rtn, "aser {e_aser} vs rtn {e_rtn}");
    }

    #[test]
    fn smoothing_plan_finds_joint_outliers() {
        let mut rng = Pcg64::seed(125);
        let d = 32;
        let mut w = Matrix::randn(&mut rng, d, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 128, d, 1.0);
        // Channel 5: big acts AND big weights → top outlier.
        for r in 0..x.rows {
            x[(r, 5)] *= 50.0;
        }
        for r in 0..d {
            w[(r, 5)] *= 5.0;
        }
        let calib = crate::methods::LayerCalib::from_sample(x);
        let aser = Aser { outlier_f: 4, ..Default::default() };
        let plan = aser.smoothing_plan(&w, &calib);
        assert!(plan.outliers.contains(&5));
        assert!(plan.m[5] > 1.0);
        // Non-outliers untouched.
        let untouched = (0..d).filter(|i| !plan.outliers.contains(i)).all(|i| plan.m[i] == 1.0);
        assert!(untouched);
    }

    #[test]
    fn m_ratios_match_eq11() {
        let mut rng = Pcg64::seed(126);
        let d = 16;
        let w = Matrix::randn(&mut rng, d, d, 0.1);
        let mut x = Matrix::randn(&mut rng, 64, d, 1.0);
        for (k, &c) in [2usize, 7, 11].iter().enumerate() {
            for r in 0..x.rows {
                x[(r, c)] *= 10.0 * (k + 1) as f32;
            }
        }
        let calib = crate::methods::LayerCalib::from_sample(x);
        let aser = Aser { outlier_f: 3, ..Default::default() };
        let plan = aser.smoothing_plan(&w, &calib);
        let x_bar = &calib.x_abs_mean;
        let x_min = plan.outliers.iter().map(|&i| x_bar[i]).fold(f32::INFINITY, f32::min);
        for &i in &plan.outliers {
            let want = x_bar[i] / x_min;
            assert!((plan.m[i] - want).abs() / want < 1e-5);
        }
    }

    #[test]
    fn forward_is_function_preserving_without_quant_error() {
        // If W quantization is (nearly) exact (8-bit) and acts stay fp, the
        // smoothed + compensated forward ≈ plain WX.
        let (w, calib) = aniso_setup(127, 24);
        let prec = Precision::new(8, 16);
        let aser = Aser { rank: RankPolicy::Fixed(24), outlier_f: 4, ..Default::default() };
        let q = aser.quantize_layer(&w, &calib, prec);
        let want = matmul_bt(&calib.x, &w);
        let got = q.forward_matrix(&calib.x);
        let rel = want.sub(&got).frob_norm() / want.frob_norm();
        assert!(rel < 2e-3, "rel={rel}");
    }

    #[test]
    fn scale_gram_matches_direct() {
        let mut rng = Pcg64::seed(128);
        let x = Matrix::randn(&mut rng, 60, 10, 1.0);
        let calib = crate::methods::LayerCalib::from_sample(x.clone());
        let diag: Vec<f32> = (0..10).map(|i| 0.5 + i as f32 * 0.3).collect();
        let scaled = scale_gram(&calib.gram, 10, &diag);
        let x_scaled = x.scale_cols(&diag);
        let direct = crate::methods::LayerCalib::from_sample(x_scaled);
        for (a, b) in scaled.iter().zip(&direct.gram) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn rank_threshold_gives_small_ranks_for_lowrank_errors() {
        let (w, calib) = aniso_setup(129, 32);
        let aser = Aser { rank: RankPolicy::Threshold(0.3), smooth: false, ..Default::default() };
        let q = aser.quantize_layer(&w, &calib, Precision::w4a8());
        assert!(q.rank() >= 1);
        assert!(q.rank() < 32);
    }
}
