//! Round-To-Nearest — the no-frills baseline every table starts from.

use super::{LayerCalib, PtqMethod, QuantizedLinear};
use crate::quant::{Precision, QuantizedWeight};
use crate::tensor::Matrix;

/// Plain per-channel symmetric RTN; per-token activation quantization.
pub struct Rtn;

impl PtqMethod for Rtn {
    fn name(&self) -> String {
        "rtn".into()
    }

    fn quantize_layer(&self, w: &Matrix, _calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        QuantizedLinear {
            weight: QuantizedWeight::quantize(w, prec.wbits),
            act_smooth: None,
            low_rank: None,
            fp_cols: Vec::new(),
            abits: prec.abits,
            method: self.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::{layer_error, layer_error_rel};
    use crate::util::rng::Pcg64;

    fn setup(d_in: usize, d_out: usize) -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(61);
        let w = Matrix::randn(&mut rng, d_out, d_in, 0.05);
        let x = Matrix::randn(&mut rng, 128, d_in, 1.0);
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn forward_matches_fake_quant_semantics() {
        let (w, calib) = setup(32, 16);
        let q = Rtn.quantize_layer(&w, &calib, Precision::w4a16());
        // W4A16: forward == X · Q(W)ᵀ exactly.
        let want = crate::tensor::matmul_bt(&calib.x, &q.weight.dequantize());
        let got = q.forward_matrix(&calib.x);
        assert!(want.max_diff(&got) < 1e-5);
    }

    #[test]
    fn error_grows_as_bits_shrink() {
        let (w, calib) = setup(48, 24);
        let mut last = 0.0;
        for (wb, ab) in [(8, 8), (4, 8), (4, 6), (3, 6)] {
            let q = Rtn.quantize_layer(&w, &calib, Precision::new(wb, ab));
            let e = layer_error(&w, &q, &calib.x);
            assert!(e > last, "W{wb}A{ab}: {e} <= {last}");
            last = e;
        }
    }

    #[test]
    fn rel_error_sane_at_w8a8() {
        let (w, calib) = setup(64, 32);
        let q = Rtn.quantize_layer(&w, &calib, Precision::new(8, 8));
        let rel = layer_error_rel(&w, &q, &calib.x);
        assert!(rel < 0.02, "rel={rel}");
    }

    #[test]
    fn no_extra_params() {
        let (w, calib) = setup(16, 16);
        let q = Rtn.quantize_layer(&w, &calib, Precision::w4a8());
        assert_eq!(q.extra_params(), 0);
        assert_eq!(q.extra_flops_per_token(), 0);
        assert_eq!(q.rank(), 0);
    }
}
