//! GPTQ (Frantar et al. 2022) — Hessian-guided sequential quantization.
//!
//! For each weight row, quantize columns left-to-right; after fixing column
//! j, distribute its rounding error onto the not-yet-quantized columns using
//! the inverse Hessian `H⁻¹` (H = 2XXᵀ + λI shared across rows). We use the
//! Cholesky formulation from the paper: with `H⁻¹ = Uᵀ U` (U upper
//! triangular), the update for column j is
//! `w[:, k] -= err · U[j,k]/U[j,j]` for k > j.

use super::{LayerCalib, PtqMethod, QuantizedLinear};
use crate::linalg::Cholesky;
use crate::quant::{BitWidth, Precision, QuantizedWeight};
use crate::tensor::Matrix;

pub struct Gptq {
    /// Relative diagonal damping (`percdamp` in the reference code).
    pub percdamp: f64,
}

impl Default for Gptq {
    fn default() -> Self {
        Gptq { percdamp: 0.01 }
    }
}

impl Gptq {
    /// Compute the upper Cholesky factor U of H⁻¹ (H⁻¹ = Uᵀ U).
    /// U = Lᵀ where H⁻¹ = L Lᵀ is the ordinary lower factorization.
    fn hinv_upper(&self, calib: &LayerCalib) -> anyhow::Result<(Vec<f64>, usize)> {
        let d = calib.in_features();
        // H = 2·XᵀX (the 2 and the 1/tokens normalization cancel in the
        // update ratio U[j,k]/U[j,j], so we use the stored normalized Gram).
        let mut h = calib.gram.clone();
        let mean_diag = (0..d).map(|i| h[i * d + i]).sum::<f64>() / d as f64;
        let damp = self.percdamp * mean_diag.max(1e-12);
        for i in 0..d {
            h[i * d + i] += damp;
        }
        let ch = Cholesky::damped(&h, d)?;
        // H⁻¹ = L⁻ᵀ L⁻¹ from H = L Lᵀ.
        let linv = ch.inverse_lower(); // L⁻¹ lower
        let mut hinv = vec![0f64; d * d];
        // H⁻¹[i][j] = Σ_k L⁻¹[k][i]·L⁻¹[k][j]  (k ≥ max(i,j))
        for i in 0..d {
            for j in i..d {
                let mut s = 0f64;
                for k in j..d {
                    s += linv[k * d + i] * linv[k * d + j];
                }
                hinv[i * d + j] = s;
                hinv[j * d + i] = s;
            }
        }
        let ch2 = Cholesky::damped(&hinv, d)?;
        // U = L2ᵀ, stored row-major upper-triangular.
        let mut u = vec![0f64; d * d];
        for i in 0..d {
            for j in 0..=i {
                u[j * d + i] = ch2.l[i * d + j];
            }
        }
        Ok((u, d))
    }
}

impl PtqMethod for Gptq {
    fn name(&self) -> String {
        "gptq".into()
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let (u, d) = match self.hinv_upper(calib) {
            Ok(x) => x,
            Err(_) => {
                // Degenerate calibration: fall back to RTN semantics.
                return super::rtn::Rtn.quantize_layer(w, calib, prec);
            }
        };
        assert_eq!(d, w.cols);
        let qmax = BitWidth(prec.wbits).qmax();
        // Per-row scales fixed from the original weights.
        let scales: Vec<f32> = (0..w.rows)
            .map(|r| {
                let amax = w.row(r).iter().fold(0f32, |m, x| m.max(x.abs()));
                if amax > 0.0 {
                    amax / qmax
                } else {
                    1.0
                }
            })
            .collect();

        // Work on an f64 copy; codes filled column-by-column.
        let mut work: Vec<f64> = w.data.iter().map(|&x| x as f64).collect();
        let mut codes = vec![0i8; w.rows * w.cols];
        for j in 0..d {
            let ujj = u[j * d + j];
            for r in 0..w.rows {
                let wj = work[r * d + j];
                let scale = scales[r] as f64;
                let q = (wj / scale).round().clamp(-qmax as f64, qmax as f64);
                codes[r * d + j] = q as i8;
                let deq = q * scale;
                if ujj.abs() > 1e-30 {
                    let err = (wj - deq) / ujj;
                    // Propagate onto the remaining columns of this row.
                    let urow = &u[j * d..(j + 1) * d];
                    let wrow = &mut work[r * d..(r + 1) * d];
                    for k in j + 1..d {
                        wrow[k] -= err * urow[k];
                    }
                }
            }
        }
        QuantizedLinear {
            weight: QuantizedWeight {
                rows: w.rows,
                cols: w.cols,
                bits: prec.wbits,
                codes,
                scales,
            },
            act_smooth: None,
            low_rank: None,
            fp_cols: Vec::new(),
            abits: prec.abits,
            method: self.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::{layer_error, rtn::Rtn, LayerCalib};
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(seed);
        let d = 48;
        let w = Matrix::randn(&mut rng, 32, d, 0.05);
        // Correlated activations (what gives GPTQ its edge over RTN).
        let base = Matrix::randn(&mut rng, 256, 8, 1.0);
        let mix = Matrix::randn(&mut rng, 8, d, 1.0);
        let x = crate::tensor::matmul(&base, &mix)
            .add(&Matrix::randn(&mut rng, 256, d, 0.3));
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_acts() {
        let (w, calib) = setup(101);
        let prec = Precision::w4a16();
        let e_gptq = layer_error(&w, &Gptq::default().quantize_layer(&w, &calib, prec), &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_gptq < e_rtn, "gptq {e_gptq} !< rtn {e_rtn}");
    }

    #[test]
    fn codes_respect_grid() {
        let (w, calib) = setup(102);
        let q = Gptq::default().quantize_layer(&w, &calib, Precision::w4a16());
        let qmax = BitWidth(4).qmax() as i8;
        assert!(q.weight.codes.iter().all(|&c| -qmax <= c && c <= qmax));
    }

    #[test]
    fn output_finite_and_close_at_8bit() {
        let (w, calib) = setup(103);
        let q = Gptq::default().quantize_layer(&w, &calib, Precision::new(8, 16));
        let deq = q.weight.dequantize();
        assert!(deq.is_finite());
        // 8-bit should be nearly lossless relative to weight scale.
        let rel = w.sub(&deq).frob_norm() / w.frob_norm();
        assert!(rel < 0.01, "rel={rel}");
    }

    #[test]
    fn degenerate_calibration_falls_back() {
        let mut rng = Pcg64::seed(104);
        let w = Matrix::randn(&mut rng, 4, 16, 0.05);
        // All-zero activations: Hessian is singular even after damping scale.
        let x = Matrix::zeros(8, 16);
        let calib = LayerCalib::from_sample(x);
        let q = Gptq::default().quantize_layer(&w, &calib, Precision::w4a16());
        assert!(q.weight.dequantize().is_finite());
    }
}
