//! LLM.int8()-style mixed-precision decomposition (Dettmers et al. 2022),
//! with int4 weights — the tables' "LLM.int4()" baseline.
//!
//! Activation channels whose calibration magnitude exceeds a threshold are
//! routed through a full-precision side GEMM (their weight columns are kept
//! fp and excluded from the int grid); everything else goes through the
//! quantized path.

use super::{LayerCalib, PtqMethod, QuantizedLinear};
use crate::quant::{Precision, QuantizedWeight};
use crate::tensor::Matrix;

pub struct LlmInt {
    /// Channels with X̄ ≥ `threshold_mult` × mean(X̄) are outliers.
    pub threshold_mult: f32,
    /// Cap on the number of fp channels (keeps the side GEMM skinny).
    pub max_outliers: usize,
}

impl Default for LlmInt {
    fn default() -> Self {
        // ~matches the 6.0-ish magnitude criterion of LLM.int8() scaled to
        // mean-relative form; ≤1% channels in our models.
        LlmInt { threshold_mult: 6.0, max_outliers: 64 }
    }
}

impl LlmInt {
    /// Pick outlier channel indices from calibration statistics.
    pub fn outlier_channels(&self, calib: &LayerCalib) -> Vec<usize> {
        let xm = &calib.x_abs_mean;
        let mean = xm.iter().sum::<f32>() / xm.len().max(1) as f32;
        let thr = mean * self.threshold_mult;
        let mut idx: Vec<usize> =
            (0..xm.len()).filter(|&i| xm[i] >= thr && xm[i] > 0.0).collect();
        // Keep the largest if over budget.
        idx.sort_by(|&a, &b| xm[b].partial_cmp(&xm[a]).unwrap());
        idx.truncate(self.max_outliers);
        idx.sort_unstable();
        idx
    }
}

impl PtqMethod for LlmInt {
    fn name(&self) -> String {
        "llm_int".into()
    }

    fn quantize_layer(&self, w: &Matrix, calib: &LayerCalib, prec: Precision) -> QuantizedLinear {
        let outliers = self.outlier_channels(calib);
        // Split W into int part (outlier cols zeroed) + fp columns.
        let (w_int, _) = w.split_cols(&outliers);
        let fp_cols: Vec<(usize, Vec<f32>)> =
            outliers.iter().map(|&c| (c, w.col(c))).collect();
        QuantizedLinear {
            weight: QuantizedWeight::quantize(&w_int, prec.wbits),
            act_smooth: None,
            low_rank: None,
            fp_cols,
            abits: prec.abits,
            method: self.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::layer_error;
    use crate::methods::rtn::Rtn;
    use crate::util::rng::Pcg64;

    /// Calibration with strong outlier channels — the regime this method is
    /// built for.
    fn outlier_setup() -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(71);
        let d_in = 64;
        let w = Matrix::randn(&mut rng, 32, d_in, 0.05);
        let mut x = Matrix::randn(&mut rng, 256, d_in, 1.0);
        for &c in &[5usize, 17, 40] {
            for r in 0..x.rows {
                x[(r, c)] *= 40.0;
            }
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn finds_planted_outliers() {
        let (_, calib) = outlier_setup();
        let m = LlmInt::default();
        let idx = m.outlier_channels(&calib);
        assert_eq!(idx, vec![5, 17, 40]);
    }

    #[test]
    fn beats_rtn_with_act_outliers() {
        let (w, calib) = outlier_setup();
        let prec = Precision::w4a8();
        let e_mixed = layer_error(&w, &LlmInt::default().quantize_layer(&w, &calib, prec), &calib.x);
        let e_rtn = layer_error(&w, &Rtn.quantize_layer(&w, &calib, prec), &calib.x);
        assert!(e_mixed < e_rtn, "mixed {e_mixed} !< rtn {e_rtn}");
    }

    #[test]
    fn respects_outlier_budget() {
        let mut rng = Pcg64::seed(72);
        let d = 128;
        let _w = Matrix::randn(&mut rng, 16, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 64, d, 1.0);
        for c in 0..d / 2 {
            for r in 0..x.rows {
                x[(r, c)] *= 50.0;
            }
        }
        let calib = LayerCalib::from_sample(x);
        let m = LlmInt { threshold_mult: 2.0, max_outliers: 8 };
        assert!(m.outlier_channels(&calib).len() <= 8);
    }

    #[test]
    fn no_outliers_degenerates_to_rtn() {
        let mut rng = Pcg64::seed(73);
        let w = Matrix::randn(&mut rng, 8, 24, 0.05);
        let x = Matrix::randn(&mut rng, 64, 24, 1.0);
        let calib = LayerCalib::from_sample(x);
        let q = LlmInt::default().quantize_layer(&w, &calib, Precision::w4a8());
        assert!(q.fp_cols.is_empty());
        let q_rtn = Rtn.quantize_layer(&w, &calib, Precision::w4a8());
        assert!(q.forward_matrix(&calib.x).max_diff(&q_rtn.forward_matrix(&calib.x)) < 1e-6);
    }
}
