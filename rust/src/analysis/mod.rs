//! Quantization-error analysis — the computations behind Figures 2-4, 6-8.

use crate::linalg::{effective_rank, svd, Whitener};
use crate::methods::{LayerCalib, QuantizedLinear};
use crate::quant::fake_quant_weight;
use crate::tensor::{matmul, matmul_bt, Matrix};

/// Fig. 2: normalized top-k singular values of E_q and E_q·X for one layer.
/// Returns (sv of E_q, sv of E_qX), both normalized to σ₁ = 1.
pub fn error_spectra(w: &Matrix, calib: &LayerCalib, wbits: u8, top_k: usize) -> (Vec<f32>, Vec<f32>) {
    let e_q = w.sub(&fake_quant_weight(w, wbits));
    let s_w = svd(&e_q).s;
    // E_q X with X = xᵀ (d×tokens): singular values of E_q·Xᵀ equal those of
    // (X·E_qᵀ); use the thinner orientation.
    let ex = matmul_bt(&calib.x, &e_q); // tokens × out
    let s_ex = svd(&ex).s;
    (normalize_top(&s_w, top_k), normalize_top(&s_ex, top_k))
}

fn normalize_top(s: &[f32], k: usize) -> Vec<f32> {
    let top = &s[..k.min(s.len())];
    let s1 = top.first().copied().unwrap_or(1.0).max(1e-20);
    top.iter().map(|&v| v / s1).collect()
}

/// Fig. 3: effective rank of E_q·X for one layer.
pub fn error_effective_rank(w: &Matrix, calib: &LayerCalib, wbits: u8) -> f32 {
    let e_q = w.sub(&fake_quant_weight(w, wbits));
    let ex = matmul_bt(&calib.x, &e_q);
    effective_rank(&svd(&ex).s)
}

/// Fig. 4: per-channel magnitudes — ‖(E_qX) restricted to channel c‖,
/// X̄_c, W̄_c and X̄·W̄, channels sorted by X̄·W̄ descending.
pub struct ChannelProfile {
    pub order: Vec<usize>,
    pub err_norm: Vec<f32>,
    pub x_bar: Vec<f32>,
    pub w_bar: Vec<f32>,
    pub xw: Vec<f32>,
}

pub fn channel_profile(w: &Matrix, calib: &LayerCalib, wbits: u8, top: usize) -> ChannelProfile {
    let d = w.cols;
    let e_q = w.sub(&fake_quant_weight(w, wbits));
    let x_bar = calib.x_abs_mean.clone();
    let w_bar = w.col_abs_mean();
    let xw: Vec<f32> = x_bar.iter().zip(&w_bar).map(|(a, b)| a * b).collect();
    // Per-channel error contribution: ‖x_c · E_q[:,c]‖_F over the sample.
    let mut err = vec![0f32; d];
    for c in 0..d {
        let ec = e_q.col(c);
        let ec_norm: f32 = ec.iter().map(|v| v * v).sum::<f32>();
        let xc_norm: f32 = (0..calib.x.rows).map(|r| calib.x[(r, c)].powi(2)).sum();
        err[c] = (ec_norm * xc_norm).sqrt();
    }
    let mut order: Vec<usize> = (0..d).collect();
    order.sort_by(|&a, &b| xw[b].partial_cmp(&xw[a]).unwrap());
    order.truncate(top.min(d));
    ChannelProfile {
        err_norm: order.iter().map(|&c| err[c]).collect(),
        x_bar: order.iter().map(|&c| x_bar[c]).collect(),
        w_bar: order.iter().map(|&c| w_bar[c]).collect(),
        xw: order.iter().map(|&c| xw[c]).collect(),
        order,
    }
}

/// Fig. 6: remaining integral error ‖WX − ŷ(X)‖_F after a method's
/// compensation (RTN = no compensation baseline).
pub fn remaining_error(w: &Matrix, q: &QuantizedLinear, calib: &LayerCalib) -> f32 {
    crate::methods::layer_error(w, q, &calib.x)
}

/// Fig. 8: ranks selected per layer by the α threshold on the *whitened*
/// error spectrum (the quantity ASER actually truncates).
pub fn selected_rank(w: &Matrix, calib: &LayerCalib, wbits: u8, alpha: f64) -> usize {
    let e_q = w.sub(&fake_quant_weight(w, wbits));
    match Whitener::from_gram(&calib.gram, w.cols) {
        Ok(wh) => {
            let es = matmul(&e_q, &wh.s);
            crate::linalg::rank_for_threshold(&svd(&es).s, alpha)
        }
        Err(_) => 0,
    }
}

/// Fig. 7: activation + weight channel ranges before/after smoothing.
pub struct SmoothingEffect {
    pub act_before: Vec<f32>,
    pub act_after: Vec<f32>,
    pub w_before: Vec<f32>,
    pub w_after: Vec<f32>,
}

pub fn smoothing_effect(
    w: &Matrix,
    calib: &LayerCalib,
    aser: &crate::methods::aser::Aser,
) -> SmoothingEffect {
    let plan = aser.smoothing_plan(w, calib);
    let act_before = calib.x_abs_mean.clone();
    let act_after: Vec<f32> =
        act_before.iter().zip(&plan.m).map(|(&x, &m)| x / m).collect();
    let w_before = w.col_abs_max();
    let w_after = w.scale_cols(&plan.m).col_abs_max();
    SmoothingEffect { act_before, act_after, w_before, w_after }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::LayerCalib;
    use crate::util::rng::Pcg64;

    fn setup() -> (Matrix, LayerCalib) {
        let mut rng = Pcg64::seed(171);
        let d = 48;
        let w = Matrix::randn(&mut rng, d, d, 0.05);
        let mut x = Matrix::randn(&mut rng, 160, d, 1.0);
        for &c in &[2usize, 20] {
            for r in 0..x.rows {
                x[(r, c)] *= 25.0;
            }
        }
        (w, LayerCalib::from_sample(x))
    }

    #[test]
    fn spectra_show_lowrank_structure_of_eqx() {
        let (w, calib) = setup();
        let (s_w, s_ex) = error_spectra(&w, &calib, 4, 32);
        assert_eq!(s_w[0], 1.0);
        assert_eq!(s_ex[0], 1.0);
        // The activation-weighted spectrum decays faster (Fig. 2's claim).
        let tail_w: f32 = s_w[8..].iter().sum();
        let tail_ex: f32 = s_ex[8..].iter().sum();
        assert!(tail_ex < tail_w, "E_qX tail {tail_ex} !< E_q tail {tail_w}");
    }

    #[test]
    fn effective_rank_lower_for_eqx_than_dim() {
        let (w, calib) = setup();
        let er = error_effective_rank(&w, &calib, 4);
        assert!(er > 1.0 && er < 48.0, "er={er}");
    }

    #[test]
    fn channel_profile_sorted_and_correlated() {
        let (w, calib) = setup();
        let p = channel_profile(&w, &calib, 4, 20);
        assert_eq!(p.order.len(), 20);
        for i in 1..p.xw.len() {
            assert!(p.xw[i - 1] >= p.xw[i]);
        }
        // Outlier channels (planted at 2, 20) must rank at the top.
        assert!(p.order[..4].contains(&2) || p.order[..4].contains(&20));
        // Error concentrates in the top channels (paper's Fig. 4 claim).
        let top_err: f32 = p.err_norm[..4].iter().sum();
        let rest_err: f32 = p.err_norm[4..].iter().sum();
        assert!(top_err > rest_err / 4.0);
    }

    #[test]
    fn selected_rank_monotone_in_alpha() {
        let (w, calib) = setup();
        let r1 = selected_rank(&w, &calib, 4, 0.05);
        let r2 = selected_rank(&w, &calib, 4, 0.3);
        assert!(r1 <= r2);
        assert!(r2 >= 1);
    }

    #[test]
    fn smoothing_flattens_activations() {
        let (w, calib) = setup();
        let aser = crate::methods::aser::Aser { outlier_f: 4, ..Default::default() };
        let e = smoothing_effect(&w, &calib, &aser);
        let max_before = e.act_before.iter().cloned().fold(0f32, f32::max);
        let max_after = e.act_after.iter().cloned().fold(0f32, f32::max);
        assert!(max_after < max_before, "{max_after} !< {max_before}");
        // Weight range grows where activations shrank.
        let wmax_b = e.w_before.iter().cloned().fold(0f32, f32::max);
        let wmax_a = e.w_after.iter().cloned().fold(0f32, f32::max);
        assert!(wmax_a >= wmax_b);
    }
}
