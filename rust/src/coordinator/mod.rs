//! L3 coordinator: the quantization pipeline orchestrator and the serving
//! runtime (streaming engine, continuous batcher, KV-cache pool, the
//! batch-and-drain compat router, and the HTTP/SSE network front end).

pub mod batcher;
pub mod engine;
pub mod faults;
pub mod http;
pub mod kvpool;
pub mod pipeline;
pub mod router;
pub mod server;

pub use batcher::{
    BatchConfig, BatchMetrics, FinishReason, GenRequest, Submission, TokenEvent,
};
pub use engine::{
    poll_streams, Engine, EngineConfig, RequestHandle, Response, Shutdown, SubmitError, TryEvent,
};
pub use faults::{Fault, FaultPlan, FaultPlanConfig};
pub use kvpool::{KvDtype, KvPool};
pub use pipeline::{calibrate_model, quantize_model, run_ptq, CalibStats, PipelineReport};
pub use router::{serve_requests, synthetic_requests, ServerConfig, ServerRun};
pub use server::{HttpServer, HttpServerConfig};
