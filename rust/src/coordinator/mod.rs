//! L3 coordinator: the quantization pipeline orchestrator and the serving
//! runtime (continuous batcher, KV-cache pool, request router).

pub mod batcher;
pub mod kvpool;
pub mod pipeline;
pub mod router;

pub use batcher::{BatchConfig, BatchMetrics, Request, Response};
pub use kvpool::KvPool;
pub use pipeline::{calibrate_model, quantize_model, run_ptq, CalibStats, PipelineReport};
pub use router::{serve_requests, synthetic_requests, ServerConfig, ServerRun};
