//! Continuous (iteration-level) dynamic batcher — Orca-style scheduling on
//! top of the packed quantized execution engine, with chunked multi-token
//! prefill and right-sized KV leases.
//!
//! The decode loop keeps an *active set* of sequences. Every iteration it
//! (1) admits queued requests while there is batch room AND the KV pool
//! grants a lease (backpressure), (2) plans a **ragged chunk batch** under
//! a per-iteration token budget and advances it through ONE
//! [`Gpt::forward_chunk_batch`] call, and (3) retires finished sequences,
//! freeing their KV lease. New requests therefore join between
//! *iterations*, not between requests.
//!
//! ## Scheduling policy (step 2)
//!
//! Each iteration assembles at most [`BatchConfig::token_budget`] token
//! rows:
//! - **Decode rows first.** Every sequence past its prompt contributes
//!   exactly one row, unconditionally — decode latency never queues behind
//!   a long prefill.
//! - **Prompt chunks share the remainder.** Each still-prefilling sequence
//!   may feed up to [`BatchConfig::prefill_chunk`] prompt tokens from the
//!   leftover budget. The grant order rotates across iterations
//!   (round-robin start), so one long prompt cannot monopolize the chunk
//!   budget and starve later arrivals of their TTFT.
//!
//! All planned spans stack into a single ragged forward: one batched
//! quantized GEMM per layer per iteration over Σ span rows, with the
//! lm_head GEMM run only for rows the scheduler reads back (prefill-final
//! and decode rows — mid-prefill chunks skip the vocab projection). This is
//! where long-prompt TTFT is won: prompt tokens hit the packed int8
//! kernels as wide token tiles instead of one skinny row per iteration.
//! Between those GEMMs, per-sequence attention fans out across
//! (sequence × head) work items on the head-major KV tiles
//! (`Gpt::attn_layer` + `tensor::attn_kernel`), so long-context decode
//! iterations keep every core busy instead of walking sequences serially.
//!
//! ## KV leases (admission + growth)
//!
//! Admission distinguishes **transient** capacity pushback (the pool is
//! full right now; the request is re-queued and admitted when leases free
//! up — `BatchMetrics::rejected_capacity`) from **impossible** requests
//! that could never run: empty prompts, and prompts whose minimum
//! footprint (prompt + one generated token) exceeds the KV window or the
//! whole pool. Those are refused immediately with an explicit [`Response`]
//! carrying `rejected: true` and an empty token list
//! (`BatchMetrics::rejected_impossible`) — re-queueing them forever was an
//! admission livelock. With impossible requests refused up front,
//! `run_batcher` terminates on any finite request stream.
//!
//! Feasible requests lease **right-sized**, not worst-case: the initial
//! lease covers `prompt + min(max_new, kv_reserve)` tokens, and decode
//! extends it incrementally through [`KvPool::grow`]
//! (`BatchMetrics::kv_grows`). When the pool cannot grow a lease even by
//! one token, the sequence finishes gracefully with what it has generated
//! (`BatchMetrics::truncated_kv`) instead of panicking — so tight pools
//! run more sequences concurrently and EOS-early sequences never strand a
//! `max_new`-sized reservation.
//!
//! TTFT (`Response::ttft`) is stamped when the chunked forward that ends a
//! sequence's prefill writes its logits back — the instant its first
//! generated token is determined — not when the next iteration argmaxes
//! that token.
//!
//! Determinism scope: per-sequence attention is identical across chunkings
//! by construction, and the int-GEMM path is bitwise identical across
//! batch shapes, so greedy outputs match single-sequence generation
//! token-for-token on quantized models (and to f32 tolerance on dense
//! ones; see `tensor::gemm::matmul_bt_acc` for the fp caveats).

use super::kvpool::{KvPool, Lease};
use crate::data::vocab::EOS;
use crate::model::{argmax, ChunkLogits, Gpt, KvCache, SeqChunk, PREFILL_CHUNK};
use crate::tensor::QGemmArena;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time from submit to first generated token (stamped when the logits
    /// of the prefill-final forward are written back). For rejected
    /// requests this equals `total` (time to rejection).
    pub ttft: Duration,
    /// Time from submit to completion.
    pub total: Duration,
    pub prompt_len: usize,
    /// True when the request was refused at admission because it could
    /// never run (empty prompt, or prompt + 1 beyond the KV window or the
    /// whole pool); `tokens` is empty.
    pub rejected: bool,
}

struct Active {
    req: Request,
    cache: KvCache,
    lease: Lease,
    /// Next prompt index to feed (prefill progress).
    fed: usize,
    generated: Vec<u32>,
    last_logits: Vec<f32>,
    first_token_at: Option<Instant>,
    /// Finished early because the KV pool could not grow the lease.
    truncated: bool,
}

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Max concurrently active sequences.
    pub max_batch: usize,
    /// Per-iteration token-row budget for the ragged forward. Decode rows
    /// (one per decoding sequence, bounded by `max_batch`) are always
    /// planned; prompt chunks share whatever remains.
    pub token_budget: usize,
    /// Max prompt tokens one sequence feeds per iteration.
    pub prefill_chunk: usize,
    /// Decode headroom reserved at admission: the initial KV lease covers
    /// `prompt + min(max_new, kv_reserve)` tokens; the rest is leased
    /// incrementally by [`KvPool::grow`] during decode.
    pub kv_reserve: usize,
    /// Preferred tokens per decode-time lease grow (amortizes pool-lock
    /// traffic; growth falls back to the single token actually needed when
    /// the pool is nearly full).
    pub kv_grow: usize,
    /// Wait at most this long for work when idle.
    pub idle_wait: Duration,
    pub stop_on_eos: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 8,
            token_budget: 2 * PREFILL_CHUNK,
            prefill_chunk: PREFILL_CHUNK,
            kv_reserve: 16,
            kv_grow: 16,
            idle_wait: Duration::from_millis(5),
            stop_on_eos: true,
        }
    }
}

/// Metrics the server reports.
#[derive(Clone, Debug, Default)]
pub struct BatchMetrics {
    pub requests: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub iterations: usize,
    pub peak_batch: usize,
    /// Most token rows fed in one ragged forward. Bounded by
    /// `max(token_budget, concurrent decode rows)` — decode rows (≤
    /// `max_batch`) are planned unconditionally; only prompt chunks are
    /// budget-limited.
    pub peak_iter_tokens: usize,
    /// Transient pool pushback: the request was re-queued and admitted
    /// later.
    pub rejected_capacity: usize,
    /// Requests refused outright with a `rejected` response because they
    /// could never run (see the module doc's admission rules).
    pub rejected_impossible: usize,
    /// Successful incremental lease grows during decode.
    pub kv_grows: usize,
    /// Sequences finished early (gracefully) because the pool could not
    /// grow their lease by even one token.
    pub truncated_kv: usize,
}

/// Run the batching loop until the request channel closes and the active
/// set drains. Responses are delivered through `respond`.
pub fn run_batcher(
    model: &Gpt,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: Receiver<Request>,
    mut respond: impl FnMut(Response),
) -> BatchMetrics {
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = BatchMetrics::default();
    let mut channel_open = true;
    let mut pending: Vec<Request> = Vec::new();
    // Reusable activation-quantization scratch for the chunked forward.
    let mut arena = QGemmArena::new();
    // Rotating start index for prefill chunk grants (fairness).
    let mut prefill_rr = 0usize;

    while channel_open || !active.is_empty() || !pending.is_empty() {
        // ---- admission ----
        while active.len() < cfg.max_batch && channel_open {
            match rx.recv_timeout(if active.is_empty() && pending.is_empty() {
                cfg.idle_wait
            } else {
                Duration::ZERO
            }) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    channel_open = false;
                }
            }
        }
        let mut still_pending = Vec::new();
        for req in pending.drain(..) {
            if active.len() >= cfg.max_batch {
                still_pending.push(req);
                continue;
            }
            // A request is IMPOSSIBLE only when even its minimum footprint
            // — the prompt plus one generated token — can never fit the KV
            // window or the whole pool (or the prompt is empty: no logits
            // to decode from). Larger demands are admissible: the lease is
            // right-sized now and grown during decode, truncating
            // gracefully if the pool runs out.
            let min_need = req.prompt.len() + 1;
            if req.prompt.is_empty()
                || min_need > model.cfg.max_seq
                || min_need > pool.capacity_tokens()
            {
                metrics.rejected_impossible += 1;
                let waited = Instant::now() - req.submitted;
                respond(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft: waited,
                    total: waited,
                    prompt_len: req.prompt.len(),
                    rejected: true,
                });
                continue;
            }
            // Right-sized lease: prompt + min(max_new, kv_reserve), clamped
            // to the KV window and pool size (never below prompt + 1).
            let reserve = req.max_new.clamp(1, cfg.kv_reserve.max(1));
            let want = (req.prompt.len() + reserve)
                .min(model.cfg.max_seq)
                .min(pool.capacity_tokens());
            match pool.alloc(want) {
                Some(lease) => {
                    active.push(Active {
                        // Pre-size the tiles to the lease so prefill never
                        // repacks mid-flight; decode-time lease growth
                        // re-sizes lazily on the next span append.
                        cache: KvCache::with_capacity(&model.cfg, lease.tokens),
                        lease,
                        fed: 0,
                        generated: Vec::new(),
                        last_logits: Vec::new(),
                        first_token_at: None,
                        truncated: false,
                        req,
                    });
                    metrics.requests += 1;
                }
                None => {
                    metrics.rejected_capacity += 1;
                    still_pending.push(req);
                }
            }
        }
        pending = still_pending;
        metrics.peak_batch = metrics.peak_batch.max(active.len());
        if active.is_empty() {
            if !channel_open && pending.is_empty() {
                break;
            }
            if !pending.is_empty() {
                // Feasible requests are waiting on pool space held outside
                // this loop (externally shared pool): back off instead of
                // spinning the admission loop hot.
                std::thread::sleep(cfg.idle_wait);
            }
            continue;
        }

        // ---- one iteration: plan a ragged prefill+decode batch under the
        //      token budget, advance it through one chunked forward ----
        metrics.iterations += 1;
        let budget = cfg.token_budget.max(1);
        // Planned spans: (active idx, start in `flat`, len, logits kind).
        // Tokens are copied into `flat` so the spans borrow one buffer
        // instead of `active` (whose caches the forward borrows mutably).
        let mut flat: Vec<u32> = Vec::new();
        let mut spans: Vec<(usize, usize, usize, ChunkLogits)> = Vec::new();

        // Decode rows first: every decoding sequence advances by one token
        // regardless of prefill pressure.
        for (i, a) in active.iter_mut().enumerate() {
            if a.fed < a.req.prompt.len() {
                continue;
            }
            let next = argmax(&a.last_logits) as u32;
            a.generated.push(next);
            metrics.generated_tokens += 1;
            let mut done = a.generated.len() >= a.req.max_new
                || (cfg.stop_on_eos && next == EOS)
                || a.cache.len() + 1 >= model.cfg.max_seq;
            if !done && a.cache.len() + 1 > a.lease.tokens {
                // Lease exhausted: grow by the preferred step, falling back
                // to the single token actually needed; truncate gracefully
                // when even that fails.
                let need = a.cache.len() + 1 - a.lease.tokens;
                let cap_total = (a.req.prompt.len() + a.req.max_new).min(model.cfg.max_seq);
                let step = cap_total
                    .saturating_sub(a.lease.tokens)
                    .min(cfg.kv_grow.max(1))
                    .max(need);
                if pool.grow(&mut a.lease, step)
                    || (step > need && pool.grow(&mut a.lease, need))
                {
                    metrics.kv_grows += 1;
                } else {
                    metrics.truncated_kv += 1;
                    a.truncated = true;
                    done = true;
                }
            }
            if !done {
                spans.push((i, flat.len(), 1, ChunkLogits::Last));
                flat.push(next);
            }
        }
        let mut budget_left = budget.saturating_sub(spans.len());

        // Prompt chunks from the leftover budget, rotating the start index
        // so chunk grants are fair across prefilling sequences.
        let prefilling: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, a)| a.fed < a.req.prompt.len())
            .map(|(i, _)| i)
            .collect();
        if !prefilling.is_empty() {
            let start = prefill_rr % prefilling.len();
            prefill_rr = prefill_rr.wrapping_add(1);
            for k in 0..prefilling.len() {
                if budget_left == 0 {
                    break;
                }
                let i = prefilling[(start + k) % prefilling.len()];
                let a = &mut active[i];
                let remaining = a.req.prompt.len() - a.fed;
                let grant = cfg.prefill_chunk.max(1).min(remaining).min(budget_left);
                let logits = if a.fed + grant == a.req.prompt.len() {
                    ChunkLogits::Last
                } else {
                    ChunkLogits::None
                };
                spans.push((i, flat.len(), grant, logits));
                flat.extend_from_slice(&a.req.prompt[a.fed..a.fed + grant]);
                a.fed += grant;
                metrics.prefill_tokens += grant;
                budget_left -= grant;
            }
        }
        metrics.peak_iter_tokens = metrics.peak_iter_tokens.max(flat.len());

        if !spans.is_empty() {
            // forward_chunk_batch pairs chunks[i] with caches[i]; sort by
            // active index so the ascending &mut gather below lines up.
            spans.sort_unstable_by_key(|&(i, ..)| i);
            let chunks: Vec<SeqChunk> = spans
                .iter()
                .map(|&(_, f0, len, lg)| SeqChunk { tokens: &flat[f0..f0 + len], logits: lg })
                .collect();
            let logits = {
                let mut want = spans.iter().map(|&(i, ..)| i).peekable();
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(spans.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        caches.push(&mut a.cache);
                    }
                }
                model.forward_chunk_batch(&chunks, &mut caches, &mut arena)
            };
            // Logits are materialized now: any sequence that just fed its
            // final prompt token has its first generated token determined
            // at this instant, so TTFT is stamped here — not one iteration
            // later when the decode branch argmaxes it.
            let logits_at = Instant::now();
            let mut row = 0usize;
            for &(i, _, _, lg) in &spans {
                if lg == ChunkLogits::None {
                    continue;
                }
                let a = &mut active[i];
                a.last_logits = logits.row(row).to_vec();
                row += 1;
                if a.first_token_at.is_none() && a.fed >= a.req.prompt.len() {
                    a.first_token_at = Some(logits_at);
                }
            }
        }

        // ---- retire finished ----
        let mut i = 0;
        while i < active.len() {
            let done = {
                let a = &active[i];
                // The KV-window clause must not fire on a fresh
                // prefill-final sequence: its first token is already
                // determined by the prefill logits and needs no KV slot,
                // so the next iteration's decode pass emits it (and only
                // then stops feeding).
                a.truncated
                    || (a.fed >= a.req.prompt.len()
                        && (a.generated.len() >= a.req.max_new
                            || (cfg.stop_on_eos && a.generated.last() == Some(&EOS))
                            || (!a.generated.is_empty()
                                && a.cache.len() + 1 >= model.cfg.max_seq)))
            };
            if done {
                let a = active.swap_remove(i);
                pool.free(a.lease);
                let now = Instant::now();
                respond(Response {
                    id: a.req.id,
                    prompt_len: a.req.prompt.len(),
                    tokens: a.generated,
                    ttft: a
                        .first_token_at
                        .map(|t| t - a.req.submitted)
                        .unwrap_or_else(|| now - a.req.submitted),
                    total: now - a.req.submitted,
                    rejected: false,
                });
            } else {
                i += 1;
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use std::sync::mpsc::channel;

    fn serve_cfg(
        reqs: Vec<Request>,
        cfg: BatchConfig,
        kv_tokens: usize,
    ) -> (Vec<Response>, BatchMetrics) {
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(kv_tokens, 8);
        let (tx, rx) = channel();
        for r in reqs {
            tx.send(r).unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        let m = run_batcher(&model, &pool, &cfg, rx, |r| out.push(r));
        assert_eq!(pool.used_tokens(), 0, "all leases freed");
        (out, m)
    }

    fn serve(reqs: Vec<Request>, max_batch: usize, kv_tokens: usize) -> (Vec<Response>, BatchMetrics) {
        serve_cfg(reqs, BatchConfig { max_batch, ..Default::default() }, kv_tokens)
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request { id, prompt, max_new, submitted: Instant::now() }
    }

    #[test]
    fn serves_all_requests() {
        let reqs: Vec<Request> =
            (0..10).map(|i| req(i, vec![1 + i as u32, 2, 3], 4)).collect();
        let (out, m) = serve(reqs, 4, 10_000);
        assert_eq!(out.len(), 10);
        assert_eq!(m.requests, 10);
        assert!(m.peak_batch <= 4);
        assert!(out.iter().all(|r| r.tokens.len() <= 4 && !r.tokens.is_empty()));
    }

    #[test]
    fn batched_output_matches_unbatched_greedy() {
        let model = synthetic_model("micro", 51).unwrap();
        let prompt = vec![5u32, 9, 13];
        let want = model.generate_greedy(&prompt, 6);
        let (out, _) = serve(
            vec![req(1, prompt.clone(), 6), req(2, vec![7, 7], 6), req(3, prompt.clone(), 6)],
            3,
            10_000,
        );
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        let r3 = out.iter().find(|r| r.id == 3).unwrap();
        let trim = |v: &[u32]| {
            // greedy may stop at EOS in batcher; compare prefix
            v.to_vec()
        };
        assert!(want.starts_with(&trim(&r1.tokens)) || r1.tokens == want);
        assert_eq!(r1.tokens, r3.tokens, "same prompt ⇒ same output");
    }

    #[test]
    fn capacity_backpressure_still_completes() {
        // Pool fits only ~1 sequence at a time; everything must still finish.
        let reqs: Vec<Request> = (0..6).map(|i| req(i, vec![2, 3], 3)).collect();
        let (out, m) = serve(reqs, 4, 6);
        assert_eq!(out.len(), 6);
        assert!(m.rejected_capacity > 0, "expected capacity pushback");
    }

    #[test]
    fn kv_lease_right_sizing_grows_and_truncates_gracefully() {
        // Pool holds 4 tokens. id 0 fits outright. id 1 wants 2+10=12 —
        // under the old upfront prompt+max_new policy this was refused as
        // impossible; right-sized admission serves it and finishes it
        // truncated when the pool cannot grow the lease any further.
        let reqs = vec![req(0, vec![2, 3], 2), req(1, vec![2, 3], 10)];
        let cfg = BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() };
        let (out, m) = serve_cfg(reqs, cfg, 4);
        assert_eq!(out.len(), 2, "every request gets exactly one response");
        for r in &out {
            assert!(!r.rejected, "id {} must be served, not rejected", r.id);
            assert!(!r.tokens.is_empty());
        }
        let truncated = out.iter().find(|r| r.id == 1).unwrap();
        assert!(
            truncated.tokens.len() < 10,
            "a 4-token pool cannot hold 12 KV positions; got {} tokens",
            truncated.tokens.len()
        );
        assert_eq!(m.requests, 2);
        assert_eq!(m.rejected_impossible, 0);
        assert!(m.truncated_kv >= 1, "grow failure must be counted");
    }

    #[test]
    fn impossible_min_footprint_still_rejected() {
        // Pool holds 3 tokens total; a 3-token prompt needs 4 (prompt + one
        // generated token) — impossible even with lease growth, so it must
        // be refused up front while the feasible request completes.
        let reqs = vec![req(0, vec![2, 3], 2), req(1, vec![2, 3, 4], 5)];
        let (out, m) = serve(reqs, 4, 3);
        assert_eq!(out.len(), 2);
        let served = out.iter().find(|r| r.id == 0).unwrap();
        assert!(!served.rejected);
        assert!(!served.tokens.is_empty());
        let rejected = out.iter().find(|r| r.id == 1).unwrap();
        assert!(rejected.rejected);
        assert!(rejected.tokens.is_empty());
        assert_eq!(rejected.ttft, rejected.total);
        assert_eq!(m.requests, 1);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn right_sized_leases_raise_concurrency_under_tight_pools() {
        // Upfront prompt+max_new leasing needs 10 tokens per sequence
        // (2+8), so a 12-token pool would serialize them. Right-sized
        // admission (prompt + kv_reserve = 4) runs both concurrently and
        // extends leases on demand during decode.
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(12, 8);
        let (tx, rx) = channel();
        for i in 0..2u64 {
            tx.send(req(i, vec![2, 3 + i as u32], 8)).unwrap();
        }
        drop(tx);
        let cfg = BatchConfig {
            max_batch: 4,
            kv_reserve: 2,
            stop_on_eos: false,
            ..Default::default()
        };
        let mut out = Vec::new();
        let m = run_batcher(&model, &pool, &cfg, rx, |r| out.push(r));
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(out.len(), 2);
        assert_eq!(m.peak_batch, 2, "right-sizing must admit both up front");
        assert!(m.kv_grows > 0, "decode must extend leases incrementally");
        assert!(out.iter().all(|r| !r.rejected && !r.tokens.is_empty()));
    }

    #[test]
    fn token_budget_bounds_mixed_iterations() {
        // Five 20-token prompts under an 8-row budget: every iteration's
        // ragged batch stays within the budget, prompts are fed as chunks
        // (not one token per sequence per iteration), and everything
        // completes.
        let reqs: Vec<Request> = (0..5)
            .map(|i| {
                req(i, (0..20).map(|t| 1 + ((t + i as usize) % 100) as u32).collect(), 4)
            })
            .collect();
        let cfg = BatchConfig {
            max_batch: 4,
            token_budget: 8,
            prefill_chunk: 4,
            ..Default::default()
        };
        let (out, m) = serve_cfg(reqs, cfg, 10_000);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|r| !r.rejected && !r.tokens.is_empty() && r.tokens.len() <= 4));
        assert!(
            m.peak_iter_tokens <= 8,
            "token budget violated: {} rows in one iteration",
            m.peak_iter_tokens
        );
        assert_eq!(m.prefill_tokens, 100);
        // 100 prompt tokens at ≤ 8 rows/iteration needs ≥ 13 iterations;
        // well-formed chunking keeps it far under the 100 a per-token
        // scheduler would take.
        assert!(m.iterations >= 13, "iterations {}", m.iterations);
        assert!(m.iterations < 60, "iterations {}", m.iterations);
    }

    #[test]
    fn over_long_prompt_rejected_at_admission() {
        // micro's max_seq is 64. A 70-token prompt can never fit the KV
        // window with one generated token, so it must be rejected at
        // admission; a prompt that just fits (63 tokens, room for exactly
        // one generated token) still runs.
        let long: Vec<u32> = (0..70).map(|i| 1 + (i % 100) as u32).collect();
        let edge: Vec<u32> = (0..63).map(|i| 1 + (i % 100) as u32).collect();
        let (out, m) =
            serve(vec![req(0, long, 3), req(1, edge, 5), req(2, vec![1, 2], 2)], 3, 10_000);
        assert_eq!(out.len(), 3);
        let r0 = out.iter().find(|r| r.id == 0).unwrap();
        assert!(r0.rejected, "over-long prompt must be rejected");
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        assert!(!r1.rejected);
        assert_eq!(r1.tokens.len(), 1, "KV window leaves room for exactly one token");
        assert!(!out.iter().find(|r| r.id == 2).unwrap().rejected);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn empty_prompt_rejected() {
        let (out, m) = serve(vec![req(0, Vec::new(), 4), req(1, vec![3], 2)], 2, 10_000);
        assert_eq!(out.len(), 2);
        assert!(out.iter().find(|r| r.id == 0).unwrap().rejected);
        assert!(!out.iter().find(|r| r.id == 1).unwrap().rejected);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn ttft_stamped_at_prefill_completion() {
        // TTFT is stamped when the prefill-final forward writes its logits
        // back. Invariants pinned: served responses have 0 < ttft <= total,
        // and a prompt whose prefill needs more iterations (narrow chunks
        // force the 12-token prompt through ≥ 3 of them) reaches its first
        // token no earlier than a short one admitted in the same batch.
        let short = req(0, vec![2, 3], 6);
        let long = req(1, (0..12).map(|i| 1 + i as u32).collect(), 6);
        let cfg = BatchConfig {
            max_batch: 2,
            prefill_chunk: 4,
            token_budget: 8,
            ..Default::default()
        };
        let (out, _) = serve_cfg(vec![short, long], cfg, 10_000);
        let r_short = out.iter().find(|r| r.id == 0).unwrap();
        let r_long = out.iter().find(|r| r.id == 1).unwrap();
        for r in [r_short, r_long] {
            assert!(!r.rejected);
            assert!(r.ttft > Duration::ZERO, "ttft must be stamped");
            assert!(r.ttft <= r.total, "ttft {:?} > total {:?}", r.ttft, r.total);
        }
        assert!(
            r_long.ttft >= r_short.ttft,
            "longer prefill cannot reach its first token earlier (short {:?}, long {:?})",
            r_short.ttft,
            r_long.ttft
        );
    }

    #[test]
    fn iteration_count_reflects_continuous_batching() {
        // 4 requests × (2 prompt + 3 decode): chunked prefill feeds each
        // whole prompt in one iteration, so ~4-5 iterations total — not 20.
        let reqs: Vec<Request> = (0..4).map(|i| req(i, vec![2, 3], 3)).collect();
        let (_, m) = serve(reqs, 4, 10_000);
        assert!(m.iterations < 12, "iterations {}", m.iterations);
        assert_eq!(m.prefill_tokens, 8);
        assert!(m.peak_iter_tokens >= 4, "prompts should batch as chunks");
    }

    #[test]
    fn chunked_serving_output_matches_per_token_prefill() {
        // Scheduling policy must not change results: the same request
        // stream served with chunk 1 (old behavior) and with wide chunks
        // produces identical token streams.
        let reqs = || -> Vec<Request> {
            (0..3)
                .map(|i| {
                    req(i, (0..17).map(|t| 1 + ((t * 3 + i as usize) % 90) as u32).collect(), 5)
                })
                .collect()
        };
        let wide = BatchConfig { max_batch: 3, ..Default::default() };
        let narrow = BatchConfig {
            max_batch: 3,
            prefill_chunk: 1,
            token_budget: 3,
            ..Default::default()
        };
        let (out_w, _) = serve_cfg(reqs(), wide, 10_000);
        let (out_n, _) = serve_cfg(reqs(), narrow, 10_000);
        for id in 0..3u64 {
            let w = out_w.iter().find(|r| r.id == id).unwrap();
            let n = out_n.iter().find(|r| r.id == id).unwrap();
            assert_eq!(w.tokens, n.tokens, "id {id}: chunking changed output");
        }
    }
}
