//! Continuous (iteration-level) dynamic batcher — Orca-style scheduling on
//! top of the packed quantized execution engine.
//!
//! The decode loop keeps an *active set* of sequences. Every iteration it
//! (1) admits queued requests while there is batch room AND the KV pool
//! grants a lease (backpressure), (2) advances every active sequence by one
//! token (prompt tokens first — chunked prefill — then greedy decode), and
//! (3) retires finished sequences, freeing their KV lease. New requests
//! therefore join between *iterations*, not between requests.
//!
//! Step (2) is where the throughput property is actually realized: all
//! advancing sequences are stacked into one [`Gpt::forward_step_batch`]
//! call, so each transformer layer runs ONE batched quantized GEMM per
//! iteration (tile-packed int8 weight panels streamed once per batch)
//! instead of one scalar token forward per sequence. The per-token
//! activation-quantization scratch lives in a loop-owned
//! [`QGemmArena`], so the steady-state decode loop does not allocate
//! quantization buffers.
//!
//! Determinism scope: for decode batches under 32 sequences (the default
//! `max_batch` is 8) the batched step is bitwise identical to per-sequence
//! `forward_step`, so greedy outputs match single-sequence generation
//! token-for-token (see `tensor::gemm::matmul_bt_acc`). Larger batches take
//! the split-K blocked kernels and agree only to f32 tolerance.

use super::kvpool::{KvPool, Lease};
use crate::data::vocab::EOS;
use crate::model::{argmax, Gpt, KvCache};
use crate::tensor::QGemmArena;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time from submit to first generated token.
    pub ttft: Duration,
    /// Time from submit to completion.
    pub total: Duration,
    pub prompt_len: usize,
}

struct Active {
    req: Request,
    cache: KvCache,
    lease: Lease,
    /// Next prompt index to feed (prefill progress).
    fed: usize,
    generated: Vec<u32>,
    last_logits: Vec<f32>,
    first_token_at: Option<Instant>,
}

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    /// Wait at most this long for work when idle.
    pub idle_wait: Duration,
    pub stop_on_eos: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 8, idle_wait: Duration::from_millis(5), stop_on_eos: true }
    }
}

/// Metrics the server reports.
#[derive(Clone, Debug, Default)]
pub struct BatchMetrics {
    pub requests: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub iterations: usize,
    pub peak_batch: usize,
    pub rejected_capacity: usize,
}

/// Run the batching loop until the request channel closes and the active
/// set drains. Responses are delivered through `respond`.
pub fn run_batcher(
    model: &Gpt,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: Receiver<Request>,
    mut respond: impl FnMut(Response),
) -> BatchMetrics {
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = BatchMetrics::default();
    let mut channel_open = true;
    let mut pending: Vec<Request> = Vec::new();
    // Reusable activation-quantization scratch for the batched decode step.
    let mut arena = QGemmArena::new();

    while channel_open || !active.is_empty() || !pending.is_empty() {
        // ---- admission ----
        while active.len() < cfg.max_batch && channel_open {
            match rx.recv_timeout(if active.is_empty() && pending.is_empty() {
                cfg.idle_wait
            } else {
                Duration::ZERO
            }) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    channel_open = false;
                }
            }
        }
        let mut still_pending = Vec::new();
        for req in pending.drain(..) {
            if active.len() >= cfg.max_batch {
                still_pending.push(req);
                continue;
            }
            // Lease the full prompt + expected generation upfront.
            let want = req.prompt.len() + req.max_new;
            match pool.alloc(want.min(model.cfg.max_seq)) {
                Some(lease) => {
                    active.push(Active {
                        cache: KvCache::new(&model.cfg),
                        lease,
                        fed: 0,
                        generated: Vec::new(),
                        last_logits: Vec::new(),
                        first_token_at: None,
                        req,
                    });
                    metrics.requests += 1;
                }
                None => {
                    metrics.rejected_capacity += 1;
                    still_pending.push(req);
                }
            }
        }
        pending = still_pending;
        metrics.peak_batch = metrics.peak_batch.max(active.len());
        if active.is_empty() {
            if !channel_open && pending.is_empty() {
                break;
            }
            continue;
        }

        // ---- one iteration: advance every active sequence by one token,
        //      all stacked into a single batched step (one quantized GEMM
        //      per layer per iteration, not per sequence) ----
        metrics.iterations += 1;
        let mut step_tokens: Vec<u32> = Vec::with_capacity(active.len());
        let mut step_idx: Vec<usize> = Vec::with_capacity(active.len());
        for (i, a) in active.iter_mut().enumerate() {
            if a.fed < a.req.prompt.len() {
                let tok = a.req.prompt[a.fed];
                a.fed += 1;
                metrics.prefill_tokens += 1;
                step_tokens.push(tok);
                step_idx.push(i);
            } else {
                let next = argmax(&a.last_logits) as u32;
                a.generated.push(next);
                metrics.generated_tokens += 1;
                if a.first_token_at.is_none() {
                    a.first_token_at = Some(Instant::now());
                }
                let done = a.generated.len() >= a.req.max_new
                    || (cfg.stop_on_eos && next == EOS)
                    || a.cache.len() + 1 >= model.cfg.max_seq;
                if !done {
                    step_tokens.push(next);
                    step_idx.push(i);
                }
            }
        }
        if !step_tokens.is_empty() {
            let logits = {
                // Gather &mut caches for exactly the advancing sequences
                // (step_idx is ascending by construction).
                let mut want = step_idx.iter().copied().peekable();
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(step_idx.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        caches.push(&mut a.cache);
                    }
                }
                model.forward_step_batch(&step_tokens, &mut caches, &mut arena)
            };
            for (row, &i) in step_idx.iter().enumerate() {
                active[i].last_logits = logits.row(row).to_vec();
            }
        }

        // ---- retire finished ----
        let mut i = 0;
        while i < active.len() {
            let done = {
                let a = &active[i];
                a.fed >= a.req.prompt.len()
                    && (a.generated.len() >= a.req.max_new
                        || (cfg.stop_on_eos && a.generated.last() == Some(&EOS))
                        || a.cache.len() + 1 >= model.cfg.max_seq)
            };
            if done {
                let a = active.swap_remove(i);
                pool.free(a.lease);
                let now = Instant::now();
                respond(Response {
                    id: a.req.id,
                    prompt_len: a.req.prompt.len(),
                    tokens: a.generated,
                    ttft: a
                        .first_token_at
                        .map(|t| t - a.req.submitted)
                        .unwrap_or_else(|| now - a.req.submitted),
                    total: now - a.req.submitted,
                });
            } else {
                i += 1;
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use std::sync::mpsc::channel;

    fn serve(reqs: Vec<Request>, max_batch: usize, kv_tokens: usize) -> (Vec<Response>, BatchMetrics) {
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(kv_tokens, 8);
        let (tx, rx) = channel();
        for r in reqs {
            tx.send(r).unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        let cfg = BatchConfig { max_batch, ..Default::default() };
        let m = run_batcher(&model, &pool, &cfg, rx, |r| out.push(r));
        assert_eq!(pool.used_tokens(), 0, "all leases freed");
        (out, m)
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request { id, prompt, max_new, submitted: Instant::now() }
    }

    #[test]
    fn serves_all_requests() {
        let reqs: Vec<Request> =
            (0..10).map(|i| req(i, vec![1 + i as u32, 2, 3], 4)).collect();
        let (out, m) = serve(reqs, 4, 10_000);
        assert_eq!(out.len(), 10);
        assert_eq!(m.requests, 10);
        assert!(m.peak_batch <= 4);
        assert!(out.iter().all(|r| r.tokens.len() <= 4 && !r.tokens.is_empty()));
    }

    #[test]
    fn batched_output_matches_unbatched_greedy() {
        let model = synthetic_model("micro", 51).unwrap();
        let prompt = vec![5u32, 9, 13];
        let want = model.generate_greedy(&prompt, 6);
        let (out, _) = serve(
            vec![req(1, prompt.clone(), 6), req(2, vec![7, 7], 6), req(3, prompt.clone(), 6)],
            3,
            10_000,
        );
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        let r3 = out.iter().find(|r| r.id == 3).unwrap();
        let trim = |v: &[u32]| {
            // greedy may stop at EOS in batcher; compare prefix
            v.to_vec()
        };
        assert!(want.starts_with(&trim(&r1.tokens)) || r1.tokens == want);
        assert_eq!(r1.tokens, r3.tokens, "same prompt ⇒ same output");
    }

    #[test]
    fn capacity_backpressure_still_completes() {
        // Pool fits only ~1 sequence at a time; everything must still finish.
        let reqs: Vec<Request> = (0..6).map(|i| req(i, vec![2, 3], 3)).collect();
        let (out, m) = serve(reqs, 4, 6);
        assert_eq!(out.len(), 6);
        assert!(m.rejected_capacity > 0, "expected capacity pushback");
    }

    #[test]
    fn iteration_count_reflects_continuous_batching() {
        // 4 requests × (2 prompt + 3 decode) ≈ 5 iterations if perfectly
        // batched, not 20 — continuous batching interleaves.
        let reqs: Vec<Request> = (0..4).map(|i| req(i, vec![2, 3], 3)).collect();
        let (_, m) = serve(reqs, 4, 10_000);
        assert!(m.iterations < 12, "iterations {}", m.iterations);
        assert_eq!(m.prefill_tokens, 8);
    }
}
