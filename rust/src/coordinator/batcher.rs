//! Continuous (iteration-level) dynamic batcher — Orca-style scheduling on
//! top of the packed quantized execution engine, now driving **streaming,
//! cancellable, per-request-sampled** generation for the [`super::engine`]
//! facade.
//!
//! The decode loop keeps an *active set* of sequences. Every iteration it
//! (1) admits queued [`Submission`]s while there is batch room AND the KV
//! pool grants a lease (backpressure), (2) sweeps cancellation flags,
//! (3) plans a **ragged chunk batch** under a per-iteration token budget and
//! advances it through ONE [`Gpt::forward_chunk_batch`] call, sampling and
//! **emitting each token the instant its logits are written back**, and
//! (4) retires finished sequences, freeing their KV lease and sending a
//! terminal [`TokenEvent::Finished`]. New requests therefore join between
//! *iterations*, not between requests.
//!
//! ## Streaming protocol
//!
//! Each sequence's event channel carries, in order:
//! `PrefillDone { ttft }`, then one `Token { token, index }` per generated
//! token (indices are contiguous from 0), then exactly one
//! `Finished { reason, .. }`. Rejected and cancelled-while-queued requests
//! skip straight to `Finished`. The KV lease is returned to the pool
//! **before** the `Finished` event is sent, so an observer that has seen the
//! terminal event can rely on the capacity being reusable.
//!
//! ## Sampling (per-`Active` state)
//!
//! The pre-Engine batcher hardwired `argmax` over a terminal logits buffer.
//! Now every active sequence owns a [`Sampler`] built from its request's
//! [`SamplingParams`]; the token is drawn at logits writeback (greedy /
//! temperature / top-k / top-p with the request's private seeded RNG) and
//! `max_new` / EOS / per-request stop tokens are evaluated at the same
//! moment. Because a sampler consumes RNG draws only for its own rows —
//! exactly one per non-greedy token — token streams are bitwise-reproducible
//! across batch shapes, chunk widths, and co-scheduled traffic.
//!
//! ## Cancellation
//!
//! Every submission carries a shared `AtomicBool`. The loop checks it once
//! per iteration (and at admission for still-queued requests): a raised flag
//! finishes the sequence with [`FinishReason::Cancelled`], frees its KV
//! lease that same iteration, and emits the terminal event. A closed event
//! channel (the handle was dropped) is treated as an implicit cancel the
//! next time the loop tries to emit, so abandoned streams cannot pin KV
//! capacity.
//!
//! ## Scheduling policy (step 3)
//!
//! Each iteration assembles at most [`BatchConfig::token_budget`] token
//! rows:
//! - **Decode rows first.** Every sequence past its prompt feeds its one
//!   pending token, unconditionally — decode latency never queues behind a
//!   long prefill.
//! - **Prompt chunks share the remainder.** Each still-prefilling sequence
//!   may feed up to [`BatchConfig::prefill_chunk`] prompt tokens from the
//!   leftover budget, with a rotating round-robin start for fairness.
//!
//! All planned spans stack into a single ragged forward: one batched
//! quantized GEMM per layer per iteration over Σ span rows, with the
//! lm_head GEMM run only for rows the scheduler reads back. Between those
//! GEMMs, per-sequence attention fans out across (sequence × head) work
//! items on the paged head-major KV storage (`Gpt::attn_layer` +
//! `tensor::attn_kernel`).
//!
//! ## Prefix cache (admission reuse)
//!
//! Under [`BatchConfig::prefix_cache`] admission asks the pool for the
//! longest cached prefix of the prompt ([`KvPool::match_prefix`]): the
//! matched whole-page positions are adopted as the sequence's leading KV
//! pages (ref-counted, read-only until a divergent write copies them) and
//! `fed` starts past them, so prefill runs only over the novel suffix —
//! TTFT reflects the skipped work. When a prefill completes, its
//! whole-page prefix is published back into the pool's token trie
//! ([`KvPool::insert_prefix`]) for later requests. The lease still covers
//! the FULL sequence span including matched positions: prefix reuse saves
//! compute, not pool accounting, so admission backpressure is unchanged.
//! Cached pages hold bitwise exactly what a cold prefill would recompute
//! (per-position attention and per-position int8 quantization are
//! chunking-invariant), so serving output is identical with the cache on
//! or off.
//!
//! ## KV leases (admission + growth)
//!
//! Admission distinguishes **transient** capacity pushback (re-queued;
//! `BatchMetrics::rejected_capacity`) from **impossible** requests — empty
//! prompt, or `prompt + 1` beyond the KV window or the whole pool — which
//! finish immediately with [`FinishReason::Rejected`]
//! (`BatchMetrics::rejected_impossible`); re-queueing them forever was an
//! admission livelock. Feasible requests lease right-sized
//! (`prompt + min(max_new, kv_reserve)`) and decode extends the lease
//! through [`KvPool::grow`]; when the pool cannot grow a lease even by one
//! token the sequence finishes gracefully with
//! [`FinishReason::TruncatedKv`].
//!
//! ## Speculative decoding ([`BatchConfig::spec_k`] + a [`DraftModel`])
//!
//! With a draft model attached ([`run_batcher_spec`]) and `spec_k ≥ 1`,
//! each decode-ready sequence runs the draft/verify/rollback protocol
//! documented in [`crate::model::draft`]: the draft proposes up to
//! `spec_k` tokens (batched across sequences at draft depth), the planner
//! stacks `[pending, d₁ … d_k]` as one [`ChunkLogits::All`] span of the
//! SAME ragged target forward prefill shares, and writeback walks the
//! `k+1` logits rows accepting the longest draft prefix plus one
//! corrected (or bonus) token via [`Sampler::accept`]. Unconfirmed
//! positions roll back with `KvCache::truncate` on both the target and
//! draft caches — whole rolled-back pages return to the pool meter. The
//! per-sequence depth degrades (never the correctness) near `max_new`,
//! the KV window, or an ungrowable lease, and `spec_k = 0` (or no draft)
//! is exactly the non-speculative path. Output streams are bitwise
//! invariant to `spec_k` — see the distribution argument in
//! [`crate::model::draft`] — so speculation is purely a throughput knob,
//! accounted by `BatchMetrics::{spec_drafted, spec_accepted,
//! spec_rejected}`.
//!
//! TTFT is stamped when the chunked forward that ends a sequence's prefill
//! writes its logits back — the instant its first token is sampled — and
//! delivered immediately as `PrefillDone`.
//!
//! Determinism scope: per-sequence attention is identical across chunkings
//! by construction, the int-GEMM path is bitwise identical across batch
//! shapes, and sampler RNG consumption is batch-independent, so outputs
//! match single-sequence generation token-for-token on quantized models
//! (greedy: exactly the `Gpt::generate_greedy` stream, truncated at the
//! KV window).

use super::kvpool::{KvPool, Lease};
use crate::data::vocab::EOS;
use crate::model::{
    ChunkLogits, DraftModel, Gpt, KvCache, KvDtype, Sampler, SamplingParams, SeqChunk,
    PREFILL_CHUNK,
};
use crate::tensor::QGemmArena;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One generation request, as submitted through `Engine::submit` (or the
/// `serve_requests` compat wrapper).
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request decoding policy (greedy, temperature/top-k/top-p with a
    /// deterministic seed, extra stop tokens).
    pub sampling: SamplingParams,
    pub submitted: Instant,
    /// Time-to-first-token budget, measured from `submitted`. A request
    /// still queued or prefilling when it elapses finishes with
    /// [`FinishReason::DeadlineExceeded`] on the next sweep; once the first
    /// token is out this deadline is moot.
    pub ttft_deadline: Option<Duration>,
    /// End-to-end budget, measured from `submitted`. Swept once per batcher
    /// iteration (and at admission), so an expired stream keeps whatever
    /// tokens it already emitted and its KV lease is released within one
    /// iteration.
    pub deadline: Option<Duration>,
}

impl GenRequest {
    /// Greedy request stamped now — the common case for benches and tests.
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new,
            sampling: SamplingParams::greedy(),
            submitted: Instant::now(),
            ttft_deadline: None,
            deadline: None,
        }
    }

    /// Builder-style end-to-end deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> GenRequest {
        self.deadline = Some(deadline);
        self
    }

    /// Builder-style TTFT deadline.
    pub fn with_ttft_deadline(mut self, deadline: Duration) -> GenRequest {
        self.ttft_deadline = Some(deadline);
        self
    }

    /// Has this request blown a deadline as of `now`? `first_token_out`
    /// gates the TTFT deadline: it only applies while the first token is
    /// still pending.
    fn expired(&self, now: Instant, first_token_out: bool) -> bool {
        let elapsed = now.saturating_duration_since(self.submitted);
        if let Some(d) = self.deadline {
            if elapsed > d {
                return true;
            }
        }
        if !first_token_out {
            if let Some(d) = self.ttft_deadline {
                if elapsed > d {
                    return true;
                }
            }
        }
        false
    }
}

/// Why a request's stream ended. Replaces the old `Response::rejected` flag
/// with the full outcome taxonomy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// EOS (under `BatchConfig::stop_on_eos`) or a per-request stop token
    /// was generated; the stop token itself is the stream's last token.
    Eos,
    /// `max_new` tokens were generated (zero for a `max_new == 0` request,
    /// which finishes at admission), or the model's context window
    /// (`ModelConfig::max_seq`) left no room to feed another token.
    Length,
    /// `RequestHandle::cancel()` was called (or the handle was dropped).
    Cancelled,
    /// The KV pool could not grow the sequence's lease by even one token;
    /// the stream keeps everything generated so far.
    TruncatedKv,
    /// Refused at admission: the request could never run (empty prompt, or
    /// `prompt + 1` beyond the KV window or the whole pool). No tokens.
    Rejected,
    /// The request's `deadline` (or `ttft_deadline`, while the first token
    /// was still pending) elapsed. The stream keeps everything generated
    /// before expiry; the KV lease was released the same iteration.
    DeadlineExceeded,
    /// The worker serving this request died mid-flight (a panic caught by
    /// the batcher's isolation layer, or a stranded queue drained at
    /// shutdown). In-flight progress is lost; queued requests are
    /// re-dispatched to surviving workers instead, so this reason is only
    /// seen when no worker could take the request over.
    WorkerFailed,
}

impl FinishReason {
    /// True for streams that ran to a natural end (served requests):
    /// rejected, cancelled, expired, and worker-failed streams carry no
    /// complete latency signal.
    pub fn is_completed(&self) -> bool {
        matches!(self, FinishReason::Eos | FinishReason::Length | FinishReason::TruncatedKv)
    }

    /// The one `FinishReason` → wire-string mapping, shared by every external
    /// surface (the HTTP front end's `finish_reason` field, benches, tools).
    /// `Eos` serializes as `"stop"` per the OpenAI completions convention.
    /// Deliberately an exhaustive match with no wildcard arm: a new variant
    /// fails compilation here until it is given a wire name, and
    /// `wire_str_pins_every_variant` pins each existing name so none can
    /// silently change.
    pub fn wire_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "stop",
            FinishReason::Length => "length",
            FinishReason::Cancelled => "cancelled",
            FinishReason::TruncatedKv => "truncated_kv",
            FinishReason::Rejected => "rejected",
            FinishReason::DeadlineExceeded => "deadline",
            FinishReason::WorkerFailed => "worker_failed",
        }
    }
}

/// One event on a request's stream. See the module doc for the protocol
/// (`PrefillDone` → `Token`* → `Finished`).
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// Prefill completed; the first token was just determined. `ttft` is
    /// measured from `GenRequest::submitted`.
    PrefillDone { ttft: Duration },
    /// A generated token. `index` counts from 0 and is contiguous.
    Token { token: u32, index: usize },
    /// Terminal event: the stream is complete and the KV lease has already
    /// been returned to the pool. For streams that never reached their
    /// first token (rejected / early-cancelled), `ttft == total`.
    Finished { reason: FinishReason, n_tokens: usize, ttft: Duration, total: Duration },
}

/// A drop-guard that releases one unit of engine-side accounting exactly
/// once, no matter which worker (or cleanup path) retires the request it
/// rides on. `counter -= amount` on drop; panic-safe by construction —
/// worker-failure cleanup drops the owning `Submission`/`Active` and the
/// accounting drains with it, so load/queue counters can never wedge the
/// engine's routing or `submit_wait`.
pub struct CountGuard {
    counter: Arc<AtomicUsize>,
    amount: usize,
}

impl CountGuard {
    /// Add `amount` to `counter` now; subtract it back when dropped.
    pub fn add(counter: &Arc<AtomicUsize>, amount: usize) -> CountGuard {
        counter.fetch_add(amount, Ordering::SeqCst);
        CountGuard { counter: Arc::clone(counter), amount }
    }
}

impl Drop for CountGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(self.amount, Ordering::SeqCst);
    }
}

/// A request paired with its event channel and cancellation flag — the unit
/// the engine routes to a worker. Public so tests can drive [`run_batcher`]
/// directly; `Engine::submit` is the normal constructor.
pub struct Submission {
    pub req: GenRequest,
    pub events: Sender<TokenEvent>,
    pub cancel: Arc<AtomicBool>,
    /// Engine load accounting (`prompt + max_new` against the origin
    /// worker), released at the terminal event by whichever worker (or
    /// cleanup path) gets there. `None` for direct batcher tests.
    pub load: Option<CountGuard>,
    /// Engine queue-depth accounting, released at admission (or a queued
    /// finish) — the counter behind `EngineConfig::queue_cap`.
    pub queue_slot: Option<CountGuard>,
}

impl Submission {
    /// Wire a request to a fresh event channel + cancel flag. Returns the
    /// submission plus the receiving side (what `RequestHandle` wraps).
    pub fn channel(req: GenRequest) -> (Submission, Receiver<TokenEvent>, Arc<AtomicBool>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        (
            Submission {
                req,
                events: tx,
                cancel: Arc::clone(&cancel),
                load: None,
                queue_slot: None,
            },
            rx,
            cancel,
        )
    }
}

/// Cross-worker hand-off shelf for requests stranded by a dead worker: the
/// panic-isolation path pushes its queued (not-yet-admitted) submissions
/// here, and every surviving worker adopts from it during intake. Whatever
/// is still here after all workers have joined is failed by the engine with
/// [`FinishReason::WorkerFailed`] terminal events — the backstop that keeps
/// "exactly one terminal event per submission" true even when the last
/// worker dies.
#[derive(Default)]
pub struct Orphanage {
    /// Queued submissions a dying worker shelved for re-dispatch.
    subs: Mutex<Vec<Submission>>,
    /// Dead workers' submission receivers, parked so the channels stay
    /// open: a submit that raced the worker's death lands here instead of
    /// vanishing into a dropped `Receiver`, and [`Orphanage::adopt`] picks
    /// it up.
    rxs: Mutex<Vec<Receiver<Submission>>>,
}

impl Orphanage {
    pub fn new() -> Orphanage {
        Orphanage::default()
    }

    /// Shelve queued submissions from a dying worker.
    pub fn push_all(&self, subs: impl IntoIterator<Item = Submission>) {
        // A worker cannot panic while holding these locks (no user code
        // runs under them), but recover from poisoning anyway.
        self.subs.lock().unwrap_or_else(|e| e.into_inner()).extend(subs);
    }

    /// Park a dead worker's receiver so its channel never closes with a
    /// submission still in flight.
    pub fn park_receiver(&self, rx: Receiver<Submission>) {
        self.rxs.lock().unwrap_or_else(|e| e.into_inner()).push(rx);
    }

    /// Take everything stranded right now: the shelf, plus whatever is
    /// readable from parked dead-worker channels.
    pub fn adopt(&self) -> Vec<Submission> {
        let mut out = std::mem::take(&mut *self.subs.lock().unwrap_or_else(|e| e.into_inner()));
        for rx in self.rxs.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            while let Ok(sub) = rx.try_recv() {
                out.push(sub);
            }
        }
        out
    }
}

/// Per-worker runtime environment for [`run_batcher_env`]: everything the
/// resilience layer threads into the loop. `RunEnv::default()` is the
/// plain single-worker setup the direct batcher tests use.
#[derive(Default)]
pub struct RunEnv {
    /// Worker index, for fault attribution and diagnostics.
    pub worker: usize,
    /// Engine-raised abort switch: when set, the loop cancels every active
    /// and queued request and exits without further model work.
    pub abort: Option<Arc<AtomicBool>>,
    /// Cleared (set `false`) when this worker's loop exits for any reason —
    /// the engine routes submissions only to workers still flagged alive.
    pub alive: Option<Arc<AtomicBool>>,
    /// Shared shelf for dead workers' queued requests; surviving workers
    /// adopt from it during intake.
    pub orphans: Option<Arc<Orphanage>>,
    /// Deterministic fault schedule (injected panics / KV clamps / stalls)
    /// for this worker; see [`super::faults`].
    pub faults: Option<super::faults::WorkerFaults>,
}

/// Per-sequence speculative-decoding state (present only when a draft
/// model is attached): the draft's private layer-truncated KV cache plus
/// the full emitted token history (prompt + generated) the draft trails
/// behind on. `hist[cache.len()..]` is always the catch-up tail.
struct DraftSeq {
    cache: KvCache,
    hist: Vec<u32>,
}

/// An in-flight sequence.
struct Active {
    req: GenRequest,
    events: Sender<TokenEvent>,
    cancel: Arc<AtomicBool>,
    sampler: Sampler,
    cache: KvCache,
    lease: Lease,
    /// Next prompt index to feed (prefill progress).
    fed: usize,
    /// Tokens sampled (and emitted) so far.
    n_generated: usize,
    /// Sampled but not yet fed back to the model.
    pending: Option<u32>,
    first_token_at: Option<Instant>,
    /// Set when a terminal condition is decided; retired at end of iteration.
    finish: Option<FinishReason>,
    /// Speculation state; `None` when serving non-speculatively.
    draft: Option<DraftSeq>,
    /// This iteration's draft proposals (set at planning, consumed at
    /// writeback by the acceptance walk).
    proposed: Vec<u32>,
    /// Engine load accounting, released on drop (i.e. when this sequence
    /// retires — by any path, including worker-failure cleanup).
    _load: Option<CountGuard>,
}

impl Active {
    /// Emit an event; a closed channel (dropped handle) becomes an implicit
    /// cancel so abandoned streams release their KV lease.
    fn emit(&mut self, ev: TokenEvent) {
        if self.events.send(ev).is_err() && self.finish.is_none() {
            self.finish = Some(FinishReason::Cancelled);
        }
    }
}

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Max concurrently active sequences.
    pub max_batch: usize,
    /// Per-iteration token-row budget for the ragged forward. Decode rows
    /// (one per decoding sequence, bounded by `max_batch`) are always
    /// planned; prompt chunks share whatever remains.
    pub token_budget: usize,
    /// Max prompt tokens one sequence feeds per iteration.
    pub prefill_chunk: usize,
    /// Decode headroom reserved at admission: the initial KV lease covers
    /// `prompt + min(max_new, kv_reserve)` tokens; the rest is leased
    /// incrementally by [`KvPool::grow`] during decode.
    pub kv_reserve: usize,
    /// Preferred tokens per decode-time lease grow (amortizes pool-lock
    /// traffic; growth falls back to the single token actually needed when
    /// the pool is nearly full).
    pub kv_grow: usize,
    /// Wait at most this long for work when idle.
    pub idle_wait: Duration,
    pub stop_on_eos: bool,
    /// KV-cache storage dtype for admitted sequences. `Int8` stores K/V as
    /// symmetric int8 codes + per-row scales (≈ 3–4x more resident
    /// sequences at equal pool bytes — engine pool sizing follows this
    /// knob) and sweeps attention through the fused-dequant kernels; `F32`
    /// is the exact baseline.
    pub kv_dtype: KvDtype,
    /// Reuse cached KV prefix pages at admission and publish every
    /// completed prefill's whole-page prefix into the pool's token trie.
    /// Output is bitwise identical on or off (see the module doc); off
    /// disables both matching and publishing — useful for A/B benches and
    /// as a kill switch.
    pub prefix_cache: bool,
    /// Speculation depth: draft tokens proposed per sequence per decode
    /// iteration (effective only when a [`DraftModel`] is attached via
    /// [`run_batcher_spec`]). `0` disables speculation; output streams are
    /// bitwise invariant to this knob (see the module doc).
    pub spec_k: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 8,
            token_budget: 2 * PREFILL_CHUNK,
            prefill_chunk: PREFILL_CHUNK,
            kv_reserve: 16,
            kv_grow: 16,
            idle_wait: Duration::from_millis(5),
            stop_on_eos: true,
            kv_dtype: KvDtype::F32,
            prefix_cache: true,
            spec_k: 0,
        }
    }
}

/// Metrics the server reports. Finished streams are counted once each under
/// their [`FinishReason`]: `finished_eos + finished_length + cancelled +
/// truncated_kv + rejected_impossible` equals the number of terminal events
/// emitted.
#[derive(Clone, Debug, Default)]
pub struct BatchMetrics {
    /// Requests admitted into the active set.
    pub requests: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub iterations: usize,
    pub peak_batch: usize,
    /// Most token rows fed in one ragged forward. Bounded by
    /// `max(token_budget, concurrent decode rows · (1 + spec_k))` — decode
    /// rows (≤ `max_batch`, up to `1 + spec_k` rows each when
    /// speculating) are planned unconditionally; only prompt chunks are
    /// budget-limited.
    pub peak_iter_tokens: usize,
    /// Transient pool pushback: the request was re-queued and admitted
    /// later.
    pub rejected_capacity: usize,
    /// Streams finished [`FinishReason::Rejected`] (see the module doc's
    /// admission rules).
    pub rejected_impossible: usize,
    /// Successful incremental lease grows during decode.
    pub kv_grows: usize,
    /// Streams finished [`FinishReason::TruncatedKv`].
    pub truncated_kv: usize,
    /// Streams finished [`FinishReason::Cancelled`] — by flag, or by a
    /// dropped handle.
    pub cancelled: usize,
    /// Streams finished [`FinishReason::Eos`].
    pub finished_eos: usize,
    /// Streams finished [`FinishReason::Length`].
    pub finished_length: usize,
    /// Pool-occupancy high-water mark over the run: leased + trie-cached
    /// tokens, sampled after every alloc/grow — the KV pressure signal.
    pub peak_tokens: usize,
    /// Admissions that adopted ≥ 1 cached prefix page.
    pub prefix_hits: usize,
    /// Prompt tokens skipped at prefill time because a cached prefix page
    /// already held them (whole `KV_TILE` pages per hit).
    pub prefix_hit_tokens: usize,
    /// Draft tokens proposed across all speculative verify spans.
    pub spec_drafted: usize,
    /// Draft tokens confirmed by the target's acceptance walk — each one
    /// is a decode token that skipped its own target iteration.
    pub spec_accepted: usize,
    /// Draft tokens rolled back (`spec_drafted − spec_accepted`): rejected
    /// by the acceptance sample, or discarded past a mid-span finish.
    pub spec_rejected: usize,
    /// Streams finished [`FinishReason::DeadlineExceeded`] — TTFT or
    /// end-to-end budget blown while queued, prefilling, or decoding.
    pub deadline_expired: usize,
    /// Streams finished [`FinishReason::WorkerFailed`]: in-flight on a
    /// worker when it died, or stranded in a queue no survivor could adopt.
    pub worker_failed: usize,
    /// Submissions the engine refused with `SubmitError::QueueFull`
    /// (per-worker queue depth at `EngineConfig::queue_cap`). Counted by
    /// the engine at reject time and folded into this worker's metrics at
    /// join — these requests never produced a stream.
    pub shed_queue_full: usize,
}

impl BatchMetrics {
    pub(crate) fn count_finish(&mut self, reason: FinishReason) {
        match reason {
            FinishReason::Eos => self.finished_eos += 1,
            FinishReason::Length => self.finished_length += 1,
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::TruncatedKv => self.truncated_kv += 1,
            FinishReason::Rejected => self.rejected_impossible += 1,
            FinishReason::DeadlineExceeded => self.deadline_expired += 1,
            FinishReason::WorkerFailed => self.worker_failed += 1,
        }
    }
}

/// Finish a request that never entered the active set (rejected at
/// admission, or cancelled while queued): terminal event + bookkeeping.
fn finish_queued(
    sub: Submission,
    reason: FinishReason,
    metrics: &mut BatchMetrics,
    on_finish: &mut impl FnMut(&GenRequest, FinishReason),
) {
    metrics.count_finish(reason);
    let waited = Instant::now() - sub.req.submitted;
    let _ = sub.events.send(TokenEvent::Finished {
        reason,
        n_tokens: 0,
        ttft: waited,
        total: waited,
    });
    on_finish(&sub.req, reason);
}

/// Run the batching loop until the submission channel closes and the active
/// set drains. Token streams are delivered through each submission's event
/// channel; `on_finish` fires once per request after its terminal event
/// (the engine uses it for load accounting).
pub fn run_batcher(
    model: &Gpt,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: Receiver<Submission>,
    on_finish: impl FnMut(&GenRequest, FinishReason),
) -> BatchMetrics {
    run_batcher_spec(model, None, pool, cfg, rx, on_finish)
}

/// [`run_batcher`] with an optional speculative draft model. Speculation
/// engages only when BOTH a draft is supplied and `cfg.spec_k ≥ 1`;
/// otherwise this is exactly the non-speculative loop. See the module
/// doc's speculation section for the protocol.
pub fn run_batcher_spec(
    model: &Gpt,
    draft: Option<&DraftModel>,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: Receiver<Submission>,
    on_finish: impl FnMut(&GenRequest, FinishReason),
) -> BatchMetrics {
    run_batcher_env(model, draft, pool, cfg, rx, RunEnv::default(), on_finish)
}

/// Loop state that outlives one iteration — kept outside the
/// `catch_unwind` boundary so the failure path can still walk the active
/// set, free leases, and re-home queued requests after a panic. Every
/// mutation inside an iteration leaves this structurally valid (panics can
/// interrupt a forward pass, never a `Vec` splice).
struct LoopState {
    active: Vec<Active>,
    metrics: BatchMetrics,
    channel_open: bool,
    pending: Vec<Submission>,
    /// Reusable activation-quantization scratch for the chunked forward.
    arena: QGemmArena,
    /// Rotating start index for prefill chunk grants (fairness).
    prefill_rr: usize,
    /// Loop-pass counter. Unlike `metrics.iterations` it advances on idle
    /// passes too — fault schedules key off it, so a clamp window always
    /// lifts even when the clamp itself has emptied the active set.
    pass: usize,
}

/// What one loop pass decided.
enum Step {
    Continue,
    Done,
}

/// [`run_batcher_spec`] with an explicit worker environment — the full
/// resilience-aware entry point the engine uses. Each loop pass runs under
/// `catch_unwind`: a panic anywhere in the iteration body (injected fault
/// or real bug) terminates this worker's in-flight streams with
/// [`FinishReason::WorkerFailed`], frees their leases, quarantines the
/// prefix trie, and hands queued requests (plus the still-open submission
/// channel) to the [`Orphanage`] so surviving workers adopt them — the
/// worker dies, the engine doesn't. An engine-raised `env.abort` cancels
/// everything and exits without further model work (the engine drops the
/// sender right after raising it, so the final drain terminates).
pub fn run_batcher_env(
    model: &Gpt,
    draft: Option<&DraftModel>,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: Receiver<Submission>,
    mut env: RunEnv,
    mut on_finish: impl FnMut(&GenRequest, FinishReason),
) -> BatchMetrics {
    // Speculation is on for the whole run or not at all; per-sequence
    // depth still degrades dynamically near limits.
    let draft = if cfg.spec_k > 0 { draft } else { None };
    let mut st = LoopState {
        active: Vec::new(),
        metrics: BatchMetrics::default(),
        channel_open: true,
        pending: Vec::new(),
        arena: QGemmArena::new(),
        prefill_rr: 0,
        pass: 0,
    };
    let mut failed = false;
    loop {
        if env.abort.as_ref().is_some_and(|a| a.load(Ordering::Acquire)) {
            abort_all(&mut st, pool, &rx, &mut on_finish);
            break;
        }
        let step = catch_unwind(AssertUnwindSafe(|| {
            iteration(model, draft, pool, cfg, &rx, &mut st, &mut env, &mut on_finish)
        }));
        match step {
            Ok(Step::Continue) => {}
            Ok(Step::Done) => break,
            Err(_) => {
                worker_failed_cleanup(&mut st, pool, &rx, &mut env, &mut on_finish);
                failed = true;
                break;
            }
        }
    }
    // Flip alive BEFORE parking the receiver: the engine routes only to
    // alive workers, and anything that raced past the check lands in the
    // parked channel where survivors (or the engine's shutdown drain)
    // adopt it — no submission is ever silently dropped.
    if let Some(alive) = &env.alive {
        alive.store(false, Ordering::Release);
    }
    if failed {
        if let Some(orph) = &env.orphans {
            orph.park_receiver(rx);
        }
    }
    st.metrics.peak_tokens = pool.peak_tokens();
    st.metrics
}

/// Engine-raised abort: cancel every in-flight and queued request with a
/// terminal event, free leases, and drain the submission channel until it
/// disconnects (the engine drops the sender right after raising abort).
fn abort_all(
    st: &mut LoopState,
    pool: &KvPool,
    rx: &Receiver<Submission>,
    on_finish: &mut impl FnMut(&GenRequest, FinishReason),
) {
    for a in st.active.drain(..) {
        retire_one(a, FinishReason::Cancelled, pool, &mut st.metrics, on_finish);
    }
    for sub in st.pending.drain(..) {
        finish_queued(sub, FinishReason::Cancelled, &mut st.metrics, on_finish);
    }
    loop {
        match rx.try_recv() {
            Ok(sub) => finish_queued(sub, FinishReason::Cancelled, &mut st.metrics, on_finish),
            Err(TryRecvError::Empty) => std::thread::sleep(Duration::from_micros(200)),
            Err(TryRecvError::Disconnected) => break,
        }
    }
}

/// The panic-isolation path: the iteration body unwound. In-flight streams
/// terminate with [`FinishReason::WorkerFailed`] (lease freed before the
/// terminal event, as everywhere), the prefix trie is quarantined — a
/// panic may have interrupted a page write, and dropping the trie both
/// discards any suspect cached state and lets the page meter drain — and
/// queued requests are shelved for surviving workers (or failed here when
/// running without an engine).
fn worker_failed_cleanup(
    st: &mut LoopState,
    pool: &KvPool,
    rx: &Receiver<Submission>,
    env: &mut RunEnv,
    on_finish: &mut impl FnMut(&GenRequest, FinishReason),
) {
    // A worker that dies inside a fault clamp window must not leave its
    // pool pinched forever.
    if let Some(f) = env.faults.as_mut() {
        f.restore(pool);
    }
    pool.clear_prefix_cache();
    for a in st.active.drain(..) {
        retire_one(a, FinishReason::WorkerFailed, pool, &mut st.metrics, on_finish);
    }
    let mut stranded: Vec<Submission> = st.pending.drain(..).collect();
    while let Ok(sub) = rx.try_recv() {
        stranded.push(sub);
    }
    match env.orphans.as_deref() {
        Some(orph) => orph.push_all(stranded),
        None => {
            for sub in stranded {
                finish_queued(sub, FinishReason::WorkerFailed, &mut st.metrics, on_finish);
            }
        }
    }
}

/// Free the lease and emit the terminal event for one active sequence —
/// the single retire path shared by the normal loop, abort, and
/// worker-failure cleanup.
fn retire_one(
    mut a: Active,
    reason: FinishReason,
    pool: &KvPool,
    metrics: &mut BatchMetrics,
    on_finish: &mut impl FnMut(&GenRequest, FinishReason),
) {
    // Free the lease BEFORE the terminal event: once `Finished` is
    // observable, the capacity is back in the pool.
    pool.free(a.lease);
    metrics.count_finish(reason);
    let now = Instant::now();
    let total = now - a.req.submitted;
    let ttft = a.first_token_at.map(|t| t - a.req.submitted).unwrap_or(total);
    let n_tokens = a.n_generated;
    a.emit(TokenEvent::Finished { reason, n_tokens, ttft, total });
    on_finish(&a.req, reason);
}

/// One pass of the batcher loop: faults → intake (incl. orphan adoption) →
/// admission → cancellation/deadline sweep → ragged plan → one forward →
/// sample/emit → retire. Runs under `catch_unwind` in
/// [`run_batcher_env`].
#[allow(clippy::too_many_arguments)]
fn iteration(
    model: &Gpt,
    draft: Option<&DraftModel>,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: &Receiver<Submission>,
    st: &mut LoopState,
    env: &mut RunEnv,
    mut on_finish: impl FnMut(&GenRequest, FinishReason),
) -> Step {
    let LoopState { active, metrics, channel_open, pending, arena, prefill_rr, pass } = st;
    *pass += 1;
    // Injected faults fire before any pool or model work this pass: stalls
    // sleep, capacity clamps retune the pool, panics unwind into the
    // isolation layer above.
    if let Some(f) = env.faults.as_mut() {
        f.before_pass(*pass, pool);
    }

    {
        // ---- intake ----
        while active.len() < cfg.max_batch && *channel_open {
            match rx.recv_timeout(if active.is_empty() && pending.is_empty() {
                cfg.idle_wait
            } else {
                Duration::ZERO
            }) {
                Ok(sub) => pending.push(sub),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    *channel_open = false;
                }
            }
        }
        // Adopt requests stranded by dead sibling workers (their queued
        // submissions, plus anything still readable from their parked
        // channels).
        if let Some(orph) = env.orphans.as_deref() {
            pending.extend(orph.adopt());
        }
        if !*channel_open && active.is_empty() && pending.is_empty() {
            return Step::Done;
        }

        // ---- admission ----
        let mut still_pending = Vec::new();
        let admit_now = Instant::now();
        for sub in pending.drain(..) {
            if sub.cancel.load(Ordering::Acquire) {
                finish_queued(sub, FinishReason::Cancelled, metrics, &mut on_finish);
                continue;
            }
            // Queued requests are swept against their deadlines too: a
            // request that blew its TTFT (or total) budget while waiting
            // for batch room or pool capacity sheds here instead of
            // burning a prefill it can no longer use.
            if sub.req.expired(admit_now, false) {
                finish_queued(sub, FinishReason::DeadlineExceeded, metrics, &mut on_finish);
                continue;
            }
            if active.len() >= cfg.max_batch {
                still_pending.push(sub);
                continue;
            }
            // A request is IMPOSSIBLE only when even its minimum footprint
            // — the prompt plus one generated token — can never fit the KV
            // window or the whole pool (or the prompt is empty: no logits
            // to decode from). Larger demands are admissible: the lease is
            // right-sized now and grown during decode, truncating
            // gracefully if the pool runs out.
            let min_need = sub.req.prompt.len() + 1;
            if sub.req.prompt.is_empty()
                || min_need > model.cfg.max_seq
                || min_need > pool.capacity_tokens()
            {
                finish_queued(sub, FinishReason::Rejected, metrics, &mut on_finish);
                continue;
            }
            if sub.req.max_new == 0 {
                // Valid request asking for nothing: finish immediately with
                // zero tokens instead of burning a prefill whose first
                // sampled token would overshoot the limit. (Checked after
                // the validity rules so an impossible request still reports
                // Rejected, not a "completed" empty stream.)
                finish_queued(sub, FinishReason::Length, metrics, &mut on_finish);
                continue;
            }
            // Right-sized lease: prompt + min(max_new, kv_reserve), clamped
            // to the KV window and pool size (never below prompt + 1).
            let reserve = sub.req.max_new.clamp(1, cfg.kv_reserve.max(1));
            let want = (sub.req.prompt.len() + reserve)
                .min(model.cfg.max_seq)
                .min(pool.capacity_tokens());
            match pool.alloc(want) {
                Some(lease) => {
                    // Longest cached prefix (whole KV_TILE pages; the match
                    // always leaves ≥ 1 novel token so the final chunk still
                    // produces first-token logits). Matched positions are
                    // adopted as shared read-only pages and skipped by
                    // prefill; the lease covers the full span regardless —
                    // reuse saves compute, not accounting.
                    let (matched, pages) = if cfg.prefix_cache {
                        pool.match_prefix(&sub.req.prompt, cfg.kv_dtype)
                    } else {
                        (0, Vec::new())
                    };
                    if matched > 0 {
                        metrics.prefix_hits += 1;
                        metrics.prefix_hit_tokens += matched;
                    }
                    active.push(Active {
                        sampler: Sampler::new(&sub.req.sampling),
                        // Pre-size the page list to the lease so prefill
                        // never repages mid-flight; decode-time lease growth
                        // re-sizes lazily on the next span append.
                        cache: pool.new_cache(&model.cfg, cfg.kv_dtype, pages, lease.tokens),
                        lease,
                        fed: matched,
                        n_generated: 0,
                        pending: None,
                        first_token_at: None,
                        finish: None,
                        // The draft trails the FULL prompt even under a
                        // prefix-cache hit: its private cache is cold.
                        draft: draft.map(|d| DraftSeq {
                            cache: d.new_cache(),
                            hist: sub.req.prompt.clone(),
                        }),
                        proposed: Vec::new(),
                        _load: sub.load,
                        req: sub.req,
                        events: sub.events,
                        cancel: sub.cancel,
                    });
                    // `sub.queue_slot` drops here: the request has left the
                    // submit queue, freeing one `queue_cap` slot.
                    metrics.requests += 1;
                }
                None => {
                    metrics.rejected_capacity += 1;
                    still_pending.push(sub);
                }
            }
        }
        *pending = still_pending;
        metrics.peak_batch = metrics.peak_batch.max(active.len());

        // ---- cancellation + deadline sweep ----
        // Raised flags (and blown deadlines) finish this iteration: the
        // sequence is skipped by the planner below and its lease is freed
        // in the retire phase at the bottom — cancellation- (or expiry-)
        // to-lease-return is at most one iteration.
        let sweep_now = Instant::now();
        for a in active.iter_mut() {
            if a.finish.is_none() && a.cancel.load(Ordering::Acquire) {
                a.finish = Some(FinishReason::Cancelled);
            }
            if a.finish.is_none() && a.req.expired(sweep_now, a.first_token_at.is_some()) {
                a.finish = Some(FinishReason::DeadlineExceeded);
            }
        }

        if active.is_empty() {
            if !*channel_open && pending.is_empty() {
                return Step::Done;
            }
            if !pending.is_empty() {
                // Feasible requests are waiting on pool space held outside
                // this loop (externally shared pool): back off instead of
                // spinning the admission loop hot.
                std::thread::sleep(cfg.idle_wait);
            }
            return Step::Continue;
        }

        // ---- one iteration: plan a ragged prefill+decode batch under the
        //      token budget, advance it through one chunked forward ----
        metrics.iterations += 1;
        let budget = cfg.token_budget.max(1);
        // Planned spans: (active idx, start in `flat`, len, logits kind).
        // Tokens are copied into `flat` so the spans borrow one buffer
        // instead of `active` (whose caches the forward borrows mutably).
        let mut flat: Vec<u32> = Vec::new();
        let mut spans: Vec<(usize, usize, usize, ChunkLogits)> = Vec::new();

        // Decode rows first: every decoding sequence feeds its pending
        // token regardless of prefill pressure. Speculation candidates are
        // collected as (active idx, depth, pending token) — their spans
        // are planned after the batched draft proposal below.
        let mut spec: Vec<(usize, usize, u32)> = Vec::new();
        for (i, a) in active.iter_mut().enumerate() {
            if a.finish.is_some() || a.fed < a.req.prompt.len() {
                continue;
            }
            let Some(next) = a.pending else { continue };
            if a.cache.len() + 1 >= model.cfg.max_seq {
                // The KV window has no room to feed another token; the
                // pending token was already emitted (it needed no slot).
                a.finish = Some(FinishReason::Length);
                continue;
            }
            if a.cache.len() + 1 > a.lease.tokens {
                // Lease exhausted: grow by the preferred step, falling back
                // to the single token actually needed; truncate gracefully
                // when even that fails.
                let need = a.cache.len() + 1 - a.lease.tokens;
                let cap_total = (a.req.prompt.len() + a.req.max_new).min(model.cfg.max_seq);
                let step = cap_total
                    .saturating_sub(a.lease.tokens)
                    .min(cfg.kv_grow.max(1))
                    .max(need);
                if pool.grow(&mut a.lease, step)
                    || (step > need && pool.grow(&mut a.lease, need))
                {
                    metrics.kv_grows += 1;
                } else {
                    a.finish = Some(FinishReason::TruncatedKv);
                    continue;
                }
            }
            // Speculation depth for this step, degraded (never failed)
            // near limits: emit at most the `max_new` remainder; sample
            // row j only where non-speculative decode would still have
            // fed a token (so Length finishes land on the same stream
            // position); stay within the grown lease.
            let mut k_eff = 0usize;
            if a.draft.is_some() {
                k_eff = cfg
                    .spec_k
                    .min((a.req.max_new - a.n_generated).saturating_sub(1))
                    .min((model.cfg.max_seq - a.cache.len()).saturating_sub(2));
                if k_eff > 0 && a.cache.len() + 1 + k_eff > a.lease.tokens {
                    let extra = a.cache.len() + 1 + k_eff - a.lease.tokens;
                    if pool.grow(&mut a.lease, extra) {
                        metrics.kv_grows += 1;
                    } else {
                        k_eff = a.lease.tokens - a.cache.len() - 1;
                    }
                }
            }
            if k_eff > 0 {
                spec.push((i, k_eff, next));
            } else {
                spans.push((i, flat.len(), 1, ChunkLogits::Last));
                flat.push(next);
            }
            a.pending = None;
        }

        // Batched draft proposal: one ragged catch-up forward over every
        // speculating sequence's unseen tail, then ≤ spec_k − 1 batched
        // single-row rounds — all at draft depth. Verify spans stack
        // `[pending, d₁ … d_k]` with ChunkLogits::All for the acceptance
        // walk at writeback.
        if !spec.is_empty() {
            let d = draft.expect("spec candidates only exist with a draft");
            let tails: Vec<Vec<u32>> = spec
                .iter()
                .map(|&(i, ..)| {
                    let ds = active[i].draft.as_ref().expect("speculating without draft state");
                    ds.hist[ds.cache.len()..].to_vec()
                })
                .collect();
            let ks: Vec<usize> = spec.iter().map(|&(_, k, _)| k).collect();
            let props = {
                let mut want = spec.iter().map(|&(i, ..)| i).peekable();
                let mut dcaches: Vec<&mut KvCache> = Vec::with_capacity(spec.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        dcaches.push(&mut a.draft.as_mut().unwrap().cache);
                    }
                }
                d.propose_batch(&tails, &ks, &mut dcaches, arena)
            };
            for (ps, &(i, k, next)) in props.into_iter().zip(&spec) {
                metrics.spec_drafted += k;
                spans.push((i, flat.len(), 1 + k, ChunkLogits::All));
                flat.push(next);
                flat.extend_from_slice(&ps);
                active[i].proposed = ps;
            }
        }
        let mut budget_left = budget.saturating_sub(flat.len());

        // Prompt chunks from the leftover budget, rotating the start index
        // so chunk grants are fair across prefilling sequences.
        let prefilling: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, a)| a.finish.is_none() && a.fed < a.req.prompt.len())
            .map(|(i, _)| i)
            .collect();
        if !prefilling.is_empty() {
            let start = *prefill_rr % prefilling.len();
            *prefill_rr = prefill_rr.wrapping_add(1);
            for k in 0..prefilling.len() {
                if budget_left == 0 {
                    break;
                }
                let i = prefilling[(start + k) % prefilling.len()];
                let a = &mut active[i];
                let remaining = a.req.prompt.len() - a.fed;
                let grant = cfg.prefill_chunk.max(1).min(remaining).min(budget_left);
                let logits = if a.fed + grant == a.req.prompt.len() {
                    ChunkLogits::Last
                } else {
                    ChunkLogits::None
                };
                spans.push((i, flat.len(), grant, logits));
                flat.extend_from_slice(&a.req.prompt[a.fed..a.fed + grant]);
                a.fed += grant;
                metrics.prefill_tokens += grant;
                budget_left -= grant;
            }
        }
        metrics.peak_iter_tokens = metrics.peak_iter_tokens.max(flat.len());

        if !spans.is_empty() {
            // forward_chunk_batch pairs chunks[i] with caches[i]; sort by
            // active index so the ascending &mut gather below lines up.
            spans.sort_unstable_by_key(|&(i, ..)| i);
            let chunks: Vec<SeqChunk> = spans
                .iter()
                .map(|&(_, f0, len, lg)| SeqChunk { tokens: &flat[f0..f0 + len], logits: lg })
                .collect();
            let logits = {
                let mut want = spans.iter().map(|&(i, ..)| i).peekable();
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(spans.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        caches.push(&mut a.cache);
                    }
                }
                model.forward_chunk_batch(&chunks, &mut caches, arena)
            };
            // Logits are materialized now: sample each row's next token at
            // this instant — generation time — and emit it immediately,
            // instead of parking a terminal logits buffer for the next
            // iteration to argmax.
            let logits_at = Instant::now();
            let mut row = 0usize;
            for &(i, _, len, lg) in &spans {
                let nrows = lg.rows(len);
                if nrows == 0 {
                    continue;
                }
                let a = &mut active[i];
                let r0 = row;
                row += nrows;
                if lg == ChunkLogits::All {
                    // Speculative verify span `[pending, d₁ … d_k]`:
                    // acceptance walk over the k+1 rows in position order.
                    // Each emitted token is a plain sampler draw from the
                    // target's row — the draft only decides whether the
                    // walk continues — so RNG consumption and the emitted
                    // stream match non-speculative decode exactly.
                    let props = std::mem::take(&mut a.proposed);
                    let k = nrows - 1;
                    debug_assert_eq!(props.len(), k);
                    // `seen` already advanced over the whole span.
                    let base = a.cache.len() - nrows;
                    let mut n_acc = 0usize;
                    for j in 0..nrows {
                        let lrow = logits.row(r0 + j);
                        let (tok, accepted) = if j < k {
                            a.sampler.accept(lrow, props[j])
                        } else {
                            (a.sampler.sample(lrow), false) // bonus row
                        };
                        let index = a.n_generated;
                        a.n_generated += 1;
                        metrics.generated_tokens += 1;
                        a.emit(TokenEvent::Token { token: tok, index });
                        if accepted {
                            n_acc += 1;
                        }
                        if a.finish.is_some() {
                            break; // channel died mid-emit
                        }
                        if let Some(ds) = a.draft.as_mut() {
                            ds.hist.push(tok);
                        }
                        if (cfg.stop_on_eos && tok == EOS) || a.req.sampling.is_stop_token(tok) {
                            a.finish = Some(FinishReason::Eos);
                        } else if a.n_generated >= a.req.max_new {
                            a.finish = Some(FinishReason::Length);
                        } else if !accepted {
                            // Correction (j < k) or bonus (j == k) token:
                            // it was emitted from a valid row but never
                            // fed — it becomes the next pending token.
                            a.pending = Some(tok);
                        }
                        if a.finish.is_some() || !accepted {
                            break;
                        }
                    }
                    metrics.spec_accepted += n_acc;
                    metrics.spec_rejected += k - n_acc;
                    // Roll back unconfirmed suffix positions on BOTH
                    // caches: the target keeps pending + accepted drafts;
                    // the draft (which consumed its tail + k−1 proposals)
                    // keeps its context + accepted drafts. Whole freed
                    // pages return to the pool meter.
                    a.cache.truncate(base + 1 + n_acc);
                    if let Some(ds) = a.draft.as_mut() {
                        let ctx = ds.cache.len() + 1 - k;
                        ds.cache.truncate(ctx + n_acc);
                    }
                    continue;
                }
                let lrow = logits.row(r0);
                if a.first_token_at.is_none() && a.fed >= a.req.prompt.len() {
                    // Prefill just completed: its first generated token is
                    // determined by these logits, so TTFT is stamped (and
                    // streamed) here. The finished prefix is published to
                    // the pool's trie now, while the pages still hold
                    // exactly the prompt's whole-page positions (the first
                    // decode write lands past them, or COWs on divergence).
                    if cfg.prefix_cache {
                        pool.insert_prefix(&a.req.prompt, &a.cache);
                    }
                    a.first_token_at = Some(logits_at);
                    a.emit(TokenEvent::PrefillDone { ttft: logits_at - a.req.submitted });
                }
                if a.finish.is_some() {
                    continue; // channel died on the PrefillDone emit
                }
                let tok = a.sampler.sample(lrow);
                let index = a.n_generated;
                a.n_generated += 1;
                metrics.generated_tokens += 1;
                a.emit(TokenEvent::Token { token: tok, index });
                if a.finish.is_some() {
                    continue; // channel died mid-emit
                }
                if let Some(ds) = a.draft.as_mut() {
                    // Keep the draft's history in sync on non-speculative
                    // steps too (prefill-final rows, degraded-depth steps).
                    ds.hist.push(tok);
                }
                if (cfg.stop_on_eos && tok == EOS) || a.req.sampling.is_stop_token(tok) {
                    a.finish = Some(FinishReason::Eos);
                } else if a.n_generated >= a.req.max_new {
                    a.finish = Some(FinishReason::Length);
                } else {
                    a.pending = Some(tok);
                }
            }
        }

        // ---- retire finished ----
        let mut i = 0;
        while i < active.len() {
            if active[i].finish.is_none() {
                i += 1;
                continue;
            }
            let a = active.swap_remove(i);
            let reason = a.finish.unwrap_or(FinishReason::Cancelled);
            retire_one(a, reason, pool, metrics, &mut on_finish);
        }
    }
    Step::Continue
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use std::sync::mpsc::channel;

    /// Drain a request's event stream into (tokens, finish info), checking
    /// the protocol invariants on the way: PrefillDone (if any) precedes
    /// tokens, indices are contiguous, Finished is terminal and consistent.
    fn drain(rx: &Receiver<TokenEvent>) -> (Vec<u32>, FinishReason, Duration, Duration) {
        let mut tokens = Vec::new();
        let mut saw_prefill = false;
        loop {
            match rx.try_recv().expect("stream must be complete") {
                TokenEvent::PrefillDone { .. } => {
                    assert!(!saw_prefill, "duplicate PrefillDone");
                    assert!(tokens.is_empty(), "PrefillDone after tokens");
                    saw_prefill = true;
                }
                TokenEvent::Token { token, index } => {
                    assert_eq!(index, tokens.len(), "non-contiguous token index");
                    assert!(saw_prefill, "Token before PrefillDone");
                    tokens.push(token);
                }
                TokenEvent::Finished { reason, n_tokens, ttft, total } => {
                    assert_eq!(n_tokens, tokens.len(), "Finished token count drift");
                    assert!(rx.try_recv().is_err(), "events after Finished");
                    return (tokens, reason, ttft, total);
                }
            }
        }
    }

    struct Served {
        id: u64,
        tokens: Vec<u32>,
        reason: FinishReason,
        ttft: Duration,
        total: Duration,
    }

    fn serve_cfg(
        reqs: Vec<GenRequest>,
        cfg: BatchConfig,
        kv_tokens: usize,
    ) -> (Vec<Served>, BatchMetrics) {
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(kv_tokens, 8);
        let (tx, rx) = channel();
        let mut streams = Vec::new();
        for r in reqs {
            let id = r.id;
            let (sub, erx, _cancel) = Submission::channel(r);
            tx.send(sub).unwrap();
            streams.push((id, erx));
        }
        drop(tx);
        let mut n_finished = 0usize;
        let m = run_batcher(&model, &pool, &cfg, rx, |_, _| n_finished += 1);
        assert_eq!(pool.used_tokens(), 0, "all leases freed");
        assert_eq!(n_finished, streams.len(), "on_finish fired per request");
        let out = streams
            .iter()
            .map(|(id, erx)| {
                let (tokens, reason, ttft, total) = drain(erx);
                Served { id: *id, tokens, reason, ttft, total }
            })
            .collect();
        (out, m)
    }

    fn serve(
        reqs: Vec<GenRequest>,
        max_batch: usize,
        kv_tokens: usize,
    ) -> (Vec<Served>, BatchMetrics) {
        serve_cfg(reqs, BatchConfig { max_batch, ..Default::default() }, kv_tokens)
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> GenRequest {
        GenRequest::new(id, prompt, max_new)
    }

    #[test]
    fn serves_all_requests() {
        let reqs: Vec<GenRequest> =
            (0..10).map(|i| req(i, vec![1 + i as u32, 2, 3], 4)).collect();
        let (out, m) = serve(reqs, 4, 10_000);
        assert_eq!(out.len(), 10);
        assert_eq!(m.requests, 10);
        assert!(m.peak_batch <= 4);
        assert!(out.iter().all(|r| r.tokens.len() <= 4 && !r.tokens.is_empty()));
        assert_eq!(m.finished_eos + m.finished_length, 10, "all complete naturally");
    }

    #[test]
    fn batched_output_matches_unbatched_greedy() {
        let model = synthetic_model("micro", 51).unwrap();
        let prompt = vec![5u32, 9, 13];
        let want = model.generate_greedy(&prompt, 6);
        let (out, _) = serve(
            vec![req(1, prompt.clone(), 6), req(2, vec![7, 7], 6), req(3, prompt.clone(), 6)],
            3,
            10_000,
        );
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        let r3 = out.iter().find(|r| r.id == 3).unwrap();
        assert!(want.starts_with(&r1.tokens) || r1.tokens == want);
        assert_eq!(r1.tokens, r3.tokens, "same prompt ⇒ same output");
    }

    #[test]
    fn capacity_backpressure_still_completes() {
        // Pool fits only ~1 sequence at a time; everything must still finish.
        let reqs: Vec<GenRequest> = (0..6).map(|i| req(i, vec![2, 3], 3)).collect();
        let (out, m) = serve(reqs, 4, 6);
        assert_eq!(out.len(), 6);
        assert!(m.rejected_capacity > 0, "expected capacity pushback");
    }

    #[test]
    fn kv_lease_right_sizing_grows_and_truncates_gracefully() {
        // Pool holds 4 tokens. id 0 fits outright. id 1 wants 2+10=12 —
        // under the old upfront prompt+max_new policy this was refused as
        // impossible; right-sized admission serves it and finishes it
        // truncated when the pool cannot grow the lease any further.
        let reqs = vec![req(0, vec![2, 3], 2), req(1, vec![2, 3], 10)];
        let cfg = BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() };
        let (out, m) = serve_cfg(reqs, cfg, 4);
        assert_eq!(out.len(), 2, "every request gets exactly one stream");
        for r in &out {
            assert!(
                r.reason != FinishReason::Rejected,
                "id {} must be served, not rejected",
                r.id
            );
            assert!(!r.tokens.is_empty());
        }
        let truncated = out.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(truncated.reason, FinishReason::TruncatedKv);
        assert!(
            truncated.tokens.len() < 10,
            "a 4-token pool cannot hold 12 KV positions; got {} tokens",
            truncated.tokens.len()
        );
        assert_eq!(m.requests, 2);
        assert_eq!(m.rejected_impossible, 0);
        assert!(m.truncated_kv >= 1, "grow failure must be counted");
    }

    #[test]
    fn impossible_min_footprint_still_rejected() {
        // Pool holds 3 tokens total; a 3-token prompt needs 4 (prompt + one
        // generated token) — impossible even with lease growth, so it must
        // be refused up front while the feasible request completes.
        let reqs = vec![req(0, vec![2, 3], 2), req(1, vec![2, 3, 4], 5)];
        let (out, m) = serve(reqs, 4, 3);
        assert_eq!(out.len(), 2);
        let served = out.iter().find(|r| r.id == 0).unwrap();
        assert!(served.reason.is_completed());
        assert!(!served.tokens.is_empty());
        let rejected = out.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(rejected.reason, FinishReason::Rejected);
        assert!(rejected.tokens.is_empty());
        assert_eq!(rejected.ttft, rejected.total);
        assert_eq!(m.requests, 1);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn right_sized_leases_raise_concurrency_under_tight_pools() {
        // Upfront prompt+max_new leasing needs 10 tokens per sequence
        // (2+8), so a 12-token pool would serialize them. Right-sized
        // admission (prompt + kv_reserve = 4) runs both concurrently and
        // extends leases on demand during decode.
        let reqs = (0..2u64).map(|i| req(i, vec![2, 3 + i as u32], 8)).collect();
        let cfg = BatchConfig {
            max_batch: 4,
            kv_reserve: 2,
            stop_on_eos: false,
            ..Default::default()
        };
        let (out, m) = serve_cfg(reqs, cfg, 12);
        assert_eq!(out.len(), 2);
        assert_eq!(m.peak_batch, 2, "right-sizing must admit both up front");
        assert!(m.kv_grows > 0, "decode must extend leases incrementally");
        assert!(out.iter().all(|r| r.reason.is_completed() && !r.tokens.is_empty()));
    }

    #[test]
    fn token_budget_bounds_mixed_iterations() {
        // Five 20-token prompts under an 8-row budget: every iteration's
        // ragged batch stays within the budget, prompts are fed as chunks
        // (not one token per sequence per iteration), and everything
        // completes.
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| {
                req(i, (0..20).map(|t| 1 + ((t + i as usize) % 100) as u32).collect(), 4)
            })
            .collect();
        let cfg = BatchConfig {
            max_batch: 4,
            token_budget: 8,
            prefill_chunk: 4,
            ..Default::default()
        };
        let (out, m) = serve_cfg(reqs, cfg, 10_000);
        assert_eq!(out.len(), 5);
        assert!(out
            .iter()
            .all(|r| r.reason.is_completed() && !r.tokens.is_empty() && r.tokens.len() <= 4));
        assert!(
            m.peak_iter_tokens <= 8,
            "token budget violated: {} rows in one iteration",
            m.peak_iter_tokens
        );
        assert_eq!(m.prefill_tokens, 100);
        // 100 prompt tokens at ≤ 8 rows/iteration needs ≥ 13 iterations;
        // well-formed chunking keeps it far under the 100 a per-token
        // scheduler would take.
        assert!(m.iterations >= 13, "iterations {}", m.iterations);
        assert!(m.iterations < 60, "iterations {}", m.iterations);
    }

    #[test]
    fn over_long_prompt_rejected_at_admission() {
        // micro's max_seq is 64. A 70-token prompt can never fit the KV
        // window with one generated token, so it must be rejected at
        // admission; a prompt that just fits (63 tokens, room for exactly
        // one KV slot) still runs.
        let long: Vec<u32> = (0..70).map(|i| 1 + (i % 100) as u32).collect();
        let edge: Vec<u32> = (0..63).map(|i| 1 + (i % 100) as u32).collect();
        let (out, m) =
            serve(vec![req(0, long, 3), req(1, edge, 5), req(2, vec![1, 2], 2)], 3, 10_000);
        assert_eq!(out.len(), 3);
        let r0 = out.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(r0.reason, FinishReason::Rejected, "over-long prompt must be rejected");
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        assert!(r1.reason.is_completed());
        assert_eq!(r1.tokens.len(), 1, "KV window leaves room for exactly one token");
        assert!(out.iter().find(|r| r.id == 2).unwrap().reason.is_completed());
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn empty_prompt_rejected() {
        let (out, m) = serve(vec![req(0, Vec::new(), 4), req(1, vec![3], 2)], 2, 10_000);
        assert_eq!(out.len(), 2);
        assert_eq!(out.iter().find(|r| r.id == 0).unwrap().reason, FinishReason::Rejected);
        assert!(out.iter().find(|r| r.id == 1).unwrap().reason.is_completed());
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn ttft_stamped_at_prefill_completion() {
        // TTFT is stamped when the prefill-final forward writes its logits
        // back. Invariants pinned: served streams have 0 < ttft <= total,
        // and a prompt whose prefill needs more iterations (narrow chunks
        // force the 12-token prompt through ≥ 3 of them) reaches its first
        // token no earlier than a short one admitted in the same batch.
        let short = req(0, vec![2, 3], 6);
        let long = req(1, (0..12).map(|i| 1 + i as u32).collect(), 6);
        let cfg = BatchConfig {
            max_batch: 2,
            prefill_chunk: 4,
            token_budget: 8,
            ..Default::default()
        };
        let (out, _) = serve_cfg(vec![short, long], cfg, 10_000);
        let r_short = out.iter().find(|r| r.id == 0).unwrap();
        let r_long = out.iter().find(|r| r.id == 1).unwrap();
        for r in [r_short, r_long] {
            assert!(r.reason.is_completed());
            assert!(r.ttft > Duration::ZERO, "ttft must be stamped");
            assert!(r.ttft <= r.total, "ttft {:?} > total {:?}", r.ttft, r.total);
        }
        assert!(
            r_long.ttft >= r_short.ttft,
            "longer prefill cannot reach its first token earlier (short {:?}, long {:?})",
            r_short.ttft,
            r_long.ttft
        );
    }

    #[test]
    fn iteration_count_reflects_continuous_batching() {
        // 4 requests × (2 prompt + 3 decode): chunked prefill feeds each
        // whole prompt in one iteration, so ~4-5 iterations total — not 20.
        let reqs: Vec<GenRequest> = (0..4).map(|i| req(i, vec![2, 3], 3)).collect();
        let (_, m) = serve(reqs, 4, 10_000);
        assert!(m.iterations < 12, "iterations {}", m.iterations);
        assert_eq!(m.prefill_tokens, 8);
        assert!(m.peak_iter_tokens >= 4, "prompts should batch as chunks");
    }

    #[test]
    fn chunked_serving_output_matches_per_token_prefill() {
        // Scheduling policy must not change results: the same request
        // stream served with chunk 1 (old behavior) and with wide chunks
        // produces identical token streams.
        let reqs = || -> Vec<GenRequest> {
            (0..3)
                .map(|i| {
                    req(i, (0..17).map(|t| 1 + ((t * 3 + i as usize) % 90) as u32).collect(), 5)
                })
                .collect()
        };
        let wide = BatchConfig { max_batch: 3, ..Default::default() };
        let narrow = BatchConfig {
            max_batch: 3,
            prefill_chunk: 1,
            token_budget: 3,
            ..Default::default()
        };
        let (out_w, _) = serve_cfg(reqs(), wide, 10_000);
        let (out_n, _) = serve_cfg(reqs(), narrow, 10_000);
        for id in 0..3u64 {
            let w = out_w.iter().find(|r| r.id == id).unwrap();
            let n = out_n.iter().find(|r| r.id == id).unwrap();
            assert_eq!(w.tokens, n.tokens, "id {id}: chunking changed output");
        }
    }

    #[test]
    fn cancel_mid_decode_frees_lease_and_finishes_stream() {
        // Cancel a long-running request after its first streamed token; the
        // stream must terminate with Cancelled, the pool must fully drain,
        // and the co-scheduled request must be unaffected. The KV window is
        // stretched so the request cannot race to a Length finish before
        // the cancel flag is swept.
        let mut model = synthetic_model("micro", 51).unwrap();
        model.cfg.max_seq = 8192;
        model.refresh_derived();
        let pool = KvPool::new(10_000, 8);
        let cfg = BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() };
        let (tx, rx) = channel();
        let long = req(0, vec![2, 3, 4], 4000);
        let (sub_l, erx_l, cancel_l) = Submission::channel(long);
        let short = req(1, vec![5, 6], 4);
        let (sub_s, erx_s, _cancel_s) = Submission::channel(short);
        tx.send(sub_l).unwrap();
        tx.send(sub_s).unwrap();
        drop(tx);
        std::thread::scope(|scope| {
            let worker = scope.spawn(|| run_batcher(&model, &pool, &cfg, rx, |_, _| {}));
            // Wait for the long request's first token, then cancel it.
            loop {
                match erx_l.recv().expect("stream open") {
                    TokenEvent::Token { .. } => break,
                    TokenEvent::Finished { .. } => panic!("finished before first token"),
                    TokenEvent::PrefillDone { .. } => {}
                }
            }
            cancel_l.store(true, Ordering::Release);
            // Drain to the terminal event — after it, the lease is freed.
            let reason = loop {
                match erx_l.recv().expect("stream open") {
                    TokenEvent::Finished { reason, .. } => break reason,
                    _ => {}
                }
            };
            assert_eq!(reason, FinishReason::Cancelled);
            let m = worker.join().unwrap();
            assert_eq!(m.cancelled, 1);
            assert!(m.generated_tokens < 4000, "cancel must stop generation early");
        });
        assert_eq!(pool.used_tokens(), 0, "cancelled lease leaked");
        assert_eq!(pool.live_leases(), 0);
        // The co-scheduled request still completes normally.
        let (tokens, reason, _, _) = drain(&erx_s);
        assert!(reason.is_completed());
        assert!(!tokens.is_empty());
    }

    #[test]
    fn cancel_while_queued_never_admits() {
        // A request cancelled before the batcher picks it up must finish
        // Cancelled without consuming a lease or producing tokens.
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(10_000, 8);
        let (tx, rx) = channel();
        let (sub, erx, cancel) = Submission::channel(req(0, vec![2, 3], 4));
        cancel.store(true, Ordering::Release);
        tx.send(sub).unwrap();
        drop(tx);
        let m = run_batcher(&model, &pool, &BatchConfig::default(), rx, |_, _| {});
        let (tokens, reason, ttft, total) = drain(&erx);
        assert!(tokens.is_empty());
        assert_eq!(reason, FinishReason::Cancelled);
        assert_eq!(ttft, total);
        assert_eq!(m.requests, 0, "cancelled-in-queue must not be admitted");
        assert_eq!(m.cancelled, 1);
        assert_eq!(pool.used_tokens(), 0);
    }

    #[test]
    fn dropped_stream_acts_as_cancel() {
        // Dropping the receiving side mid-run must not wedge the batcher or
        // leak the lease: the first failed send turns into a cancel.
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(10_000, 8);
        let cfg = BatchConfig { stop_on_eos: false, ..Default::default() };
        let (tx, rx) = channel();
        let (sub, erx, _cancel) = Submission::channel(req(0, vec![2, 3], 2000));
        drop(erx); // handle abandoned before serving even starts
        tx.send(sub).unwrap();
        drop(tx);
        let m = run_batcher(&model, &pool, &cfg, rx, |_, _| {});
        assert_eq!(m.cancelled, 1);
        assert!(m.generated_tokens < 2000, "dead stream must stop generation early");
        assert_eq!(pool.used_tokens(), 0);
    }

    #[test]
    fn per_request_sampling_params_apply() {
        // Two requests over the same prompt: one greedy, one hot-temperature
        // seeded. Greedy must match generate_greedy exactly; the sampled one
        // must (a) be reproducible under the same seed across runs and
        // (b) diverge from greedy on this prompt.
        let model = synthetic_model("micro", 51).unwrap();
        let prompt = vec![5u32, 9, 13];
        let want = model.generate_greedy(&prompt, 8);
        let sampled_req = |id: u64| {
            let mut r = req(id, prompt.clone(), 8);
            r.sampling =
                SamplingParams { temperature: 3.0, top_k: 0, top_p: 1.0, seed: 42, stop_tokens: vec![] };
            r
        };
        let run_pair = || {
            let cfg = BatchConfig { max_batch: 2, stop_on_eos: false, ..Default::default() };
            let (out, _) = serve_cfg(vec![req(0, prompt.clone(), 8), sampled_req(1)], cfg, 10_000);
            let g = out.iter().find(|r| r.id == 0).unwrap().tokens.clone();
            let s = out.iter().find(|r| r.id == 1).unwrap().tokens.clone();
            (g, s)
        };
        let (g1, s1) = run_pair();
        let (g2, s2) = run_pair();
        assert_eq!(g1, want, "greedy request must pin to the argmax path");
        assert_eq!(s1, s2, "same seed must reproduce the sampled stream");
        assert_eq!(g1, g2);
        assert_ne!(s1, g1, "temperature 3.0 should diverge from greedy here");
    }

    #[test]
    fn max_new_zero_finishes_with_no_tokens() {
        // A valid max_new == 0 request completes empty at admission; an
        // INVALID one (empty prompt) still reports Rejected, not Length.
        let (out, m) = serve(
            vec![req(0, vec![2, 3], 0), req(1, vec![2, 3], 3), req(2, Vec::new(), 0)],
            2,
            10_000,
        );
        assert_eq!(out.len(), 3);
        let r0 = out.iter().find(|r| r.id == 0).unwrap();
        assert!(r0.tokens.is_empty(), "max_new 0 must emit nothing");
        assert_eq!(r0.reason, FinishReason::Length);
        assert_eq!(r0.ttft, r0.total);
        assert!(!out.iter().find(|r| r.id == 1).unwrap().tokens.is_empty());
        assert_eq!(out.iter().find(|r| r.id == 2).unwrap().reason, FinishReason::Rejected);
        assert_eq!(m.requests, 1, "max_new 0 finishes at admission");
        assert_eq!(m.rejected_impossible, 1);
    }

    /// Serve with a self-draft attached at the given depth/spec_k.
    fn serve_spec(
        reqs: Vec<GenRequest>,
        cfg: BatchConfig,
        kv_tokens: usize,
        draft_layers: usize,
    ) -> (Vec<Served>, BatchMetrics) {
        let model = Arc::new(synthetic_model("micro", 51).unwrap());
        let draft = crate::model::DraftModel::self_draft(Arc::clone(&model), draft_layers).unwrap();
        let pool = KvPool::new(kv_tokens, 8);
        let (tx, rx) = channel();
        let mut streams = Vec::new();
        for r in reqs {
            let id = r.id;
            let (sub, erx, _cancel) = Submission::channel(r);
            tx.send(sub).unwrap();
            streams.push((id, erx));
        }
        drop(tx);
        let m = run_batcher_spec(&model, Some(&draft), &pool, &cfg, rx, |_, _| {});
        assert_eq!(pool.used_tokens(), 0, "all leases freed");
        let out = streams
            .iter()
            .map(|(id, erx)| {
                let (tokens, reason, ttft, total) = drain(erx);
                Served { id: *id, tokens, reason, ttft, total }
            })
            .collect();
        (out, m)
    }

    #[test]
    fn speculative_streams_match_plain_serving_bitwise() {
        // The headline invariant: with a draft attached, every stream —
        // token for token, finish reason for finish reason — equals the
        // non-speculative serve, across spec_k and draft depths. Mixed
        // greedy + seeded-sampling traffic, plus a stop-token request.
        let reqs = || -> Vec<GenRequest> {
            let mut v: Vec<GenRequest> = (0..4u64)
                .map(|i| req(i, vec![5 + i as u32, 9, 13 + i as u32], 12))
                .collect();
            v[1].sampling = SamplingParams {
                temperature: 2.0,
                top_k: 8,
                top_p: 0.9,
                seed: 77,
                stop_tokens: vec![],
            };
            v[2].sampling = SamplingParams::with_temperature(1.0, 5);
            v
        };
        let base_cfg =
            || BatchConfig { max_batch: 4, stop_on_eos: false, ..Default::default() };
        let (want, _) = serve_cfg(reqs(), base_cfg(), 10_000);
        for draft_layers in [1usize, 2] {
            for k in [1usize, 2, 4] {
                let cfg = BatchConfig { spec_k: k, ..base_cfg() };
                let (got, m) = serve_spec(reqs(), cfg, 10_000, draft_layers);
                for id in 0..4u64 {
                    let w = want.iter().find(|r| r.id == id).unwrap();
                    let g = got.iter().find(|r| r.id == id).unwrap();
                    assert_eq!(
                        g.tokens, w.tokens,
                        "stream diverged: id {id}, draft self:{draft_layers}, spec_k {k}"
                    );
                    assert_eq!(g.reason, w.reason, "finish reason drift: id {id}, spec_k {k}");
                }
                assert!(m.spec_drafted > 0, "speculation must engage at spec_k {k}");
                assert_eq!(m.spec_drafted, m.spec_accepted + m.spec_rejected);
            }
        }
    }

    #[test]
    fn full_depth_self_draft_accepts_everything_greedy() {
        // A self-draft over ALL layers proposes exactly the target's greedy
        // tokens, so greedy requests must accept every draft (only the
        // final short span near max_new degrades the depth).
        let cfg = BatchConfig { max_batch: 2, stop_on_eos: false, spec_k: 4, ..Default::default() };
        let (out, m) = serve_spec(
            (0..2u64).map(|i| req(i, vec![5 + i as u32, 9], 13)).collect(),
            cfg,
            10_000,
            2,
        );
        assert!(out.iter().all(|r| r.reason.is_completed() && r.tokens.len() == 13));
        assert_eq!(m.spec_rejected, 0, "full-depth greedy self-draft must never miss");
        assert!(m.spec_accepted > 0);
        // Accepted drafts shrink the iteration count well below one
        // target pass per token.
        assert!(
            m.iterations < 2 + 13,
            "speculation should cut iterations, got {}",
            m.iterations
        );
    }

    #[test]
    fn spec_zero_and_missing_draft_are_plain_serving() {
        let reqs = || vec![req(0, vec![5, 9, 13], 6)];
        let (want, wm) = serve_cfg(reqs(), BatchConfig::default(), 10_000);
        // spec_k = 0 with a draft attached: draft must never run.
        let (got, m) = serve_spec(reqs(), BatchConfig { spec_k: 0, ..Default::default() }, 10_000, 1);
        assert_eq!(got[0].tokens, want[0].tokens);
        assert_eq!((m.spec_drafted, m.spec_accepted, m.spec_rejected), (0, 0, 0));
        assert_eq!(m.iterations, wm.iterations, "spec_k 0 must be the identical loop");
        // spec_k > 0 without a draft: run_batcher has none to use.
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(10_000, 8);
        let (tx, rx) = channel();
        let (sub, erx, _c) = Submission::channel(req(0, vec![5, 9, 13], 6));
        tx.send(sub).unwrap();
        drop(tx);
        let m2 =
            run_batcher(&model, &pool, &BatchConfig { spec_k: 3, ..Default::default() }, rx, |_, _| {});
        let (tokens, ..) = drain(&erx);
        assert_eq!(tokens, want[0].tokens);
        assert_eq!(m2.spec_drafted, 0);
    }

    #[test]
    fn speculation_respects_kv_window_edge() {
        // 63-token prompt on a 64-position window: exactly one token fits.
        // Speculation must degrade to zero depth, emit the same single
        // token, and finish Length — not overrun the window.
        let edge: Vec<u32> = (0..63).map(|i| 1 + (i % 100) as u32).collect();
        let cfg = BatchConfig { max_batch: 2, spec_k: 4, ..Default::default() };
        let (out, _) = serve_spec(vec![req(0, edge.clone(), 5)], cfg, 10_000, 1);
        assert_eq!(out[0].tokens.len(), 1, "KV window leaves room for exactly one token");
        assert_eq!(out[0].reason, FinishReason::Length);
        let (want, _) = serve(vec![req(0, edge, 5)], 2, 10_000);
        assert_eq!(out[0].tokens, want[0].tokens);
    }

    #[test]
    fn stop_tokens_end_the_stream() {
        // Serve greedily once, then resubmit with the first generated token
        // as a stop token: the stream must end at (and include) it.
        let model = synthetic_model("micro", 51).unwrap();
        let prompt = vec![5u32, 9, 13];
        let want = model.generate_greedy(&prompt, 6);
        assert!(want.len() > 1, "need a multi-token greedy stream");
        let mut r = req(0, prompt, 6);
        r.sampling.stop_tokens = vec![want[0]];
        let cfg = BatchConfig { stop_on_eos: false, ..Default::default() };
        let (out, m) = serve_cfg(vec![r], cfg, 10_000);
        assert_eq!(out[0].tokens, vec![want[0]], "stream must stop at the stop token");
        assert_eq!(out[0].reason, FinishReason::Eos);
        assert_eq!(m.finished_eos, 1);
    }

    /// Satellite guard: pin every `FinishReason` wire string. The match in
    /// `wire_str` is exhaustive (compile error on a new variant); this test
    /// keeps the existing names from drifting, since clients key on them.
    #[test]
    fn wire_str_pins_every_variant() {
        let all = [
            (FinishReason::Eos, "stop"),
            (FinishReason::Length, "length"),
            (FinishReason::Cancelled, "cancelled"),
            (FinishReason::TruncatedKv, "truncated_kv"),
            (FinishReason::Rejected, "rejected"),
            (FinishReason::DeadlineExceeded, "deadline"),
            (FinishReason::WorkerFailed, "worker_failed"),
        ];
        for (reason, wire) in all {
            assert_eq!(reason.wire_str(), wire, "{reason:?}");
        }
        // Every wire name is distinct — two variants must never alias.
        let mut names: Vec<&str> = all.iter().map(|(_, w)| *w).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }
}
