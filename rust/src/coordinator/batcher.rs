//! Continuous (iteration-level) dynamic batcher — Orca-style scheduling on
//! top of the packed quantized execution engine.
//!
//! The decode loop keeps an *active set* of sequences. Every iteration it
//! (1) admits queued requests while there is batch room AND the KV pool
//! grants a lease (backpressure), (2) advances every active sequence by one
//! token (prompt tokens first — chunked prefill — then greedy decode), and
//! (3) retires finished sequences, freeing their KV lease. New requests
//! therefore join between *iterations*, not between requests.
//!
//! Admission distinguishes **transient** capacity pushback (the pool is
//! full right now; the request is re-queued and admitted when leases free
//! up — `BatchMetrics::rejected_capacity`) from **impossible** requests
//! that could never run: empty prompts, prompts that cannot fit in the KV
//! window with at least one generated token, and clamped KV demands larger
//! than the whole pool. Those are refused immediately with an explicit
//! [`Response`] carrying `rejected: true` and an empty token list
//! (`BatchMetrics::rejected_impossible`) — re-queueing them forever was an
//! admission livelock, and over-long prompts used to be prefilled
//! token-by-token straight past the KV-cache bound. With impossible
//! requests refused up front, `run_batcher` terminates on any finite
//! request stream.
//!
//! TTFT (`Response::ttft`) is stamped when the batched forward that ends a
//! sequence's prefill writes its logits back — the instant its first
//! generated token is determined — not when the next iteration argmaxes
//! that token.
//!
//! Step (2) is where the throughput property is actually realized: all
//! advancing sequences are stacked into one [`Gpt::forward_step_batch`]
//! call, so each transformer layer runs ONE batched quantized GEMM per
//! iteration (tile-packed int8 weight panels streamed once per batch)
//! instead of one scalar token forward per sequence. The per-token
//! activation-quantization scratch lives in a loop-owned
//! [`QGemmArena`], so the steady-state decode loop does not allocate
//! quantization buffers.
//!
//! Determinism scope: for decode batches under 32 sequences (the default
//! `max_batch` is 8) the batched step is bitwise identical to per-sequence
//! `forward_step`, so greedy outputs match single-sequence generation
//! token-for-token (see `tensor::gemm::matmul_bt_acc`). Larger batches take
//! the split-K blocked kernels and agree only to f32 tolerance.

use super::kvpool::{KvPool, Lease};
use crate::data::vocab::EOS;
use crate::model::{argmax, Gpt, KvCache};
use crate::tensor::QGemmArena;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time from submit to first generated token (stamped when the logits
    /// of the prefill-final forward are written back). For rejected
    /// requests this equals `total` (time to rejection).
    pub ttft: Duration,
    /// Time from submit to completion.
    pub total: Duration,
    pub prompt_len: usize,
    /// True when the request was refused at admission because it could
    /// never run (empty prompt, prompt too long for the KV window, or KV
    /// demand beyond total pool capacity); `tokens` is empty.
    pub rejected: bool,
}

struct Active {
    req: Request,
    cache: KvCache,
    lease: Lease,
    /// Next prompt index to feed (prefill progress).
    fed: usize,
    generated: Vec<u32>,
    last_logits: Vec<f32>,
    first_token_at: Option<Instant>,
}

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    /// Wait at most this long for work when idle.
    pub idle_wait: Duration,
    pub stop_on_eos: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 8, idle_wait: Duration::from_millis(5), stop_on_eos: true }
    }
}

/// Metrics the server reports.
#[derive(Clone, Debug, Default)]
pub struct BatchMetrics {
    pub requests: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub iterations: usize,
    pub peak_batch: usize,
    /// Transient pool pushback: the request was re-queued and admitted
    /// later.
    pub rejected_capacity: usize,
    /// Requests refused outright with a `rejected` response because they
    /// could never run (see the module doc's admission rules).
    pub rejected_impossible: usize,
}

/// Run the batching loop until the request channel closes and the active
/// set drains. Responses are delivered through `respond`.
pub fn run_batcher(
    model: &Gpt,
    pool: &KvPool,
    cfg: &BatchConfig,
    rx: Receiver<Request>,
    mut respond: impl FnMut(Response),
) -> BatchMetrics {
    let mut active: Vec<Active> = Vec::new();
    let mut metrics = BatchMetrics::default();
    let mut channel_open = true;
    let mut pending: Vec<Request> = Vec::new();
    // Reusable activation-quantization scratch for the batched decode step.
    let mut arena = QGemmArena::new();

    while channel_open || !active.is_empty() || !pending.is_empty() {
        // ---- admission ----
        while active.len() < cfg.max_batch && channel_open {
            match rx.recv_timeout(if active.is_empty() && pending.is_empty() {
                cfg.idle_wait
            } else {
                Duration::ZERO
            }) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    channel_open = false;
                }
            }
        }
        let mut still_pending = Vec::new();
        for req in pending.drain(..) {
            if active.len() >= cfg.max_batch {
                still_pending.push(req);
                continue;
            }
            // Lease the full prompt + expected generation upfront, clamped
            // to the model's KV window.
            let want = (req.prompt.len() + req.max_new).min(model.cfg.max_seq);
            // Requests that can NEVER run are refused with an explicit
            // rejected response instead of being re-queued forever:
            //  - empty prompts (no logits to decode from),
            //  - prompts that don't fit the KV window with ≥1 generated
            //    token (they used to be prefilled token-by-token straight
            //    past the KV-cache bound),
            //  - clamped KV demands beyond the whole pool (they used to be
            //    re-queued forever: admission livelock once the channel
            //    closed).
            if req.prompt.is_empty()
                || req.prompt.len() + 1 > model.cfg.max_seq
                || want > pool.capacity_tokens()
            {
                metrics.rejected_impossible += 1;
                let waited = Instant::now() - req.submitted;
                respond(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft: waited,
                    total: waited,
                    prompt_len: req.prompt.len(),
                    rejected: true,
                });
                continue;
            }
            match pool.alloc(want) {
                Some(lease) => {
                    active.push(Active {
                        cache: KvCache::new(&model.cfg),
                        lease,
                        fed: 0,
                        generated: Vec::new(),
                        last_logits: Vec::new(),
                        first_token_at: None,
                        req,
                    });
                    metrics.requests += 1;
                }
                None => {
                    metrics.rejected_capacity += 1;
                    still_pending.push(req);
                }
            }
        }
        pending = still_pending;
        metrics.peak_batch = metrics.peak_batch.max(active.len());
        if active.is_empty() {
            if !channel_open && pending.is_empty() {
                break;
            }
            if !pending.is_empty() {
                // Feasible requests are waiting on pool space held outside
                // this loop (externally shared pool): back off instead of
                // spinning the admission loop hot.
                std::thread::sleep(cfg.idle_wait);
            }
            continue;
        }

        // ---- one iteration: advance every active sequence by one token,
        //      all stacked into a single batched step (one quantized GEMM
        //      per layer per iteration, not per sequence) ----
        metrics.iterations += 1;
        let mut step_tokens: Vec<u32> = Vec::with_capacity(active.len());
        let mut step_idx: Vec<usize> = Vec::with_capacity(active.len());
        for (i, a) in active.iter_mut().enumerate() {
            if a.fed < a.req.prompt.len() {
                let tok = a.req.prompt[a.fed];
                a.fed += 1;
                metrics.prefill_tokens += 1;
                step_tokens.push(tok);
                step_idx.push(i);
            } else {
                let next = argmax(&a.last_logits) as u32;
                a.generated.push(next);
                metrics.generated_tokens += 1;
                let done = a.generated.len() >= a.req.max_new
                    || (cfg.stop_on_eos && next == EOS)
                    || a.cache.len() + 1 >= model.cfg.max_seq;
                if !done {
                    step_tokens.push(next);
                    step_idx.push(i);
                }
            }
        }
        if !step_tokens.is_empty() {
            let logits = {
                // Gather &mut caches for exactly the advancing sequences
                // (step_idx is ascending by construction).
                let mut want = step_idx.iter().copied().peekable();
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(step_idx.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        caches.push(&mut a.cache);
                    }
                }
                model.forward_step_batch(&step_tokens, &mut caches, &mut arena)
            };
            // Logits are materialized now: any sequence that just fed its
            // final prompt token has its first generated token determined
            // at this instant, so TTFT is stamped here — not one iteration
            // later when the decode branch argmaxes it.
            let logits_at = Instant::now();
            for (row, &i) in step_idx.iter().enumerate() {
                let a = &mut active[i];
                a.last_logits = logits.row(row).to_vec();
                if a.first_token_at.is_none() && a.fed >= a.req.prompt.len() {
                    a.first_token_at = Some(logits_at);
                }
            }
        }

        // ---- retire finished ----
        let mut i = 0;
        while i < active.len() {
            let done = {
                let a = &active[i];
                a.fed >= a.req.prompt.len()
                    && (a.generated.len() >= a.req.max_new
                        || (cfg.stop_on_eos && a.generated.last() == Some(&EOS))
                        || a.cache.len() + 1 >= model.cfg.max_seq)
            };
            if done {
                let a = active.swap_remove(i);
                pool.free(a.lease);
                let now = Instant::now();
                respond(Response {
                    id: a.req.id,
                    prompt_len: a.req.prompt.len(),
                    tokens: a.generated,
                    ttft: a
                        .first_token_at
                        .map(|t| t - a.req.submitted)
                        .unwrap_or_else(|| now - a.req.submitted),
                    total: now - a.req.submitted,
                    rejected: false,
                });
            } else {
                i += 1;
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use std::sync::mpsc::channel;

    fn serve(reqs: Vec<Request>, max_batch: usize, kv_tokens: usize) -> (Vec<Response>, BatchMetrics) {
        let model = synthetic_model("micro", 51).unwrap();
        let pool = KvPool::new(kv_tokens, 8);
        let (tx, rx) = channel();
        for r in reqs {
            tx.send(r).unwrap();
        }
        drop(tx);
        let mut out = Vec::new();
        let cfg = BatchConfig { max_batch, ..Default::default() };
        let m = run_batcher(&model, &pool, &cfg, rx, |r| out.push(r));
        assert_eq!(pool.used_tokens(), 0, "all leases freed");
        (out, m)
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request { id, prompt, max_new, submitted: Instant::now() }
    }

    #[test]
    fn serves_all_requests() {
        let reqs: Vec<Request> =
            (0..10).map(|i| req(i, vec![1 + i as u32, 2, 3], 4)).collect();
        let (out, m) = serve(reqs, 4, 10_000);
        assert_eq!(out.len(), 10);
        assert_eq!(m.requests, 10);
        assert!(m.peak_batch <= 4);
        assert!(out.iter().all(|r| r.tokens.len() <= 4 && !r.tokens.is_empty()));
    }

    #[test]
    fn batched_output_matches_unbatched_greedy() {
        let model = synthetic_model("micro", 51).unwrap();
        let prompt = vec![5u32, 9, 13];
        let want = model.generate_greedy(&prompt, 6);
        let (out, _) = serve(
            vec![req(1, prompt.clone(), 6), req(2, vec![7, 7], 6), req(3, prompt.clone(), 6)],
            3,
            10_000,
        );
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        let r3 = out.iter().find(|r| r.id == 3).unwrap();
        let trim = |v: &[u32]| {
            // greedy may stop at EOS in batcher; compare prefix
            v.to_vec()
        };
        assert!(want.starts_with(&trim(&r1.tokens)) || r1.tokens == want);
        assert_eq!(r1.tokens, r3.tokens, "same prompt ⇒ same output");
    }

    #[test]
    fn capacity_backpressure_still_completes() {
        // Pool fits only ~1 sequence at a time; everything must still finish.
        let reqs: Vec<Request> = (0..6).map(|i| req(i, vec![2, 3], 3)).collect();
        let (out, m) = serve(reqs, 4, 6);
        assert_eq!(out.len(), 6);
        assert!(m.rejected_capacity > 0, "expected capacity pushback");
    }

    #[test]
    fn impossible_kv_demand_rejected_not_livelocked() {
        // Pool holds 4 tokens total; id 1 wants 2+10=12 — it can never be
        // admitted. Before the fix it was re-queued forever and, once the
        // channel closed with nothing active, run_batcher spun without
        // terminating. Now it must be refused with an explicit rejected
        // response while the feasible request still completes.
        let reqs = vec![req(0, vec![2, 3], 2), req(1, vec![2, 3], 10)];
        let (out, m) = serve(reqs, 4, 4);
        assert_eq!(out.len(), 2, "every request gets exactly one response");
        let served = out.iter().find(|r| r.id == 0).unwrap();
        assert!(!served.rejected);
        assert!(!served.tokens.is_empty());
        let rejected = out.iter().find(|r| r.id == 1).unwrap();
        assert!(rejected.rejected);
        assert!(rejected.tokens.is_empty());
        assert_eq!(rejected.ttft, rejected.total);
        assert_eq!(m.requests, 1);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn over_long_prompt_rejected_at_admission() {
        // micro's max_seq is 64. A 70-token prompt used to be prefilled
        // token-by-token past the KV-cache bound (the done-check requires
        // fed >= prompt.len() first), tripping the kv-cache-full assert.
        // It must be rejected at admission instead; a prompt that just fits
        // (63 tokens, room for exactly one generated token) still runs.
        let long: Vec<u32> = (0..70).map(|i| 1 + (i % 100) as u32).collect();
        let edge: Vec<u32> = (0..63).map(|i| 1 + (i % 100) as u32).collect();
        let (out, m) =
            serve(vec![req(0, long, 3), req(1, edge, 5), req(2, vec![1, 2], 2)], 3, 10_000);
        assert_eq!(out.len(), 3);
        let r0 = out.iter().find(|r| r.id == 0).unwrap();
        assert!(r0.rejected, "over-long prompt must be rejected");
        let r1 = out.iter().find(|r| r.id == 1).unwrap();
        assert!(!r1.rejected);
        assert_eq!(r1.tokens.len(), 1, "KV window leaves room for exactly one token");
        assert!(!out.iter().find(|r| r.id == 2).unwrap().rejected);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn empty_prompt_rejected() {
        let (out, m) = serve(vec![req(0, Vec::new(), 4), req(1, vec![3], 2)], 2, 10_000);
        assert_eq!(out.len(), 2);
        assert!(out.iter().find(|r| r.id == 0).unwrap().rejected);
        assert!(!out.iter().find(|r| r.id == 1).unwrap().rejected);
        assert_eq!(m.rejected_impossible, 1);
    }

    #[test]
    fn ttft_stamped_at_prefill_completion() {
        // TTFT is stamped when the prefill-final forward writes its logits
        // back. Invariants pinned: served responses have 0 < ttft <= total,
        // and a longer prompt admitted in the same batch reaches its first
        // token no earlier than a shorter one submitted at the same time.
        let short = req(0, vec![2, 3], 6);
        let long = req(1, (0..12).map(|i| 1 + i as u32).collect(), 6);
        let (out, _) = serve(vec![short, long], 2, 10_000);
        let r_short = out.iter().find(|r| r.id == 0).unwrap();
        let r_long = out.iter().find(|r| r.id == 1).unwrap();
        for r in [r_short, r_long] {
            assert!(!r.rejected);
            assert!(r.ttft > Duration::ZERO, "ttft must be stamped");
            assert!(r.ttft <= r.total, "ttft {:?} > total {:?}", r.ttft, r.total);
        }
        assert!(
            r_long.ttft >= r_short.ttft,
            "longer prefill cannot reach its first token earlier (short {:?}, long {:?})",
            r_short.ttft,
            r_long.ttft
        );
    }

    #[test]
    fn iteration_count_reflects_continuous_batching() {
        // 4 requests × (2 prompt + 3 decode) ≈ 5 iterations if perfectly
        // batched, not 20 — continuous batching interleaves.
        let reqs: Vec<Request> = (0..4).map(|i| req(i, vec![2, 3], 3)).collect();
        let (_, m) = serve(reqs, 4, 10_000);
        assert!(m.iterations < 12, "iterations {}", m.iterations);
        assert_eq!(m.prefill_tokens, 8);
    }
}
