//! The listener half of the HTTP front end: a bounded accept loop fanned
//! over a small worker thread pool, plus the server lifecycle.
//!
//! [`HttpServer::bind`] spawns one non-blocking accept thread and `threads`
//! connection workers sharing a bounded channel of accepted sockets; when
//! the channel is full the accept thread answers a minimal 503 and drops the
//! connection instead of queueing unbounded work. Each worker runs
//! [`handle_connection`](super::http::handle_connection) — the per-connection
//! state machine documented in [`super::http`].
//!
//! Shutdown is two-phase ([`HttpServer::shutdown`]): first stop accepting
//! and let in-flight connections finish their current response within a
//! grace period, then flip the abort flag so streaming handlers cancel their
//! engine requests and exit. The engine itself is returned to the caller,
//! which drains it via `Engine::shutdown_mode(Drain, ..)` — the server never
//! tears down the engine behind the caller's back. The SIGTERM-equivalent
//! trigger is `POST /admin/shutdown` (std has no signal API), surfaced
//! through [`HttpServer::shutdown_requested`] for the serve CLI loop.

use super::engine::Engine;
use super::http::{handle_connection, write_response, ServeCtx};
use crate::data::Vocab;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sizing and per-connection policy for [`HttpServer`].
#[derive(Clone, Debug)]
pub struct HttpServerConfig {
    /// Connection worker threads (concurrent connections served).
    pub threads: usize,
    /// Accepted-socket channel bound; overflow is answered 503 and dropped.
    pub backlog: usize,
    /// Idle keep-alive window before a quiet connection closes.
    pub keep_alive: Duration,
    /// Request body cap in bytes.
    pub max_body: usize,
    /// Reported by `GET /v1/models` and echoed in completions.
    pub model_id: String,
    /// Default end-to-end deadline stamped on requests that carry none.
    pub default_deadline: Option<Duration>,
}

impl Default for HttpServerConfig {
    fn default() -> HttpServerConfig {
        HttpServerConfig {
            threads: 4,
            backlog: 64,
            keep_alive: Duration::from_secs(5),
            max_body: 1 << 20,
            model_id: "aser".to_string(),
            default_deadline: None,
        }
    }
}

/// A running HTTP front end over an [`Engine`]. Dropping an un-shutdown
/// server aborts its threads (zero grace); prefer [`HttpServer::shutdown`].
pub struct HttpServer {
    addr: SocketAddr,
    ctx: Arc<ServeCtx>,
    engine: Arc<Engine>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving `engine` immediately.
    pub fn bind(
        addr: &str,
        engine: Arc<Engine>,
        vocab: Arc<Vocab>,
        cfg: HttpServerConfig,
    ) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        // Non-blocking accepts: the loop must poll the stop flag, and std
        // offers no way to interrupt a blocking `accept`.
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let vocab_size = vocab.size;
        let ctx = Arc::new(ServeCtx {
            engine: Arc::clone(&engine),
            vocab,
            vocab_size,
            model_id: cfg.model_id.clone(),
            next_id: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            abort: AtomicBool::new(false),
            shutdown_req: AtomicBool::new(false),
            keep_alive: cfg.keep_alive,
            max_body: cfg.max_body,
            default_deadline: cfg.default_deadline,
        });
        let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(cfg.backlog.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers: Vec<JoinHandle<()>> = (0..cfg.threads.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let ctx = Arc::clone(&ctx);
                std::thread::Builder::new()
                    .name(format!("http-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &ctx))
                    .expect("spawn http worker")
            })
            .collect();
        let accept = {
            let ctx = Arc::clone(&ctx);
            std::thread::Builder::new()
                .name("http-accept".to_string())
                .spawn(move || accept_loop(listener, tx, &ctx))
                .expect("spawn http accept loop")
        };
        Ok(HttpServer { addr: local, ctx, engine, accept: Some(accept), workers })
    }

    /// The bound address — the actual port when bound to `:0`.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client hit `POST /admin/shutdown` (or
    /// [`HttpServer::request_shutdown`] ran). The serve loop polls this.
    pub fn shutdown_requested(&self) -> bool {
        self.ctx.shutdown_req.load(Ordering::SeqCst)
    }

    /// Programmatic equivalent of `POST /admin/shutdown`.
    pub fn request_shutdown(&self) {
        self.ctx.shutdown_req.store(true, Ordering::SeqCst);
    }

    /// The engine this server fronts (for meters in tests and benches).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Stop the front end: close admission, give in-flight connections
    /// `grace` to finish, then cancel the stragglers and join every thread.
    /// Returns the engine so the caller can drain it
    /// (`Engine::shutdown_mode(Drain, ..)`) and collect worker metrics.
    pub fn shutdown(mut self, grace: Duration) -> Arc<Engine> {
        self.stop_threads(grace);
        Arc::clone(&self.engine)
    }

    fn stop_threads(&mut self, grace: Duration) {
        self.ctx.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // The accept loop dropped its sender, so idle workers drain the
        // channel and exit; busy ones get the grace period.
        let deadline = Instant::now() + grace;
        while Instant::now() < deadline && self.workers.iter().any(|w| !w.is_finished()) {
            std::thread::sleep(Duration::from_millis(2));
        }
        self.ctx.abort.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if self.accept.is_some() || !self.workers.is_empty() {
            self.stop_threads(Duration::ZERO);
        }
    }
}

fn worker_loop(rx: &Arc<Mutex<Receiver<TcpStream>>>, ctx: &Arc<ServeCtx>) {
    loop {
        // Take the lock only to pull the next socket — holding it across
        // `handle_connection` would serialize the whole pool.
        let conn = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return,
        };
        match conn {
            Ok(stream) => handle_connection(stream, ctx),
            // Sender gone: the accept loop exited; nothing more will come.
            Err(_) => return,
        }
    }
}

fn accept_loop(listener: TcpListener, tx: SyncSender<TcpStream>, ctx: &Arc<ServeCtx>) {
    while !ctx.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => match tx.try_send(stream) {
                Ok(()) => {}
                Err(TrySendError::Full(stream)) => reject_busy(stream),
                Err(TrySendError::Disconnected(_)) => return,
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            // Transient accept errors (ECONNABORTED etc.): keep listening.
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    // `tx` drops here; idle workers see Disconnected and exit.
}

/// Every worker is busy and the hand-off channel is full: shed at the edge
/// with a minimal 503 rather than queueing unbounded connections.
fn reject_busy(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let body = super::http::error_body(503, "overloaded", "all connection workers are busy");
    let _ = write_response(
        &mut stream,
        503,
        "Service Unavailable",
        "application/json",
        body.as_bytes(),
        false,
    );
    let _ = stream.flush();
}
