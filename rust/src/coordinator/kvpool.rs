//! KV-cache memory: ref-counted copy-on-write [`KvPage`]s, the per-sequence
//! [`KvCache`] page list, and the [`KvPool`] that accounts tokens across
//! concurrent sequences and indexes live prefixes in a token-trie.
//!
//! ## Page layout
//!
//! KV storage is paged: a [`KvPage`] holds exactly [`KV_TILE`] positions of
//! K and V for **every** layer and head, stored head-major — per (layer,
//! head) one contiguous `KV_TILE × hd` panel (position-major within the
//! panel):
//!
//! ```text
//! page.keys = [ (l0,h0): pos 0 | … | pos KV_TILE-1 ]
//!             [ (l0,h1): pos 0 | … | pos KV_TILE-1 ] …
//!             [ (l1,h0): … ] …
//! ```
//!
//! so position `p` of head `h` in layer `l` lives at
//! `((l·nh + h)·KV_TILE + p)·hd`. Consecutive positions of one head are
//! `hd` floats apart — the attention kernels (`tensor::attn_kernel`)
//! stream each panel as one unit-stride run, and the span drivers walk a
//! sequence's page list segment by segment (a softmax row is computed over
//! per-page partial score spans, which is bitwise-identical to the old
//! contiguous sweep because scores and weighted-V accumulation are
//! per-position independent).
//!
//! A [`KvCache`] is a `Vec<Arc<KvPage>>` plus a live-position count
//! (`seen`); page `i` covers positions `i·KV_TILE .. (i+1)·KV_TILE`.
//! Capacity grows by appending fresh pages ([`KvCache::reserve`]) — no
//! repack, growth never copies resident K/V.
//!
//! ## Quantized pages ([`KvDtype::Int8`])
//!
//! Pages are dtype-parametric. An `Int8` page stores the same head-major
//! geometry as int8 codes plus one f32 scale per (layer, head, position)
//! row for K and V independently (`quant::act::quantize_tile` at write
//! time, fused dequant in `attn_head_span_int8` at read time). Because
//! each position quantizes independently, codes are invariant to prompt
//! chunking — which is also what makes cached prefix pages bit-exact
//! reusable. `Int8` cuts the per-token footprint to
//! `2·layers·(d_model + 4·nh)` bytes vs `2·layers·d_model·4` for f32
//! (~3.2–3.9x more resident tokens per pool byte budget).
//!
//! ## Copy-on-write
//!
//! Pages are shared by `Arc`: the prefix trie and any number of sequences
//! may hold the same physical page. Sharing is read-only — every write
//! path calls [`KvCache::reserve`] for the span it is about to fill, and
//! `reserve` replaces each page in the write range whose refcount is > 1
//! with a private deep copy (`Arc::get_mut` then asserts uniqueness at the
//! actual write). On the serving hot path COW never fires: trie-matched
//! prefix pages are full (positions `< matched`) and the novel suffix
//! lands in fresh pages; COW exists for truncate-then-rewrite and cloned
//! caches.
//!
//! ## `KvPool`, prefix trie, and eviction
//!
//! The pool accounts a fixed token budget. Sequences hold token-granular
//! [`Lease`]s exactly as before the page refactor — a lease covers the
//! **full** sequence span including trie-matched positions (prefix reuse
//! saves prefill compute, not lease accounting), so admission backpressure
//! is unchanged. Cached prefix pages are accounted separately
//! (`cached_tokens`, [`KV_TILE`] tokens per trie page) and the invariant is
//! `used_tokens + cached_tokens ≤ capacity_tokens`.
//!
//! Live prefixes are indexed by a radix tree over token IDs with
//! [`KV_TILE`]-token chunk edges, one trie per dtype (pages of different
//! dtypes are never interchangeable). [`KvPool::match_prefix`] walks the
//! trie over a prompt and returns the longest run of full cached pages,
//! capped so at least one novel token remains (the final forward must
//! produce first-token logits). [`KvPool::insert_prefix`] publishes a
//! finished prefill's fully-covered prompt pages (idempotent; skips pages
//! that don't fit the budget). Under pressure, [`KvPool::alloc`] /
//! [`KvPool::grow`] evict LRU trie **leaves** whose page refcount is 1
//! (nobody but the trie holds them); interior nodes become evictable
//! leaves once their children go. A failed grow after eviction is still a
//! normal signal (the batcher finishes the sequence truncated).

use crate::model::ModelConfig;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Positions per KV page (and per trie chunk edge).
pub const KV_TILE: usize = 64;

/// Storage dtype of a [`KvCache`]'s K/V pages. `F32` keeps the raw floats;
/// `Int8` stores symmetric int8 codes with one f32 scale per cached row
/// (per position per head) and relies on the fused-dequant attention
/// kernels (`tensor::attn_kernel::attn_head_span_int8`) at read time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    #[default]
    F32,
    Int8,
}

impl KvDtype {
    /// Every `--kv-bits` value that maps to a dtype, for CLI error text.
    pub const SUPPORTED_BITS: [usize; 2] = [32, 8];

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
        }
    }

    /// Bits per stored K/V element (scale overhead not included).
    pub fn bits(self) -> usize {
        match self {
            KvDtype::F32 => 32,
            KvDtype::Int8 => 8,
        }
    }

    /// Map a `--kv-bits` style knob to a dtype.
    pub fn from_bits(bits: usize) -> Option<KvDtype> {
        match bits {
            32 => Some(KvDtype::F32),
            8 => Some(KvDtype::Int8),
            _ => None,
        }
    }

    /// Trie index: one prefix trie per dtype (see the module doc).
    fn index(self) -> usize {
        match self {
            KvDtype::F32 => 0,
            KvDtype::Int8 => 1,
        }
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One fixed-size KV page: [`KV_TILE`] positions of K and V for every
/// (layer, head) panel of one sequence segment (see the module-doc layout).
/// Pages are shared by `Arc` between sequences and the pool's prefix trie;
/// the optional `meter` counts physical pages alive per pool (created on
/// allocation and deep copy, decremented on drop) for leak tests and
/// observability.
pub struct KvPage {
    /// F32 K panels: `layers·nh` panels of `KV_TILE × hd`.
    keys: Vec<f32>,
    /// F32 V panels, same layout as `keys`.
    values: Vec<f32>,
    /// Int8 K code panels, same geometry as `keys`.
    qkeys: Vec<i8>,
    /// Int8 V code panels.
    qvalues: Vec<i8>,
    /// Per-row K scales: `layers·nh·KV_TILE`, row `(l·nh + h)·KV_TILE + p`.
    kscales: Vec<f32>,
    /// Per-row V scales, same layout as `kscales`.
    vscales: Vec<f32>,
    dtype: KvDtype,
    nh: usize,
    hd: usize,
    meter: Option<Arc<AtomicUsize>>,
}

impl KvPage {
    fn new(layers: usize, nh: usize, hd: usize, dtype: KvDtype, meter: Option<Arc<AtomicUsize>>) -> KvPage {
        let panel = layers * nh * KV_TILE * hd;
        let rows = layers * nh * KV_TILE;
        let (keys, values, qkeys, qvalues, kscales, vscales) = match dtype {
            KvDtype::F32 => (vec![0.0; panel], vec![0.0; panel], Vec::new(), Vec::new(), Vec::new(), Vec::new()),
            KvDtype::Int8 => (Vec::new(), Vec::new(), vec![0; panel], vec![0; panel], vec![0.0; rows], vec![0.0; rows]),
        };
        if let Some(m) = &meter {
            m.fetch_add(1, Ordering::Relaxed);
        }
        KvPage { keys, values, qkeys, qvalues, kscales, vscales, dtype, nh, hd, meter }
    }

    /// The first `n ≤ KV_TILE` positions of (layer, head)'s key and value
    /// panels as contiguous `n × hd` tiles — one attention-kernel segment.
    /// F32 pages only; int8 pages use [`KvPage::head_panel_quant`].
    #[inline]
    pub fn head_panel(&self, l: usize, h: usize, n: usize) -> (&[f32], &[f32]) {
        debug_assert!(n <= KV_TILE, "page read of {n} beyond {KV_TILE}");
        debug_assert_eq!(self.dtype, KvDtype::F32, "head_panel on an int8 page");
        let off = (l * self.nh + h) * KV_TILE * self.hd;
        let len = n * self.hd;
        (&self.keys[off..off + len], &self.values[off..off + len])
    }

    /// Quantized segment view: `n × hd` K/V code tiles plus the matching
    /// `n` per-row scales. Int8 pages only.
    #[inline]
    pub fn head_panel_quant(&self, l: usize, h: usize, n: usize) -> (&[i8], &[i8], &[f32], &[f32]) {
        debug_assert!(n <= KV_TILE, "page read of {n} beyond {KV_TILE}");
        debug_assert_eq!(self.dtype, KvDtype::Int8, "head_panel_quant on an f32 page");
        let off = (l * self.nh + h) * KV_TILE * self.hd;
        let len = n * self.hd;
        let srow = (l * self.nh + h) * KV_TILE;
        (
            &self.qkeys[off..off + len],
            &self.qvalues[off..off + len],
            &self.kscales[srow..srow + n],
            &self.vscales[srow..srow + n],
        )
    }

    #[inline]
    fn kv_row_mut(&mut self, l: usize, h: usize, p: usize) -> (&mut [f32], &mut [f32]) {
        debug_assert_eq!(self.dtype, KvDtype::F32, "kv_row_mut on an int8 page");
        let off = ((l * self.nh + h) * KV_TILE + p) * self.hd;
        let hd = self.hd;
        (&mut self.keys[off..off + hd], &mut self.values[off..off + hd])
    }

    #[inline]
    fn kv_row_quant_mut(&mut self, l: usize, h: usize, p: usize) -> (&mut [i8], &mut [i8], &mut f32, &mut f32) {
        debug_assert_eq!(self.dtype, KvDtype::Int8, "kv_row_quant_mut on an f32 page");
        let row = (l * self.nh + h) * KV_TILE + p;
        let off = row * self.hd;
        let hd = self.hd;
        let (qk, qv) = (&mut self.qkeys[off..off + hd], &mut self.qvalues[off..off + hd]);
        (qk, qv, &mut self.kscales[row], &mut self.vscales[row])
    }
}

impl Clone for KvPage {
    /// Deep copy — the COW path. A clone is a new physical page, so the
    /// pool's page meter is bumped.
    fn clone(&self) -> KvPage {
        if let Some(m) = &self.meter {
            m.fetch_add(1, Ordering::Relaxed);
        }
        KvPage {
            keys: self.keys.clone(),
            values: self.values.clone(),
            qkeys: self.qkeys.clone(),
            qvalues: self.qvalues.clone(),
            kscales: self.kscales.clone(),
            vscales: self.vscales.clone(),
            dtype: self.dtype,
            nh: self.nh,
            hd: self.hd,
            meter: self.meter.clone(),
        }
    }
}

impl Drop for KvPage {
    fn drop(&mut self) {
        if let Some(m) = &self.meter {
            m.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Per-sequence KV cache: an ordered list of shared pages (page `i` covers
/// positions `i·KV_TILE..(i+1)·KV_TILE`) plus the live-position count.
/// `seen` is the number of positions whose K/V are live; the forward paths
/// write span positions `seen..seen+t` first and advance `seen` once per
/// multi-layer forward. Cloning shares pages (cheap); the first write into
/// a shared page copies it (see the module-doc COW rules).
#[derive(Clone)]
pub struct KvCache {
    pages: Vec<Arc<KvPage>>,
    dtype: KvDtype,
    /// Live positions (decoded so far).
    pub seen: usize,
    layers: usize,
    nh: usize,
    hd: usize,
    meter: Option<Arc<AtomicUsize>>,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::new_with(cfg, KvDtype::F32)
    }

    /// A cache with the given storage dtype (see [`KvDtype`]).
    pub fn new_with(cfg: &ModelConfig, dtype: KvDtype) -> KvCache {
        KvCache::with_layers_dtype(cfg, cfg.n_layers, dtype)
    }

    /// A cache pre-sized to `positions` (the batcher sizes to the admission
    /// lease so steady-state prefill/decode never allocates mid-flight).
    pub fn with_capacity(cfg: &ModelConfig, positions: usize) -> KvCache {
        KvCache::with_capacity_dtype(cfg, positions, KvDtype::F32)
    }

    /// Pre-sized cache with an explicit storage dtype.
    pub fn with_capacity_dtype(cfg: &ModelConfig, positions: usize, dtype: KvDtype) -> KvCache {
        let mut c = KvCache::new_with(cfg, dtype);
        c.reserve(positions);
        c
    }

    /// Single-layer scratch cache for the teacher-forced path, which runs
    /// one block's span attention at a time (always at cache layer 0).
    pub(crate) fn span_scratch(cfg: &ModelConfig) -> KvCache {
        KvCache::with_layers_dtype(cfg, 1, KvDtype::F32)
    }

    /// Layer-truncated cache for a truncated-layer draft forward
    /// ([`crate::model::DraftModel`]): K/V pages cover only the first
    /// `n_layers` blocks, so a self-draft's cache costs
    /// `n_layers / cfg.n_layers` of the target's per-token bytes.
    pub fn for_layers(cfg: &ModelConfig, n_layers: usize) -> KvCache {
        assert!(n_layers >= 1 && n_layers <= cfg.n_layers, "draft layers out of range");
        KvCache::with_layers_dtype(cfg, n_layers, KvDtype::F32)
    }

    fn with_layers_dtype(cfg: &ModelConfig, n_layers: usize, dtype: KvDtype) -> KvCache {
        KvCache {
            pages: Vec::new(),
            dtype,
            seen: 0,
            layers: n_layers,
            nh: cfg.n_heads,
            hd: cfg.d_model / cfg.n_heads,
            meter: None,
        }
    }

    /// Storage dtype of this cache's pages.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn len(&self) -> usize {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Positions the page list can hold before the next page append.
    pub fn capacity(&self) -> usize {
        self.pages.len() * KV_TILE
    }

    /// Number of pages in the list (shared or private).
    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    /// Page `i` of the list — covers positions `i·KV_TILE..(i+1)·KV_TILE`.
    /// The `Arc` is exposed so the pool can publish prompt pages into the
    /// prefix trie without copying.
    #[inline]
    pub fn page(&self, i: usize) -> &Arc<KvPage> {
        &self.pages[i]
    }

    /// Live KV bytes (`seen` positions across all layers). Capacity beyond
    /// `seen` is pool-accounted via the sequence's lease, not counted here.
    /// For `Int8` this is the true quantized footprint: 1-byte codes plus
    /// one f32 scale per row (K and V each) per position per head.
    pub fn bytes(&self) -> usize {
        let rows = 2 * self.layers * self.seen * self.nh;
        match self.dtype {
            KvDtype::F32 => rows * self.hd * 4,
            KvDtype::Int8 => rows * self.hd + rows * 4,
        }
    }

    /// Ensure the page list covers `positions` AND that every page in the
    /// upcoming write range `seen..positions` is privately owned: shared
    /// pages (refcount > 1 — held by the prefix trie or a cloned cache) are
    /// replaced with deep copies before the caller takes `&mut` rows. Every
    /// write path reserves its span first, so this is the single COW gate.
    pub fn reserve(&mut self, positions: usize) {
        let want_pages = positions.div_ceil(KV_TILE);
        while self.pages.len() < want_pages {
            self.pages.push(Arc::new(KvPage::new(
                self.layers,
                self.nh,
                self.hd,
                self.dtype,
                self.meter.clone(),
            )));
        }
        if positions > self.seen {
            let first = self.seen / KV_TILE;
            let last = (positions - 1) / KV_TILE;
            for i in first..=last {
                if Arc::strong_count(&self.pages[i]) > 1 {
                    let private = Arc::new(KvPage::clone(&self.pages[i]));
                    self.pages[i] = private;
                }
            }
        }
    }

    /// Mutable K/V rows for (layer, head, position) — the append target of
    /// the span staging pass. The caller must have [`KvCache::reserve`]d
    /// `pos + 1` positions (which also runs COW on the write range). F32
    /// caches only; int8 caches use [`KvCache::kv_row_quant_mut`].
    #[inline]
    pub fn kv_row_mut(&mut self, l: usize, h: usize, pos: usize) -> (&mut [f32], &mut [f32]) {
        debug_assert!(pos < self.capacity(), "kv write at {pos} beyond capacity {}", self.capacity());
        let page = Arc::get_mut(&mut self.pages[pos / KV_TILE])
            .expect("write to shared KV page (reserve() must precede writes)");
        page.kv_row_mut(l, h, pos % KV_TILE)
    }

    /// Quantized append target for (layer, head, position): the K and V code
    /// rows plus their scale slots, for the staging pass to fill via
    /// `quant::act::quantize_tile`. Int8 caches only.
    #[inline]
    pub fn kv_row_quant_mut(
        &mut self,
        l: usize,
        h: usize,
        pos: usize,
    ) -> (&mut [i8], &mut [i8], &mut f32, &mut f32) {
        debug_assert!(pos < self.capacity(), "kv write at {pos} beyond capacity {}", self.capacity());
        let page = Arc::get_mut(&mut self.pages[pos / KV_TILE])
            .expect("write to shared KV page (reserve() must precede writes)");
        page.kv_row_quant_mut(l, h, pos % KV_TILE)
    }

    /// Drop everything after position `n` (speculative rollback, cancel,
    /// prefix reuse). Clamps `seen` and releases whole pages past the last
    /// live one: dropping the `Arc` decrements the pool's page meter when
    /// this cache held the final reference, so rolled-back positions stop
    /// pinning physical memory. Stale rows within the kept tail page are
    /// never read (every read is bounded by a caller-passed position
    /// count), and rewriting truncated positions COWs any still-shared
    /// page via [`KvCache::reserve`].
    pub fn truncate(&mut self, n: usize) {
        self.seen = self.seen.min(n);
        self.pages.truncate(self.seen.div_ceil(KV_TILE));
    }
}

/// A live-prefix index node: one [`KV_TILE`]-token chunk edge per child.
/// A node at depth `d` (1-based) caches page `d-1` of every sequence whose
/// prompt starts with the concatenated path chunks.
struct TrieNode {
    children: HashMap<Vec<u32>, TrieNode>,
    page: Arc<KvPage>,
    last_used: u64,
}

struct PoolState {
    capacity_tokens: usize,
    used_tokens: usize,
    /// Tokens pinned by trie-cached pages ([`KV_TILE`] per page). Separate
    /// from `used_tokens`: leases never cover trie retention.
    cached_tokens: usize,
    next_id: u64,
    live: std::collections::BTreeMap<u64, usize>,
    /// Peak of `used_tokens + cached_tokens`.
    peak_tokens: usize,
    /// Monotonic LRU clock, bumped per match/insert.
    lru_tick: u64,
    /// One prefix trie per dtype ([`KvDtype::index`]).
    tries: [HashMap<Vec<u32>, TrieNode>; 2],
}

/// Shared pool handle.
#[derive(Clone)]
pub struct KvPool {
    state: Arc<Mutex<PoolState>>,
    /// Physical pages alive (allocated or deep-copied minus dropped) across
    /// every cache and trie node attached to this pool.
    pages_meter: Arc<AtomicUsize>,
    /// Per-token KV bytes for accounting (2 · n_layers · d_model · 4).
    pub bytes_per_token: usize,
}

/// An allocation lease for one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    pub id: u64,
    pub tokens: usize,
}

impl KvPool {
    /// Lock the pool state, recovering from mutex poisoning. A worker that
    /// panicked mid-iteration (fault injection, or a real bug caught by the
    /// batcher's isolation layer) must not wedge its siblings or the engine
    /// facade: pool mutations are small and complete-or-not-started, so the
    /// inner state is still structurally sound after a poisoned unlock.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn new(capacity_tokens: usize, bytes_per_token: usize) -> KvPool {
        KvPool {
            state: Arc::new(Mutex::new(PoolState {
                capacity_tokens,
                used_tokens: 0,
                cached_tokens: 0,
                next_id: 1,
                live: Default::default(),
                peak_tokens: 0,
                lru_tick: 0,
                tries: [HashMap::new(), HashMap::new()],
            })),
            pages_meter: Arc::new(AtomicUsize::new(0)),
            bytes_per_token,
        }
    }

    /// Per-token KV bytes for a model at the given storage dtype: K + V,
    /// all layers. F32 is `2·layers·d_model·4`; Int8 is 1-byte codes plus
    /// one f32 scale per head row (K and V each), `2·layers·(d_model + 4·nh)`
    /// — the scale overhead is what keeps int8 at ~3.2x (micro) rather than
    /// a flat 4x.
    fn model_bytes_per_token_dtype(cfg: &crate::model::ModelConfig, dtype: KvDtype) -> usize {
        match dtype {
            KvDtype::F32 => 2 * cfg.n_layers * cfg.d_model * 4,
            KvDtype::Int8 => 2 * cfg.n_layers * (cfg.d_model + 4 * cfg.n_heads),
        }
    }

    /// Pool holding `capacity_tokens` positions with byte accounting sized
    /// from the model config — the one constructor serve-time callers need.
    pub fn for_model_tokens(cfg: &crate::model::ModelConfig, capacity_tokens: usize) -> KvPool {
        KvPool::for_model_tokens_dtype(cfg, capacity_tokens, KvDtype::F32)
    }

    /// Token-capacity pool with byte accounting for the given KV dtype.
    pub fn for_model_tokens_dtype(
        cfg: &crate::model::ModelConfig,
        capacity_tokens: usize,
        dtype: KvDtype,
    ) -> KvPool {
        KvPool::new(
            capacity_tokens.max(1),
            KvPool::model_bytes_per_token_dtype(cfg, dtype),
        )
    }

    /// For a model: capacity from a byte budget.
    pub fn for_model(cfg: &crate::model::ModelConfig, budget_bytes: usize) -> KvPool {
        KvPool::for_model_dtype(cfg, budget_bytes, KvDtype::F32)
    }

    /// Byte-budget pool sized for the given KV dtype — an int8 pool admits
    /// ~`f32_bpt / int8_bpt` times the resident tokens at equal budget.
    pub fn for_model_dtype(
        cfg: &crate::model::ModelConfig,
        budget_bytes: usize,
        dtype: KvDtype,
    ) -> KvPool {
        let per_token = KvPool::model_bytes_per_token_dtype(cfg, dtype);
        KvPool::new((budget_bytes / per_token).max(1), per_token)
    }

    /// Evict LRU trie pages until `need` more tokens fit beside the live
    /// leases and remaining cached pages. False when the trie is drained
    /// (or pinned by in-flight sequences) and the request still can't fit.
    fn make_room(s: &mut PoolState, need: usize) -> bool {
        while s.used_tokens + s.cached_tokens + need > s.capacity_tokens {
            if !KvPool::evict_one(s) {
                return false;
            }
        }
        true
    }

    /// Remove the least-recently-used evictable trie leaf (page refcount 1:
    /// only the trie holds it). Interior nodes are skipped — dropping one
    /// would take its whole subtree down, including recently-used deeper
    /// pages; they become leaves, and candidates, as their children go.
    fn evict_one(s: &mut PoolState) -> bool {
        let mut best: Option<(u64, usize, Vec<Vec<u32>>)> = None;
        for (ti, root) in s.tries.iter().enumerate() {
            KvPool::find_lru_leaf(root, ti, &mut Vec::new(), &mut best);
        }
        let Some((_, ti, path)) = best else { return false };
        KvPool::remove_path(&mut s.tries[ti], &path);
        s.cached_tokens -= KV_TILE;
        true
    }

    fn find_lru_leaf(
        level: &HashMap<Vec<u32>, TrieNode>,
        ti: usize,
        path: &mut Vec<Vec<u32>>,
        best: &mut Option<(u64, usize, Vec<Vec<u32>>)>,
    ) {
        for (chunk, node) in level {
            path.push(chunk.clone());
            if node.children.is_empty() {
                let evictable = Arc::strong_count(&node.page) == 1;
                let colder = match best {
                    Some((t, _, _)) => node.last_used < *t,
                    None => true,
                };
                if evictable && colder {
                    *best = Some((node.last_used, ti, path.clone()));
                }
            } else {
                KvPool::find_lru_leaf(&node.children, ti, path, best);
            }
            path.pop();
        }
    }

    fn remove_path(level: &mut HashMap<Vec<u32>, TrieNode>, path: &[Vec<u32>]) {
        match path {
            [last] => {
                level.remove(last);
            }
            [head, rest @ ..] => {
                if let Some(node) = level.get_mut(head) {
                    KvPool::remove_path(&mut node.children, rest);
                }
            }
            [] => {}
        }
    }

    /// Try to lease `tokens` tokens of KV space, evicting cached prefix
    /// pages under pressure (live sequences always outrank the cache).
    pub fn alloc(&self, tokens: usize) -> Option<Lease> {
        let mut s = self.lock_state();
        if !KvPool::make_room(&mut s, tokens) {
            return None;
        }
        s.used_tokens += tokens;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens + s.cached_tokens);
        let id = s.next_id;
        s.next_id += 1;
        s.live.insert(id, tokens);
        Some(Lease { id, tokens })
    }

    /// Grow an existing lease by `extra` tokens (decode step), evicting
    /// cached prefix pages under pressure. A lease the pool no longer knows
    /// (possible only after a worker-failure cleanup raced a retire) is a
    /// debug-time invariant violation but degrades to a failed grow in
    /// release — the sequence finishes truncated instead of panicking a
    /// second worker.
    pub fn grow(&self, lease: &mut Lease, extra: usize) -> bool {
        let mut s = self.lock_state();
        if !KvPool::make_room(&mut s, extra) {
            return false;
        }
        let Some(entry) = s.live.get_mut(&lease.id) else {
            debug_assert!(false, "grow of unknown KV lease {}", lease.id);
            return false;
        };
        *entry += extra;
        s.used_tokens += extra;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens + s.cached_tokens);
        lease.tokens += extra;
        true
    }

    /// Release a lease. A double free is a true invariant violation —
    /// loud under `debug_assertions` — but degrades to a no-op in release
    /// so a worker-failure cleanup path can never take the process down.
    pub fn free(&self, lease: Lease) {
        let mut s = self.lock_state();
        let Some(tokens) = s.live.remove(&lease.id) else {
            debug_assert!(false, "double free of KV lease {}", lease.id);
            return;
        };
        debug_assert_eq!(tokens, lease.tokens, "lease size drift");
        s.used_tokens -= tokens;
    }

    /// Clamp (or restore) the pool's token capacity at runtime. Used by the
    /// fault-injection harness to simulate transient memory pressure: a
    /// clamp below current occupancy does not reclaim anything by itself —
    /// it just makes every `alloc`/`grow` fail (after eviction) until
    /// occupancy drains or the capacity is restored. Admission treats the
    /// clamped value exactly like a small pool (transient pushback for
    /// feasible requests, `Rejected` for ones that could never fit).
    pub fn set_capacity_tokens(&self, tokens: usize) {
        let mut s = self.lock_state();
        s.capacity_tokens = tokens.max(1);
    }

    /// Build a sequence cache attached to this pool's page meter, seeded
    /// with trie-matched prefix pages (pass an empty vec for a cold start)
    /// and pre-sized to `positions`. `seen` starts at the matched length —
    /// the caller feeds only the novel suffix.
    pub fn new_cache(
        &self,
        cfg: &ModelConfig,
        dtype: KvDtype,
        prefix_pages: Vec<Arc<KvPage>>,
        positions: usize,
    ) -> KvCache {
        let mut c = KvCache::with_layers_dtype(cfg, cfg.n_layers, dtype);
        c.meter = Some(Arc::clone(&self.pages_meter));
        c.seen = prefix_pages.len() * KV_TILE;
        c.pages = prefix_pages;
        c.reserve(positions.max(c.seen));
        c
    }

    /// Longest cached prefix of `tokens`: walks the dtype's trie over
    /// [`KV_TILE`]-token chunks, returns `(matched_tokens, pages)` and
    /// bumps LRU stamps along the path. Capped at
    /// `(tokens.len() − 1) / KV_TILE` pages so at least one prompt token is
    /// always prefilled (the final forward must emit first-token logits).
    pub fn match_prefix(&self, tokens: &[u32], dtype: KvDtype) -> (usize, Vec<Arc<KvPage>>) {
        if tokens.len() <= 1 {
            return (0, Vec::new());
        }
        let max_pages = (tokens.len() - 1) / KV_TILE;
        let mut s = self.lock_state();
        s.lru_tick += 1;
        let tick = s.lru_tick;
        let mut pages = Vec::new();
        let mut level = &mut s.tries[dtype.index()];
        for chunk in tokens.chunks_exact(KV_TILE).take(max_pages) {
            if let Some(node) = level.get_mut(chunk) {
                node.last_used = tick;
                pages.push(Arc::clone(&node.page));
                level = &mut node.children;
            } else {
                break;
            }
        }
        (pages.len() * KV_TILE, pages)
    }

    /// Publish the fully-prompt-covered pages of a finished prefill into
    /// the prefix trie: `floor(tokens.len() / KV_TILE)` pages, keyed by
    /// their token chunks. Idempotent (existing path nodes only get an LRU
    /// bump); new pages are admitted best-effort against the pool budget
    /// (evicting colder entries first, never failing the caller).
    pub fn insert_prefix(&self, tokens: &[u32], cache: &KvCache) {
        let n_pages = (tokens.len() / KV_TILE).min(cache.page_count()).min(cache.seen / KV_TILE);
        if n_pages == 0 {
            return;
        }
        let mut s = self.lock_state();
        s.lru_tick += 1;
        let tick = s.lru_tick;
        let ti = cache.dtype().index();
        // Pass 1: how much of the path already exists? (Bump its LRU stamps
        // while walking — an insert is a use.)
        let mut present = 0;
        {
            let mut level = &mut s.tries[ti];
            for chunk in tokens.chunks_exact(KV_TILE).take(n_pages) {
                if let Some(node) = level.get_mut(chunk) {
                    node.last_used = tick;
                    present += 1;
                    level = &mut node.children;
                } else {
                    break;
                }
            }
        }
        let missing = n_pages - present;
        if missing == 0 {
            return;
        }
        // Pass 2: best-effort room for the missing pages (never evict live
        // leases; an overfull pool just caches a shorter prefix).
        let _ = KvPool::make_room(&mut s, missing * KV_TILE);
        let budget = s.capacity_tokens.saturating_sub(s.used_tokens + s.cached_tokens) / KV_TILE;
        // Pass 3: upsert the path, creating nodes while the budget lasts.
        // (Eviction in pass 2 may have removed a pass-1 node whose subtree
        // was cold — the upsert recreates it from the cache's page, whose
        // content for that chunk is identical.)
        let mut created = 0usize;
        {
            let mut level = &mut s.tries[ti];
            for (i, chunk) in tokens.chunks_exact(KV_TILE).take(n_pages).enumerate() {
                match level.entry(chunk.to_vec()) {
                    Entry::Occupied(e) => {
                        let node = e.into_mut();
                        node.last_used = tick;
                        level = &mut node.children;
                    }
                    Entry::Vacant(e) => {
                        if created >= budget {
                            break;
                        }
                        created += 1;
                        let node = e.insert(TrieNode {
                            children: HashMap::new(),
                            page: Arc::clone(cache.page(i)),
                            last_used: tick,
                        });
                        level = &mut node.children;
                    }
                }
            }
        }
        s.cached_tokens += created * KV_TILE;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens + s.cached_tokens);
    }

    /// Drop every cached prefix page (pages shared with live sequences
    /// survive until those sequences finish).
    pub fn clear_prefix_cache(&self) {
        let mut s = self.lock_state();
        s.tries = [HashMap::new(), HashMap::new()];
        s.cached_tokens = 0;
    }

    pub fn used_tokens(&self) -> usize {
        self.lock_state().used_tokens
    }

    /// Tokens pinned by trie-cached prefix pages ([`KV_TILE`] per page).
    pub fn cached_tokens(&self) -> usize {
        self.lock_state().cached_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.lock_state().capacity_tokens
    }

    /// Peak of leased + cached tokens.
    pub fn peak_tokens(&self) -> usize {
        self.lock_state().peak_tokens
    }

    pub fn live_leases(&self) -> usize {
        self.lock_state().live.len()
    }

    /// Physical KV pages alive across this pool's caches and trie.
    pub fn live_pages(&self) -> usize {
        self.pages_meter.load(Ordering::Relaxed)
    }

    pub fn used_bytes(&self) -> usize {
        self.used_tokens() * self.bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let pool = KvPool::new(100, 8);
        let a = pool.alloc(40).unwrap();
        let b = pool.alloc(60).unwrap();
        assert!(pool.alloc(1).is_none(), "over capacity");
        assert_eq!(pool.used_tokens(), 100);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 60);
        let c = pool.alloc(30).unwrap();
        pool.free(b);
        pool.free(c);
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
        assert_eq!(pool.peak_tokens(), 100);
    }

    #[test]
    fn grow_respects_capacity() {
        let pool = KvPool::new(50, 8);
        let mut a = pool.alloc(45).unwrap();
        assert!(pool.grow(&mut a, 5));
        assert!(!pool.grow(&mut a, 1));
        assert_eq!(a.tokens, 50);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 0);
    }

    #[test]
    #[cfg(debug_assertions)] // release degrades to a no-op (worker-failure cleanup safety)
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let pool = KvPool::new(10, 8);
        let a = pool.alloc(5).unwrap();
        pool.free(a);
        pool.free(a);
    }

    #[test]
    fn for_model_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model(&cfg, 1 << 20);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        assert_eq!(pool.capacity_tokens(), (1 << 20) / (2 * 2 * 64 * 4));
    }

    #[test]
    fn for_model_tokens_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model_tokens(&cfg, 4096);
        assert_eq!(pool.capacity_tokens(), 4096);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        // Degenerate budget still yields a usable pool.
        assert_eq!(KvPool::for_model_tokens(&cfg, 0).capacity_tokens(), 1);
    }

    #[test]
    fn kv_cache_page_layout_roundtrip() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let mut c = KvCache::new(&cfg);
        assert_eq!(c.capacity(), 0);
        let positions = 5usize;
        c.reserve(positions);
        assert!(c.capacity() >= positions);
        assert_eq!(c.capacity() % KV_TILE, 0);
        assert_eq!(c.page_count(), 1);
        // Write a distinct pattern per (layer, head, pos, lane) and read it
        // back through the page panel accessor.
        let val = |l: usize, h: usize, p: usize, i: usize| {
            (l * 1000 + h * 100 + p * 10 + i) as f32
        };
        for l in 0..cfg.n_layers {
            for p in 0..positions {
                for h in 0..nh {
                    let (k, v) = c.kv_row_mut(l, h, p);
                    for i in 0..hd {
                        k[i] = val(l, h, p, i);
                        v[i] = -val(l, h, p, i);
                    }
                }
            }
        }
        c.seen = positions;
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (kt, vt) = c.page(0).head_panel(l, h, positions);
                assert_eq!(kt.len(), positions * hd);
                for p in 0..positions {
                    for i in 0..hd {
                        assert_eq!(kt[p * hd + i], val(l, h, p, i), "L{l} h{h} p{p} i{i}");
                        assert_eq!(vt[p * hd + i], -val(l, h, p, i));
                    }
                }
            }
        }
        // Growth appends pages without touching resident contents.
        let old_cap = c.capacity();
        c.reserve(old_cap + 1);
        assert!(c.capacity() > old_cap);
        assert_eq!(c.page_count(), 2);
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (kt, _) = c.page(0).head_panel(l, h, positions);
                for p in 0..positions {
                    for i in 0..hd {
                        assert_eq!(kt[p * hd + i], val(l, h, p, i), "post-grow L{l} h{h} p{p}");
                    }
                }
            }
        }
    }

    #[test]
    fn int8_kv_cache_page_layout_roundtrip() {
        // The quantized mirror of kv_cache_page_layout_roundtrip: codes and
        // per-row scales written through kv_row_quant_mut read back through
        // head_panel_quant, across a page-grow.
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let mut c = KvCache::new_with(&cfg, KvDtype::Int8);
        assert_eq!(c.dtype(), KvDtype::Int8);
        assert_eq!(c.capacity(), 0);
        let positions = 5usize;
        c.reserve(positions);
        assert!(c.capacity() >= positions);
        assert_eq!(c.capacity() % KV_TILE, 0);
        let code = |l: usize, h: usize, p: usize, i: usize| ((l * 31 + h * 17 + p * 5 + i) % 255) as i32 - 127;
        let kscale = |l: usize, h: usize, p: usize| (l * 100 + h * 10 + p + 1) as f32 * 0.5;
        for l in 0..cfg.n_layers {
            for p in 0..positions {
                for h in 0..nh {
                    let (kc, vc, ks, vs) = c.kv_row_quant_mut(l, h, p);
                    for i in 0..hd {
                        kc[i] = code(l, h, p, i) as i8;
                        vc[i] = -(code(l, h, p, i) as i8);
                    }
                    *ks = kscale(l, h, p);
                    *vs = -kscale(l, h, p);
                }
            }
        }
        c.seen = positions;
        let check = |c: &KvCache, tag: &str| {
            for l in 0..cfg.n_layers {
                for h in 0..nh {
                    let (kt, vt, ks, vs) = c.page(0).head_panel_quant(l, h, positions);
                    assert_eq!(kt.len(), positions * hd);
                    assert_eq!(ks.len(), positions);
                    for p in 0..positions {
                        for i in 0..hd {
                            assert_eq!(kt[p * hd + i], code(l, h, p, i) as i8, "{tag} L{l} h{h} p{p} i{i}");
                            assert_eq!(vt[p * hd + i], -(code(l, h, p, i) as i8));
                        }
                        assert_eq!(ks[p], kscale(l, h, p), "{tag} kscale L{l} h{h} p{p}");
                        assert_eq!(vs[p], -kscale(l, h, p), "{tag} vscale L{l} h{h} p{p}");
                    }
                }
            }
        };
        check(&c, "pre-grow");
        let old_cap = c.capacity();
        c.reserve(old_cap + 1);
        assert!(c.capacity() > old_cap);
        check(&c, "post-grow");
    }

    #[test]
    fn cow_preserves_shared_page_contents() {
        // A cloned cache shares pages; truncate-then-rewrite on one side
        // must copy the shared page, leaving the other side's view intact.
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let mut a = KvCache::with_capacity(&cfg, KV_TILE);
        for p in 0..KV_TILE {
            for l in 0..cfg.n_layers {
                for h in 0..nh {
                    let (k, v) = a.kv_row_mut(l, h, p);
                    k.fill(p as f32 + 1.0);
                    v.fill(-(p as f32) - 1.0);
                }
            }
        }
        a.seen = KV_TILE;
        let b = a.clone();
        assert_eq!(Arc::strong_count(a.page(0)), 2, "clone shares the page");
        // Diverge a at position 10.
        a.truncate(10);
        a.reserve(11);
        assert_eq!(Arc::strong_count(b.page(0)), 1, "COW split the page");
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (k, v) = a.kv_row_mut(l, h, 10);
                k.fill(999.0);
                v.fill(-999.0);
            }
        }
        a.seen = 11;
        // b still sees the original contents everywhere…
        let (kb, _) = b.page(0).head_panel(0, 0, KV_TILE);
        for p in 0..KV_TILE {
            assert_eq!(kb[p * hd], p as f32 + 1.0, "b must keep pre-COW contents at {p}");
        }
        // …and a sees the shared prefix plus its divergent write.
        let (ka, _) = a.page(0).head_panel(0, 0, 11);
        for p in 0..10 {
            assert_eq!(ka[p * hd], p as f32 + 1.0, "a keeps the shared prefix at {p}");
        }
        assert_eq!(ka[10 * hd], 999.0, "a sees its divergent write");
    }

    #[test]
    fn prefix_trie_match_insert_evict() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::new(6 * KV_TILE, 8);
        let prompt_a: Vec<u32> = (0..150).map(|i| i as u32).collect();
        // Cold: nothing cached.
        assert_eq!(pool.match_prefix(&prompt_a, KvDtype::F32).0, 0);
        // Simulate a finished prefill and publish its prompt pages.
        let mut ca = pool.new_cache(&cfg, KvDtype::F32, Vec::new(), prompt_a.len());
        for p in 0..prompt_a.len() {
            let (k, _) = ca.kv_row_mut(0, 0, p);
            k.fill(p as f32);
        }
        ca.seen = prompt_a.len();
        pool.insert_prefix(&prompt_a, &ca);
        assert_eq!(pool.cached_tokens(), 2 * KV_TILE, "150 tokens → 2 full pages");
        pool.insert_prefix(&prompt_a, &ca); // idempotent
        assert_eq!(pool.cached_tokens(), 2 * KV_TILE);
        // A prompt sharing the full preamble matches both pages…
        let (m, pages) = pool.match_prefix(&prompt_a, KvDtype::F32);
        assert_eq!((m, pages.len()), (2 * KV_TILE, 2));
        let (k, _) = pages[1].head_panel(0, 0, KV_TILE);
        assert_eq!(k[0], KV_TILE as f32, "page 1 starts at position 64");
        // …an exactly-two-page prompt is capped to one (a novel final token
        // must remain to produce first-token logits)…
        assert_eq!(pool.match_prefix(&prompt_a[..2 * KV_TILE], KvDtype::F32).0, KV_TILE);
        // …a divergent second chunk matches only the first page…
        let mut div = prompt_a.clone();
        div[KV_TILE] = 9999;
        assert_eq!(pool.match_prefix(&div, KvDtype::F32).0, KV_TILE);
        // …and the other dtype's trie is independent.
        assert_eq!(pool.match_prefix(&prompt_a, KvDtype::Int8).0, 0);
        // Seeded caches start past the matched prefix.
        let warm = pool.new_cache(&cfg, KvDtype::F32, pages, prompt_a.len());
        assert_eq!(warm.seen, 2 * KV_TILE);
        assert!(warm.capacity() >= prompt_a.len());
        drop(warm);
        // Eviction under pressure: a second, disjoint prefix fills the
        // budget; an alloc that needs the space reclaims LRU pages.
        let prompt_b: Vec<u32> = (0..150).map(|i| 10_000 + i as u32).collect();
        let mut cb = pool.new_cache(&cfg, KvDtype::F32, Vec::new(), prompt_b.len());
        cb.seen = prompt_b.len();
        pool.insert_prefix(&prompt_b, &cb);
        assert_eq!(pool.cached_tokens(), 4 * KV_TILE);
        drop(ca);
        drop(cb);
        // Pages pinned only by the trie now; prefix A is older (B's insert
        // bumped B's path last). A big alloc forces eviction, oldest first.
        let lease = pool.alloc(3 * KV_TILE).unwrap();
        assert!(pool.cached_tokens() <= 3 * KV_TILE, "alloc evicted cached pages");
        assert_eq!(pool.match_prefix(&prompt_b, KvDtype::F32).0, 2 * KV_TILE, "hotter prefix survives");
        pool.free(lease);
        // clear_prefix_cache drops the rest; with no caches alive the
        // physical page meter drains to the freshly-allocated none.
        pool.clear_prefix_cache();
        assert_eq!(pool.cached_tokens(), 0);
        assert_eq!(pool.live_pages(), 0, "no physical pages after clear + cache drops");
        assert_eq!(pool.match_prefix(&prompt_a, KvDtype::F32).0, 0);
    }

    #[test]
    fn trie_pages_shared_with_live_caches_are_not_evicted() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::new(2 * KV_TILE, 8);
        let prompt: Vec<u32> = (0..KV_TILE as u32 + 10).collect();
        let mut c = pool.new_cache(&cfg, KvDtype::F32, Vec::new(), prompt.len());
        c.seen = prompt.len();
        pool.insert_prefix(&prompt, &c);
        assert_eq!(pool.cached_tokens(), KV_TILE);
        // The cache still holds the page → refcount 2 → pinned: an alloc
        // that would need the cached tokens fails instead of evicting.
        assert!(pool.alloc(2 * KV_TILE).is_none(), "pinned page must not evict");
        drop(c);
        // Once the sequence is gone the page is evictable.
        let lease = pool.alloc(2 * KV_TILE).expect("evictable after cache drop");
        assert_eq!(pool.cached_tokens(), 0);
        pool.free(lease);
    }

    #[test]
    fn int8_kv_bytes_and_pool_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        // micro: 2 layers, d_model 64, 4 heads → f32 1024 B/token, int8
        // 2·2·(64 + 16) = 320 B/token — a 3.2x capacity win at equal budget
        // (the acceptance floor is 3x).
        let f32_pool = KvPool::for_model_dtype(&cfg, 1 << 20, KvDtype::F32);
        let i8_pool = KvPool::for_model_dtype(&cfg, 1 << 20, KvDtype::Int8);
        assert_eq!(f32_pool.bytes_per_token, 2 * 2 * 64 * 4);
        assert_eq!(i8_pool.bytes_per_token, 2 * 2 * (64 + 4 * 4));
        let ratio = i8_pool.capacity_tokens() as f64 / f32_pool.capacity_tokens() as f64;
        assert!(ratio >= 3.0, "int8 capacity win {ratio} below the 3x floor");
        assert_eq!(
            KvPool::for_model_tokens_dtype(&cfg, 4096, KvDtype::Int8).bytes_per_token,
            i8_pool.bytes_per_token
        );
        // KvCache::bytes agrees with the pool's per-token accounting.
        let mut c = KvCache::with_capacity_dtype(&cfg, 10, KvDtype::Int8);
        assert_eq!(c.bytes(), 0);
        c.seen = 4;
        assert_eq!(c.bytes(), 4 * i8_pool.bytes_per_token);
        let mut f = KvCache::with_capacity(&cfg, 10);
        f.seen = 4;
        assert_eq!(f.bytes(), 4 * f32_pool.bytes_per_token);
    }

    #[test]
    fn kv_dtype_bits_roundtrip() {
        assert_eq!(KvDtype::from_bits(32), Some(KvDtype::F32));
        assert_eq!(KvDtype::from_bits(8), Some(KvDtype::Int8));
        assert_eq!(KvDtype::from_bits(4), None);
        assert_eq!(KvDtype::F32.bits(), 32);
        assert_eq!(KvDtype::Int8.bits(), 8);
        assert_eq!(KvDtype::Int8.name(), "int8");
        assert_eq!(format!("{}", KvDtype::F32), "f32");
        for bits in KvDtype::SUPPORTED_BITS {
            assert!(KvDtype::from_bits(bits).is_some(), "{bits} advertised but unsupported");
        }
    }

    #[test]
    fn kv_cache_bytes_and_truncate() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let mut c = KvCache::with_capacity(&cfg, 10);
        assert_eq!(c.bytes(), 0, "no live positions yet");
        c.seen = 4;
        let live4 = c.bytes();
        assert_eq!(live4, 2 * cfg.n_layers * 4 * cfg.d_model * 4);
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert!(c.bytes() < live4);
        assert!(c.capacity() >= 10, "truncate keeps the partially-live page");
        c.truncate(7); // truncating above seen is a no-op
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn truncate_releases_whole_pages_to_the_meter() {
        // Rollback past a page boundary must drop the now-unreferenced
        // pages (speculative rejection / cancel must not pin memory).
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model_tokens(&cfg, 16 * KV_TILE);
        let mut c = pool.new_cache(&cfg, KvDtype::F32, Vec::new(), 3 * KV_TILE);
        assert_eq!((c.page_count(), pool.live_pages()), (3, 3));
        c.seen = 3 * KV_TILE;
        // Truncate into page 1: page 2 is released, page 1 (partially
        // live) and page 0 stay.
        c.truncate(KV_TILE + 5);
        assert_eq!((c.page_count(), pool.live_pages()), (2, 2));
        assert_eq!(c.len(), KV_TILE + 5);
        // Truncate to a page boundary keeps exactly the covering pages.
        c.truncate(KV_TILE);
        assert_eq!((c.page_count(), pool.live_pages()), (1, 1));
        // Pages shared with another holder survive elsewhere: the meter
        // only drains when the last reference goes.
        let shared = Arc::clone(c.page(0));
        c.truncate(0);
        assert_eq!(c.page_count(), 0);
        assert_eq!(pool.live_pages(), 1, "shared page still alive");
        drop(shared);
        assert_eq!(pool.live_pages(), 0);
        // Re-growing after a full truncate allocates fresh pages.
        c.reserve(1);
        assert_eq!((c.page_count(), pool.live_pages()), (1, 1));
    }

    #[test]
    fn concurrent_alloc_free_consistent() {
        let pool = KvPool::new(1000, 8);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Some(l) = p.alloc(7) {
                            p.free(l);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
    }
}
