//! KV-cache memory: the per-sequence [`KvCache`] (head-major tile storage)
//! and the [`KvPool`] slot pool that accounts it across concurrent
//! sequences.
//!
//! ## `KvCache` tile layout
//!
//! Keys and values are stored **head-major**: per layer, per head, one
//! contiguous `cap × hd` panel (position-major within the panel), with a
//! layer's `nh` panels concatenated into one buffer:
//!
//! ```text
//! keys[layer] = [ head 0: pos 0 | pos 1 | … | pos cap-1 ]
//!               [ head 1: pos 0 | pos 1 | … | pos cap-1 ] …
//! ```
//!
//! so position `p` of head `h` lives at `(h·cap + p)·hd`. Consecutive cache
//! positions of one head are `hd` floats apart — the attention score sweep
//! and weighted-V accumulation (`tensor::attn_kernel`) stream each panel as
//! one unit-stride run. The previous layout interleaved all heads within a
//! d-model row, which forced a `d_model` stride between positions and
//! defeated SIMD loads.
//!
//! Capacity grows in [`KV_TILE`]-position quanta via [`KvCache::reserve`]
//! (amortized doubling; growth repacks each head panel at the new stride).
//! The batcher pre-sizes caches to their admission lease
//! ([`KvCache::with_capacity`]) so steady-state prefill/decode never
//! repacks; decode-time lease growth re-sizes lazily on the next append.
//! [`KvCache::truncate`] is a length-only rollback (prefix reuse keeps the
//! allocation), and [`KvCache::bytes`] reports the **live** footprint
//! (`seen` positions) — capacity is accounted by the pool's leases, not
//! per-cache.
//!
//! ## `KvPool`
//!
//! Accounts a fixed token budget across concurrent sequences; the batcher
//! must hold a lease before admitting a request, which provides the
//! backpressure that keeps the decode loop inside memory limits. Leases
//! start right-sized (prompt + a small decode reserve) and are extended
//! incrementally through [`KvPool::grow`] as decode proceeds — a failed
//! grow is a normal signal (the batcher finishes the sequence truncated),
//! not an error. Leases are RAII-free (explicit free) because they cross
//! thread boundaries with the sequence state.

use crate::model::ModelConfig;
use std::sync::{Arc, Mutex};

/// Positions per capacity-grow quantum of a [`KvCache`] panel.
pub const KV_TILE: usize = 64;

/// Per-layer KV cache for one sequence, stored as head-major tiles (see the
/// module doc for the layout). `seen` is the number of positions whose K/V
/// are live; the forward paths write span positions `seen..seen+t` first
/// and advance `seen` once per multi-layer forward.
#[derive(Clone)]
pub struct KvCache {
    /// keys[layer]: `nh` head panels of `cap × hd`, concatenated.
    keys: Vec<Vec<f32>>,
    /// values[layer]: same layout as `keys`.
    values: Vec<Vec<f32>>,
    /// Live positions (decoded so far).
    pub seen: usize,
    cap: usize,
    nh: usize,
    hd: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::with_layers(cfg, cfg.n_layers)
    }

    /// A cache pre-sized to `positions` (the batcher sizes to the admission
    /// lease so prefill never repacks mid-flight).
    pub fn with_capacity(cfg: &ModelConfig, positions: usize) -> KvCache {
        let mut c = KvCache::new(cfg);
        c.reserve(positions);
        c
    }

    /// Single-layer scratch cache for the teacher-forced path, which runs
    /// one block's span attention at a time (always at cache layer 0).
    pub(crate) fn span_scratch(cfg: &ModelConfig) -> KvCache {
        KvCache::with_layers(cfg, 1)
    }

    fn with_layers(cfg: &ModelConfig, n_layers: usize) -> KvCache {
        KvCache {
            keys: vec![Vec::new(); n_layers],
            values: vec![Vec::new(); n_layers],
            seen: 0,
            cap: 0,
            nh: cfg.n_heads,
            hd: cfg.d_model / cfg.n_heads,
        }
    }

    pub fn len(&self) -> usize {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Positions the tiles can hold before the next repack.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Live KV bytes (`seen` positions across all layers). Capacity beyond
    /// `seen` is pool-accounted via the sequence's lease, not counted here.
    pub fn bytes(&self) -> usize {
        2 * self.keys.len() * self.seen * self.nh * self.hd * 4
    }

    /// Ensure the tiles can hold `positions`. Growth rounds up to the next
    /// [`KV_TILE`] multiple of at least double the current capacity and
    /// repacks every head panel at the new `cap` stride (full panels are
    /// copied, so pending span rows beyond `seen` survive too).
    pub fn reserve(&mut self, positions: usize) {
        if positions <= self.cap {
            return;
        }
        let new_cap = positions.max(self.cap * 2).div_ceil(KV_TILE) * KV_TILE;
        let (nh, hd, old_cap) = (self.nh, self.hd, self.cap);
        let repack = |bufs: &mut Vec<Vec<f32>>| {
            for buf in bufs.iter_mut() {
                let mut nb = vec![0f32; nh * new_cap * hd];
                if old_cap > 0 {
                    for h in 0..nh {
                        nb[h * new_cap * hd..h * new_cap * hd + old_cap * hd]
                            .copy_from_slice(&buf[h * old_cap * hd..(h + 1) * old_cap * hd]);
                    }
                }
                *buf = nb;
            }
        };
        repack(&mut self.keys);
        repack(&mut self.values);
        self.cap = new_cap;
    }

    /// Mutable K/V rows for (layer, head, position) — the append target of
    /// the span staging pass. The caller must have [`KvCache::reserve`]d
    /// `pos + 1` positions.
    #[inline]
    pub fn kv_row_mut(&mut self, l: usize, h: usize, pos: usize) -> (&mut [f32], &mut [f32]) {
        debug_assert!(pos < self.cap, "kv write at {pos} beyond capacity {}", self.cap);
        let off = (h * self.cap + pos) * self.hd;
        let hd = self.hd;
        (&mut self.keys[l][off..off + hd], &mut self.values[l][off..off + hd])
    }

    /// The first `n` positions of (layer, head)'s key and value panels as
    /// contiguous `n × hd` tiles — what the attention kernels stream.
    #[inline]
    pub fn head_tiles(&self, l: usize, h: usize, n: usize) -> (&[f32], &[f32]) {
        debug_assert!(n <= self.cap, "kv read of {n} beyond capacity {}", self.cap);
        let off = h * self.cap * self.hd;
        let len = n * self.hd;
        (&self.keys[l][off..off + len], &self.values[l][off..off + len])
    }

    /// Drop everything after position `n` (prefix reuse). Length-only: the
    /// tiles keep their allocation, and stale rows beyond `seen` are never
    /// read (every read is bounded by a caller-passed position count).
    pub fn truncate(&mut self, n: usize) {
        self.seen = self.seen.min(n);
    }
}

#[derive(Debug)]
struct PoolState {
    capacity_tokens: usize,
    used_tokens: usize,
    next_id: u64,
    live: std::collections::BTreeMap<u64, usize>,
    peak_tokens: usize,
}

/// Shared pool handle.
#[derive(Clone)]
pub struct KvPool {
    state: Arc<Mutex<PoolState>>,
    /// Per-token KV bytes for accounting (2 · n_layers · d_model · 4).
    pub bytes_per_token: usize,
}

/// An allocation lease for one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    pub id: u64,
    pub tokens: usize,
}

impl KvPool {
    pub fn new(capacity_tokens: usize, bytes_per_token: usize) -> KvPool {
        KvPool {
            state: Arc::new(Mutex::new(PoolState {
                capacity_tokens,
                used_tokens: 0,
                next_id: 1,
                live: Default::default(),
                peak_tokens: 0,
            })),
            bytes_per_token,
        }
    }

    /// Per-token KV bytes for a model: K + V, all layers, f32.
    fn model_bytes_per_token(cfg: &crate::model::ModelConfig) -> usize {
        2 * cfg.n_layers * cfg.d_model * 4
    }

    /// Pool holding `capacity_tokens` positions with byte accounting sized
    /// from the model config — the one constructor serve-time callers need
    /// (the engine used to build a throwaway `for_model` pool just to copy
    /// its `bytes_per_token` into a second `new`).
    pub fn for_model_tokens(cfg: &crate::model::ModelConfig, capacity_tokens: usize) -> KvPool {
        KvPool::new(capacity_tokens.max(1), KvPool::model_bytes_per_token(cfg))
    }

    /// For a model: capacity from a byte budget.
    pub fn for_model(cfg: &crate::model::ModelConfig, budget_bytes: usize) -> KvPool {
        let per_token = KvPool::model_bytes_per_token(cfg);
        KvPool::new((budget_bytes / per_token).max(1), per_token)
    }

    /// Try to lease `tokens` tokens of KV space.
    pub fn alloc(&self, tokens: usize) -> Option<Lease> {
        let mut s = self.state.lock().unwrap();
        if s.used_tokens + tokens > s.capacity_tokens {
            return None;
        }
        s.used_tokens += tokens;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens);
        let id = s.next_id;
        s.next_id += 1;
        s.live.insert(id, tokens);
        Some(Lease { id, tokens })
    }

    /// Grow an existing lease by `extra` tokens (decode step).
    pub fn grow(&self, lease: &mut Lease, extra: usize) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.used_tokens + extra > s.capacity_tokens {
            return false;
        }
        let entry = s.live.get_mut(&lease.id).expect("lease alive");
        *entry += extra;
        s.used_tokens += extra;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens);
        lease.tokens += extra;
        true
    }

    /// Release a lease. Panics on double free (a bug we want loud).
    pub fn free(&self, lease: Lease) {
        let mut s = self.state.lock().unwrap();
        let tokens = s.live.remove(&lease.id).expect("double free of KV lease");
        assert_eq!(tokens, lease.tokens, "lease size drift");
        s.used_tokens -= tokens;
    }

    pub fn used_tokens(&self) -> usize {
        self.state.lock().unwrap().used_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.state.lock().unwrap().capacity_tokens
    }

    pub fn peak_tokens(&self) -> usize {
        self.state.lock().unwrap().peak_tokens
    }

    pub fn live_leases(&self) -> usize {
        self.state.lock().unwrap().live.len()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_tokens() * self.bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let pool = KvPool::new(100, 8);
        let a = pool.alloc(40).unwrap();
        let b = pool.alloc(60).unwrap();
        assert!(pool.alloc(1).is_none(), "over capacity");
        assert_eq!(pool.used_tokens(), 100);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 60);
        let c = pool.alloc(30).unwrap();
        pool.free(b);
        pool.free(c);
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
        assert_eq!(pool.peak_tokens(), 100);
    }

    #[test]
    fn grow_respects_capacity() {
        let pool = KvPool::new(50, 8);
        let mut a = pool.alloc(45).unwrap();
        assert!(pool.grow(&mut a, 5));
        assert!(!pool.grow(&mut a, 1));
        assert_eq!(a.tokens, 50);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let pool = KvPool::new(10, 8);
        let a = pool.alloc(5).unwrap();
        pool.free(a);
        pool.free(a);
    }

    #[test]
    fn for_model_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model(&cfg, 1 << 20);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        assert_eq!(pool.capacity_tokens(), (1 << 20) / (2 * 2 * 64 * 4));
    }

    #[test]
    fn for_model_tokens_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model_tokens(&cfg, 4096);
        assert_eq!(pool.capacity_tokens(), 4096);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        // Degenerate budget still yields a usable pool.
        assert_eq!(KvPool::for_model_tokens(&cfg, 0).capacity_tokens(), 1);
    }

    #[test]
    fn kv_cache_tile_layout_roundtrip() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let mut c = KvCache::new(&cfg);
        assert_eq!(c.capacity(), 0);
        let positions = 5usize;
        c.reserve(positions);
        assert!(c.capacity() >= positions);
        assert_eq!(c.capacity() % KV_TILE, 0);
        // Write a distinct pattern per (layer, head, pos, lane) and read it
        // back through the tile accessor.
        let val = |l: usize, h: usize, p: usize, i: usize| {
            (l * 1000 + h * 100 + p * 10 + i) as f32
        };
        for l in 0..cfg.n_layers {
            for p in 0..positions {
                for h in 0..nh {
                    let (k, v) = c.kv_row_mut(l, h, p);
                    for i in 0..hd {
                        k[i] = val(l, h, p, i);
                        v[i] = -val(l, h, p, i);
                    }
                }
            }
        }
        c.seen = positions;
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (kt, vt) = c.head_tiles(l, h, positions);
                assert_eq!(kt.len(), positions * hd);
                for p in 0..positions {
                    for i in 0..hd {
                        assert_eq!(kt[p * hd + i], val(l, h, p, i), "L{l} h{h} p{p} i{i}");
                        assert_eq!(vt[p * hd + i], -val(l, h, p, i));
                    }
                }
            }
        }
        // Growth repacks panels at the new stride without losing contents.
        let old_cap = c.capacity();
        c.reserve(old_cap + 1);
        assert!(c.capacity() > old_cap);
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (kt, _) = c.head_tiles(l, h, positions);
                for p in 0..positions {
                    for i in 0..hd {
                        assert_eq!(kt[p * hd + i], val(l, h, p, i), "post-grow L{l} h{h} p{p}");
                    }
                }
            }
        }
    }

    #[test]
    fn kv_cache_bytes_and_truncate() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let mut c = KvCache::with_capacity(&cfg, 10);
        assert_eq!(c.bytes(), 0, "no live positions yet");
        c.seen = 4;
        let live4 = c.bytes();
        assert_eq!(live4, 2 * cfg.n_layers * 4 * cfg.d_model * 4);
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert!(c.bytes() < live4);
        assert!(c.capacity() >= 10, "truncate keeps the allocation");
        c.truncate(7); // truncating above seen is a no-op
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn concurrent_alloc_free_consistent() {
        let pool = KvPool::new(1000, 8);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Some(l) = p.alloc(7) {
                            p.free(l);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
    }
}
