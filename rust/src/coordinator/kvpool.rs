//! KV-cache memory: the per-sequence [`KvCache`] (head-major tile storage)
//! and the [`KvPool`] slot pool that accounts it across concurrent
//! sequences.
//!
//! ## `KvCache` tile layout
//!
//! Keys and values are stored **head-major**: per layer, per head, one
//! contiguous `cap × hd` panel (position-major within the panel), with a
//! layer's `nh` panels concatenated into one buffer:
//!
//! ```text
//! keys[layer] = [ head 0: pos 0 | pos 1 | … | pos cap-1 ]
//!               [ head 1: pos 0 | pos 1 | … | pos cap-1 ] …
//! ```
//!
//! so position `p` of head `h` lives at `(h·cap + p)·hd`. Consecutive cache
//! positions of one head are `hd` floats apart — the attention score sweep
//! and weighted-V accumulation (`tensor::attn_kernel`) stream each panel as
//! one unit-stride run. The previous layout interleaved all heads within a
//! d-model row, which forced a `d_model` stride between positions and
//! defeated SIMD loads.
//!
//! Capacity grows in [`KV_TILE`]-position quanta via [`KvCache::reserve`]
//! (amortized doubling; growth repacks each head panel at the new stride).
//! The batcher pre-sizes caches to their admission lease
//! ([`KvCache::with_capacity`]) so steady-state prefill/decode never
//! repacks; decode-time lease growth re-sizes lazily on the next append.
//! [`KvCache::truncate`] is a length-only rollback (prefix reuse keeps the
//! allocation), and [`KvCache::bytes`] reports the **live** footprint
//! (`seen` positions) — capacity is accounted by the pool's leases, not
//! per-cache.
//!
//! ## Quantized tile layout ([`KvDtype::Int8`])
//!
//! A cache is dtype-parametric at construction ([`KvCache::new_with`]).
//! `Int8` caches store the SAME head-major geometry, but each (layer, head)
//! panel holds `cap × hd` **int8 codes** instead of floats, paired with one
//! **f32 scale per tile row** (= per cached position per head): per layer a
//! `nh × cap` scale buffer, position `p` of head `h` at `h·cap + p`, for
//! keys and values independently:
//!
//! ```text
//! qkeys[layer]   = [ head 0: cap × hd i8 codes ][ head 1: … ]   (panels)
//! kscales[layer] = [ head 0: cap f32 scales    ][ head 1: … ]   (rows)
//! ```
//!
//! Rows are quantized symmetrically at **write time** (the staging pass of
//! `Gpt::attn_layer`, through `quant::act::quantize_tile` — one scale per
//! roped K row / raw V row, codes in `[-127, 127]`, never −128) and
//! dequantization is **fused into the attention kernels**
//! (`tensor::attn_kernel::attn_head_span_int8`): scales are applied at
//! i32-accumulator writeback, so the code tiles stream straight into the
//! int8 q·K and P·V loops. Because each position quantizes independently,
//! codes are invariant to prompt chunking, and [`KvCache::reserve`]'s
//! repack carries code panels and scale rows to the new `cap` stride with
//! the same full-panel copy as the f32 path (pending span rows beyond
//! `seen` survive). `Int8` cuts the per-token footprint to
//! `2·layers·(d_model + 4·nh)` bytes (codes + scales) vs
//! `2·layers·d_model·4` for f32 — ~3.2–3.9x more resident sequences per
//! pool byte budget ([`KvPool::for_model_dtype`] accounts it exactly).
//! The accessors are dtype-checked: [`KvCache::kv_row_mut`] /
//! [`KvCache::head_tiles`] serve f32 caches, [`KvCache::kv_row_quant_mut`]
//! / [`KvCache::head_tiles_quant`] serve int8 caches.
//!
//! ## `KvPool`
//!
//! Accounts a fixed token budget across concurrent sequences; the batcher
//! must hold a lease before admitting a request, which provides the
//! backpressure that keeps the decode loop inside memory limits. Leases
//! start right-sized (prompt + a small decode reserve) and are extended
//! incrementally through [`KvPool::grow`] as decode proceeds — a failed
//! grow is a normal signal (the batcher finishes the sequence truncated),
//! not an error. Leases are RAII-free (explicit free) because they cross
//! thread boundaries with the sequence state.

use crate::model::ModelConfig;
use std::sync::{Arc, Mutex};

/// Positions per capacity-grow quantum of a [`KvCache`] panel.
pub const KV_TILE: usize = 64;

/// Storage dtype of a [`KvCache`]'s K/V tiles. `F32` keeps the raw floats;
/// `Int8` stores symmetric int8 codes with one f32 scale per cached row
/// (per position per head) and relies on the fused-dequant attention
/// kernels (`tensor::attn_kernel::attn_head_span_int8`) at read time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    #[default]
    F32,
    Int8,
}

impl KvDtype {
    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
        }
    }

    /// Bits per stored K/V element (scale overhead not included).
    pub fn bits(self) -> usize {
        match self {
            KvDtype::F32 => 32,
            KvDtype::Int8 => 8,
        }
    }

    /// Map a `--kv-bits` style knob to a dtype.
    pub fn from_bits(bits: usize) -> Option<KvDtype> {
        match bits {
            32 => Some(KvDtype::F32),
            8 => Some(KvDtype::Int8),
            _ => None,
        }
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-layer KV cache for one sequence, stored as head-major tiles (see the
/// module doc for the layout). `seen` is the number of positions whose K/V
/// are live; the forward paths write span positions `seen..seen+t` first
/// and advance `seen` once per multi-layer forward.
///
/// Storage is dtype-parametric: an `F32` cache uses `keys`/`values`, an
/// `Int8` cache uses `qkeys`/`qvalues` plus the per-row scale buffers. All
/// six layer vectors always hold `n_layers` entries (the inactive dtype's
/// inner vectors stay empty) so layer count and capacity logic are shared.
#[derive(Clone)]
pub struct KvCache {
    /// keys[layer]: `nh` head panels of `cap × hd`, concatenated (F32).
    keys: Vec<Vec<f32>>,
    /// values[layer]: same layout as `keys` (F32).
    values: Vec<Vec<f32>>,
    /// qkeys[layer]: `nh` head panels of `cap × hd` int8 codes (Int8).
    qkeys: Vec<Vec<i8>>,
    /// qvalues[layer]: same layout as `qkeys` (Int8).
    qvalues: Vec<Vec<i8>>,
    /// kscales[layer]: `nh × cap` per-row key scales, row `h·cap + p` (Int8).
    kscales: Vec<Vec<f32>>,
    /// vscales[layer]: same layout as `kscales`, for values (Int8).
    vscales: Vec<Vec<f32>>,
    dtype: KvDtype,
    /// Live positions (decoded so far).
    pub seen: usize,
    cap: usize,
    nh: usize,
    hd: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::new_with(cfg, KvDtype::F32)
    }

    /// A cache with the given storage dtype (see [`KvDtype`]).
    pub fn new_with(cfg: &ModelConfig, dtype: KvDtype) -> KvCache {
        KvCache::with_layers_dtype(cfg, cfg.n_layers, dtype)
    }

    /// A cache pre-sized to `positions` (the batcher sizes to the admission
    /// lease so prefill never repacks mid-flight).
    pub fn with_capacity(cfg: &ModelConfig, positions: usize) -> KvCache {
        KvCache::with_capacity_dtype(cfg, positions, KvDtype::F32)
    }

    /// Pre-sized cache with an explicit storage dtype.
    pub fn with_capacity_dtype(cfg: &ModelConfig, positions: usize, dtype: KvDtype) -> KvCache {
        let mut c = KvCache::new_with(cfg, dtype);
        c.reserve(positions);
        c
    }

    /// Single-layer scratch cache for the teacher-forced path, which runs
    /// one block's span attention at a time (always at cache layer 0).
    pub(crate) fn span_scratch(cfg: &ModelConfig) -> KvCache {
        KvCache::with_layers_dtype(cfg, 1, KvDtype::F32)
    }

    fn with_layers_dtype(cfg: &ModelConfig, n_layers: usize, dtype: KvDtype) -> KvCache {
        KvCache {
            keys: vec![Vec::new(); n_layers],
            values: vec![Vec::new(); n_layers],
            qkeys: vec![Vec::new(); n_layers],
            qvalues: vec![Vec::new(); n_layers],
            kscales: vec![Vec::new(); n_layers],
            vscales: vec![Vec::new(); n_layers],
            dtype,
            seen: 0,
            cap: 0,
            nh: cfg.n_heads,
            hd: cfg.d_model / cfg.n_heads,
        }
    }

    /// Storage dtype of this cache's tiles.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn len(&self) -> usize {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Positions the tiles can hold before the next repack.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Live KV bytes (`seen` positions across all layers). Capacity beyond
    /// `seen` is pool-accounted via the sequence's lease, not counted here.
    /// For `Int8` this is the true quantized footprint: 1-byte codes plus
    /// one f32 scale per row (K and V each) per position per head.
    pub fn bytes(&self) -> usize {
        let rows = 2 * self.keys.len() * self.seen * self.nh;
        match self.dtype {
            KvDtype::F32 => rows * self.hd * 4,
            KvDtype::Int8 => rows * self.hd + rows * 4,
        }
    }

    /// Ensure the tiles can hold `positions`. Growth rounds up to the next
    /// [`KV_TILE`] multiple of at least double the current capacity and
    /// repacks every head panel at the new `cap` stride (full panels are
    /// copied, so pending span rows beyond `seen` survive too). For `Int8`,
    /// code panels repack at `unit = hd` and scale rows at `unit = 1` with
    /// the same per-head copy, so codes and scales stay paired.
    pub fn reserve(&mut self, positions: usize) {
        if positions <= self.cap {
            return;
        }
        let new_cap = positions.max(self.cap * 2).div_ceil(KV_TILE) * KV_TILE;
        let (nh, old_cap, hd) = (self.nh, self.cap, self.hd);
        fn repack<T: Copy + Default>(bufs: &mut [Vec<T>], nh: usize, old_cap: usize, new_cap: usize, unit: usize) {
            for buf in bufs.iter_mut() {
                let mut nb = vec![T::default(); nh * new_cap * unit];
                if old_cap > 0 {
                    for h in 0..nh {
                        nb[h * new_cap * unit..h * new_cap * unit + old_cap * unit]
                            .copy_from_slice(&buf[h * old_cap * unit..(h + 1) * old_cap * unit]);
                    }
                }
                *buf = nb;
            }
        }
        match self.dtype {
            KvDtype::F32 => {
                repack(&mut self.keys, nh, old_cap, new_cap, hd);
                repack(&mut self.values, nh, old_cap, new_cap, hd);
            }
            KvDtype::Int8 => {
                repack(&mut self.qkeys, nh, old_cap, new_cap, hd);
                repack(&mut self.qvalues, nh, old_cap, new_cap, hd);
                repack(&mut self.kscales, nh, old_cap, new_cap, 1);
                repack(&mut self.vscales, nh, old_cap, new_cap, 1);
            }
        }
        self.cap = new_cap;
    }

    /// Mutable K/V rows for (layer, head, position) — the append target of
    /// the span staging pass. The caller must have [`KvCache::reserve`]d
    /// `pos + 1` positions. F32 caches only; int8 caches use
    /// [`KvCache::kv_row_quant_mut`].
    #[inline]
    pub fn kv_row_mut(&mut self, l: usize, h: usize, pos: usize) -> (&mut [f32], &mut [f32]) {
        debug_assert!(pos < self.cap, "kv write at {pos} beyond capacity {}", self.cap);
        debug_assert_eq!(self.dtype, KvDtype::F32, "kv_row_mut on an int8 cache");
        let off = (h * self.cap + pos) * self.hd;
        let hd = self.hd;
        (&mut self.keys[l][off..off + hd], &mut self.values[l][off..off + hd])
    }

    /// Quantized append target for (layer, head, position): the K and V code
    /// rows plus their scale slots, for the staging pass to fill via
    /// `quant::act::quantize_tile`. Int8 caches only.
    #[inline]
    pub fn kv_row_quant_mut(
        &mut self,
        l: usize,
        h: usize,
        pos: usize,
    ) -> (&mut [i8], &mut [i8], &mut f32, &mut f32) {
        debug_assert!(pos < self.cap, "kv write at {pos} beyond capacity {}", self.cap);
        debug_assert_eq!(self.dtype, KvDtype::Int8, "kv_row_quant_mut on an f32 cache");
        let row = h * self.cap + pos;
        let off = row * self.hd;
        let hd = self.hd;
        (
            &mut self.qkeys[l][off..off + hd],
            &mut self.qvalues[l][off..off + hd],
            &mut self.kscales[l][row],
            &mut self.vscales[l][row],
        )
    }

    /// The first `n` positions of (layer, head)'s key and value panels as
    /// contiguous `n × hd` tiles — what the attention kernels stream. F32
    /// caches only; int8 caches use [`KvCache::head_tiles_quant`].
    #[inline]
    pub fn head_tiles(&self, l: usize, h: usize, n: usize) -> (&[f32], &[f32]) {
        debug_assert!(n <= self.cap, "kv read of {n} beyond capacity {}", self.cap);
        debug_assert_eq!(self.dtype, KvDtype::F32, "head_tiles on an int8 cache");
        let off = h * self.cap * self.hd;
        let len = n * self.hd;
        (&self.keys[l][off..off + len], &self.values[l][off..off + len])
    }

    /// Quantized read view of the first `n` positions of (layer, head):
    /// `n × hd` K and V code tiles plus the matching `n` per-row scales —
    /// what the fused-dequant attention kernels stream. Int8 caches only.
    #[inline]
    pub fn head_tiles_quant(&self, l: usize, h: usize, n: usize) -> (&[i8], &[i8], &[f32], &[f32]) {
        debug_assert!(n <= self.cap, "kv read of {n} beyond capacity {}", self.cap);
        debug_assert_eq!(self.dtype, KvDtype::Int8, "head_tiles_quant on an f32 cache");
        let off = h * self.cap * self.hd;
        let len = n * self.hd;
        let srow = h * self.cap;
        (
            &self.qkeys[l][off..off + len],
            &self.qvalues[l][off..off + len],
            &self.kscales[l][srow..srow + n],
            &self.vscales[l][srow..srow + n],
        )
    }

    /// Drop everything after position `n` (prefix reuse). Length-only: the
    /// tiles keep their allocation, and stale rows beyond `seen` are never
    /// read (every read is bounded by a caller-passed position count).
    pub fn truncate(&mut self, n: usize) {
        self.seen = self.seen.min(n);
    }
}

#[derive(Debug)]
struct PoolState {
    capacity_tokens: usize,
    used_tokens: usize,
    next_id: u64,
    live: std::collections::BTreeMap<u64, usize>,
    peak_tokens: usize,
}

/// Shared pool handle.
#[derive(Clone)]
pub struct KvPool {
    state: Arc<Mutex<PoolState>>,
    /// Per-token KV bytes for accounting (2 · n_layers · d_model · 4).
    pub bytes_per_token: usize,
}

/// An allocation lease for one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    pub id: u64,
    pub tokens: usize,
}

impl KvPool {
    pub fn new(capacity_tokens: usize, bytes_per_token: usize) -> KvPool {
        KvPool {
            state: Arc::new(Mutex::new(PoolState {
                capacity_tokens,
                used_tokens: 0,
                next_id: 1,
                live: Default::default(),
                peak_tokens: 0,
            })),
            bytes_per_token,
        }
    }

    /// Per-token KV bytes for a model at the given storage dtype: K + V,
    /// all layers. F32 is `2·layers·d_model·4`; Int8 is 1-byte codes plus
    /// one f32 scale per head row (K and V each), `2·layers·(d_model + 4·nh)`
    /// — the scale overhead is what keeps int8 at ~3.2x (micro) rather than
    /// a flat 4x.
    fn model_bytes_per_token_dtype(cfg: &crate::model::ModelConfig, dtype: KvDtype) -> usize {
        match dtype {
            KvDtype::F32 => 2 * cfg.n_layers * cfg.d_model * 4,
            KvDtype::Int8 => 2 * cfg.n_layers * (cfg.d_model + 4 * cfg.n_heads),
        }
    }

    /// Pool holding `capacity_tokens` positions with byte accounting sized
    /// from the model config — the one constructor serve-time callers need
    /// (the engine used to build a throwaway `for_model` pool just to copy
    /// its `bytes_per_token` into a second `new`).
    pub fn for_model_tokens(cfg: &crate::model::ModelConfig, capacity_tokens: usize) -> KvPool {
        KvPool::for_model_tokens_dtype(cfg, capacity_tokens, KvDtype::F32)
    }

    /// Token-capacity pool with byte accounting for the given KV dtype.
    pub fn for_model_tokens_dtype(
        cfg: &crate::model::ModelConfig,
        capacity_tokens: usize,
        dtype: KvDtype,
    ) -> KvPool {
        KvPool::new(
            capacity_tokens.max(1),
            KvPool::model_bytes_per_token_dtype(cfg, dtype),
        )
    }

    /// For a model: capacity from a byte budget.
    pub fn for_model(cfg: &crate::model::ModelConfig, budget_bytes: usize) -> KvPool {
        KvPool::for_model_dtype(cfg, budget_bytes, KvDtype::F32)
    }

    /// Byte-budget pool sized for the given KV dtype — an int8 pool admits
    /// ~`f32_bpt / int8_bpt` times the resident tokens at equal budget.
    pub fn for_model_dtype(
        cfg: &crate::model::ModelConfig,
        budget_bytes: usize,
        dtype: KvDtype,
    ) -> KvPool {
        let per_token = KvPool::model_bytes_per_token_dtype(cfg, dtype);
        KvPool::new((budget_bytes / per_token).max(1), per_token)
    }

    /// Try to lease `tokens` tokens of KV space.
    pub fn alloc(&self, tokens: usize) -> Option<Lease> {
        let mut s = self.state.lock().unwrap();
        if s.used_tokens + tokens > s.capacity_tokens {
            return None;
        }
        s.used_tokens += tokens;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens);
        let id = s.next_id;
        s.next_id += 1;
        s.live.insert(id, tokens);
        Some(Lease { id, tokens })
    }

    /// Grow an existing lease by `extra` tokens (decode step).
    pub fn grow(&self, lease: &mut Lease, extra: usize) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.used_tokens + extra > s.capacity_tokens {
            return false;
        }
        let entry = s.live.get_mut(&lease.id).expect("lease alive");
        *entry += extra;
        s.used_tokens += extra;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens);
        lease.tokens += extra;
        true
    }

    /// Release a lease. Panics on double free (a bug we want loud).
    pub fn free(&self, lease: Lease) {
        let mut s = self.state.lock().unwrap();
        let tokens = s.live.remove(&lease.id).expect("double free of KV lease");
        assert_eq!(tokens, lease.tokens, "lease size drift");
        s.used_tokens -= tokens;
    }

    pub fn used_tokens(&self) -> usize {
        self.state.lock().unwrap().used_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.state.lock().unwrap().capacity_tokens
    }

    pub fn peak_tokens(&self) -> usize {
        self.state.lock().unwrap().peak_tokens
    }

    pub fn live_leases(&self) -> usize {
        self.state.lock().unwrap().live.len()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_tokens() * self.bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let pool = KvPool::new(100, 8);
        let a = pool.alloc(40).unwrap();
        let b = pool.alloc(60).unwrap();
        assert!(pool.alloc(1).is_none(), "over capacity");
        assert_eq!(pool.used_tokens(), 100);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 60);
        let c = pool.alloc(30).unwrap();
        pool.free(b);
        pool.free(c);
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
        assert_eq!(pool.peak_tokens(), 100);
    }

    #[test]
    fn grow_respects_capacity() {
        let pool = KvPool::new(50, 8);
        let mut a = pool.alloc(45).unwrap();
        assert!(pool.grow(&mut a, 5));
        assert!(!pool.grow(&mut a, 1));
        assert_eq!(a.tokens, 50);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let pool = KvPool::new(10, 8);
        let a = pool.alloc(5).unwrap();
        pool.free(a);
        pool.free(a);
    }

    #[test]
    fn for_model_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model(&cfg, 1 << 20);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        assert_eq!(pool.capacity_tokens(), (1 << 20) / (2 * 2 * 64 * 4));
    }

    #[test]
    fn for_model_tokens_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model_tokens(&cfg, 4096);
        assert_eq!(pool.capacity_tokens(), 4096);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        // Degenerate budget still yields a usable pool.
        assert_eq!(KvPool::for_model_tokens(&cfg, 0).capacity_tokens(), 1);
    }

    #[test]
    fn kv_cache_tile_layout_roundtrip() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let mut c = KvCache::new(&cfg);
        assert_eq!(c.capacity(), 0);
        let positions = 5usize;
        c.reserve(positions);
        assert!(c.capacity() >= positions);
        assert_eq!(c.capacity() % KV_TILE, 0);
        // Write a distinct pattern per (layer, head, pos, lane) and read it
        // back through the tile accessor.
        let val = |l: usize, h: usize, p: usize, i: usize| {
            (l * 1000 + h * 100 + p * 10 + i) as f32
        };
        for l in 0..cfg.n_layers {
            for p in 0..positions {
                for h in 0..nh {
                    let (k, v) = c.kv_row_mut(l, h, p);
                    for i in 0..hd {
                        k[i] = val(l, h, p, i);
                        v[i] = -val(l, h, p, i);
                    }
                }
            }
        }
        c.seen = positions;
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (kt, vt) = c.head_tiles(l, h, positions);
                assert_eq!(kt.len(), positions * hd);
                for p in 0..positions {
                    for i in 0..hd {
                        assert_eq!(kt[p * hd + i], val(l, h, p, i), "L{l} h{h} p{p} i{i}");
                        assert_eq!(vt[p * hd + i], -val(l, h, p, i));
                    }
                }
            }
        }
        // Growth repacks panels at the new stride without losing contents.
        let old_cap = c.capacity();
        c.reserve(old_cap + 1);
        assert!(c.capacity() > old_cap);
        for l in 0..cfg.n_layers {
            for h in 0..nh {
                let (kt, _) = c.head_tiles(l, h, positions);
                for p in 0..positions {
                    for i in 0..hd {
                        assert_eq!(kt[p * hd + i], val(l, h, p, i), "post-grow L{l} h{h} p{p}");
                    }
                }
            }
        }
    }

    #[test]
    fn int8_kv_cache_tile_layout_and_repack_roundtrip() {
        // The quantized mirror of kv_cache_tile_layout_roundtrip: codes and
        // per-row scales written through kv_row_quant_mut read back through
        // head_tiles_quant, and reserve's repack preserves both in lockstep.
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let (nh, hd) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let mut c = KvCache::new_with(&cfg, KvDtype::Int8);
        assert_eq!(c.dtype(), KvDtype::Int8);
        assert_eq!(c.capacity(), 0);
        let positions = 5usize;
        c.reserve(positions);
        assert!(c.capacity() >= positions);
        assert_eq!(c.capacity() % KV_TILE, 0);
        let code = |l: usize, h: usize, p: usize, i: usize| ((l * 31 + h * 17 + p * 5 + i) % 255) as i32 - 127;
        let kscale = |l: usize, h: usize, p: usize| (l * 100 + h * 10 + p + 1) as f32 * 0.5;
        for l in 0..cfg.n_layers {
            for p in 0..positions {
                for h in 0..nh {
                    let (kc, vc, ks, vs) = c.kv_row_quant_mut(l, h, p);
                    for i in 0..hd {
                        kc[i] = code(l, h, p, i) as i8;
                        vc[i] = -(code(l, h, p, i) as i8);
                    }
                    *ks = kscale(l, h, p);
                    *vs = -kscale(l, h, p);
                }
            }
        }
        c.seen = positions;
        let check = |c: &KvCache, tag: &str| {
            for l in 0..cfg.n_layers {
                for h in 0..nh {
                    let (kt, vt, ks, vs) = c.head_tiles_quant(l, h, positions);
                    assert_eq!(kt.len(), positions * hd);
                    assert_eq!(ks.len(), positions);
                    for p in 0..positions {
                        for i in 0..hd {
                            assert_eq!(kt[p * hd + i], code(l, h, p, i) as i8, "{tag} L{l} h{h} p{p} i{i}");
                            assert_eq!(vt[p * hd + i], -(code(l, h, p, i) as i8));
                        }
                        assert_eq!(ks[p], kscale(l, h, p), "{tag} kscale L{l} h{h} p{p}");
                        assert_eq!(vs[p], -kscale(l, h, p), "{tag} vscale L{l} h{h} p{p}");
                    }
                }
            }
        };
        check(&c, "pre-grow");
        let old_cap = c.capacity();
        c.reserve(old_cap + 1);
        assert!(c.capacity() > old_cap);
        check(&c, "post-grow");
    }

    #[test]
    fn int8_kv_bytes_and_pool_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        // micro: 2 layers, d_model 64, 4 heads → f32 1024 B/token, int8
        // 2·2·(64 + 16) = 320 B/token — a 3.2x capacity win at equal budget
        // (the acceptance floor is 3x).
        let f32_pool = KvPool::for_model_dtype(&cfg, 1 << 20, KvDtype::F32);
        let i8_pool = KvPool::for_model_dtype(&cfg, 1 << 20, KvDtype::Int8);
        assert_eq!(f32_pool.bytes_per_token, 2 * 2 * 64 * 4);
        assert_eq!(i8_pool.bytes_per_token, 2 * 2 * (64 + 4 * 4));
        let ratio = i8_pool.capacity_tokens() as f64 / f32_pool.capacity_tokens() as f64;
        assert!(ratio >= 3.0, "int8 capacity win {ratio} below the 3x floor");
        assert_eq!(
            KvPool::for_model_tokens_dtype(&cfg, 4096, KvDtype::Int8).bytes_per_token,
            i8_pool.bytes_per_token
        );
        // KvCache::bytes agrees with the pool's per-token accounting.
        let mut c = KvCache::with_capacity_dtype(&cfg, 10, KvDtype::Int8);
        assert_eq!(c.bytes(), 0);
        c.seen = 4;
        assert_eq!(c.bytes(), 4 * i8_pool.bytes_per_token);
        let mut f = KvCache::with_capacity(&cfg, 10);
        f.seen = 4;
        assert_eq!(f.bytes(), 4 * f32_pool.bytes_per_token);
    }

    #[test]
    fn kv_dtype_bits_roundtrip() {
        assert_eq!(KvDtype::from_bits(32), Some(KvDtype::F32));
        assert_eq!(KvDtype::from_bits(8), Some(KvDtype::Int8));
        assert_eq!(KvDtype::from_bits(4), None);
        assert_eq!(KvDtype::F32.bits(), 32);
        assert_eq!(KvDtype::Int8.bits(), 8);
        assert_eq!(KvDtype::Int8.name(), "int8");
        assert_eq!(format!("{}", KvDtype::F32), "f32");
    }

    #[test]
    fn kv_cache_bytes_and_truncate() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let mut c = KvCache::with_capacity(&cfg, 10);
        assert_eq!(c.bytes(), 0, "no live positions yet");
        c.seen = 4;
        let live4 = c.bytes();
        assert_eq!(live4, 2 * cfg.n_layers * 4 * cfg.d_model * 4);
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert!(c.bytes() < live4);
        assert!(c.capacity() >= 10, "truncate keeps the allocation");
        c.truncate(7); // truncating above seen is a no-op
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn concurrent_alloc_free_consistent() {
        let pool = KvPool::new(1000, 8);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Some(l) = p.alloc(7) {
                            p.free(l);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
    }
}
