//! KV-cache slot pool — serving memory manager.
//!
//! Accounts a fixed token budget across concurrent sequences; the batcher
//! must hold a lease before admitting a request, which provides the
//! backpressure that keeps the decode loop inside memory limits. Leases
//! start right-sized (prompt + a small decode reserve) and are extended
//! incrementally through [`KvPool::grow`] as decode proceeds — a failed
//! grow is a normal signal (the batcher finishes the sequence truncated),
//! not an error. Leases are RAII-free (explicit free) because they cross
//! thread boundaries with the sequence state.

use std::sync::{Arc, Mutex};

#[derive(Debug)]
struct PoolState {
    capacity_tokens: usize,
    used_tokens: usize,
    next_id: u64,
    live: std::collections::BTreeMap<u64, usize>,
    peak_tokens: usize,
}

/// Shared pool handle.
#[derive(Clone)]
pub struct KvPool {
    state: Arc<Mutex<PoolState>>,
    /// Per-token KV bytes for accounting (2 · n_layers · d_model · 4).
    pub bytes_per_token: usize,
}

/// An allocation lease for one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    pub id: u64,
    pub tokens: usize,
}

impl KvPool {
    pub fn new(capacity_tokens: usize, bytes_per_token: usize) -> KvPool {
        KvPool {
            state: Arc::new(Mutex::new(PoolState {
                capacity_tokens,
                used_tokens: 0,
                next_id: 1,
                live: Default::default(),
                peak_tokens: 0,
            })),
            bytes_per_token,
        }
    }

    /// For a model: capacity from a byte budget.
    pub fn for_model(cfg: &crate::model::ModelConfig, budget_bytes: usize) -> KvPool {
        let per_token = 2 * cfg.n_layers * cfg.d_model * 4;
        KvPool::new((budget_bytes / per_token).max(1), per_token)
    }

    /// Try to lease `tokens` tokens of KV space.
    pub fn alloc(&self, tokens: usize) -> Option<Lease> {
        let mut s = self.state.lock().unwrap();
        if s.used_tokens + tokens > s.capacity_tokens {
            return None;
        }
        s.used_tokens += tokens;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens);
        let id = s.next_id;
        s.next_id += 1;
        s.live.insert(id, tokens);
        Some(Lease { id, tokens })
    }

    /// Grow an existing lease by `extra` tokens (decode step).
    pub fn grow(&self, lease: &mut Lease, extra: usize) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.used_tokens + extra > s.capacity_tokens {
            return false;
        }
        let entry = s.live.get_mut(&lease.id).expect("lease alive");
        *entry += extra;
        s.used_tokens += extra;
        s.peak_tokens = s.peak_tokens.max(s.used_tokens);
        lease.tokens += extra;
        true
    }

    /// Release a lease. Panics on double free (a bug we want loud).
    pub fn free(&self, lease: Lease) {
        let mut s = self.state.lock().unwrap();
        let tokens = s.live.remove(&lease.id).expect("double free of KV lease");
        assert_eq!(tokens, lease.tokens, "lease size drift");
        s.used_tokens -= tokens;
    }

    pub fn used_tokens(&self) -> usize {
        self.state.lock().unwrap().used_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.state.lock().unwrap().capacity_tokens
    }

    pub fn peak_tokens(&self) -> usize {
        self.state.lock().unwrap().peak_tokens
    }

    pub fn live_leases(&self) -> usize {
        self.state.lock().unwrap().live.len()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_tokens() * self.bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let pool = KvPool::new(100, 8);
        let a = pool.alloc(40).unwrap();
        let b = pool.alloc(60).unwrap();
        assert!(pool.alloc(1).is_none(), "over capacity");
        assert_eq!(pool.used_tokens(), 100);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 60);
        let c = pool.alloc(30).unwrap();
        pool.free(b);
        pool.free(c);
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
        assert_eq!(pool.peak_tokens(), 100);
    }

    #[test]
    fn grow_respects_capacity() {
        let pool = KvPool::new(50, 8);
        let mut a = pool.alloc(45).unwrap();
        assert!(pool.grow(&mut a, 5));
        assert!(!pool.grow(&mut a, 1));
        assert_eq!(a.tokens, 50);
        pool.free(a);
        assert_eq!(pool.used_tokens(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let pool = KvPool::new(10, 8);
        let a = pool.alloc(5).unwrap();
        pool.free(a);
        pool.free(a);
    }

    #[test]
    fn for_model_sizing() {
        let cfg = crate::model::ModelConfig::by_name("micro").unwrap();
        let pool = KvPool::for_model(&cfg, 1 << 20);
        assert_eq!(pool.bytes_per_token, 2 * 2 * 64 * 4);
        assert_eq!(pool.capacity_tokens(), (1 << 20) / (2 * 2 * 64 * 4));
    }

    #[test]
    fn concurrent_alloc_free_consistent() {
        let pool = KvPool::new(1000, 8);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Some(l) = p.alloc(7) {
                            p.free(l);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.used_tokens(), 0);
        assert_eq!(pool.live_leases(), 0);
    }
}
