//! Seeded fault injection for the serving engine — the deterministic chaos
//! harness behind the resilience property tests.
//!
//! A [`FaultPlan`] is a per-worker schedule of three fault kinds, keyed by
//! the batcher's **loop pass** counter (which advances even on idle passes,
//! so clamp windows always lift):
//!
//! - [`Fault::Panic`] — `panic!` at the top of a chosen pass, exercising
//!   the batcher's `catch_unwind` isolation layer end to end: in-flight
//!   streams must terminate with `FinishReason::WorkerFailed`, queued
//!   requests must re-dispatch to surviving workers, and the pool must
//!   stay consistent.
//! - [`Fault::ClampKv`] — transiently clamp the worker pool's token
//!   capacity to a fraction of nominal for a window of passes (restored
//!   automatically when the window closes, or by the worker-failure
//!   cleanup if the worker dies inside it). Simulates memory pressure:
//!   admission backpressure, failed lease grows (`TruncatedKv`), and
//!   `Rejected` sheds for requests that can no longer ever fit.
//! - [`Fault::Stall`] — sleep at the top of a pass, simulating a slow
//!   iteration (GC pause, noisy neighbor) so deadline sweeps and drain
//!   timeouts get exercised under latency jitter.
//!
//! Plans are built from [`Pcg64`], so a failing property case reproduces
//! from its seed alone. Tests that inject panics on purpose can install
//! [`silence_injected_panics`] once per process to keep the default panic
//! hook from spraying backtraces for expected unwinds.

use super::kvpool::KvPool;
use crate::util::rng::Pcg64;
use std::time::Duration;

/// Marker prefix carried by every injected panic's payload; the quiet
/// panic hook uses it to tell expected unwinds from real bugs.
pub const INJECTED_PANIC: &str = "injected worker panic";

/// One scheduled fault. Pass numbers are 1-based (the batcher bumps its
/// pass counter before consulting the schedule).
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Panic this worker's loop at pass `at`.
    Panic { at: usize },
    /// Clamp the worker pool to `frac` of its nominal token capacity for
    /// passes `from..until`, restoring the nominal capacity afterwards.
    ClampKv { from: usize, until: usize, frac: f64 },
    /// Sleep `ms` milliseconds at the top of pass `at`.
    Stall { at: usize, ms: u64 },
}

/// Knobs for [`FaultPlan::random`].
#[derive(Clone, Debug)]
pub struct FaultPlanConfig {
    /// Total injected panics across all workers.
    pub panics: usize,
    /// Total transient KV capacity clamps.
    pub clamps: usize,
    /// Total slow-iteration stalls.
    pub stalls: usize,
    /// Faults land on passes `1..=max_pass` (clamp windows may extend one
    /// window length past it).
    pub max_pass: usize,
    /// Clamp severity range: capacity fraction drawn from
    /// `[min_frac, max_frac)`.
    pub min_frac: f64,
    pub max_frac: f64,
    /// Stall length drawn from `1..=max_stall_ms`.
    pub max_stall_ms: u64,
}

impl Default for FaultPlanConfig {
    fn default() -> Self {
        FaultPlanConfig {
            panics: 1,
            clamps: 1,
            stalls: 1,
            max_pass: 12,
            min_frac: 0.05,
            max_frac: 0.5,
            max_stall_ms: 2,
        }
    }
}

/// A deterministic per-worker fault schedule. Clone-cheap; the engine
/// hands each worker its own [`WorkerFaults`] cursor via
/// [`FaultPlan::worker`].
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub per_worker: Vec<Vec<Fault>>,
}

impl FaultPlan {
    /// A plan with no faults for `workers` workers.
    pub fn none(workers: usize) -> FaultPlan {
        FaultPlan { per_worker: vec![Vec::new(); workers] }
    }

    /// Draw a random schedule for `workers` workers from `seed`. The same
    /// `(seed, workers, cfg)` always yields the same plan — a failing
    /// property case reproduces from its seed.
    pub fn random(seed: u64, workers: usize, cfg: &FaultPlanConfig) -> FaultPlan {
        let workers = workers.max(1);
        let mut rng = Pcg64::new(seed, crate::util::rng::hash_label("fault-plan"));
        let mut per_worker = vec![Vec::new(); workers];
        let max_pass = cfg.max_pass.max(1);
        for _ in 0..cfg.panics {
            let w = rng.below(workers);
            per_worker[w].push(Fault::Panic { at: 1 + rng.below(max_pass) });
        }
        for _ in 0..cfg.clamps {
            let w = rng.below(workers);
            let from = 1 + rng.below(max_pass);
            let until = from + 1 + rng.below(max_pass);
            let frac = cfg.min_frac + (cfg.max_frac - cfg.min_frac) * rng.f64();
            per_worker[w].push(Fault::ClampKv { from, until, frac });
        }
        for _ in 0..cfg.stalls {
            let w = rng.below(workers);
            per_worker[w].push(Fault::Stall {
                at: 1 + rng.below(max_pass),
                ms: 1 + rng.below(cfg.max_stall_ms.max(1) as usize) as u64,
            });
        }
        FaultPlan { per_worker }
    }

    /// This worker's schedule as a runtime cursor (empty when the plan has
    /// fewer workers than the engine).
    pub fn worker(&self, w: usize) -> WorkerFaults {
        WorkerFaults {
            worker: w,
            faults: self.per_worker.get(w).cloned().unwrap_or_default(),
            nominal_capacity: None,
            clamped: false,
        }
    }

    /// Total scheduled panics — how many workers the plan will kill (a
    /// worker dies at its first panic; later panics on it are moot).
    pub fn panic_count(&self) -> usize {
        self.per_worker
            .iter()
            .map(|fs| fs.iter().filter(|f| matches!(f, Fault::Panic { .. })).count())
            .sum()
    }
}

/// One worker's live fault cursor: the batcher calls
/// [`WorkerFaults::before_pass`] at the top of every loop pass.
#[derive(Debug)]
pub struct WorkerFaults {
    worker: usize,
    faults: Vec<Fault>,
    /// Pool capacity observed before the first clamp; clamps are relative
    /// to it and restores write it back.
    nominal_capacity: Option<usize>,
    clamped: bool,
}

impl WorkerFaults {
    /// Apply every fault scheduled for `pass`: stalls first, then clamp
    /// state (enter/leave), panics last — so a pass that both clamps and
    /// panics leaves the clamp visible to the cleanup path, which calls
    /// [`WorkerFaults::restore`].
    pub fn before_pass(&mut self, pass: usize, pool: &KvPool) {
        for f in &self.faults {
            if let Fault::Stall { at, ms } = f {
                if *at == pass {
                    std::thread::sleep(Duration::from_millis(*ms));
                }
            }
        }
        // The tightest clamp covering this pass wins.
        let mut frac: Option<f64> = None;
        for f in &self.faults {
            if let Fault::ClampKv { from, until, frac: fr } = f {
                if (*from..*until).contains(&pass) {
                    frac = Some(frac.map_or(*fr, |cur: f64| cur.min(*fr)));
                }
            }
        }
        match frac {
            Some(fr) => {
                let nominal =
                    *self.nominal_capacity.get_or_insert_with(|| pool.capacity_tokens());
                pool.set_capacity_tokens(((nominal as f64 * fr) as usize).max(1));
                self.clamped = true;
            }
            None => self.restore(pool),
        }
        for f in &self.faults {
            if let Fault::Panic { at } = f {
                if *at == pass {
                    panic!("{INJECTED_PANIC}: worker {} pass {}", self.worker, pass);
                }
            }
        }
    }

    /// Lift any active clamp (idempotent). The worker-failure cleanup path
    /// calls this so a worker that dies mid-clamp doesn't leave its pool
    /// pinched forever.
    pub fn restore(&mut self, pool: &KvPool) {
        if self.clamped {
            if let Some(n) = self.nominal_capacity {
                pool.set_capacity_tokens(n);
            }
            self.clamped = false;
        }
    }
}

/// Install (once per process) a panic hook that swallows injected-fault
/// panics and forwards everything else to the previous hook. Keeps
/// fault-schedule property tests from burying real failures under pages of
/// expected backtraces.
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.starts_with(INJECTED_PANIC) {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_per_seed() {
        let cfg = FaultPlanConfig { panics: 3, clamps: 2, stalls: 2, ..Default::default() };
        let a = FaultPlan::random(42, 3, &cfg);
        let b = FaultPlan::random(42, 3, &cfg);
        assert_eq!(a.per_worker, b.per_worker);
        assert_eq!(a.panic_count(), 3);
        let c = FaultPlan::random(43, 3, &cfg);
        assert_ne!(a.per_worker, c.per_worker, "different seeds must differ");
        assert_eq!(a.per_worker.len(), 3);
    }

    #[test]
    fn clamp_applies_and_restores() {
        let pool = KvPool::new(1000, 8);
        let plan = FaultPlan {
            per_worker: vec![vec![Fault::ClampKv { from: 2, until: 4, frac: 0.1 }]],
        };
        let mut wf = plan.worker(0);
        wf.before_pass(1, &pool);
        assert_eq!(pool.capacity_tokens(), 1000);
        wf.before_pass(2, &pool);
        assert_eq!(pool.capacity_tokens(), 100);
        assert!(pool.alloc(500).is_none(), "clamped pool must refuse");
        wf.before_pass(3, &pool);
        assert_eq!(pool.capacity_tokens(), 100);
        wf.before_pass(4, &pool);
        assert_eq!(pool.capacity_tokens(), 1000, "window closed: capacity restored");
        assert!(pool.alloc(500).is_some());
    }

    #[test]
    fn restore_lifts_clamp_for_cleanup_paths() {
        let pool = KvPool::new(64, 8);
        let plan = FaultPlan {
            per_worker: vec![vec![Fault::ClampKv { from: 1, until: 100, frac: 0.25 }]],
        };
        let mut wf = plan.worker(0);
        wf.before_pass(1, &pool);
        assert_eq!(pool.capacity_tokens(), 16);
        wf.restore(&pool);
        assert_eq!(pool.capacity_tokens(), 64);
        wf.restore(&pool); // idempotent
        assert_eq!(pool.capacity_tokens(), 64);
    }

    #[test]
    fn panic_fires_on_its_pass() {
        silence_injected_panics();
        let pool = KvPool::new(64, 8);
        let plan = FaultPlan { per_worker: vec![vec![Fault::Panic { at: 3 }]] };
        let mut wf = plan.worker(0);
        wf.before_pass(1, &pool);
        wf.before_pass(2, &pool);
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            wf.before_pass(3, &pool);
        }));
        assert!(unwound.is_err(), "scheduled panic must fire");
    }
}
