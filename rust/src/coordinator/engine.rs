//! The serving engine facade: request-granular **submit → stream → cancel**
//! over the multi-worker continuous batcher.
//!
//! PRs 1–4 built a fast execution engine behind a batch-and-drain call
//! (`serve_requests(model, cfg, Vec<GenRequest>) -> ServerRun`) that blocked
//! until every response was collected and decoded greedy-only. [`Engine`] is
//! the request-granular redesign: it owns the worker threads (each running
//! [`super::batcher::run_batcher_spec`] over its own [`KvPool`], with an
//! optional speculative [`DraftModel`] proposer), routes each
//! submission to the least-loaded worker, and hands back a
//! [`RequestHandle`] immediately — tokens stream out as they are generated,
//! and the handle can cancel the request mid-flight.
//!
//! ## API tour
//!
//! ```text
//! let engine = Engine::new(model, EngineConfig::default());
//! let mut req = GenRequest::new(0, prompt, 64);
//! req.sampling = SamplingParams { temperature: 0.8, top_k: 40, top_p: 0.95,
//!                                 seed: 7, stop_tokens: vec![] };
//! let handle = engine.submit(req);          // returns immediately
//! while let Some(ev) = handle.recv() {      // blocking receipt
//!     match ev {
//!         TokenEvent::PrefillDone { ttft } => ...,
//!         TokenEvent::Token { token, index } => ...,   // streamed live
//!         TokenEvent::Finished { reason, .. } => break,
//!     }
//! }                                          // or: handle.try_recv() to poll,
//!                                            //     handle.cancel() to abort,
//!                                            //     handle.wait() to drain
//! let per_worker = engine.shutdown();        // drain + join workers
//! ```
//!
//! ## Request lifecycle
//!
//! ```text
//!  submit(GenRequest{ sampling, .. })
//!     │  least-loaded routing (outstanding prompt+max_new tokens)
//!     ▼
//!  worker queue ──► admission ──► Active { Sampler, KvCache, Lease }
//!     │   impossible → Finished{Rejected}       │ per-iteration loop:
//!     │                                         │  cancel sweep → ragged
//!     ▼                                         │  forward → sample+emit
//!  RequestHandle ◄── PrefillDone{ttft} ◄────────┤
//!     │          ◄── Token{token,index}* ◄──────┤   (generation time)
//!     │          ◄── Finished{reason,..} ◄── lease freed BEFORE the
//!     │                                       terminal event
//!     └── cancel() / drop ──► flag swept each iteration ──► Cancelled
//! ```
//!
//! Every stream terminates with exactly one `Finished` carrying a
//! [`FinishReason`] (eos / length / cancelled / truncated-kv / rejected).
//! Dropping a handle without draining it cancels the request — abandoned
//! streams never pin KV capacity.
//!
//! The old batch-and-drain surface survives as a thin compat wrapper:
//! [`super::router::serve_requests`] submits everything, waits on every
//! handle, and aggregates a `ServerRun`.

use super::batcher::{
    run_batcher_spec, BatchConfig, BatchMetrics, FinishReason, GenRequest, Submission, TokenEvent,
};
use super::kvpool::KvPool;
use crate::model::{DraftModel, Gpt};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Engine sizing: worker replicas, per-worker batcher policy, per-worker KV
/// pool capacity (tokens).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    pub batch: BatchConfig,
    /// KV token budget per worker.
    pub kv_tokens: usize,
    /// Speculative-decoding proposer, cloned into every worker (the handle
    /// is `Arc`-backed, so no weights are copied). Inert unless
    /// `batch.spec_k > 0`.
    pub draft: Option<DraftModel>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            batch: BatchConfig::default(),
            kv_tokens: 1 << 16,
            draft: None,
        }
    }
}

/// Aggregated outcome of one request, built by [`RequestHandle::wait`] (and
/// the `serve_requests` compat wrapper).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time from submit to first generated token (stamped when the logits
    /// of the prefill-final forward are written back). For streams that
    /// never reached a first token (rejected / early-cancelled) this equals
    /// `total`.
    pub ttft: Duration,
    /// Time from submit to the terminal event.
    pub total: Duration,
    pub prompt_len: usize,
    /// Why the stream ended.
    pub finish: FinishReason,
}

impl Response {
    /// True when the request was refused at admission (no tokens).
    pub fn is_rejected(&self) -> bool {
        self.finish == FinishReason::Rejected
    }
}

/// Non-blocking poll outcome from [`RequestHandle::try_recv`].
#[derive(Clone, Debug)]
pub enum TryEvent {
    /// An event was ready.
    Event(TokenEvent),
    /// Nothing ready right now; poll again.
    Empty,
    /// The stream is over: either the terminal `Finished` was already
    /// delivered, or the worker died without one. Poll loops must treat
    /// this as terminal or they will spin forever on a dead stream.
    Closed,
}

/// The caller's side of one submitted request: a live token stream plus the
/// cancellation switch. Obtained from [`Engine::submit`]; see the module doc
/// for the event protocol. Dropping the handle cancels the request (the
/// admission path and per-iteration sweep both check the flag), so an
/// abandoned stream never pins KV capacity — even if it is still queued and
/// has not had a single event sent yet.
pub struct RequestHandle {
    id: u64,
    prompt_len: usize,
    submitted: Instant,
    events: Receiver<TokenEvent>,
    cancel: Arc<AtomicBool>,
}

impl Drop for RequestHandle {
    /// Raise the cancel flag: a no-op for streams that already finished,
    /// an immediate admission-time cancel for streams still queued (the
    /// event-send failure path alone would only catch the drop after the
    /// whole prefill had run).
    fn drop(&mut self) {
        self.cancel.store(true, Ordering::Release);
    }
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Time since the request was submitted.
    pub fn elapsed(&self) -> Duration {
        self.submitted.elapsed()
    }

    /// Ask the engine to abort this request. Asynchronous: the batcher
    /// sweeps cancel flags once per iteration, frees the KV lease, and
    /// closes the stream with `Finished { reason: Cancelled }`. Safe to
    /// call at any point (even after the stream finished — then a no-op).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    /// Blocking receipt of the next event. `None` once the stream is over
    /// (terminal `Finished` already delivered, or the worker is gone).
    pub fn recv(&self) -> Option<TokenEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking receipt. [`TryEvent::Closed`] (stream over, or worker
    /// gone) is distinct from [`TryEvent::Empty`] so poll loops can stop.
    pub fn try_recv(&self) -> TryEvent {
        match self.events.try_recv() {
            Ok(ev) => TryEvent::Event(ev),
            Err(TryRecvError::Empty) => TryEvent::Empty,
            Err(TryRecvError::Disconnected) => TryEvent::Closed,
        }
    }

    /// Blocking receipt with a deadline.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<TokenEvent> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Drain the stream to completion and aggregate it into a [`Response`]
    /// — the submit-all/drain-all compat path. If the worker disappears
    /// without a terminal event (it panicked), the partial stream is
    /// reported as `Cancelled`.
    pub fn wait(self) -> Response {
        let mut tokens = Vec::new();
        let mut ttft = None;
        loop {
            match self.events.recv() {
                Ok(TokenEvent::PrefillDone { ttft: t }) => ttft = Some(t),
                Ok(TokenEvent::Token { token, .. }) => tokens.push(token),
                Ok(TokenEvent::Finished { reason, ttft, total, .. }) => {
                    return Response {
                        id: self.id,
                        tokens,
                        ttft,
                        total,
                        prompt_len: self.prompt_len,
                        finish: reason,
                    };
                }
                Err(_) => {
                    let total = self.submitted.elapsed();
                    return Response {
                        id: self.id,
                        tokens,
                        ttft: ttft.unwrap_or(total),
                        total,
                        prompt_len: self.prompt_len,
                        finish: FinishReason::Cancelled,
                    };
                }
            }
        }
    }
}

/// Drive a set of handles round-robin with non-blocking receipt until every
/// stream has delivered its terminal `Finished` — or closed without one
/// (worker gone), reported as `on_event(index, None)`. Events arrive in
/// per-stream order and each stream notifies the callback of exactly one
/// terminal (a `Finished` event or `None`). Receive time tracks generation
/// time for all streams simultaneously, unlike draining handles one
/// blocking `wait()` at a time. Empty sweeps back off with a sub-iteration
/// sleep (decode iterations are ~ms; the nap is µs) so the drain neither
/// pins a core nor blurs receive-time metrics — still a foreground drain,
/// not a background idle loop.
pub fn poll_streams(
    handles: &[RequestHandle],
    mut on_event: impl FnMut(usize, Option<TokenEvent>),
) {
    let mut done = vec![false; handles.len()];
    let mut open = handles.len();
    while open > 0 {
        let mut advanced = false;
        for (i, h) in handles.iter().enumerate() {
            if done[i] {
                continue;
            }
            loop {
                match h.try_recv() {
                    TryEvent::Event(ev) => {
                        advanced = true;
                        let terminal = matches!(ev, TokenEvent::Finished { .. });
                        on_event(i, Some(ev));
                        if terminal {
                            done[i] = true;
                            open -= 1;
                            break;
                        }
                    }
                    TryEvent::Empty => break,
                    TryEvent::Closed => {
                        advanced = true;
                        on_event(i, None);
                        done[i] = true;
                        open -= 1;
                        break;
                    }
                }
            }
        }
        if !advanced && open > 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

struct Worker {
    tx: Sender<Submission>,
    load: Arc<AtomicUsize>,
    pool: KvPool,
    handle: thread::JoinHandle<BatchMetrics>,
}

/// Multi-worker streaming serving engine. See the module doc.
pub struct Engine {
    workers: Vec<Worker>,
}

impl Engine {
    /// Spawn `cfg.workers` batcher threads (at least one), each with its own
    /// [`KvPool`] sized from the model config, over a shared immutable model
    /// snapshot.
    pub fn new(model: Arc<Gpt>, cfg: EngineConfig) -> Engine {
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for _ in 0..cfg.workers.max(1) {
            let (tx, rx) = std::sync::mpsc::channel::<Submission>();
            let pool =
                KvPool::for_model_tokens_dtype(&model.cfg, cfg.kv_tokens, cfg.batch.kv_dtype);
            let worker_pool = pool.clone();
            let model = Arc::clone(&model);
            let bcfg = cfg.batch.clone();
            let draft = cfg.draft.clone();
            let load = Arc::new(AtomicUsize::new(0));
            let load2 = Arc::clone(&load);
            let handle = thread::spawn(move || {
                run_batcher_spec(&model, draft.as_ref(), &worker_pool, &bcfg, rx, |req, _| {
                    load2.fetch_sub(req.prompt.len() + req.max_new, Ordering::SeqCst);
                })
            });
            workers.push(Worker { tx, load, pool, handle });
        }
        Engine { workers }
    }

    /// Submit a request to the least-loaded worker (outstanding
    /// `prompt + max_new` token estimate) and return its stream handle
    /// immediately.
    pub fn submit(&self, req: GenRequest) -> RequestHandle {
        let cost = req.prompt.len() + req.max_new;
        let w = self
            .workers
            .iter()
            .min_by_key(|w| w.load.load(Ordering::SeqCst))
            .expect("engine has workers");
        w.load.fetch_add(cost, Ordering::SeqCst);
        let (sub, events, cancel) = Submission::channel(req);
        let handle = RequestHandle {
            id: sub.req.id,
            prompt_len: sub.req.prompt.len(),
            submitted: sub.req.submitted,
            events,
            cancel,
        };
        w.tx.send(sub).expect("engine worker alive");
        handle
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// KV tokens currently leased across all worker pools (observability +
    /// leak tests: returns to 0 once every stream has finished).
    pub fn kv_used_tokens(&self) -> usize {
        self.workers.iter().map(|w| w.pool.used_tokens()).sum()
    }

    /// Live KV leases across all worker pools.
    pub fn kv_live_leases(&self) -> usize {
        self.workers.iter().map(|w| w.pool.live_leases()).sum()
    }

    /// Tokens parked in the prefix tries across all worker pools (whole
    /// `KV_TILE` pages held for reuse; evicted under pressure).
    pub fn kv_cached_tokens(&self) -> usize {
        self.workers.iter().map(|w| w.pool.cached_tokens()).sum()
    }

    /// Physical KV pages alive across all worker pools — every `Arc` page
    /// a live cache or trie holds, COW copies included. The leak-test
    /// counterpart of [`Engine::kv_used_tokens`] for the paged model.
    pub fn kv_live_pages(&self) -> usize {
        self.workers.iter().map(|w| w.pool.live_pages()).sum()
    }

    /// Close the submission side, drain in-flight requests, join the worker
    /// threads, and return their per-worker metrics.
    pub fn shutdown(mut self) -> Vec<BatchMetrics> {
        self.drain_workers()
    }

    fn drain_workers(&mut self) -> Vec<BatchMetrics> {
        let mut per_worker = Vec::with_capacity(self.workers.len());
        for w in self.workers.drain(..) {
            drop(w.tx);
            per_worker.push(w.handle.join().expect("worker panicked"));
        }
        per_worker
    }
}

impl Drop for Engine {
    /// Dropping the engine without [`Engine::shutdown`] still drains and
    /// joins the workers (in-flight requests run to completion) so no
    /// detached thread outlives the facade.
    fn drop(&mut self) {
        let _ = self.drain_workers();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, SamplingParams};

    fn micro_engine(workers: usize) -> Engine {
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        Engine::new(
            model,
            EngineConfig { workers, kv_tokens: 4096, ..Default::default() },
        )
    }

    #[test]
    fn submit_streams_and_matches_greedy() {
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        let prompt = vec![3u32, 5, 7];
        let want = model.generate_greedy(&prompt, 5);
        let engine =
            Engine::new(Arc::clone(&model), EngineConfig { workers: 1, kv_tokens: 4096, ..Default::default() });
        let handle = engine.submit(GenRequest::new(9, prompt, 5));
        assert_eq!(handle.id(), 9);
        let mut tokens = Vec::new();
        let mut saw_prefill = false;
        let reason = loop {
            match handle.recv().expect("stream open") {
                TokenEvent::PrefillDone { ttft } => {
                    saw_prefill = true;
                    assert!(ttft > Duration::ZERO);
                }
                TokenEvent::Token { token, index } => {
                    assert_eq!(index, tokens.len());
                    tokens.push(token);
                }
                TokenEvent::Finished { reason, n_tokens, .. } => {
                    assert_eq!(n_tokens, tokens.len());
                    break reason;
                }
            }
        };
        assert!(saw_prefill);
        assert!(reason.is_completed());
        assert!(want.starts_with(&tokens) || tokens == want);
        let per_worker = engine.shutdown();
        assert_eq!(per_worker.len(), 1);
        assert_eq!(per_worker[0].requests, 1);
    }

    #[test]
    fn wait_aggregates_a_response() {
        let engine = micro_engine(2);
        let handles: Vec<RequestHandle> = (0..6)
            .map(|i| engine.submit(GenRequest::new(i, vec![2 + i as u32, 3], 4)))
            .collect();
        let responses: Vec<Response> = handles.into_iter().map(|h| h.wait()).collect();
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert!(r.finish.is_completed());
            assert!(!r.is_rejected());
            assert!(!r.tokens.is_empty() && r.tokens.len() <= 4);
            assert!(r.ttft <= r.total);
            assert_eq!(r.prompt_len, 2);
        }
        assert_eq!(engine.kv_used_tokens(), 0, "leases must drain with the streams");
        let per_worker = engine.shutdown();
        let total: usize = per_worker.iter().map(|m| m.requests).sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn cancel_mid_stream_frees_the_lease() {
        let mut base = synthetic_model("micro", 71).unwrap();
        base.cfg.max_seq = 8192; // room to decode until cancelled
        base.refresh_derived();
        let engine = Engine::new(
            Arc::new(base),
            EngineConfig {
                workers: 1,
                kv_tokens: 1 << 14,
                batch: BatchConfig { stop_on_eos: false, ..Default::default() },
                draft: None,
            },
        );
        let mut req = GenRequest::new(0, vec![2, 3, 4], 5000);
        req.sampling = SamplingParams::greedy();
        let handle = engine.submit(req);
        // First token, then cancel.
        loop {
            match handle.recv().expect("stream open") {
                TokenEvent::Token { .. } => break,
                TokenEvent::Finished { .. } => panic!("finished before cancel"),
                _ => {}
            }
        }
        handle.cancel();
        let reason = loop {
            match handle.recv().expect("terminal event must arrive") {
                TokenEvent::Finished { reason, n_tokens, .. } => {
                    assert!(n_tokens < 5000);
                    break reason;
                }
                _ => {}
            }
        };
        assert_eq!(reason, FinishReason::Cancelled);
        // The lease was freed before the terminal event was sent.
        assert_eq!(engine.kv_used_tokens(), 0);
        assert_eq!(engine.kv_live_leases(), 0);
        let m = engine.shutdown();
        assert_eq!(m[0].cancelled, 1);
    }

    #[test]
    fn per_request_sampling_is_engine_visible() {
        let engine = micro_engine(1);
        let prompt = vec![5u32, 9, 13];
        let mut sampled = GenRequest::new(0, prompt.clone(), 6);
        sampled.sampling = SamplingParams {
            temperature: 2.0,
            top_k: 8,
            top_p: 0.9,
            seed: 77,
            stop_tokens: vec![],
        };
        let greedy = GenRequest::new(1, prompt, 6);
        let hs = engine.submit(sampled.clone());
        let hg = engine.submit(greedy);
        let rs1 = hs.wait();
        let rg = hg.wait();
        // Reproducible under the same seed on a fresh submit.
        let rs2 = engine.submit(sampled).wait();
        assert_eq!(rs1.tokens, rs2.tokens, "seeded resubmit must reproduce");
        assert!(!rg.tokens.is_empty());
        drop(engine);
    }

    #[test]
    fn poll_streams_delivers_every_stream_once() {
        let engine = micro_engine(2);
        let handles: Vec<RequestHandle> = (0..5)
            .map(|i| engine.submit(GenRequest::new(i, vec![2 + i as u32, 3], 4)))
            .collect();
        let mut tokens = vec![0usize; handles.len()];
        let mut terminals = vec![0usize; handles.len()];
        poll_streams(&handles, |i, ev| match ev {
            Some(TokenEvent::Token { .. }) => tokens[i] += 1,
            Some(TokenEvent::Finished { n_tokens, .. }) => {
                terminals[i] += 1;
                assert_eq!(n_tokens, tokens[i], "stream {i} token count drift");
            }
            Some(TokenEvent::PrefillDone { .. }) => {}
            None => panic!("stream {i} closed without terminal event"),
        });
        assert!(terminals.iter().all(|&t| t == 1), "one terminal per stream: {terminals:?}");
        assert!(tokens.iter().all(|&t| (1..=4).contains(&t)));
        engine.shutdown();
    }

    #[test]
    fn speculative_engine_streams_match_greedy_bitwise() {
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        let prompt = vec![3u32, 5, 7];
        let want = model.generate_greedy(&prompt, 8);
        let draft = DraftModel::self_draft(Arc::clone(&model), 1).unwrap();
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                kv_tokens: 4096,
                batch: BatchConfig { spec_k: 3, stop_on_eos: false, ..Default::default() },
                draft: Some(draft),
            },
        );
        let r = engine.submit(GenRequest::new(0, prompt, 8)).wait();
        assert_eq!(r.tokens, want, "speculative greedy stream must be bitwise-identical");
        assert_eq!(engine.kv_used_tokens(), 0);
        let m = engine.shutdown();
        assert_eq!(m[0].spec_drafted, m[0].spec_accepted + m[0].spec_rejected);
        assert!(m[0].spec_drafted > 0, "draft must have proposed");
    }

    #[test]
    fn drop_joins_workers() {
        let engine = micro_engine(2);
        let h = engine.submit(GenRequest::new(0, vec![4, 5], 3));
        let r = h.wait();
        assert!(r.finish.is_completed());
        drop(engine); // must not leak detached threads or hang
    }
}
