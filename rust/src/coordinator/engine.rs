//! The serving engine facade: request-granular **submit → stream → cancel**
//! over the multi-worker continuous batcher.
//!
//! PRs 1–4 built a fast execution engine behind a batch-and-drain call
//! (`serve_requests(model, cfg, Vec<GenRequest>) -> ServerRun`) that blocked
//! until every response was collected and decoded greedy-only. [`Engine`] is
//! the request-granular redesign: it owns the worker threads (each running
//! [`super::batcher::run_batcher_spec`] over its own [`KvPool`], with an
//! optional speculative [`DraftModel`] proposer), routes each
//! submission to the least-loaded worker, and hands back a
//! [`RequestHandle`] immediately — tokens stream out as they are generated,
//! and the handle can cancel the request mid-flight.
//!
//! ## API tour
//!
//! ```text
//! let engine = Engine::new(model, EngineConfig::default());
//! let mut req = GenRequest::new(0, prompt, 64);
//! req.sampling = SamplingParams { temperature: 0.8, top_k: 40, top_p: 0.95,
//!                                 seed: 7, stop_tokens: vec![] };
//! let handle = engine.submit(req)?;         // returns immediately (QueueFull sheds)
//! while let Some(ev) = handle.recv() {      // blocking receipt
//!     match ev {
//!         TokenEvent::PrefillDone { ttft } => ...,
//!         TokenEvent::Token { token, index } => ...,   // streamed live
//!         TokenEvent::Finished { reason, .. } => break,
//!     }
//! }                                          // or: handle.try_recv() to poll,
//!                                            //     handle.cancel() to abort,
//!                                            //     handle.wait() to drain
//! let per_worker = engine.shutdown();        // drain + join workers
//! ```
//!
//! ## Request lifecycle — the full state machine
//!
//! ```text
//!  submit(GenRequest{ sampling, deadline, .. })
//!     │  admission gate: alive workers only, per-worker queue depth
//!     │  < queue_cap — else Err(SubmitError::QueueFull / Closed),
//!     │  no stream is ever created          (shed_queue_full metric)
//!     │  least-loaded routing (outstanding prompt+max_new tokens)
//!     ▼
//!  QUEUED ──────────► admission ──────► ACTIVE (prefill → decode)
//!     │  impossible → Finished{Rejected}    │ per-iteration loop:
//!     │  expired    → Finished{Deadline-    │  cancel + deadline sweep →
//!     │               Exceeded}             │  ragged plan → forward →
//!     │  worker died, no survivor to adopt  │  sample+emit → retire
//!     │            → Finished{WorkerFailed} │
//!     ▼                                     ▼
//!  RequestHandle ◄── PrefillDone{ttft} ◄────┤
//!     │          ◄── Token{token,index}* ◄──┤   (generation time)
//!     │          ◄── Finished{reason,..} ◄── lease freed BEFORE the
//!     │                                      terminal event
//!     └── cancel() / drop ──► flag swept each iteration ──► Cancelled
//! ```
//!
//! Terminal exits, exhaustively: `Eos` / `Length` / `TruncatedKv`
//! (completed), `Rejected` (admission refused), `Cancelled` (flag or
//! dropped handle), `DeadlineExceeded` (TTFT or end-to-end budget blown —
//! swept every iteration, lease freed the same pass), and `WorkerFailed`
//! (the serving worker panicked mid-flight; queued requests re-dispatch to
//! surviving workers first, so only in-flight work and orphans with no
//! survivor left see this reason). A request refused with
//! [`SubmitError::QueueFull`] never enters the machine at all — no stream,
//! no terminal event — which is what distinguishes *shedding* from
//! *failing*.
//!
//! Every accepted stream terminates with exactly one `Finished`. Dropping a
//! handle without draining it cancels the request — abandoned streams never
//! pin KV capacity.
//!
//! ## Failure containment
//!
//! Each worker's iteration body runs under `catch_unwind`
//! ([`super::batcher::run_batcher_env`]): a panic kills that worker only.
//! Its in-flight streams end with `WorkerFailed`, its queued submissions go
//! to a shared [`Orphanage`] that surviving workers adopt from during
//! intake, and its submission receiver is parked there so a submit racing
//! the death still lands somewhere observable. The engine's shutdown path
//! drains the orphanage one last time after joining all workers, so
//! "exactly one terminal event per accepted submission" holds even when
//! every worker dies.
//!
//! ## Shutdown
//!
//! [`Engine::shutdown_mode`] takes a [`Shutdown`] policy: `Drain` closes
//! admission and lets in-flight work finish (escalating to abort if the
//! timeout expires), `Abort` raises every worker's abort flag and cancels
//! everything immediately. [`Engine::shutdown`] is drain-without-deadline;
//! `Drop` aborts — dropping the facade mid-stream joins the workers and
//! frees every KV page rather than hanging on stragglers.
//!
//! The old batch-and-drain surface survives as a thin compat wrapper:
//! [`super::router::serve_requests`] submits everything, waits on every
//! handle, and aggregates a `ServerRun`.

use super::batcher::{
    run_batcher_env, BatchConfig, BatchMetrics, CountGuard, FinishReason, GenRequest, Orphanage,
    RunEnv, Submission, TokenEvent,
};
use super::faults::FaultPlan;
use super::kvpool::KvPool;
use crate::model::{DraftModel, Gpt};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Engine sizing: worker replicas, per-worker batcher policy, per-worker KV
/// pool capacity (tokens), admission bound, and an optional fault schedule.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub workers: usize,
    pub batch: BatchConfig,
    /// KV token budget per worker.
    pub kv_tokens: usize,
    /// Speculative-decoding proposer, cloned into every worker (the handle
    /// is `Arc`-backed, so no weights are copied). Inert unless
    /// `batch.spec_k > 0`.
    pub draft: Option<DraftModel>,
    /// Max requests queued (submitted but not yet admitted) per worker.
    /// When every alive worker is at the cap, [`Engine::submit`] sheds the
    /// request with [`SubmitError::QueueFull`] instead of letting latency
    /// grow unboundedly. `0` means unbounded (the pre-resilience behavior).
    pub queue_cap: usize,
    /// Deterministic fault-injection schedule (test/chaos harness); worker
    /// `w` runs `faults.worker(w)`. `None` in production.
    pub faults: Option<FaultPlan>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            batch: BatchConfig::default(),
            kv_tokens: 1 << 16,
            draft: None,
            queue_cap: 0,
            faults: None,
        }
    }
}

/// Why [`Engine::submit`] refused a request. Shed requests never produce a
/// stream or a terminal event — the caller still owns the `GenRequest`.
#[derive(Clone, Debug)]
pub enum SubmitError {
    /// Every alive worker's queue is at [`EngineConfig::queue_cap`]. The
    /// request is returned so the caller can retry (see
    /// [`Engine::submit_wait`]), downgrade, or fail fast.
    QueueFull(GenRequest),
    /// No alive worker remains (all panicked, or shutdown began). Retrying
    /// cannot succeed.
    Closed(GenRequest),
}

impl SubmitError {
    /// Take the request back out of the error.
    pub fn into_request(self) -> GenRequest {
        match self {
            SubmitError::QueueFull(r) | SubmitError::Closed(r) => r,
        }
    }

    pub fn is_queue_full(&self) -> bool {
        matches!(self, SubmitError::QueueFull(_))
    }
}

/// Shutdown policy for [`Engine::shutdown_mode`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shutdown {
    /// Close admission, let in-flight and queued work finish. If a timeout
    /// is given and expires, escalate to `Abort` for whatever remains.
    Drain,
    /// Raise every worker's abort flag: in-flight and queued streams end
    /// with `Finished{Cancelled}` immediately, no further model work runs.
    Abort,
}

/// Aggregated outcome of one request, built by [`RequestHandle::wait`] (and
/// the `serve_requests` compat wrapper).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time from submit to first generated token (stamped when the logits
    /// of the prefill-final forward are written back). For streams that
    /// never reached a first token (rejected / early-cancelled) this equals
    /// `total`.
    pub ttft: Duration,
    /// Time from submit to the terminal event.
    pub total: Duration,
    pub prompt_len: usize,
    /// Why the stream ended.
    pub finish: FinishReason,
}

impl Response {
    /// True when the request was refused at admission (no tokens).
    pub fn is_rejected(&self) -> bool {
        self.finish == FinishReason::Rejected
    }
}

/// Non-blocking poll outcome from [`RequestHandle::try_recv`].
#[derive(Clone, Debug)]
pub enum TryEvent {
    /// An event was ready.
    Event(TokenEvent),
    /// Nothing ready right now; poll again.
    Empty,
    /// The stream is over: either the terminal `Finished` was already
    /// delivered, or the worker died without one. Poll loops must treat
    /// this as terminal or they will spin forever on a dead stream.
    Closed,
}

/// The caller's side of one submitted request: a live token stream plus the
/// cancellation switch. Obtained from [`Engine::submit`]; see the module doc
/// for the event protocol. Dropping the handle cancels the request (the
/// admission path and per-iteration sweep both check the flag), so an
/// abandoned stream never pins KV capacity — even if it is still queued and
/// has not had a single event sent yet.
pub struct RequestHandle {
    id: u64,
    prompt_len: usize,
    submitted: Instant,
    events: Receiver<TokenEvent>,
    cancel: Arc<AtomicBool>,
}

impl Drop for RequestHandle {
    /// Raise the cancel flag: a no-op for streams that already finished,
    /// an immediate admission-time cancel for streams still queued (the
    /// event-send failure path alone would only catch the drop after the
    /// whole prefill had run).
    fn drop(&mut self) {
        self.cancel.store(true, Ordering::Release);
    }
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Time since the request was submitted.
    pub fn elapsed(&self) -> Duration {
        self.submitted.elapsed()
    }

    /// Ask the engine to abort this request. Asynchronous: the batcher
    /// sweeps cancel flags once per iteration, frees the KV lease, and
    /// closes the stream with `Finished { reason: Cancelled }`. Safe to
    /// call at any point (even after the stream finished — then a no-op).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    /// Blocking receipt of the next event. `None` once the stream is over
    /// (terminal `Finished` already delivered, or the worker is gone).
    pub fn recv(&self) -> Option<TokenEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking receipt. [`TryEvent::Closed`] (stream over, or worker
    /// gone) is distinct from [`TryEvent::Empty`] so poll loops can stop.
    pub fn try_recv(&self) -> TryEvent {
        match self.events.try_recv() {
            Ok(ev) => TryEvent::Event(ev),
            Err(TryRecvError::Empty) => TryEvent::Empty,
            Err(TryRecvError::Disconnected) => TryEvent::Closed,
        }
    }

    /// Blocking receipt with a deadline. Returns [`TryEvent::Empty`] when
    /// the timeout elapsed with the stream still open (poll again) and
    /// [`TryEvent::Closed`] when the worker is gone — the old
    /// `Option<TokenEvent>` return conflated the two, so callers could not
    /// tell a slow stream from a dead one.
    pub fn recv_timeout(&self, timeout: Duration) -> TryEvent {
        match self.events.recv_timeout(timeout) {
            Ok(ev) => TryEvent::Event(ev),
            Err(RecvTimeoutError::Timeout) => TryEvent::Empty,
            Err(RecvTimeoutError::Disconnected) => TryEvent::Closed,
        }
    }

    /// Drain the stream to completion and aggregate it into a [`Response`]
    /// — the submit-all/drain-all compat path. If the channel closes
    /// without a terminal event (which the worker-failure and shutdown
    /// backstops make vanishingly rare), the partial stream is reported as
    /// `WorkerFailed`.
    pub fn wait(self) -> Response {
        let mut tokens = Vec::new();
        let mut ttft = None;
        loop {
            match self.events.recv() {
                Ok(TokenEvent::PrefillDone { ttft: t }) => ttft = Some(t),
                Ok(TokenEvent::Token { token, .. }) => tokens.push(token),
                Ok(TokenEvent::Finished { reason, ttft, total, .. }) => {
                    return Response {
                        id: self.id,
                        tokens,
                        ttft,
                        total,
                        prompt_len: self.prompt_len,
                        finish: reason,
                    };
                }
                Err(_) => {
                    let total = self.submitted.elapsed();
                    return Response {
                        id: self.id,
                        tokens,
                        ttft: ttft.unwrap_or(total),
                        total,
                        prompt_len: self.prompt_len,
                        finish: FinishReason::WorkerFailed,
                    };
                }
            }
        }
    }
}

/// Drive a set of handles round-robin with non-blocking receipt until every
/// stream has delivered its terminal `Finished` — or closed without one
/// (worker gone), reported as `on_event(index, None)`. Events arrive in
/// per-stream order and each stream notifies the callback of exactly one
/// terminal (a `Finished` event or `None`). Receive time tracks generation
/// time for all streams simultaneously, unlike draining handles one
/// blocking `wait()` at a time. Empty sweeps back off with a sub-iteration
/// sleep (decode iterations are ~ms; the nap is µs) so the drain neither
/// pins a core nor blurs receive-time metrics — still a foreground drain,
/// not a background idle loop.
pub fn poll_streams(
    handles: &[RequestHandle],
    mut on_event: impl FnMut(usize, Option<TokenEvent>),
) {
    let mut done = vec![false; handles.len()];
    let mut open = handles.len();
    while open > 0 {
        let mut advanced = false;
        for (i, h) in handles.iter().enumerate() {
            if done[i] {
                continue;
            }
            loop {
                match h.try_recv() {
                    TryEvent::Event(ev) => {
                        advanced = true;
                        let terminal = matches!(ev, TokenEvent::Finished { .. });
                        on_event(i, Some(ev));
                        if terminal {
                            done[i] = true;
                            open -= 1;
                            break;
                        }
                    }
                    TryEvent::Empty => break,
                    TryEvent::Closed => {
                        advanced = true;
                        on_event(i, None);
                        done[i] = true;
                        open -= 1;
                        break;
                    }
                }
            }
        }
        if !advanced && open > 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

struct Worker {
    tx: Sender<Submission>,
    /// Outstanding `prompt + max_new` token estimate (routing signal),
    /// maintained by `CountGuard`s riding on submissions.
    load: Arc<AtomicUsize>,
    /// Submitted-but-not-yet-admitted depth (admission bound).
    queued: Arc<AtomicUsize>,
    /// Requests shed at this worker with `QueueFull`; folded into its
    /// metrics at join.
    shed: Arc<AtomicUsize>,
    /// Cleared by the batcher loop on exit (panic or drain); submit routes
    /// only to alive workers.
    alive: Arc<AtomicBool>,
    /// Engine-raised abort switch for [`Shutdown::Abort`].
    abort: Arc<AtomicBool>,
    pool: KvPool,
    handle: thread::JoinHandle<BatchMetrics>,
}

impl Worker {
    fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }
}

/// Multi-worker streaming serving engine. See the module doc.
pub struct Engine {
    workers: Vec<Worker>,
    orphans: Arc<Orphanage>,
    queue_cap: usize,
}

impl Engine {
    /// Spawn `cfg.workers` batcher threads (at least one), each with its own
    /// [`KvPool`] sized from the model config, over a shared immutable model
    /// snapshot.
    pub fn new(model: Arc<Gpt>, cfg: EngineConfig) -> Engine {
        let orphans = Arc::new(Orphanage::new());
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let (tx, rx) = std::sync::mpsc::channel::<Submission>();
            let pool =
                KvPool::for_model_tokens_dtype(&model.cfg, cfg.kv_tokens, cfg.batch.kv_dtype);
            let worker_pool = pool.clone();
            let model = Arc::clone(&model);
            let bcfg = cfg.batch.clone();
            let draft = cfg.draft.clone();
            let load = Arc::new(AtomicUsize::new(0));
            let queued = Arc::new(AtomicUsize::new(0));
            let shed = Arc::new(AtomicUsize::new(0));
            let alive = Arc::new(AtomicBool::new(true));
            let abort = Arc::new(AtomicBool::new(false));
            let env = RunEnv {
                worker: i,
                abort: Some(Arc::clone(&abort)),
                alive: Some(Arc::clone(&alive)),
                orphans: Some(Arc::clone(&orphans)),
                faults: cfg.faults.as_ref().map(|p| p.worker(i)),
            };
            let handle = thread::spawn(move || {
                // Load/queue accounting rides on the submissions as drop
                // guards (panic-safe); nothing to do at finish time.
                run_batcher_env(&model, draft.as_ref(), &worker_pool, &bcfg, rx, env, |_, _| {})
            });
            workers.push(Worker { tx, load, queued, shed, alive, abort, pool, handle });
        }
        Engine { workers, orphans, queue_cap: cfg.queue_cap }
    }

    /// Submit a request to the least-loaded alive worker (outstanding
    /// `prompt + max_new` token estimate) and return its stream handle
    /// immediately. Sheds with [`SubmitError::QueueFull`] when every alive
    /// worker's queue is at [`EngineConfig::queue_cap`], and with
    /// [`SubmitError::Closed`] when no alive worker remains.
    pub fn submit(&self, req: GenRequest) -> Result<RequestHandle, SubmitError> {
        let mut best: Option<&Worker> = None;
        let mut any_alive = false;
        for w in &self.workers {
            if !w.is_alive() {
                continue;
            }
            any_alive = true;
            if self.queue_cap > 0 && w.queued.load(Ordering::SeqCst) >= self.queue_cap {
                continue;
            }
            if best.map_or(true, |b| w.load.load(Ordering::SeqCst) < b.load.load(Ordering::SeqCst))
            {
                best = Some(w);
            }
        }
        let Some(w) = best else {
            return Err(if any_alive {
                // Attribute the shed to the least-loaded alive worker so
                // per-worker metrics sum to the engine-wide shed count.
                if let Some(w) = self
                    .workers
                    .iter()
                    .filter(|w| w.is_alive())
                    .min_by_key(|w| w.load.load(Ordering::SeqCst))
                {
                    w.shed.fetch_add(1, Ordering::SeqCst);
                }
                SubmitError::QueueFull(req)
            } else {
                SubmitError::Closed(req)
            });
        };
        let cost = req.prompt.len() + req.max_new;
        let (mut sub, events, cancel) = Submission::channel(req);
        sub.load = Some(CountGuard::add(&w.load, cost));
        sub.queue_slot = Some(CountGuard::add(&w.queued, 1));
        let handle = RequestHandle {
            id: sub.req.id,
            prompt_len: sub.req.prompt.len(),
            submitted: sub.req.submitted,
            events,
            cancel,
        };
        // The worker's receiver outlives the worker (parked in the
        // orphanage on death), so this send can only fail if the engine is
        // already tearing down — in which case the shutdown backstop
        // would never see the sub either; hand it to the orphanage
        // directly rather than dropping it on the floor.
        if let Err(e) = w.tx.send(sub) {
            self.orphans.push_all([e.0]);
        }
        Ok(handle)
    }

    /// Blocking [`Engine::submit`]: on `QueueFull`, retry with a short
    /// backoff until `timeout` elapses. Returns the final error (with the
    /// request inside) if the queues never opened up, or immediately on
    /// `Closed`.
    pub fn submit_wait(
        &self,
        req: GenRequest,
        timeout: Duration,
    ) -> Result<RequestHandle, SubmitError> {
        let deadline = Instant::now() + timeout;
        let mut req = req;
        loop {
            match self.submit(req) {
                Ok(h) => return Ok(h),
                Err(SubmitError::Closed(r)) => return Err(SubmitError::Closed(r)),
                Err(SubmitError::QueueFull(r)) => {
                    if Instant::now() >= deadline {
                        return Err(SubmitError::QueueFull(r));
                    }
                    req = r;
                    thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Workers whose batcher loop is still running.
    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.is_alive()).count()
    }

    /// KV tokens currently leased across all worker pools (observability +
    /// leak tests: returns to 0 once every stream has finished).
    pub fn kv_used_tokens(&self) -> usize {
        self.workers.iter().map(|w| w.pool.used_tokens()).sum()
    }

    /// Live KV leases across all worker pools.
    pub fn kv_live_leases(&self) -> usize {
        self.workers.iter().map(|w| w.pool.live_leases()).sum()
    }

    /// Tokens parked in the prefix tries across all worker pools (whole
    /// `KV_TILE` pages held for reuse; evicted under pressure).
    pub fn kv_cached_tokens(&self) -> usize {
        self.workers.iter().map(|w| w.pool.cached_tokens()).sum()
    }

    /// Physical KV pages alive across all worker pools — every `Arc` page
    /// a live cache or trie holds, COW copies included. The leak-test
    /// counterpart of [`Engine::kv_used_tokens`] for the paged model.
    pub fn kv_live_pages(&self) -> usize {
        self.workers.iter().map(|w| w.pool.live_pages()).sum()
    }

    /// Clones of every worker's pool handle (the state is shared, not
    /// copied). Lets an observer — a leak test, a metrics exporter — keep
    /// watching the lease/page meters even across the engine's own
    /// teardown, e.g. to assert the meters drained to zero after `Drop`.
    pub fn kv_pool_handles(&self) -> Vec<KvPool> {
        self.workers.iter().map(|w| w.pool.clone()).collect()
    }

    /// Close the submission side, drain in-flight requests to completion
    /// (no deadline), join the worker threads, and return their per-worker
    /// metrics — `shutdown_mode(Shutdown::Drain, None)`.
    pub fn shutdown(self) -> Vec<BatchMetrics> {
        self.shutdown_mode(Shutdown::Drain, None)
    }

    /// Shut the engine down under an explicit policy. `Drain` closes
    /// admission and waits for in-flight work; if `timeout` expires first,
    /// the remaining workers are aborted (their streams end `Cancelled`) so
    /// the call is bounded. `Abort` cancels everything immediately. Either
    /// way all workers are joined, the orphanage backstop fails any
    /// stranded submission with a terminal event, and per-worker metrics
    /// (shed counts folded in) are returned.
    pub fn shutdown_mode(mut self, mode: Shutdown, timeout: Option<Duration>) -> Vec<BatchMetrics> {
        // Drop still runs afterwards, but with `workers` drained it is a
        // no-op beyond one extra (empty) orphanage sweep.
        self.teardown(mode, timeout)
    }

    /// Shared teardown for `shutdown_mode` and `Drop`.
    fn teardown(&mut self, mode: Shutdown, timeout: Option<Duration>) -> Vec<BatchMetrics> {
        if mode == Shutdown::Abort {
            for w in &self.workers {
                w.abort.store(true, Ordering::Release);
            }
        }
        // Closing the senders both ends drain-mode intake and lets the
        // abort path's final channel drain disconnect.
        let mut workers: Vec<Worker> = self.workers.drain(..).collect();
        for w in &mut workers {
            let (dead_tx, _) = std::sync::mpsc::channel();
            drop(std::mem::replace(&mut w.tx, dead_tx));
        }
        if mode == Shutdown::Drain {
            if let Some(t) = timeout {
                let deadline = Instant::now() + t;
                while Instant::now() < deadline
                    && workers.iter().any(|w| !w.handle.is_finished())
                {
                    thread::sleep(Duration::from_micros(500));
                }
                // Escalate: whatever has not finished draining gets aborted
                // so shutdown stays bounded.
                for w in &workers {
                    if !w.handle.is_finished() {
                        w.abort.store(true, Ordering::Release);
                    }
                }
            }
        }
        let mut per_worker = Vec::with_capacity(workers.len());
        for w in workers {
            let mut m = match w.handle.join() {
                Ok(m) => m,
                // The loop itself never unwinds (the iteration body is
                // isolated); a join error means a panic in thread teardown.
                // Keep shutting down — resilience over diagnostics here.
                Err(_) => BatchMetrics::default(),
            };
            m.shed_queue_full = w.shed.load(Ordering::SeqCst);
            per_worker.push(m);
        }
        // Backstop: every worker is joined, so nothing will ever adopt
        // what is still stranded — fail it with a terminal event now.
        let stranded_reason = match mode {
            Shutdown::Drain => FinishReason::WorkerFailed,
            Shutdown::Abort => FinishReason::Cancelled,
        };
        for sub in self.orphans.adopt() {
            let waited = sub.req.submitted.elapsed();
            let _ = sub.events.send(TokenEvent::Finished {
                reason: stranded_reason,
                n_tokens: 0,
                ttft: waited,
                total: waited,
            });
            if let Some(m) = per_worker.first_mut() {
                m.count_finish(stranded_reason);
            }
        }
        per_worker
    }
}

impl Drop for Engine {
    /// Dropping the engine without [`Engine::shutdown`] aborts: in-flight
    /// streams end `Cancelled`, workers are joined, every KV page is freed.
    /// No detached thread outlives the facade, and a drop mid-stream cannot
    /// hang on a straggler the way a drain would.
    fn drop(&mut self) {
        let _ = self.teardown(Shutdown::Abort, None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::{self, Fault};
    use crate::model::{synthetic_model, SamplingParams};

    fn micro_engine(workers: usize) -> Engine {
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        Engine::new(
            model,
            EngineConfig { workers, kv_tokens: 4096, ..Default::default() },
        )
    }

    #[test]
    fn submit_streams_and_matches_greedy() {
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        let prompt = vec![3u32, 5, 7];
        let want = model.generate_greedy(&prompt, 5);
        let engine =
            Engine::new(Arc::clone(&model), EngineConfig { workers: 1, kv_tokens: 4096, ..Default::default() });
        let handle = engine.submit(GenRequest::new(9, prompt, 5)).unwrap();
        assert_eq!(handle.id(), 9);
        let mut tokens = Vec::new();
        let mut saw_prefill = false;
        let reason = loop {
            match handle.recv().expect("stream open") {
                TokenEvent::PrefillDone { ttft } => {
                    saw_prefill = true;
                    assert!(ttft > Duration::ZERO);
                }
                TokenEvent::Token { token, index } => {
                    assert_eq!(index, tokens.len());
                    tokens.push(token);
                }
                TokenEvent::Finished { reason, n_tokens, .. } => {
                    assert_eq!(n_tokens, tokens.len());
                    break reason;
                }
            }
        };
        assert!(saw_prefill);
        assert!(reason.is_completed());
        assert!(want.starts_with(&tokens) || tokens == want);
        let per_worker = engine.shutdown();
        assert_eq!(per_worker.len(), 1);
        assert_eq!(per_worker[0].requests, 1);
    }

    #[test]
    fn wait_aggregates_a_response() {
        let engine = micro_engine(2);
        let handles: Vec<RequestHandle> = (0..6)
            .map(|i| engine.submit(GenRequest::new(i, vec![2 + i as u32, 3], 4)).unwrap())
            .collect();
        let responses: Vec<Response> = handles.into_iter().map(|h| h.wait()).collect();
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert!(r.finish.is_completed());
            assert!(!r.is_rejected());
            assert!(!r.tokens.is_empty() && r.tokens.len() <= 4);
            assert!(r.ttft <= r.total);
            assert_eq!(r.prompt_len, 2);
        }
        assert_eq!(engine.kv_used_tokens(), 0, "leases must drain with the streams");
        let per_worker = engine.shutdown();
        let total: usize = per_worker.iter().map(|m| m.requests).sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn cancel_mid_stream_frees_the_lease() {
        let mut base = synthetic_model("micro", 71).unwrap();
        base.cfg.max_seq = 8192; // room to decode until cancelled
        base.refresh_derived();
        let engine = Engine::new(
            Arc::new(base),
            EngineConfig {
                workers: 1,
                kv_tokens: 1 << 14,
                batch: BatchConfig { stop_on_eos: false, ..Default::default() },
                draft: None,
                ..Default::default()
            },
        );
        let mut req = GenRequest::new(0, vec![2, 3, 4], 5000);
        req.sampling = SamplingParams::greedy();
        let handle = engine.submit(req).unwrap();
        // First token, then cancel.
        loop {
            match handle.recv().expect("stream open") {
                TokenEvent::Token { .. } => break,
                TokenEvent::Finished { .. } => panic!("finished before cancel"),
                _ => {}
            }
        }
        handle.cancel();
        let reason = loop {
            match handle.recv().expect("terminal event must arrive") {
                TokenEvent::Finished { reason, n_tokens, .. } => {
                    assert!(n_tokens < 5000);
                    break reason;
                }
                _ => {}
            }
        };
        assert_eq!(reason, FinishReason::Cancelled);
        // The lease was freed before the terminal event was sent.
        assert_eq!(engine.kv_used_tokens(), 0);
        assert_eq!(engine.kv_live_leases(), 0);
        let m = engine.shutdown();
        assert_eq!(m[0].cancelled, 1);
    }

    #[test]
    fn per_request_sampling_is_engine_visible() {
        let engine = micro_engine(1);
        let prompt = vec![5u32, 9, 13];
        let mut sampled = GenRequest::new(0, prompt.clone(), 6);
        sampled.sampling = SamplingParams {
            temperature: 2.0,
            top_k: 8,
            top_p: 0.9,
            seed: 77,
            stop_tokens: vec![],
        };
        let greedy = GenRequest::new(1, prompt, 6);
        let hs = engine.submit(sampled.clone()).unwrap();
        let hg = engine.submit(greedy).unwrap();
        let rs1 = hs.wait();
        let rg = hg.wait();
        // Reproducible under the same seed on a fresh submit.
        let rs2 = engine.submit(sampled).unwrap().wait();
        assert_eq!(rs1.tokens, rs2.tokens, "seeded resubmit must reproduce");
        assert!(!rg.tokens.is_empty());
        drop(engine);
    }

    #[test]
    fn poll_streams_delivers_every_stream_once() {
        let engine = micro_engine(2);
        let handles: Vec<RequestHandle> = (0..5)
            .map(|i| engine.submit(GenRequest::new(i, vec![2 + i as u32, 3], 4)).unwrap())
            .collect();
        let mut tokens = vec![0usize; handles.len()];
        let mut terminals = vec![0usize; handles.len()];
        poll_streams(&handles, |i, ev| match ev {
            Some(TokenEvent::Token { .. }) => tokens[i] += 1,
            Some(TokenEvent::Finished { n_tokens, .. }) => {
                terminals[i] += 1;
                assert_eq!(n_tokens, tokens[i], "stream {i} token count drift");
            }
            Some(TokenEvent::PrefillDone { .. }) => {}
            None => panic!("stream {i} closed without terminal event"),
        });
        assert!(terminals.iter().all(|&t| t == 1), "one terminal per stream: {terminals:?}");
        assert!(tokens.iter().all(|&t| (1..=4).contains(&t)));
        engine.shutdown();
    }

    #[test]
    fn speculative_engine_streams_match_greedy_bitwise() {
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        let prompt = vec![3u32, 5, 7];
        let want = model.generate_greedy(&prompt, 8);
        let draft = DraftModel::self_draft(Arc::clone(&model), 1).unwrap();
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 1,
                kv_tokens: 4096,
                batch: BatchConfig { spec_k: 3, stop_on_eos: false, ..Default::default() },
                draft: Some(draft),
                ..Default::default()
            },
        );
        let r = engine.submit(GenRequest::new(0, prompt, 8)).unwrap().wait();
        assert_eq!(r.tokens, want, "speculative greedy stream must be bitwise-identical");
        assert_eq!(engine.kv_used_tokens(), 0);
        let m = engine.shutdown();
        assert_eq!(m[0].spec_drafted, m[0].spec_accepted + m[0].spec_rejected);
        assert!(m[0].spec_drafted > 0, "draft must have proposed");
    }

    #[test]
    fn drop_joins_workers() {
        let engine = micro_engine(2);
        let h = engine.submit(GenRequest::new(0, vec![4, 5], 3)).unwrap();
        let r = h.wait();
        assert!(r.finish.is_completed());
        drop(engine); // must not leak detached threads or hang
    }

    /// A model with a stretched context window so a long-running stream
    /// keeps decoding until something (cancel, deadline, abort) stops it.
    fn roomy_engine(batch: BatchConfig, queue_cap: usize) -> Engine {
        let mut base = synthetic_model("micro", 71).unwrap();
        base.cfg.max_seq = 8192;
        base.refresh_derived();
        Engine::new(
            Arc::new(base),
            EngineConfig {
                workers: 1,
                kv_tokens: 1 << 14,
                batch,
                queue_cap,
                ..Default::default()
            },
        )
    }

    #[test]
    fn deadline_expires_mid_decode_and_frees_lease() {
        let engine =
            roomy_engine(BatchConfig { stop_on_eos: false, ..Default::default() }, 0);
        let req = GenRequest::new(0, vec![2, 3, 4], 5000)
            .with_deadline(Duration::from_millis(10));
        let r = engine.submit(req).unwrap().wait();
        assert_eq!(r.finish, FinishReason::DeadlineExceeded);
        assert!(r.tokens.len() < 5000, "expired stream must not run to max_new");
        // The lease came back the same iteration the deadline was swept.
        assert_eq!(engine.kv_used_tokens(), 0);
        assert_eq!(engine.kv_live_leases(), 0);
        let m = engine.shutdown();
        assert_eq!(m[0].deadline_expired, 1);
    }

    #[test]
    fn ttft_deadline_only_applies_before_first_token() {
        let engine = micro_engine(1);
        // Already blown at admission: expires with zero tokens.
        let doomed = engine
            .submit(GenRequest::new(0, vec![2, 3], 8).with_ttft_deadline(Duration::ZERO))
            .unwrap();
        // Generous TTFT budget: moot once the first token is out, so the
        // stream must run to its natural end.
        let served = engine
            .submit(
                GenRequest::new(1, vec![4, 5], 4)
                    .with_ttft_deadline(Duration::from_secs(30)),
            )
            .unwrap();
        let rd = doomed.wait();
        assert_eq!(rd.finish, FinishReason::DeadlineExceeded);
        assert!(rd.tokens.is_empty(), "expired before prefill: no tokens");
        let rs = served.wait();
        assert!(rs.finish.is_completed(), "unmet TTFT budget must not expire: {:?}", rs.finish);
        assert!(!rs.tokens.is_empty());
        let m = engine.shutdown();
        assert_eq!(m[0].deadline_expired, 1);
    }

    #[test]
    fn queue_cap_sheds_and_submit_wait_times_out() {
        let engine = roomy_engine(
            BatchConfig { max_batch: 1, stop_on_eos: false, ..Default::default() },
            1,
        );
        // Occupies the single batch slot indefinitely (until cancelled).
        let blocker = engine.submit(GenRequest::new(0, vec![2, 3], 5000)).unwrap();
        // Wait until it is admitted (its queue slot is released on
        // admission), so the next submit deterministically fills the queue.
        loop {
            match blocker.recv().expect("blocker stream open") {
                TokenEvent::Token { .. } => break,
                TokenEvent::Finished { .. } => panic!("blocker finished early"),
                TokenEvent::PrefillDone { .. } => {}
            }
        }
        let queued = engine.submit(GenRequest::new(1, vec![4, 5], 4)).unwrap();
        let shed = match engine.submit(GenRequest::new(2, vec![6, 7], 4)) {
            Err(e) => e,
            Ok(_) => panic!("third submit must shed at queue_cap 1"),
        };
        assert!(shed.is_queue_full());
        assert_eq!(shed.into_request().id, 2, "the request comes back in the error");
        // submit_wait keeps retrying until its timeout, then returns the
        // request too.
        let t0 = Instant::now();
        match engine.submit_wait(GenRequest::new(3, vec![8, 9], 4), Duration::from_millis(30)) {
            Err(SubmitError::QueueFull(r)) => assert_eq!(r.id, 3),
            other => panic!("expected QueueFull after timeout, got {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(30));
        blocker.cancel();
        let q = queued.wait();
        assert!(q.finish.is_completed(), "queued request runs once the slot frees");
        let m = engine.shutdown();
        assert!(m[0].shed_queue_full >= 2, "both sheds counted: {}", m[0].shed_queue_full);
    }

    #[test]
    fn worker_panic_is_isolated_and_survivor_serves() {
        faults::silence_injected_panics();
        let model = Arc::new(synthetic_model("micro", 71).unwrap());
        // Worker 0 dies on its second pass; worker 1 is healthy.
        let plan = FaultPlan { per_worker: vec![vec![Fault::Panic { at: 2 }], Vec::new()] };
        let engine = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                workers: 2,
                kv_tokens: 4096,
                faults: Some(plan),
                ..Default::default()
            },
        );
        let handles: Vec<RequestHandle> = (0..8)
            .map(|i| engine.submit(GenRequest::new(i, vec![2 + i as u32, 3], 4)).unwrap())
            .collect();
        // Every stream must reach exactly one terminal — completed, failed
        // over, or (worst case) closed — and none may hang.
        let mut terminals = vec![0usize; handles.len()];
        poll_streams(&handles, |i, ev| match ev {
            Some(TokenEvent::Finished { .. }) | None => terminals[i] += 1,
            _ => {}
        });
        assert!(terminals.iter().all(|&t| t == 1), "one terminal per stream: {terminals:?}");
        // The dead worker must be observed as such, and the survivor must
        // still take new work.
        let t0 = Instant::now();
        while engine.alive_workers() != 1 {
            assert!(t0.elapsed() < Duration::from_secs(10), "worker death never observed");
            thread::sleep(Duration::from_millis(1));
        }
        let r = engine.submit(GenRequest::new(99, vec![5, 6], 4)).unwrap().wait();
        assert!(r.finish.is_completed(), "survivor must serve: {:?}", r.finish);
        assert_eq!(engine.kv_used_tokens(), 0, "meters drain despite the panic");
        assert_eq!(engine.kv_live_leases(), 0);
        let per_worker = engine.shutdown();
        let terminal_count: usize = per_worker
            .iter()
            .map(|m| {
                m.finished_eos
                    + m.finished_length
                    + m.cancelled
                    + m.truncated_kv
                    + m.rejected_impossible
                    + m.deadline_expired
                    + m.worker_failed
            })
            .sum();
        assert_eq!(terminal_count, 9, "all 9 submissions accounted for");
    }

    #[test]
    fn abort_shutdown_cancels_in_flight_streams() {
        let engine =
            roomy_engine(BatchConfig { stop_on_eos: false, ..Default::default() }, 0);
        let h = engine.submit(GenRequest::new(0, vec![2, 3, 4], 5000)).unwrap();
        loop {
            match h.recv().expect("stream open") {
                TokenEvent::Token { .. } => break,
                TokenEvent::Finished { .. } => panic!("finished before abort"),
                TokenEvent::PrefillDone { .. } => {}
            }
        }
        let pools = engine.kv_pool_handles();
        engine.shutdown_mode(Shutdown::Abort, None);
        let r = h.wait();
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.tokens.len() < 5000);
        assert!(pools.iter().all(|p| p.used_tokens() == 0 && p.live_leases() == 0));
    }

    #[test]
    fn drain_timeout_escalates_to_abort() {
        let engine =
            roomy_engine(BatchConfig { stop_on_eos: false, ..Default::default() }, 0);
        let h = engine.submit(GenRequest::new(0, vec![2, 3, 4], 5000)).unwrap();
        let _ = h.recv();
        let t0 = Instant::now();
        engine.shutdown_mode(Shutdown::Drain, Some(Duration::from_millis(50)));
        // Bounded: far below the time 5000 decode steps would take.
        assert!(t0.elapsed() < Duration::from_secs(10), "drain timeout must bound shutdown");
        let r = h.wait();
        assert_eq!(r.finish, FinishReason::Cancelled, "stragglers are aborted");
    }

    #[test]
    fn recv_timeout_distinguishes_empty_from_closed() {
        let engine = roomy_engine(
            BatchConfig { max_batch: 1, stop_on_eos: false, ..Default::default() },
            0,
        );
        let blocker = engine.submit(GenRequest::new(0, vec![2, 3], 5000)).unwrap();
        let starved = engine.submit(GenRequest::new(1, vec![4, 5], 2)).unwrap();
        // The starved stream is queued behind the blocker: open but silent.
        // The old Option return reported this the same as a dead worker.
        assert!(
            matches!(starved.recv_timeout(Duration::from_millis(5)), TryEvent::Empty),
            "open-but-slow stream must read as Empty"
        );
        blocker.cancel();
        let reason = loop {
            match starved.recv_timeout(Duration::from_secs(10)) {
                TryEvent::Event(TokenEvent::Finished { reason, .. }) => break reason,
                TryEvent::Event(_) => {}
                TryEvent::Empty => {}
                TryEvent::Closed => panic!("stream closed without terminal event"),
            }
        };
        assert!(reason.is_completed());
        // Terminal delivered and the worker retired the stream: the sender
        // is dropped, so the channel reads Closed — not Empty — from a
        // generous timeout.
        assert!(
            matches!(starved.recv_timeout(Duration::from_secs(10)), TryEvent::Closed),
            "finished stream must read as Closed"
        );
        engine.shutdown();
    }

    #[test]
    fn drop_mid_stream_aborts_joins_and_frees_kv() {
        let engine =
            roomy_engine(BatchConfig { stop_on_eos: false, ..Default::default() }, 0);
        let handles: Vec<RequestHandle> = (0..4)
            .map(|i| engine.submit(GenRequest::new(i, vec![2 + i as u32, 3], 5000)).unwrap())
            .collect();
        loop {
            match handles[0].recv().expect("stream open") {
                TokenEvent::Token { .. } => break,
                TokenEvent::Finished { .. } => panic!("finished before drop"),
                TokenEvent::PrefillDone { .. } => {}
            }
        }
        let pools = engine.kv_pool_handles();
        // Drop aborts: workers joined before this returns, so the meters
        // below are final, not racing a live batcher.
        drop(engine);
        for p in &pools {
            assert_eq!(p.used_tokens(), 0, "every lease returned on drop");
            assert_eq!(p.live_leases(), 0);
        }
        for h in handles {
            let r = h.wait();
            assert_eq!(r.finish, FinishReason::Cancelled, "drop aborts in-flight streams");
        }
    }
}
